"""CI docs-consistency gate: docstring coverage + doc reference checks.

Two grep-grade checks, no imports of the code under test:

1. **Docstrings** — every Python module under ``src/repro/serve/`` and
   ``src/repro/kernels/`` must open with a module docstring (packages'
   ``__init__.py`` re-export stubs are exempt).  The kernel and serving
   subsystems are the documented surface of the repo; an undocumented
   module there is a docs regression.
2. **References** — every backticked code reference in ``README.md`` and
   ``docs/*.md`` that names a file (``serve/cache.py``,
   ``benchmarks/check_docs.py``) or a dotted module path
   (``repro.serve.engine.Engine``) must resolve to a real file in the
   repo, so renames/moves can never silently strand the docs.  A
   path-looking token that matches nothing fails the build with the doc
   and token named.

Usage:  python -m benchmarks.check_docs
"""

from __future__ import annotations

import ast
import re
import sys

from benchmarks.common import REPO_ROOT

DOCSTRING_ROOTS = ("src/repro/serve", "src/repro/kernels")
DOC_FILES = ("README.md", "docs")

# `...` tokens that look like file or module references.  Deliberately
# conservative: flags only things with a path separator + known suffix,
# or a repro./benchmarks./tests. dotted prefix — shell flags, shapes,
# and identifiers never match.
_PATH_RE = re.compile(r"^[\w./-]+\.(?:py|md|json|yml)$")
_DOTTED_RE = re.compile(r"^(?:repro|benchmarks|tests|examples)(?:\.\w+)+$")


def check_docstrings() -> list:
    failures = []
    for root in DOCSTRING_ROOTS:
        for path in sorted((REPO_ROOT / root).rglob("*.py")):
            if path.name == "__init__.py":
                continue
            tree = ast.parse(path.read_text())
            doc = ast.get_docstring(tree)
            if not doc or not doc.strip():
                failures.append(
                    f"{path.relative_to(REPO_ROOT)}: missing module "
                    "docstring")
    return failures


def _repo_files() -> list:
    out = []
    for p in REPO_ROOT.rglob("*"):
        if p.is_file() and ".git" not in p.parts:
            out.append(str(p.relative_to(REPO_ROOT)))
    return out


def _resolves(token: str, files: list) -> bool:
    """Does ``token`` name a file in the repo?  Tries the token as a
    repo-relative path, under src/, and as a suffix of any file (docs
    often write ``serve/cache.py`` for ``src/repro/serve/cache.py``)."""
    for cand in (token, f"src/{token}", f"src/repro/{token}"):
        if cand in files:
            return True
    return any(f.endswith("/" + token) for f in files)


def _module_resolves(token: str, files: list) -> bool:
    """Dotted reference: strip trailing attribute segments until some
    prefix resolves to a module file or package directory."""
    parts = token.split(".")
    while parts:
        base = "/".join(parts)
        for cand in (f"{base}.py", f"{base}/__init__.py",
                     f"src/{base}.py", f"src/{base}/__init__.py"):
            if cand in files:
                return True
        parts = parts[:-1]
    return False


def check_references() -> list:
    files = _repo_files()
    failures = []
    doc_paths = [REPO_ROOT / "README.md"]
    doc_paths += sorted((REPO_ROOT / "docs").glob("*.md"))
    for doc in doc_paths:
        if not doc.exists():
            failures.append(f"{doc.name}: referenced doc page is missing")
            continue
        text = doc.read_text()
        for token in re.findall(r"`([^`\n]+)`", text):
            token = token.strip().rstrip(",.;:")
            # drop call parens / CLI fragments / ::symbol suffixes
            token = token.split("(")[0].split("::")[0].split(" ")[0]
            if _PATH_RE.match(token):
                if not _resolves(token, files):
                    failures.append(
                        f"{doc.relative_to(REPO_ROOT)}: `{token}` does "
                        "not resolve to a repo file")
            elif _DOTTED_RE.match(token):
                if not _module_resolves(token, files):
                    failures.append(
                        f"{doc.relative_to(REPO_ROOT)}: `{token}` does "
                        "not resolve to a module under src/")
    return failures


def main() -> None:
    failures = check_docstrings() + check_references()
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        sys.exit(1)
    print("docs OK: module docstrings present, all doc code references "
          "resolve")


if __name__ == "__main__":
    main()
