"""Paper Fig. 9/10: MatMul scaling and the data-preparation overhead.

CPU wall-clock reproduction of §5.1: for square MatMuls of growing size,
compare the bare library dot against the framework operator that must first
run data preparation (upcast + scale, materialized separately = MatMul1).
The prep overhead fraction shrinks as O(n)/O(n^3), matching the paper's
Amdahl analysis; the derived column reports it.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.fused_matmul.ref import matmul1


def main() -> None:
    key = jax.random.PRNGKey(0)
    for n in (256, 512, 1024, 2048):
        x8 = jax.random.randint(key, (n, n), -127, 127, jnp.int8)
        w = jax.random.normal(jax.random.fold_in(key, 1), (n, n),
                              jnp.float32)
        sc = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (n, 1)))

        bare = jax.jit(lambda a, b: a @ b)
        xf = x8.astype(jnp.float32) * sc
        t_bare = time_fn(bare, xf, w)

        op = jax.jit(lambda a, b, s: matmul1(a, b, s, out_dtype=jnp.float32))
        t_op = time_fn(op, x8, w, sc)

        overhead = max(t_op - t_bare, 0.0)
        emit(f"fig09.matmul_{n}", t_op * 1e6,
             f"kernel_us={t_bare * 1e6:.1f},prep_overhead_pct="
             f"{100 * overhead / t_op:.1f}")


if __name__ == "__main__":
    main()
