"""Paper Fig. 14: thread-pool overhead under 10k micro tasks — plus the
same overhead story at serving-engine scale.

Framework-dispatch analogue: the cost of crossing the python->jit boundary
for a trivial op, measured three ways (mirroring std::thread vs Eigen vs
Folly): (a) 1000 separate jit dispatches, (b) one jit containing the same
1000 ops (fully fused schedule), (c) 1000 eager ops.  The derived column
is per-task overhead — the price the 'scheduler' charges per operator.

The second half measures the pattern the paper says to eliminate at
request level: ``ReferenceEngine`` (per-token host syncs, per-prompt-length
retraces, Python cache splice) against the fused ``Engine``
(one dispatch per sync_interval decode steps, on-device sampling, bucketed
prefill, jitted splice).  Steps/sec, host-sync counts, and compile counts
land in the repo-root ``BENCH_serve.json`` trajectory.

``paged_kernel_comparison`` additionally benchmarks gather-then-attend
decode against the pool-direct paged-attention path
(``kernels/paged_attention``) on an oversubscribed pool, asserts token
parity against both the gather engine and the dense reference, and
checks — via the optimized decode-chunk HLO — that the gathered ring
buffer is gone from the paged executable.

``speculative_comparison`` runs the speculative engine (n-gram drafter,
K=4 — ``serve/spec``) on a repetitive-text workload: greedy token parity
vs the non-speculative engine and the dense reference, acceptance rate,
committed tokens per verify step, and steady-state decode tokens/sec vs
the plain engine (gated >= 1.2x by check_serve_regression).

``fault_tolerance_comparison`` oversubscribes the page pool (full slot
occupancy impossible) with per-request deadlines: the engine must
preempt/resume instead of throwing, and the workload gates goodput
(deadline attainment), >= 1 preemption, token parity of the
preempted-then-resumed run vs an uncontended engine, zero leaked pages,
and the same sync-free single-executable decode properties.

``chunked_prefill_comparison`` measures the tail-latency story of the
fused mixed prefill+decode chunk: long prompts arriving into a busy
decode batch.  The legacy two-executable engine stalls every decoding
neighbour for a full prefill dispatch at each arrival boundary; the
fused engine streams ``prefill_budget`` prompt tokens per micro-step
through the one chunk executable, keeping per-chunk decode-token
latency flat.  Gates (check_serve_regression): token parity between
the two engines, p99 per-chunk decode-token latency >= 1.3x better
under arrivals, zero prefill executables / one decode + one admission
executable for the fused engine, and the fused chunk's HLO free of the
gathered-ring shapes (prompt context reads are pool-direct).  TTFT
percentiles for both engines are reported ungated — streaming a prompt
through small chunks trades first-token latency for neighbour decode
latency, and the record keeps both sides of that trade visible.

``quantized_pool_comparison`` measures the int8 KV page pool against
fp32 pools on a chain-overfit model (confident greedy decisions, so
token agreement measures the pool, not init noise): positional greedy
parity (gated >= 0.99), teacher-forced max logit error, >= 1.8x
concurrent slots at equal-or-fewer page-pool bytes (scale rows billed),
preemption-resume and CoW prefix-sharing parity on 8-bit pools, zero
leaked pages, and the sync-free single-executable decode invariants.
Every gated workload additionally records ``*_pool_bytes_per_live_token``
/ ``*_kv_dtype`` / ``*_peak_live_slots`` pool-economics telemetry.

The five trajectory workloads above pin ``chunked_prefill=False``: their
committed BENCH baselines measure the legacy two-executable admission
path, and the fused path's economics (S-row decode micro-steps) are
deliberately different — it gets its own workload + gates instead of
silently shifting the old trajectories.
"""

import time

import jax
import jax.numpy as jnp

from benchmarks.common import assert_clean_teardown, emit, write_bench_json

N_TASKS = 1000


def _serve_workload(eng, n_req: int, max_new: int, track=None):
    from repro.serve.engine import Request

    for i in range(n_req):
        plen = 2 + (5 * i) % 11          # ragged 2..12: multiple buckets
        eng.submit(Request(rid=i, prompt=[(3 * i + j) % 250 + 1
                                          for j in range(plen)],
                           max_new_tokens=max_new))
    t0 = time.perf_counter()
    done = eng.run(max_steps=100_000)
    dt = time.perf_counter() - t0
    assert len(done) == n_req
    toks = sum(len(r.out_tokens) for r in done)
    if track is not None:
        track.extend(done)
    eng.finished = []
    return dt, toks


def shared_prefix_comparison(n_req: int = 12, max_new: int = 16) -> dict:
    """Shared-prefix workload: ``n_req`` requests with one common 16-token
    prompt head.  Measures what the radix/CoW admission path buys over
    exclusive page ownership (pages reserved, prefill tokens skipped) and
    proves outputs stay token-identical — plus the windowed-layer
    bytes/live-token story after per-layer pool budgets (gemma2 spec)."""
    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.cache import CacheSpec
    from repro.serve.engine import Engine, Request
    from repro.serve.reference import ReferenceEngine

    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    prefix = [(3 * j) % 200 + 1 for j in range(16)]

    seen = {}

    def load(eng):
        for i in range(n_req):
            tail = [(7 * i + j) % 150 + 1 for j in range(1 + i % 4)]
            eng.submit(Request(rid=i, prompt=prefix + tail,
                               max_new_tokens=max_new))
        t0 = time.perf_counter()
        done = eng.run(max_steps=100_000)
        dt = time.perf_counter() - t0
        assert len(done) == n_req
        toks = sum(len(r.out_tokens) for r in done)
        out = {r.rid: r.out_tokens for r in done}
        seen.setdefault(id(eng), []).extend(done)
        eng.finished = []
        return out, toks / dt

    # legacy path pinned: this trajectory baselines the two-executable
    # admission (see module docstring); fused gets its own workload
    excl = Engine(cfg, params, slots=4, max_len=64, sync_interval=16,
                  prefix_sharing=False, chunked_prefill=False)
    excl.warmup()
    out_excl, _ = load(excl)                     # warm compiles
    out_excl, excl_tps = load(excl)

    eng = Engine(cfg, params, slots=4, max_len=64, sync_interval=16,
                 chunked_prefill=False)
    eng.warmup()
    out_share, _ = load(eng)
    out_share, share_tps = load(eng)

    # correctness gate input: shared-prefix admission must be invisible
    # in the tokens (benchmarks/check_serve_regression.py fails CI if not)
    # — both vs exclusive ownership and vs the dense reference oracle
    ref = ReferenceEngine(cfg, params, slots=4, max_len=64)
    out_ref, _ = load(ref)
    outputs_match = out_share == out_excl == out_ref
    ps = eng.prefix_stats()
    pages_saved = (excl.scheduler.peak_pages_in_use
                   - eng.scheduler.peak_pages_in_use)

    # per-layer pool budgets: a windowed arch's pools are window-sized
    # now, so paged bytes match the dense layout instead of paying the
    # full num_pages budget per windowed layer (the old byte caveat)
    wspec = CacheSpec.from_config(reduced(get_config("gemma2-2b")),
                                  slots=4, max_len=64, page_size=8)
    full = {g.key: g.num_pages for g in wspec.groups}
    wstats = wspec.memory_stats(full, 4 * 64)    # pools fully occupied

    rec = {
        "prefix_requests": n_req,
        "prefix_hit_rate": ps["prefix_hit_rate"],
        "prefill_tokens_skipped": ps["prefill_tokens_skipped"],
        "prefix_shared_page_attaches": ps["shared_page_attaches"],
        "prefix_cow_copies": ps["cow_copies"],
        "prefix_outputs_match_exclusive": outputs_match,
        "prefix_tokens_per_s": share_tps,
        "exclusive_tokens_per_s": excl_tps,
        "prefix_peak_pages": eng.scheduler.peak_pages_in_use,
        "exclusive_peak_pages": excl.scheduler.peak_pages_in_use,
        "prefix_pages_saved": pages_saved,
        "prefix_decode_compiles": eng.decode_compiles,
        "prefix_decode_sync_free": True,   # chunk untouched; set below
        "windowed_dense_vs_paged_ratio":
            wstats["dense_vs_paged_capacity_ratio"],
        "windowed_hbm_bytes_per_live_token":
            wstats["hbm_bytes_per_live_token"],
    }
    # sync-free under the transfer guard, same evidence as the main run
    sync_free = True
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            toks = eng.step_chunk()
    except Exception as e:  # noqa: BLE001 - classify, don't swallow
        if "transfer" not in str(e).lower():
            raise
        sync_free = False
    else:
        eng._drain(toks)
    rec["prefix_decode_sync_free"] = sync_free
    rec.update(_pool_telemetry(eng, "prefix_"))
    assert_clean_teardown(excl, seen[id(excl)], label="prefix_exclusive")
    assert_clean_teardown(eng, seen[id(eng)], label="prefix_shared")

    emit("fig14.prefix_hit_rate", rec["prefix_hit_rate"],
         f"tokens_skipped={rec['prefill_tokens_skipped']},"
         f"cow={rec['prefix_cow_copies']}")
    emit("fig14.prefix_pages_saved", pages_saved,
         f"peak={rec['prefix_peak_pages']}/"
         f"{rec['exclusive_peak_pages']},match={outputs_match}")
    emit("fig14.windowed_paged_ratio",
         rec["windowed_dense_vs_paged_ratio"],
         f"bytes_per_live_tok={rec['windowed_hbm_bytes_per_live_token']:.0f}")
    return rec


def _pool_telemetry(eng, prefix: str) -> dict:
    """Pool-economics telemetry every gated workload records: bytes of
    leased page pool (at stored precision, scale rows included) per live
    token sampled mid-flight via a probe request, the pool precision,
    and the engine-lifetime concurrent-slot high-water."""
    from repro.serve.engine import Request

    eng.submit(Request(rid=990_001, prompt=[1, 2, 3], max_new_tokens=4))
    eng._admit()
    ms = eng.memory_stats()
    eng.run(max_steps=100_000)
    eng.finished = []
    return {
        f"{prefix}pool_bytes_per_live_token":
            ms["pool_bytes_per_live_token"],
        f"{prefix}kv_dtype": ms["kv_dtype"],
        f"{prefix}peak_live_slots": eng.memory_stats()["peak_live_slots"],
    }


def _decode_executable(eng):
    """(optimized HLO text, temp bytes | None) of the fused decode chunk."""
    ex = eng.executor
    with ex._ctx():
        lowered = ex._chunk_fn.lower(eng.params, eng.draft_params,
                                     eng.cache, eng.state)
    comp = lowered.compile()
    txt = comp.as_text()
    try:
        mem = int(comp.memory_analysis().temp_size_in_bytes)
    except Exception:   # noqa: BLE001 - backend may not expose analysis
        mem = None
    return txt, mem


def _ring_gather_shapes(eng) -> list:
    """Dim signatures of the gather-then-attend intermediates: the
    per-group gathered page block ``[slots, blocks, P, Hkv, dh]`` and its
    ring reshape ``[slots, Hkv, ring, dh]``.  The paged-kernel decode
    executable must contain neither."""
    spec, cfg = eng.spec, eng.cfg
    shapes = []
    for g in spec.groups:
        ring = g.ring_blocks * spec.page_size
        kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        shapes.append(
            f"[{spec.slots},{g.ring_blocks},{spec.page_size},{kv},{dh}]")
        shapes.append(f"[{spec.slots},{kv},{ring},{dh}]")
    return shapes


def paged_kernel_comparison(n_req: int = 12, max_new: int = 16) -> dict:
    """Gather-vs-paged-kernel decode attention at engine scale.

    The workload runs with an **oversubscribed pool** — table width 32
    blocks (max_len=256) but only 28 physical pages — the configuration
    paging exists for: the gather path pays the static worst-case table
    width every step (it gathers ``[slots, 32, P, Hkv, dh]`` per layer
    whatever the actual occupancy), while the pool-direct path
    (``kernels/paged_attention``: Pallas page streaming on TPU,
    pool-wide masked attention elsewhere) pays physical pool capacity.
    Records tokens/sec both ways, token parity vs the gather path AND
    the dense ReferenceEngine, decode-executable peak temp bytes, and a
    textual HLO check that the gathered ring buffer is gone from the
    paged decode executable."""
    import jax as _jax

    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.engine import Engine, Request
    from repro.serve.reference import ReferenceEngine

    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), _jax.random.PRNGKey(0),
                           jnp.float32)
    kw = dict(slots=4, max_len=256, page_size=8, num_pages=28,
              sync_interval=16, prefix_sharing=False,
              chunked_prefill=False)    # legacy-pinned trajectory

    seen = {}

    def load(eng):
        for i in range(n_req):
            plen = 2 + (5 * i) % 11
            eng.submit(Request(rid=i, prompt=[(3 * i + j) % 250 + 1
                                              for j in range(plen)],
                               max_new_tokens=max_new))
        t0 = time.perf_counter()
        done = eng.run(max_steps=100_000)
        dt = time.perf_counter() - t0
        assert len(done) == n_req
        toks = sum(len(r.out_tokens) for r in done)
        out = {r.rid: r.out_tokens for r in done}
        seen.setdefault(id(eng), []).extend(done)
        eng.finished = []
        return out, toks / dt

    def best_of(eng, trials=3):
        out, tps = load(eng)
        for _ in range(trials - 1):
            out, t = load(eng)
            tps = max(tps, t)
        return out, tps

    gather = Engine(cfg, params, paged_kernel=False, **kw)
    gather.warmup()
    load(gather)                                  # host-path warm
    out_gather, gather_tps = best_of(gather)

    paged = Engine(cfg, params, paged_kernel=True, **kw)
    paged.warmup()
    load(paged)
    out_paged, paged_tps = best_of(paged)
    paged_compiles = paged.decode_compiles

    ref = ReferenceEngine(cfg, params, slots=4, max_len=256)
    out_ref, _ = load(ref)
    outputs_match = out_paged == out_gather == out_ref

    # the gather buffer must be gone from the paged decode executable —
    # and the detection must actually fire on the gather executable,
    # otherwise the check is vacuous
    paged_hlo, paged_bytes = _decode_executable(paged)
    gather_hlo, gather_bytes = _decode_executable(gather)
    shapes = _ring_gather_shapes(paged)
    gather_free = not any(s in paged_hlo for s in shapes)
    detection_ok = any(s in gather_hlo for s in shapes)

    sync_free = True
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            toks = paged.step_chunk()
    except Exception as e:  # noqa: BLE001 - classify, don't swallow
        if "transfer" not in str(e).lower():
            raise
        sync_free = False
    else:
        paged._drain(toks)

    rec = {
        "paged_kernel_backend": (
            "pallas-tpu" if jax.default_backend() == "tpu"
            else "xla-poolwise"),
        "paged_kernel_tokens_per_s": paged_tps,
        "paged_gather_tokens_per_s": gather_tps,
        "paged_kernel_speedup": paged_tps / gather_tps,
        "paged_kernel_outputs_match": outputs_match,
        "paged_kernel_gather_free": gather_free,
        "gather_path_materializes_ring": detection_ok,
        "paged_kernel_peak_temp_bytes": paged_bytes,
        "paged_gather_peak_temp_bytes": gather_bytes,
        "paged_kernel_decode_compiles": paged_compiles,
        "paged_kernel_decode_sync_free": sync_free,
        "paged_kernel_num_pages": kw["num_pages"],
        "paged_kernel_table_blocks": paged.spec.max_blocks,
    }
    rec.update(_pool_telemetry(paged, "paged_kernel_"))
    assert_clean_teardown(gather, seen[id(gather)], label="paged_gather")
    assert_clean_teardown(paged, seen[id(paged)], label="paged_kernel")
    emit("fig14.paged_kernel_speedup", rec["paged_kernel_speedup"],
         f"paged={paged_tps:.0f}tok/s,gather={gather_tps:.0f}tok/s,"
         f"backend={rec['paged_kernel_backend']}")
    emit("fig14.paged_kernel_gather_free", float(gather_free),
         f"match={outputs_match},detect={detection_ok},"
         f"temp_bytes={paged_bytes}/{gather_bytes}")
    return rec


def speculative_comparison(max_new: int = 48) -> dict:
    """Speculative vs plain decoding on a repetitive-text workload.

    The workload is eight constant-token prompts (the most repetitive
    text there is): the reduced model's greedy continuations settle into
    short cycles, which is exactly the regime the prompt-lookup n-gram
    drafter exists for.  Measures and gates (check_serve_regression):

    * greedy token parity — speculative output identical to the
      non-speculative engine AND the dense ``ReferenceEngine``;
    * acceptance rate > 0.5 and committed tokens per verify step;
    * steady-state decode throughput at full slot occupancy: tokens
      delivered per second of fused-chunk wall time, speculative vs
      plain.  This is the decode-side speedup the subsystem buys
      (>= 1.2x gated); end-to-end tokens/sec (including prefill and
      admission overhead both engines share) is recorded alongside;
    * sync-free chunk (transfer guard) and executable counts: ONE decode
      chunk, ONE batched admission splice.
    """
    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.engine import Engine, Request
    from repro.serve.reference import ReferenceEngine
    from repro.serve.spec import SpecConfig

    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    # repetitive-text probes: greedy continuations of these constant
    # prompts are strongly cyclic for the seeded reduced model
    toks = [50, 80, 116, 176, 98, 128, 224, 194]
    kw = dict(slots=4, max_len=256, page_size=8, sync_interval=8,
              prefix_sharing=False,
              chunked_prefill=False)    # legacy-pinned trajectory

    seen = {}

    def load(eng):
        for i, t in enumerate(toks):
            eng.submit(Request(rid=i, prompt=[t] * 20,
                               max_new_tokens=max_new))
        t0 = time.perf_counter()
        done = eng.run(max_steps=100_000)
        dt = time.perf_counter() - t0
        assert len(done) == len(toks)
        n = sum(len(r.out_tokens) for r in done)
        out = {r.rid: r.out_tokens for r in done}
        seen.setdefault(id(eng), []).extend(done)
        eng.finished = []
        return out, n / dt

    def decode_tps(eng, chunks: int = 4):
        """Steady-state decode throughput: all slots live, no admissions
        or drains inside the timed window — tokens committed per second
        of chunk wall time.  The budget exceeds the maximum the window
        can commit ((1+chunks) * sync_interval * (K+1) tokens), so no
        slot can finish mid-measurement."""
        for i, t in enumerate(toks[:kw["slots"]]):
            eng.submit(Request(rid=100 + i, prompt=[t] * 20,
                               max_new_tokens=kw["max_len"] - 24))
        eng._admit()
        jax.block_until_ready(eng.step_chunk())          # warm dispatch
        start = jax.device_get(eng.state["out_len"]).sum()
        t0 = time.perf_counter()
        for _ in range(chunks):
            toks_h = eng.step_chunk()
        jax.block_until_ready(toks_h)
        dt = time.perf_counter() - t0
        emitted = jax.device_get(eng.state["out_len"]).sum() - start
        assert bool(jax.device_get(eng.state["active"]).all()), \
            "decode-throughput window must keep every slot live"
        return float(emitted) / dt

    base = Engine(cfg, params, **kw)
    base.warmup()
    load(base)
    out_base, base_tps = load(base)

    spec = Engine(cfg, params, spec=SpecConfig(draft="ngram", k=4,
                                               ngram=3), **kw)
    spec.warmup()
    load(spec)
    out_spec, spec_tps = load(spec)
    stats = spec.spec_stats()

    ref = ReferenceEngine(cfg, params, slots=4, max_len=256)
    out_ref, _ = load(ref)
    outputs_match = out_spec == out_base == out_ref

    base_d = Engine(cfg, params, **kw)
    base_d.warmup()
    base_decode_tps = decode_tps(base_d)
    spec_d = Engine(cfg, params, spec=SpecConfig(draft="ngram", k=4,
                                                 ngram=3), **kw)
    spec_d.warmup()
    spec_decode_tps = decode_tps(spec_d)

    sync_free = True
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            t = spec_d.step_chunk()
        jax.block_until_ready(t)   # spec_d is discarded; no drain needed
    except Exception as e:  # noqa: BLE001 - classify, don't swallow
        if "transfer" not in str(e).lower():
            raise
        sync_free = False

    rec = {
        "spec_drafter": "ngram",
        "spec_k": 4,
        "spec_outputs_match": outputs_match,
        "spec_acceptance_rate": stats["acceptance_rate"],
        "spec_tokens_per_step": stats["tokens_per_step"],
        "spec_steps": stats["spec_steps"],
        "spec_tokens_per_s": spec_tps,
        "spec_baseline_tokens_per_s": base_tps,
        "spec_decode_tokens_per_s": spec_decode_tps,
        "spec_baseline_decode_tokens_per_s": base_decode_tps,
        "spec_decode_speedup": spec_decode_tps / base_decode_tps,
        "spec_decode_sync_free": sync_free,
        "spec_decode_compiles": spec.decode_compiles,
        "spec_admit_compiles": spec.admit_compiles,
    }
    rec.update(_pool_telemetry(spec, "spec_"))
    # base_d / spec_d deliberately hold live slots (steady-state decode
    # window) and are excluded from the drained-teardown contract
    assert_clean_teardown(base, seen[id(base)], label="spec_baseline")
    assert_clean_teardown(spec, seen[id(spec)], label="spec_engine")
    emit("fig14.spec_acceptance", rec["spec_acceptance_rate"],
         f"tokens_per_step={rec['spec_tokens_per_step']:.2f},"
         f"match={outputs_match}")
    emit("fig14.spec_decode_speedup", rec["spec_decode_speedup"],
         f"spec={spec_decode_tps:.0f}tok/s,base={base_decode_tps:.0f}tok/s,"
         f"e2e={spec_tps:.0f}/{base_tps:.0f}")
    return rec


def fault_tolerance_comparison(n_req: int = 8, max_new: int = 16) -> dict:
    """Oversubscribed pool + deadlines: survive instead of throwing.

    The pool is sized so full slot occupancy is impossible (4 slots that
    would reserve 16 worst-case pages against a 12-page budget), so the
    engine MUST preempt — fewest-tokens-decoded victims are evicted with
    their prompt pages preserved in the radix index, requeued, and
    resumed with generated-so-far tokens replayed as prompt tail.  One
    extra request is submitted with an already-expired deadline and must
    be reaped as TIMED_OUT, never occupying a slot.

    Reports (gated by check_serve_regression): goodput = deadline
    attainment over everything submitted (deterministically
    ``n_req / (n_req + 1)`` — the live requests carry generous
    deadlines, the doomed one can never make it), preemption / resume
    counts (>= 1 required), recovered-prefill fraction of resumed
    admissions, token parity of the preempted-then-resumed run against
    an uncontended engine at temperature 0, zero leaked pages at drain,
    and the usual structural properties: ONE decode executable,
    sync-free chunk.

    The recovered-prefill fraction is reported, not gated: under pure
    page pressure the preserved prefix pages are refcount-1 radix
    leaves, and the admission that triggered the eviction usually
    reclaims them immediately — recovery pays off when preemption is
    NOT page-bound (watchdog / chaos storms; see the --chaos launch
    path and tests/test_fault_tolerance.py, where the fraction is
    nonzero)."""
    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.engine import Engine, Request
    from repro.serve.scheduler import RequestStatus

    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    kw = dict(slots=4, max_len=64, page_size=8, sync_interval=8,
              chunked_prefill=False)    # legacy-pinned trajectory
    prompts = [[(3 * i + j) % 250 + 1 for j in range(2 + (5 * i) % 11)]
               for i in range(n_req)]

    seen = {}

    def load(eng, ttl=None, doomed=False):
        for i, p in enumerate(prompts):
            assert eng.submit(Request(rid=i, prompt=list(p),
                                      max_new_tokens=max_new,
                                      ttl=ttl)) is None
        if doomed:
            # deadline in the past (monotonic clock starts > 0): reaped
            # as TIMED_OUT at the first chunk boundary, no slot wasted
            assert eng.submit(Request(rid=n_req, prompt=[1, 2, 3],
                                      max_new_tokens=max_new,
                                      deadline=0.0)) is None
        done = eng.run(max_steps=100_000)
        assert len(done) == n_req + (1 if doomed else 0)
        out = {r.rid: list(r.out_tokens) for r in done
               if r.status == RequestStatus.FINISHED}
        statuses = {r.rid: r.status for r in done}
        preempted = sorted(r.rid for r in done if r.preemptions > 0)
        seen.setdefault(id(eng), []).extend(done)
        eng.finished = []
        return out, statuses, preempted

    # uncontended oracle: ample pages (the default slots*max_len/P
    # budget), no deadlines — every request runs solo-quality
    calm = Engine(cfg, params, **kw)
    calm.warmup()
    out_calm, _, calm_preempted = load(calm)
    assert not calm_preempted, "uncontended run must not preempt"

    # oversubscribed: 12 pages vs 16 worst-case for full occupancy
    eng = Engine(cfg, params, num_pages=12, **kw)
    eng.warmup()
    out_ft, statuses, preempted = load(eng, ttl=600.0, doomed=True)
    fs = eng.fault_stats()

    submitted = n_req + 1
    goodput = len(out_ft) / submitted
    outputs_match = out_ft == out_calm
    timed_out = sum(1 for s in statuses.values()
                    if s == RequestStatus.TIMED_OUT)
    leaked = eng.leaked_pages()

    sync_free = True
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            toks = eng.step_chunk()
    except Exception as e:  # noqa: BLE001 - classify, don't swallow
        if "transfer" not in str(e).lower():
            raise
        sync_free = False
    else:
        eng._drain(toks)

    rec = {
        "ft_requests": submitted,
        "ft_goodput": goodput,
        "ft_preemptions": fs["preemptions"],
        "ft_pressure_preemptions": fs["pressure_preemptions"],
        "ft_resumes": fs["resumes"],
        "ft_preempted_requests": len(preempted),
        "ft_outputs_match": outputs_match,
        "ft_recovered_prefill_fraction": fs["recovered_prefill_fraction"],
        "ft_resume_replayed_tokens": fs["resume_replayed_tokens"],
        "ft_timed_out": timed_out,
        "ft_leaked_pages": leaked,
        "ft_num_pages": 12,
        "ft_peak_pages": eng.scheduler.peak_pages_in_use,
        "ft_decode_compiles": eng.decode_compiles,
        "ft_decode_sync_free": sync_free,
    }
    rec.update(_pool_telemetry(eng, "ft_"))
    assert_clean_teardown(calm, seen[id(calm)], label="ft_calm")
    assert_clean_teardown(eng, seen[id(eng)], label="ft_oversubscribed")
    emit("fig14.ft_goodput", goodput,
         f"preemptions={fs['preemptions']},"
         f"resumes={fs['resumes']},"
         f"preempted_reqs={len(preempted)},match={outputs_match}")
    emit("fig14.ft_recovered_prefill", fs["recovered_prefill_fraction"],
         f"timed_out={timed_out},leaked={leaked},"
         f"peak_pages={rec['ft_peak_pages']}/12")
    return rec


def chunked_prefill_comparison(n_arrivals: int = 3,
                               prompt_len: int = 120,
                               budget: int = 4) -> dict:
    """Long-prompt arrivals into a busy decode batch: fused vs legacy.

    Three background requests decode continuously while ``n_arrivals``
    long prompts arrive at fixed chunk boundaries.  Every ``step()`` is
    timed; per-chunk decode-token latency is chunk wall time /
    ``sync_interval``.  The legacy engine's arrival boundaries pay a
    synchronous full-prompt prefill dispatch (here bucket-padded to
    128 tokens) that stalls all three decoding neighbours — its p99
    latency is that spike.  The fused engine admits with pure
    bookkeeping and streams ``budget`` prompt tokens per micro-step
    through the one chunk executable — flat latency, no spike.  Gated
    (check_serve_regression): token parity, p99 ratio >= 1.3x, fused
    compile telemetry (0 prefill / 1 decode / 1 admit executables),
    fused chunk sync-free, and the fused ``paged_kernel=True``
    executable's HLO free of gathered-ring shapes.  TTFT is reported
    ungated: streaming trades first-token latency for neighbour decode
    latency, and the trade should stay visible in the trajectory."""
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.engine import Engine, Request

    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    kw = dict(slots=4, max_len=256, page_size=8, sync_interval=4,
              prefix_sharing=False, seed=0)
    arrival_gap = 10                       # chunks between arrivals
    warm_chunks = 2                        # untimed settle-in chunks
    seen = {}

    def long_prompt(r):
        return [(3 * r + j) % 250 + 1 for j in range(prompt_len)]

    def drive(eng):
        """Timed arrival window, then drain; returns (outputs,
        per-chunk seconds during the window, TTFT seconds per
        arrival)."""
        background = [Request(rid=i, prompt=[5 + i, 9, 2 + i],
                              max_new_tokens=200)
                      for i in range(3)]
        for r in background:
            eng.submit(r)
        arrivals = {}
        chunk_times = []
        submit_t = {}
        ttft = {}
        chunk = 0
        while True:
            gap = chunk - warm_chunks
            if gap >= 0 and gap % arrival_gap == 0 \
                    and len(arrivals) < n_arrivals:
                rid = 10 + len(arrivals)
                req = Request(rid=rid, prompt=long_prompt(rid),
                              max_new_tokens=12)
                arrivals[rid] = req
                eng.submit(req)
                submit_t[rid] = time.perf_counter()
            t0 = time.perf_counter()
            eng.step()
            dt = time.perf_counter() - t0
            if chunk >= warm_chunks:
                chunk_times.append(dt)
            for rid, req in arrivals.items():
                if rid not in ttft and req.out_tokens:
                    ttft[rid] = time.perf_counter() - submit_t[rid]
            chunk += 1
            if len(arrivals) == n_arrivals \
                    and all(r.done for r in arrivals.values()):
                break
            assert chunk < 500, "arrival window failed to drain"
        done = eng.run(max_steps=200_000)
        out = {r.rid: list(r.out_tokens) for r in done}
        seen.setdefault(id(eng), []).extend(done)
        eng.finished = []
        return out, chunk_times, [ttft[r] for r in sorted(ttft)]

    legacy = Engine(cfg, params, chunked_prefill=False, **kw)
    legacy.warmup()
    drive(legacy)                                     # warm compiles
    out_legacy, legacy_times, legacy_ttft = drive(legacy)

    fused = Engine(cfg, params, chunked_prefill=True,
                   prefill_budget=budget, **kw)
    fused.warmup()
    drive(fused)
    out_fused, fused_times, fused_ttft = drive(fused)

    outputs_match = out_fused == out_legacy
    si = kw["sync_interval"]

    def pct(xs, q):
        return float(np.percentile(np.asarray(xs), q)) / si * 1e3

    legacy_p50, legacy_p99 = pct(legacy_times, 50), pct(legacy_times, 99)
    fused_p50, fused_p99 = pct(fused_times, 50), pct(fused_times, 99)
    p99_ratio = legacy_p99 / fused_p99

    # structural checks on the fused engine
    sync_free = True
    fused.submit(Request(rid=99, prompt=[1, 2, 3], max_new_tokens=32))
    fused._admit()
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            toks = fused.step_chunk()
    except Exception as e:  # noqa: BLE001 - classify, don't swallow
        if "transfer" not in str(e).lower():
            raise
        sync_free = False
    else:
        fused._drain(toks)
    fused.run(max_steps=200_000)
    fused.finished = []

    # gather-free fused executable: the pool-direct build's chunk HLO
    # (prefill context reads included — there is no other executable)
    pooled = Engine(cfg, params, chunked_prefill=True,
                    prefill_budget=budget, paged_kernel=True, **kw)
    pooled.warmup()
    hlo, _ = _decode_executable(pooled)
    gather_free = not any(s in hlo for s in _ring_gather_shapes(pooled))

    rec = {
        "cp_prefill_budget": budget,
        "cp_long_prompt_len": prompt_len,
        "cp_arrivals": n_arrivals,
        "cp_outputs_match": outputs_match,
        "cp_decode_latency_p99_ratio": p99_ratio,
        "cp_fused_chunk_token_p50_ms": fused_p50,
        "cp_fused_chunk_token_p99_ms": fused_p99,
        "cp_legacy_chunk_token_p50_ms": legacy_p50,
        "cp_legacy_chunk_token_p99_ms": legacy_p99,
        "cp_fused_jitter": fused_p99 / fused_p50,
        "cp_legacy_jitter": legacy_p99 / legacy_p50,
        "cp_fused_ttft_p50_s": float(np.percentile(fused_ttft, 50)),
        "cp_fused_ttft_p99_s": float(np.percentile(fused_ttft, 99)),
        "cp_legacy_ttft_p50_s": float(np.percentile(legacy_ttft, 50)),
        "cp_legacy_ttft_p99_s": float(np.percentile(legacy_ttft, 99)),
        "cp_fused_prefill_compiles": fused.prefill_compiles
            + fused.suffix_prefill_compiles,
        "cp_fused_decode_compiles": fused.decode_compiles,
        "cp_fused_admit_compiles": fused.admit_compiles,
        "cp_fused_decode_sync_free": sync_free,
        "cp_fused_gather_free": gather_free,
    }
    rec.update(_pool_telemetry(fused, "cp_"))
    assert_clean_teardown(legacy, seen[id(legacy)], label="cp_legacy")
    assert_clean_teardown(fused, seen[id(fused)], label="cp_fused")
    emit("fig14.cp_p99_ratio", p99_ratio,
         f"fused_p99={fused_p99:.2f}ms,legacy_p99={legacy_p99:.2f}ms,"
         f"match={outputs_match}")
    emit("fig14.cp_fused_jitter", rec["cp_fused_jitter"],
         f"legacy_jitter={rec['cp_legacy_jitter']:.2f},"
         f"ttft_p99={rec['cp_fused_ttft_p99_s']:.2f}s/"
         f"{rec['cp_legacy_ttft_p99_s']:.2f}s")
    return rec


def quantized_pool_comparison(n_req: int = 8, max_new: int = 48) -> dict:
    """Quantized (int8) KV page pool vs fp32 pools: quality + capacity.

    Greedy-parity needs a model whose argmax is *confident*: at random
    init the top1-top2 logit gap (~0.01) sits below the int8 dequant
    noise (~0.03), so token agreement would measure noise, not the pool.
    The workload therefore overfits the reduced model on a deterministic
    token chain (``next = (cur * 31 + 17) % vocab``, 80 adamw steps,
    ~3s) until it follows the chain exactly; quantization error is then
    orders of magnitude below the decision margin and any disagreement
    is a real pool bug.

    Gated (check_serve_regression): positional greedy-token agreement
    int8 vs fp32 >= 0.99 over ``n_req * max_new`` positions; max
    absolute logit error of teacher-forced decode on int8 pools vs fp32
    pools bounded; >= 1.8x concurrent slots at equal (or fewer) HBM
    page-pool bytes with the slot high-water proving they were actually
    concurrent; preemption-resume parity on an oversubscribed int8 pool
    (>= 1 preemption, outputs identical to the calm int8 run, zero
    leaked pages — CoW page copies carry the scale rows); prefix-shared
    CoW parity; and the structural invariants every trajectory gates:
    ONE decode executable, sync-free decode chunk."""
    from repro.configs import get_config, reduced
    from repro.models import forward_decode, forward_prefill, forward_train
    from repro.models import model_defs
    from repro.models import module as m
    from repro.optim import adamw
    from repro.serve import cache as cm
    from repro.serve.cache import CacheSpec
    from repro.serve.engine import Engine, Request

    cfg = reduced(get_config("internlm2-1.8b"))
    vocab = cfg.vocab_size
    kv_dtype = "int8"

    def chain(start, n):
        toks = [start % vocab]
        for _ in range(n - 1):
            toks.append((toks[-1] * 31 + 17) % vocab)
        return toks

    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    ocfg = adamw.AdamWConfig(lr=3e-3)
    opt = adamw.init(params, ocfg)

    @jax.jit
    def train_step(p, o, toks):
        def loss_fn(q):
            return forward_train(q, cfg, {"tokens": toks[:, :-1],
                                          "labels": toks[:, 1:]})
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(p)
        new_p, new_o, _ = adamw.update(grads, o, p, ocfg)
        return new_p, new_o, loss

    loss = None
    for it in range(80):
        batch = jnp.asarray([chain(1 + 8 * it + bi, 33)
                             for bi in range(8)], jnp.int32)
        params, opt, loss = train_step(params, opt, batch)
    train_loss = float(loss)

    prompts = [chain(11 + 7 * i, 16) for i in range(n_req)]
    kw = dict(slots=4, max_len=256, page_size=8, sync_interval=8,
              prefix_sharing=False)

    seen = {}

    def load(eng, reqs, ttl=None):
        for rid, p, mn in reqs:
            eng.submit(Request(rid=rid, prompt=list(p), max_new_tokens=mn,
                               ttl=ttl))
        done = eng.run(max_steps=200_000)
        out = {r.rid: list(r.out_tokens) for r in done}
        seen.setdefault(id(eng), []).extend(done)
        eng.finished = []
        return out

    reqs = [(i, p, max_new) for i, p in enumerate(prompts)]
    base = Engine(cfg, params, kv_dtype="fp32", **kw)
    base.warmup()
    out32 = load(base, reqs)

    quant = Engine(cfg, params, kv_dtype=kv_dtype, **kw)
    assert quant.kv_dtype == kv_dtype, quant.kv_dtype
    quant.warmup()
    out8 = load(quant, reqs)

    total = n_req * max_new
    agree = sum(sum(a == b for a, b in zip(out32[i], out8[i]))
                for i in range(n_req))
    greedy_match = agree / total
    exact = sum(out32[i] == out8[i] for i in range(n_req))
    follows = sum(out32[i] == chain(prompts[i][-1], max_new + 1)[1:]
                  for i in range(n_req))

    # teacher-forced logit probe: same tokens decoded against fp32 and
    # int8 pools (prefill KV admitted through the quantizing splice, new
    # KV through the re-quantizing RMW write) — the max absolute logit
    # divergence is the whole model-quality cost of the 8-bit pool
    def admitted(sp, prompt):
        _, dense = forward_prefill(
            params, cfg, {"tokens": jnp.asarray([prompt], jnp.int32)})
        rows = {g.key: jnp.arange(1, g.ring_blocks + 1, dtype=jnp.int32)
                for g in sp.groups}
        cache = cm.admit_cache(sp, sp.init_paged_cache(), dense,
                               jnp.int32(0), jnp.int32(0),
                               jnp.int32(len(prompt)), rows)
        return cache

    probe = prompts[0]
    c32 = admitted(CacheSpec.from_config(cfg, 1, 64, page_size=8), probe)
    c8 = admitted(CacheSpec.from_config(cfg, 1, 64, page_size=8,
                                        kv_dtype=kv_dtype), probe)
    max_logit_err = 0.0
    for t in chain(probe[-1], 9)[1:]:
        tk = jnp.asarray([[t]], jnp.int32)
        lg32, c32 = forward_decode(params, cfg, tk, c32)
        lg8, c8 = forward_decode(params, cfg, tk, c8)
        max_logit_err = max(max_logit_err,
                            float(jnp.max(jnp.abs(lg32 - lg8))))

    # capacity at equal HBM: size an int8 pool to AT MOST the fp32
    # engine's page-pool bytes (scale rows included) and serve 2x the
    # slots concurrently.  Per-page byte ratio ~3.9x (int8 + 2 fp32
    # scale rows vs fp32), so double the slots leaves headroom.
    budget = base.spec.paged_kv_bytes()
    probe_a = CacheSpec.from_config(cfg, 8, 256, page_size=8,
                                    num_pages=64, kv_dtype=kv_dtype)
    probe_b = CacheSpec.from_config(cfg, 8, 256, page_size=8,
                                    num_pages=65, kv_dtype=kv_dtype)
    per_page = probe_b.paged_kv_bytes() - probe_a.paged_kv_bytes()
    fixed = probe_a.paged_kv_bytes() - 64 * per_page
    npages = int((budget - fixed) // per_page)
    cap = Engine(cfg, params, slots=8, max_len=256, page_size=8,
                 sync_interval=8, prefix_sharing=False,
                 num_pages=npages, kv_dtype=kv_dtype)
    quant_bytes = cap.spec.paged_kv_bytes()
    assert quant_bytes <= budget, (quant_bytes, budget)
    cap.warmup()
    load(cap, [(i, p, 16) for i, p in enumerate(prompts)])
    cap_peak = cap.memory_stats()["peak_live_slots"]
    slot_ratio = cap.spec.slots / base.spec.slots
    page_ratio = (base.spec.paged_kv_bytes()
                  / CacheSpec.from_config(cfg, 4, 256, page_size=8,
                                          kv_dtype=kv_dtype)
                  .paged_kv_bytes())

    # preemption-resume parity on quantized pools: 12-page budget vs 8
    # worst-case pages per request -> the engine must preempt; outputs
    # must still match the calm int8 run and no page may leak
    pre = Engine(cfg, params, num_pages=12, kv_dtype=kv_dtype, **kw)
    pre.warmup()
    out_pre = load(pre, reqs, ttl=600.0)
    pre_fs = pre.fault_stats()
    pre_match = out_pre == out8
    pre_leaked = pre.leaked_pages()

    # CoW parity: shared chain head, per-request off-chain branch token;
    # radix sharing + copy-on-write must be output-invisible on 8-bit
    # pools (copy_shared_page clones the scale rows with the page)
    head = chain(701, 16)
    cow_reqs = [(i, head + [(40 + 13 * i) % vocab], 24)
                for i in range(n_req)]
    share = Engine(cfg, params, slots=4, max_len=256, page_size=8,
                   sync_interval=8, prefix_sharing=True,
                   kv_dtype=kv_dtype)
    share.warmup()
    out_share = load(share, cow_reqs)
    excl = Engine(cfg, params, kv_dtype=kv_dtype, **kw)
    excl.warmup()
    out_excl = load(excl, cow_reqs)
    ps = share.prefix_stats()
    cow_match = out_share == out_excl

    sync_free = True
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            toks = quant.step_chunk()
    except Exception as e:  # noqa: BLE001 - classify, don't swallow
        if "transfer" not in str(e).lower():
            raise
        sync_free = False
    else:
        quant._drain(toks)

    rec = {
        "qp_requests": n_req,
        "qp_max_new": max_new,
        "qp_train_loss": train_loss,
        "qp_fp32_follows_chain": follows / n_req,
        "qp_greedy_match": greedy_match,
        "qp_exact_matches": exact,
        "qp_total_positions": total,
        "qp_max_logit_err": max_logit_err,
        "qp_fp32_pool_bytes": int(budget),
        "qp_quant_pool_bytes": int(quant_bytes),
        "qp_equal_bytes_slots": cap.spec.slots,
        "qp_baseline_slots": base.spec.slots,
        "qp_equal_bytes_slot_ratio": slot_ratio,
        "qp_equal_bytes_peak_live_slots": int(cap_peak),
        "qp_equal_bytes_num_pages": npages,
        "qp_bytes_per_page_ratio": page_ratio,
        "qp_preemptions": pre_fs["preemptions"],
        "qp_preempt_outputs_match": pre_match,
        "qp_preempt_leaked_pages": int(pre_leaked),
        "qp_cow_outputs_match": cow_match,
        "qp_prefix_hits": ps["prefix_hits"],
        "qp_cow_copies": ps["cow_copies"],
        "qp_shared_attaches": ps["shared_page_attaches"],
        "qp_decode_compiles": quant.decode_compiles,
        "qp_decode_sync_free": sync_free,
    }
    rec.update(_pool_telemetry(quant, "qp_"))
    for e, lbl in ((base, "qp_fp32"), (quant, "qp_int8"),
                   (cap, "qp_capacity"), (pre, "qp_preempt"),
                   (share, "qp_cow"), (excl, "qp_exclusive")):
        assert_clean_teardown(e, seen[id(e)], label=lbl)
    emit("fig14.qp_greedy_match", greedy_match,
         f"exact={exact}/{n_req},logit_err={max_logit_err:.4f},"
         f"loss={train_loss:.3f}")
    emit("fig14.qp_equal_bytes_slot_ratio", slot_ratio,
         f"bytes={int(quant_bytes)}<={int(budget)},"
         f"peak_live={int(cap_peak)}/{cap.spec.slots},"
         f"page_ratio={page_ratio:.2f}")
    emit("fig14.qp_fault_parity", float(pre_match and cow_match),
         f"preemptions={pre_fs['preemptions']},leaked={int(pre_leaked)},"
         f"cow={ps['cow_copies']},hits={ps['prefix_hits']}")
    return rec


def serve_engine_comparison(n_req: int = 12, max_new: int = 16) -> dict:
    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.engine import Engine, Request
    from repro.serve.reference import ReferenceEngine

    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)

    def timed_trials(eng, trials: int = 3, track=None):
        """Best tokens/sec + steps/sec over ``trials`` runs (overhead
        benchmarks take the min time; the tail is scheduler noise).
        Tokens/sec is the fair cross-engine metric: the fused engine's
        step counter includes dead tail-of-chunk steps the reference
        never pays, but both deliver the same tokens."""
        best_tps, best_sps, syncs_per_step = 0.0, 0.0, 0.0
        for _ in range(trials):
            steps0, syncs0 = eng.steps, eng.host_syncs
            dt, toks = _serve_workload(eng, n_req, max_new, track=track)
            if toks / dt > best_tps:
                best_tps = toks / dt
                best_sps = (eng.steps - steps0) / dt
                syncs_per_step = (eng.host_syncs - syncs0) / (eng.steps - steps0)
        return best_tps, best_sps, syncs_per_step

    ref = ReferenceEngine(cfg, params, slots=4, max_len=64)
    _serve_workload(ref, n_req, max_new)          # warm: compiles happen here
    ref_tps, ref_sps, ref_syncs = timed_trials(ref)

    eng = Engine(cfg, params, slots=4, max_len=64, sync_interval=16,
                 chunked_prefill=False)   # legacy-pinned trajectory
    eng.warmup()                                  # compile caches
    _serve_workload(eng, n_req, max_new)          # host-path warm, like ref

    # paged-cache memory telemetry: sample bytes/live-token mid-flight
    # (request admitted, pages leased), peak page occupancy at the end
    eng.submit(Request(rid=10_000, prompt=[1, 2, 3], max_new_tokens=max_new))
    eng._admit()
    mem_live = eng.memory_stats()
    eng.run(max_steps=100_000)
    eng.finished = []

    tracked = []
    eng_tps, eng_sps, eng_syncs = timed_trials(eng, track=tracked)

    # steady-state decode is sync-free two ways: (a) the engine's own
    # accounting — exactly one batched drain per sync_interval steps; (b)
    # a fused chunk dispatched under a device->host transfer guard, which
    # raises on any sync on accelerator backends (CPU d2h is zero-copy,
    # so there the guard is vacuous and (a) is the real evidence).
    sync_free = True
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            toks = eng.step_chunk()
    except Exception as e:  # noqa: BLE001 - classify, don't swallow
        if "transfer" not in str(e).lower():
            raise            # a real crash, not the guard firing
        sync_free = False
    else:
        eng._drain(toks)
    assert sync_free, "decode chunk performed a device->host transfer"
    assert abs(eng_syncs - 1.0 / eng.sync_interval) < 1e-9, eng_syncs
    mem_end = eng.memory_stats()
    assert_clean_teardown(eng, tracked, label="serve_engine")

    # --- tracing overhead on the SAME baseline workload: a traced twin
    # engine runs identical best-of-3 trials; the tracer records one
    # host-side event per lifecycle transition at chunk boundaries, so
    # throughput must stay within 5% (gated by check_serve_regression)
    # and the chunk must remain one sync-free executable.
    from benchmarks.check_trace import validate as validate_trace
    from repro.serve.trace import TERMINAL_KINDS

    traced = Engine(cfg, params, slots=4, max_len=64, sync_interval=16,
                    chunked_prefill=False, trace=True)
    traced.warmup()
    _serve_workload(traced, n_req, max_new)       # host-path warm
    traced_reqs = []
    trace_tps, _, _ = timed_trials(traced, track=traced_reqs)

    trace_sync_free = True
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            toks = traced.step_chunk()
    except Exception as e:  # noqa: BLE001 - classify, don't swallow
        if "transfer" not in str(e).lower():
            raise
        trace_sync_free = False
    else:
        traced._drain(toks)
    assert_clean_teardown(traced, traced_reqs, label="serve_engine_traced")

    trace_obj = traced.export_trace()
    trace_failures = validate_trace(trace_obj)
    term_events = [e for e in traced.tracer.events()
                   if e.kind in TERMINAL_KINDS]
    # every terminal request left a complete submit->terminal chain —
    # 4 workload runs (1 warm + 3 timed) of n_req requests each
    chains_complete = not any("without" in f for f in trace_failures) \
        and len(term_events) >= 4 * n_req \
        and {e.rid for e in term_events} >= set(range(n_req))

    rec_trace = {
        "trace_tokens_per_s": trace_tps,
        "trace_overhead_ratio": trace_tps / eng_tps,
        "trace_decode_sync_free": trace_sync_free,
        "trace_decode_compiles": traced.decode_compiles,
        "trace_events": len(traced.tracer),
        "trace_dropped": traced.tracer.dropped,
        "trace_schema_valid": not trace_failures,
        "trace_complete_chains": chains_complete,
    }
    for f in trace_failures:
        print(f"# trace schema failure: {f}")

    rec = {
        "arch": cfg.name,
        "requests": n_req,
        "max_new": max_new,
        "ref_steps_per_s": ref_sps,
        "new_steps_per_s": eng_sps,
        "ref_tokens_per_s": ref_tps,
        "new_tokens_per_s": eng_tps,
        "speedup": eng_tps / ref_tps,
        "ref_host_syncs_per_step": ref_syncs,
        "new_host_syncs_per_step": eng_syncs,
        "ref_prefill_compiles": ref.prefill_compiles,
        "new_prefill_compiles": eng.prefill_compiles,
        "new_decode_compiles": eng.decode_compiles,
        # batched multi-slot admission: every chunk boundary's admissions
        # land in ONE splice dispatch, and that executable compiles once
        "new_admit_compiles": eng.admit_compiles,
        "buckets": list(eng.buckets),
        "sync_interval": eng.sync_interval,
        "decode_sync_free": sync_free,
        # paged-cache memory schema (serve/cache.CacheSpec.memory_stats)
        "page_size": mem_end["page_size"],
        "num_pages": mem_end["num_pages"],
        "peak_pages_in_use": mem_end["peak_pages_in_use"],
        "hbm_bytes_per_live_token": mem_live["hbm_bytes_per_live_token"],
        "dense_vs_paged_capacity_ratio":
            mem_end["dense_vs_paged_capacity_ratio"],
        "paged_kv_bytes": mem_end["paged_kv_bytes"],
        "dense_kv_bytes": mem_end["dense_kv_bytes"],
        "pool_bytes_per_live_token": mem_live["pool_bytes_per_live_token"],
        "kv_dtype": mem_end["kv_dtype"],
        "peak_live_slots": mem_end["peak_live_slots"],
    }
    rec.update(rec_trace)
    emit("fig14.trace_overhead_ratio", rec["trace_overhead_ratio"],
         f"traced={trace_tps:.0f}tok/s,untraced={eng_tps:.0f}tok/s,"
         f"events={rec['trace_events']},"
         f"schema_valid={rec['trace_schema_valid']}")
    emit("fig14.engine_ref_steps_per_s", 1e6 / rec["ref_steps_per_s"],
         f"syncs_per_step={rec['ref_host_syncs_per_step']:.2f}")
    emit("fig14.engine_new_steps_per_s", 1e6 / rec["new_steps_per_s"],
         f"syncs_per_step={rec['new_host_syncs_per_step']:.3f}")
    emit("fig14.engine_speedup", rec["speedup"],
         f"sync_free={sync_free},prefill_compiles="
         f"{rec['new_prefill_compiles']}/{rec['ref_prefill_compiles']}")
    emit("fig14.paged_kv_mem", rec["hbm_bytes_per_live_token"],
         f"peak_pages={rec['peak_pages_in_use']}/{rec['num_pages']},"
         f"dense_vs_paged={rec['dense_vs_paged_capacity_ratio']:.2f}")
    return rec


def main() -> None:
    x = jnp.zeros((8, 8))

    inc = jax.jit(lambda v: v + 1.0)
    jax.block_until_ready(inc(x))
    t0 = time.perf_counter()
    v = x
    for _ in range(N_TASKS):
        v = inc(v)
    jax.block_until_ready(v)
    t_dispatch = time.perf_counter() - t0

    @jax.jit
    def fused(v):
        for _ in range(N_TASKS):
            v = v + 1.0
        return v

    jax.block_until_ready(fused(x))
    t0 = time.perf_counter()
    jax.block_until_ready(fused(x))
    t_fused = time.perf_counter() - t0

    t0 = time.perf_counter()
    v = x
    with jax.disable_jit():
        for _ in range(100):
            v = v + 1.0
    jax.block_until_ready(v)
    t_eager = (time.perf_counter() - t0) * (N_TASKS / 100)

    emit("fig14.per_dispatch_jit", t_dispatch / N_TASKS * 1e6,
         f"total_ms={t_dispatch * 1e3:.1f}")
    emit("fig14.per_op_fused", t_fused / N_TASKS * 1e6,
         f"overhead_ratio={t_dispatch / t_fused:.1f}x")
    emit("fig14.per_op_eager", t_eager / N_TASKS * 1e6,
         f"total_ms_est={t_eager * 1e3:.1f}")

    rec = serve_engine_comparison()
    rec.update(shared_prefix_comparison())
    rec.update(paged_kernel_comparison())
    rec.update(speculative_comparison())
    rec.update(fault_tolerance_comparison())
    rec.update(chunked_prefill_comparison())
    rec.update(quantized_pool_comparison())
    path = write_bench_json("BENCH_serve.json", rec)
    print(f"# serve trajectory appended to {path}", flush=True)


if __name__ == "__main__":
    main()
