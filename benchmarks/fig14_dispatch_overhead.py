"""Paper Fig. 14: thread-pool overhead under 10k micro tasks.

Framework-dispatch analogue: the cost of crossing the python->jit boundary
for a trivial op, measured three ways (mirroring std::thread vs Eigen vs
Folly): (a) 1000 separate jit dispatches, (b) one jit containing the same
1000 ops (fully fused schedule), (c) 1000 eager ops.  The derived column
is per-task overhead — the price the 'scheduler' charges per operator.
"""

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit

N_TASKS = 1000


def main() -> None:
    x = jnp.zeros((8, 8))

    inc = jax.jit(lambda v: v + 1.0)
    jax.block_until_ready(inc(x))
    t0 = time.perf_counter()
    v = x
    for _ in range(N_TASKS):
        v = inc(v)
    jax.block_until_ready(v)
    t_dispatch = time.perf_counter() - t0

    @jax.jit
    def fused(v):
        for _ in range(N_TASKS):
            v = v + 1.0
        return v

    jax.block_until_ready(fused(x))
    t0 = time.perf_counter()
    jax.block_until_ready(fused(x))
    t_fused = time.perf_counter() - t0

    t0 = time.perf_counter()
    v = x
    with jax.disable_jit():
        for _ in range(100):
            v = v + 1.0
    jax.block_until_ready(v)
    t_eager = (time.perf_counter() - t0) * (N_TASKS / 100)

    emit("fig14.per_dispatch_jit", t_dispatch / N_TASKS * 1e6,
         f"total_ms={t_dispatch * 1e3:.1f}")
    emit("fig14.per_op_fused", t_fused / N_TASKS * 1e6,
         f"overhead_ratio={t_dispatch / t_fused:.1f}x")
    emit("fig14.per_op_eager", t_eager / N_TASKS * 1e6,
         f"total_ms_est={t_eager * 1e3:.1f}")


if __name__ == "__main__":
    main()
