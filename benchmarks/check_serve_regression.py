"""CI gate over the BENCH_serve.json trajectory.

Compares the newest run (appended by ``benchmarks/fig14_dispatch_overhead``
in the same CI job) against the committed baseline run and fails when:

* ``decode_sync_free`` regressed — the fused decode chunk performed a
  device->host transfer, i.e. the paper-motivated sync-free property broke;
* the batched-admission splice retraced (``new_admit_compiles != 1``) —
  a chunk boundary's admissions are meant to land in ONE executable;
* the speculative workload regressed — drafted outputs diverged from the
  non-speculative engine / dense reference at temperature 0, the n-gram
  acceptance rate fell to <= 0.5 on the repetitive-text workload, the
  speculative decode throughput fell below 1.2x the non-speculative
  baseline (same machine, same run), the chunk stopped being sync-free,
  or an executable retraced;
* the paged-kernel comparison regressed — pool-direct decode outputs
  diverged from the gather path / dense reference, the gathered ring
  buffer reappeared in the paged decode executable's HLO, or pool-direct
  tokens/sec fell more than ``--threshold`` below gather-then-attend on
  the oversubscribed-pool workload (a same-machine comparison, so no
  normalization is needed);
* the fault-tolerance workload regressed — the oversubscribed-pool run
  stopped preempting (pressure path inert), goodput (deadline
  attainment) fell below 0.8, preempted-then-resumed outputs diverged
  from the uncontended engine at temperature 0, pages leaked at drain,
  the chunk stopped being sync-free, or the decode executable retraced;
* the chunked-prefill workload regressed — fused mixed-chunk outputs
  diverged from the legacy two-executable engine, the p99 per-chunk
  decode-token latency advantage under long-prompt arrivals fell below
  1.3x, a prefill executable reappeared (fused mode must compile
  exactly one decode chunk + one admission splice), the fused chunk
  stopped being sync-free, the gathered-ring shapes reappeared in
  the fused executable's HLO, or TTFT telemetry went vacuous / the
  fused TTFT p99 blew past 15x the legacy engine's (streaming
  admissions stopped making prefill progress);
* the quantized-pool workload regressed — int8 pools fell back to
  fp32, greedy-token agreement with fp32 pools fell below 0.99 on the
  chain-overfit model, the teacher-forced max logit error exceeded
  0.25, the equal-HBM capacity demo stopped fitting >= 1.8x the
  concurrent slots in at-most-the-fp32 pool bytes (or never reached
  full occupancy), preemption-resume or CoW-sharing outputs diverged,
  pages leaked, the chunk stopped being sync-free, or the decode
  executable retraced;
* the SLO-scheduling workload regressed (``benchmarks/fig04_scheduling
  --slo-mix``, merged into the same run) — the least-slack policy
  stopped beating FIFO on interactive p99 TTFT on the oversubscribed
  mixed-class trace, goodput fell below FIFO, request outputs changed
  across policies at temperature 0, the seeded traffic trace stopped
  regenerating byte-identically, pages leaked, the chunk stopped being
  sync-free, or the dynamic prefill budget retraced the decode
  executable;
* the tracing-overhead measurement regressed — the traced twin of the
  fig14 baseline workload fell below 0.95x the untraced engine's
  tokens/sec (a same-machine same-run ratio), the traced decode chunk
  stopped being sync-free or retraced, the exported Perfetto JSON
  failed schema validation (``benchmarks/check_trace.validate``), a
  submit->terminal flow chain went incomplete, or the tracer ring
  dropped events on a workload sized to fit it;
* the trace-report workload regressed (``benchmarks/fig04_scheduling
  --trace-report``, merged into the same run) — the replayed
  VirtualClock trace stopped producing byte-identical fingerprints
  across two runs, the exported timeline failed schema validation,
  the oversubscribed mixed-class trace stopped preempting, per-class
  lifecycle phase attribution went vacuous (zero total queued+running
  seconds), or ``Engine.explain`` stopped rendering causal chains;
* a **gated metric key is missing** from a workload the candidate run
  claims to include — a silently-dropped metric must read as a
  regression, not as a pass through a forgiving ``.get`` default (the
  per-workload sentinels still allow a whole workload to be absent
  only when the baseline never had it);
* tokens/sec dropped more than ``--threshold`` (default 25%) vs the
  baseline.  CI machines differ from the machine that committed the
  baseline, so the comparison is machine-normalized: both runs also
  measure the *same* ``ReferenceEngine`` workload, and the candidate's
  expected tokens/sec is the baseline's scaled by the observed
  reference-engine speed ratio::

      expected = base.new_tokens_per_s * (cand.ref_tokens_per_s /
                                          base.ref_tokens_per_s)

  so a uniformly slower CI runner does not trip the gate, while a real
  fast-path regression (fused engine slower *relative to* the reference)
  does.

Usage:  python -m benchmarks.check_serve_regression [--threshold 0.25]
"""

from __future__ import annotations

import argparse
import json
import sys

from benchmarks.common import REPO_ROOT


def _require(cand, failures, section: str, keys) -> bool:
    """Hard-fail on any gated metric key absent from the candidate run.

    Every gate below reads with a forgiving ``.get(key, <passing
    default>)`` so a partial record cannot crash the checker — but a
    metric that silently vanished (a workload edit dropped it) must
    fail CI, not sail through the default.  Returns False when any key
    is missing so value gates on garbage can be skipped."""
    missing = [k for k in keys if k not in cand]
    for k in missing:
        failures.append(f"{section}: gated metric '{k}' missing from "
                        "the candidate run — a dropped metric is a "
                        "regression, not a pass")
    return not missing


def check(runs, threshold: float) -> int:
    if len(runs) < 2:
        print("check_serve_regression: need a committed baseline run plus "
              "a fresh candidate run; got "
              f"{len(runs)} run(s) — nothing to compare")
        return 1
    base, cand = runs[-2], runs[-1]
    failures = []

    if not cand.get("decode_sync_free", False):
        failures.append("decode_sync_free regressed: the fused decode "
                        "chunk performed a device->host transfer")

    if _require(cand, failures, "engine", [
            "decode_sync_free", "ref_tokens_per_s", "new_tokens_per_s",
            "new_decode_compiles", "pool_bytes_per_live_token",
            "kv_dtype", "peak_live_slots"]):
        ref_scale = cand["ref_tokens_per_s"] / base["ref_tokens_per_s"]
        expected = base["new_tokens_per_s"] * ref_scale
        floor = (1.0 - threshold) * expected
        print(f"baseline new_tokens_per_s={base['new_tokens_per_s']:.0f} "
              f"(machine scale x{ref_scale:.2f} -> expected {expected:.0f})")
        print(f"candidate new_tokens_per_s={cand['new_tokens_per_s']:.0f} "
              f"(floor {floor:.0f} at threshold {threshold:.0%})")
        if cand["new_tokens_per_s"] < floor:
            failures.append(
                f"tokens/sec dropped >{threshold:.0%}: "
                f"{cand['new_tokens_per_s']:.0f} < {floor:.0f}")

    if cand.get("new_decode_compiles", 1) != 1:
        failures.append("decode executable count != 1: the shape-stable "
                        "chunk retraced "
                        f"({cand.get('new_decode_compiles')} compiles)")

    if "new_admit_compiles" in cand and cand["new_admit_compiles"] != 1:
        failures.append(
            "batched admission executable count != 1: the chunk-boundary "
            "splice retraced "
            f"({cand.get('new_admit_compiles')} compiles)")
    elif "new_admit_compiles" not in cand \
            and "new_admit_compiles" in base:
        failures.append("candidate run dropped the batched-admission "
                        "telemetry (new_admit_compiles missing)")

    # ---- prefix-sharing gates (shared-prefix workload in the same run).
    # Correctness first: radix/CoW admission must be invisible in the
    # tokens — shared-prefix outputs identical to exclusive ownership.
    if "prefix_outputs_match_exclusive" in cand:
        _require(cand, failures, "prefix-sharing", [
            "prefix_hit_rate", "prefix_pages_saved",
            "prefix_decode_sync_free", "prefix_decode_compiles",
            "prefix_pool_bytes_per_live_token", "prefix_peak_live_slots"])
        if not cand["prefix_outputs_match_exclusive"]:
            failures.append(
                "prefix-hit correctness regressed: shared-prefix outputs "
                "diverged from exclusive-ownership outputs")
        if not cand.get("prefix_hit_rate", 0.0) > 0.0:
            failures.append(
                "prefix sharing inert: hit rate is 0 on the shared-prefix "
                "workload")
        if not cand.get("prefix_pages_saved", 0) > 0:
            failures.append(
                "prefix sharing saved no pages vs exclusive ownership "
                f"(peak {cand.get('prefix_peak_pages')} vs "
                f"{cand.get('exclusive_peak_pages')})")
        if not cand.get("prefix_decode_sync_free", True):
            failures.append("shared-prefix decode chunk performed a "
                            "device->host transfer")
        if cand.get("prefix_decode_compiles", 1) != 1:
            failures.append(
                "shared-prefix workload retraced the decode chunk "
                f"({cand.get('prefix_decode_compiles')} compiles)")
        print(f"prefix sharing: "
              f"hit_rate={cand.get('prefix_hit_rate', 0.0):.2f} "
              f"pages_saved={cand.get('prefix_pages_saved')} "
              f"tokens_skipped={cand.get('prefill_tokens_skipped')} "
              f"match={cand.get('prefix_outputs_match_exclusive')}")
    elif "prefix_outputs_match_exclusive" in base:
        failures.append("candidate run dropped the shared-prefix workload "
                        "(prefix_* fields missing)")

    # ---- paged-kernel gates (gather-vs-pool-direct workload, same run).
    # Correctness and structure first: pool-direct decode must be
    # invisible in the tokens, and the gathered ring buffer must actually
    # be gone from its decode executable.
    if "paged_kernel_tokens_per_s" in cand:
        _require(cand, failures, "paged-kernel", [
            "paged_kernel_outputs_match", "paged_kernel_gather_free",
            "gather_path_materializes_ring",
            "paged_kernel_decode_sync_free",
            "paged_kernel_decode_compiles", "paged_gather_tokens_per_s",
            "paged_kernel_pool_bytes_per_live_token",
            "paged_kernel_peak_live_slots"])
        if not cand.get("paged_kernel_outputs_match", False):
            failures.append(
                "paged-kernel correctness regressed: pool-direct outputs "
                "diverged from the gather path / dense reference")
        if not cand.get("paged_kernel_gather_free", False):
            failures.append(
                "paged decode executable still materializes the gathered "
                "ring buffer (gather-then-attend shapes found in HLO)")
        if not cand.get("gather_path_materializes_ring", True):
            failures.append(
                "gather-buffer HLO detection went vacuous: the reference "
                "gather executable no longer shows the ring shapes the "
                "check looks for")
        if not cand.get("paged_kernel_decode_sync_free", True):
            failures.append("paged-kernel decode chunk performed a "
                            "device->host transfer")
        if cand.get("paged_kernel_decode_compiles", 1) != 1:
            failures.append(
                "paged-kernel workload retraced the decode chunk "
                f"({cand.get('paged_kernel_decode_compiles')} compiles)")
        gather_tps = cand.get("paged_gather_tokens_per_s", 0.0)
        floor = (1.0 - threshold) * gather_tps
        if cand["paged_kernel_tokens_per_s"] < floor:
            failures.append(
                "paged-kernel decode slower than gather-then-attend on "
                "the oversubscribed-pool workload: "
                f"{cand['paged_kernel_tokens_per_s']:.0f} < floor "
                f"{floor:.0f} (gather {gather_tps:.0f})")
        print(f"paged kernel [{cand.get('paged_kernel_backend')}]: "
              f"{cand['paged_kernel_tokens_per_s']:.0f} vs gather "
              f"{gather_tps:.0f} tok/s "
              f"(x{cand.get('paged_kernel_speedup', 0.0):.2f}) "
              f"gather_free={cand.get('paged_kernel_gather_free')} "
              f"match={cand.get('paged_kernel_outputs_match')}")
    elif "paged_kernel_tokens_per_s" in base:
        failures.append("candidate run dropped the paged-kernel workload "
                        "(paged_kernel_* fields missing)")

    # ---- speculative-decoding gates (repetitive-text workload, same
    # run).  Correctness first: drafted/verified decoding must be
    # invisible in the tokens at temperature 0.
    if "spec_decode_tokens_per_s" in cand:
        _require(cand, failures, "speculative", [
            "spec_outputs_match", "spec_acceptance_rate",
            "spec_baseline_decode_tokens_per_s", "spec_decode_sync_free",
            "spec_decode_compiles", "spec_admit_compiles",
            "spec_pool_bytes_per_live_token", "spec_peak_live_slots"])
        if not cand.get("spec_outputs_match", False):
            failures.append(
                "speculative correctness regressed: drafted outputs "
                "diverged from the non-speculative engine / dense "
                "reference at temperature 0")
        if not cand.get("spec_acceptance_rate", 0.0) > 0.5:
            failures.append(
                "speculative acceptance rate <= 0.5 on the repetitive "
                f"workload ({cand.get('spec_acceptance_rate', 0.0):.3f}) "
                "— the n-gram drafter stopped earning its verify cost")
        base_d = cand.get("spec_baseline_decode_tokens_per_s", 0.0)
        if not base_d > 0.0:
            failures.append(
                "speculative baseline decode throughput missing or zero "
                "(spec_baseline_decode_tokens_per_s) — the 1.2x gate "
                "would be vacuous")
        elif cand["spec_decode_tokens_per_s"] < 1.2 * base_d:
            failures.append(
                "speculative decode throughput < 1.2x the non-spec "
                f"baseline: {cand['spec_decode_tokens_per_s']:.0f} vs "
                f"{base_d:.0f} tok/s "
                f"(x{cand.get('spec_decode_speedup', 0.0):.2f})")
        if not cand.get("spec_decode_sync_free", True):
            failures.append("speculative decode chunk performed a "
                            "device->host transfer")
        if cand.get("spec_decode_compiles", 1) != 1:
            failures.append(
                "speculative workload retraced the decode chunk "
                f"({cand.get('spec_decode_compiles')} compiles)")
        if cand.get("spec_admit_compiles", 1) != 1:
            failures.append(
                "speculative workload retraced the batched admission "
                f"splice ({cand.get('spec_admit_compiles')} compiles)")
        print(f"speculative [{cand.get('spec_drafter')}, "
              f"k={cand.get('spec_k')}]: "
              f"acceptance={cand.get('spec_acceptance_rate', 0.0):.2f} "
              f"tokens/step={cand.get('spec_tokens_per_step', 0.0):.2f} "
              f"decode x{cand.get('spec_decode_speedup', 0.0):.2f} "
              f"match={cand.get('spec_outputs_match')}")
    elif "spec_decode_tokens_per_s" in base:
        failures.append("candidate run dropped the speculative workload "
                        "(spec_* fields missing)")

    # ---- fault-tolerance gates (oversubscribed pool + deadlines, same
    # run).  The engine must survive the pressure — preempt and resume
    # token-identically — not throw at it or leak pages.
    if "ft_goodput" in cand:
        _require(cand, failures, "fault-tolerance", [
            "ft_outputs_match", "ft_preemptions", "ft_leaked_pages",
            "ft_decode_sync_free", "ft_decode_compiles",
            "ft_pool_bytes_per_live_token", "ft_peak_live_slots"])
        if not cand.get("ft_outputs_match", False):
            failures.append(
                "fault-tolerance correctness regressed: preempted-then-"
                "resumed outputs diverged from the uncontended engine at "
                "temperature 0")
        if not cand.get("ft_preemptions", 0) >= 1:
            failures.append(
                "fault-tolerance workload inert: the oversubscribed pool "
                "produced no preemptions (pressure path never exercised)")
        if cand.get("ft_goodput", 0.0) < 0.8:
            failures.append(
                "fault-tolerance goodput < 0.8: deadline attainment "
                f"{cand.get('ft_goodput', 0.0):.2f} on the oversubscribed "
                "workload (only the doomed request may miss)")
        if cand.get("ft_leaked_pages", 0) != 0:
            failures.append(
                "fault-tolerance run leaked pages at drain "
                f"({cand.get('ft_leaked_pages')}) — a refcount leak in "
                "the preempt/reap/resume path")
        if not cand.get("ft_decode_sync_free", True):
            failures.append("fault-tolerance decode chunk performed a "
                            "device->host transfer")
        if cand.get("ft_decode_compiles", 1) != 1:
            failures.append(
                "fault-tolerance workload retraced the decode chunk "
                f"({cand.get('ft_decode_compiles')} compiles) — "
                "preemption/resume must reuse the one executable")
        print(f"fault tolerance: goodput={cand.get('ft_goodput', 0.0):.2f} "
              f"preemptions={cand.get('ft_preemptions')} "
              f"resumes={cand.get('ft_resumes')} "
              f"recovered_prefill="
              f"{cand.get('ft_recovered_prefill_fraction', 0.0):.2f} "
              f"match={cand.get('ft_outputs_match')} "
              f"leaked={cand.get('ft_leaked_pages')}")
    elif "ft_goodput" in base:
        failures.append("candidate run dropped the fault-tolerance "
                        "workload (ft_* fields missing)")

    # ---- chunked-prefill gates (fused mixed-chunk workload, same run).
    # The fused engine's reason to exist is flat decode-token latency
    # under long-prompt arrivals, at token parity, with zero prefill
    # executables and pool-direct (gather-free) prompt context reads.
    if "cp_decode_latency_p99_ratio" in cand:
        _require(cand, failures, "chunked-prefill", [
            "cp_outputs_match", "cp_fused_prefill_compiles",
            "cp_fused_decode_compiles", "cp_fused_admit_compiles",
            "cp_fused_decode_sync_free", "cp_fused_gather_free",
            "cp_fused_ttft_p50_s", "cp_fused_ttft_p99_s",
            "cp_legacy_ttft_p50_s", "cp_legacy_ttft_p99_s",
            "cp_pool_bytes_per_live_token", "cp_peak_live_slots"])
        if not cand.get("cp_outputs_match", False):
            failures.append(
                "chunked-prefill correctness regressed: fused mixed-chunk "
                "outputs diverged from the legacy two-executable engine "
                "at temperature 0")
        ratio = cand.get("cp_decode_latency_p99_ratio", 0.0)
        if not ratio >= 1.3:
            failures.append(
                "chunked-prefill p99 decode-token latency advantage "
                f"< 1.3x under long-prompt arrivals (x{ratio:.2f}: "
                f"legacy p99 "
                f"{cand.get('cp_legacy_chunk_token_p99_ms', 0.0):.2f}ms "
                "vs fused "
                f"{cand.get('cp_fused_chunk_token_p99_ms', 0.0):.2f}ms)")
        if cand.get("cp_fused_prefill_compiles", 0) != 0:
            failures.append(
                "fused engine compiled a prefill executable "
                f"({cand.get('cp_fused_prefill_compiles')}) — chunked "
                "prefill must stream prompts through the one chunk "
                "executable")
        if cand.get("cp_fused_decode_compiles", 1) != 1:
            failures.append(
                "chunked-prefill workload retraced the fused chunk "
                f"({cand.get('cp_fused_decode_compiles')} compiles)")
        if cand.get("cp_fused_admit_compiles", 1) != 1:
            failures.append(
                "chunked-prefill workload retraced the admission "
                f"bookkeeping ({cand.get('cp_fused_admit_compiles')} "
                "compiles)")
        if not cand.get("cp_fused_decode_sync_free", True):
            failures.append("fused mixed chunk performed a device->host "
                            "transfer")
        if not cand.get("cp_fused_gather_free", False):
            failures.append(
                "fused chunk executable materializes gathered-ring "
                "shapes — prompt context reads must stay pool-direct")
        # TTFT is the price of streaming: it may lag the legacy full-
        # prefill dispatch, but boundedly — a runaway ratio means the
        # prefill budget stopped making progress (e.g. admissions
        # starved), and a zero TTFT means the measurement went vacuous.
        # Same-machine ratio, so no normalization is needed.
        f_p99 = cand.get("cp_fused_ttft_p99_s", 0.0)
        l_p99 = cand.get("cp_legacy_ttft_p99_s", 0.0)
        if not (f_p99 > 0.0 and l_p99 > 0.0
                and cand.get("cp_fused_ttft_p50_s", 0.0) > 0.0
                and cand.get("cp_legacy_ttft_p50_s", 0.0) > 0.0):
            failures.append(
                "chunked-prefill TTFT telemetry vacuous: a percentile "
                f"is missing or zero (fused p99 {f_p99:.4f}s, legacy "
                f"p99 {l_p99:.4f}s)")
        elif f_p99 > 15.0 * l_p99:
            failures.append(
                "chunked-prefill TTFT p99 regressed: fused "
                f"{f_p99:.3f}s > 15x legacy {l_p99:.3f}s — streaming "
                "admissions stopped making prefill progress")
        print(f"chunked prefill: p99_ratio=x{ratio:.2f} "
              f"(legacy "
              f"{cand.get('cp_legacy_chunk_token_p99_ms', 0.0):.2f}ms "
              f"-> fused "
              f"{cand.get('cp_fused_chunk_token_p99_ms', 0.0):.2f}ms) "
              f"jitter={cand.get('cp_fused_jitter', 0.0):.2f}/"
              f"{cand.get('cp_legacy_jitter', 0.0):.2f} "
              f"ttft_p99={cand.get('cp_fused_ttft_p99_s', 0.0):.2f}s/"
              f"{cand.get('cp_legacy_ttft_p99_s', 0.0):.2f}s "
              f"match={cand.get('cp_outputs_match')} "
              f"gather_free={cand.get('cp_fused_gather_free')}")
    elif "cp_decode_latency_p99_ratio" in base:
        failures.append("candidate run dropped the chunked-prefill "
                        "workload (cp_* fields missing)")

    # ---- quantized-pool gates (int8 KV page pool workload, same run).
    # 8-bit pools must be invisible in the tokens of a decision-
    # confident (chain-overfit) model, pay for themselves in capacity
    # at equal HBM bytes, and survive the fault paths (preemption
    # resume, CoW sharing) without leaking pages or precision.
    if "qp_greedy_match" in cand:
        _require(cand, failures, "quantized-pool", [
            "qp_kv_dtype", "qp_max_logit_err",
            "qp_fp32_pool_bytes", "qp_quant_pool_bytes",
            "qp_equal_bytes_slot_ratio", "qp_equal_bytes_peak_live_slots",
            "qp_equal_bytes_slots", "qp_preemptions",
            "qp_preempt_outputs_match", "qp_preempt_leaked_pages",
            "qp_cow_outputs_match", "qp_prefix_hits",
            "qp_decode_sync_free", "qp_decode_compiles",
            "qp_pool_bytes_per_live_token", "qp_peak_live_slots"])
        if cand.get("qp_kv_dtype") == "fp32":
            failures.append(
                "quantized-pool workload ran on fp32 pools — the 8-bit "
                "path silently fell back (kv_dtype probe regressed)")
        if not cand.get("qp_greedy_match", 0.0) >= 0.99:
            failures.append(
                "quantized-pool greedy parity < 0.99 vs fp32 pools on "
                "the chain-overfit model "
                f"({cand.get('qp_greedy_match', 0.0):.4f} over "
                f"{cand.get('qp_total_positions')} positions) — dequant "
                "noise is eating real decision margins")
        if not cand.get("qp_max_logit_err", 1e9) <= 0.25:
            failures.append(
                "quantized-pool teacher-forced max logit error > 0.25 "
                f"({cand.get('qp_max_logit_err', 0.0):.4f}) — the 8-bit "
                "pool's precision loss grew beyond quantization noise")
        if cand.get("qp_quant_pool_bytes", 0) \
                > cand.get("qp_fp32_pool_bytes", 0):
            failures.append(
                "quantized pool used MORE page-pool bytes than the fp32 "
                f"baseline ({cand.get('qp_quant_pool_bytes')} > "
                f"{cand.get('qp_fp32_pool_bytes')}) — the equal-HBM "
                "capacity claim is vacuous")
        slot_ratio = cand.get("qp_equal_bytes_slot_ratio", 0.0)
        if not slot_ratio >= 1.8:
            failures.append(
                "quantized pool concurrent-slot ratio < 1.8x at equal "
                f"HBM bytes (x{slot_ratio:.2f})")
        if cand.get("qp_equal_bytes_peak_live_slots", 0) \
                != cand.get("qp_equal_bytes_slots", -1):
            failures.append(
                "quantized equal-bytes engine never reached full slot "
                "occupancy (peak "
                f"{cand.get('qp_equal_bytes_peak_live_slots')} of "
                f"{cand.get('qp_equal_bytes_slots')}) — the capacity "
                "ratio was not demonstrated concurrently")
        if not cand.get("qp_preemptions", 0) >= 1:
            failures.append(
                "quantized-pool preemption run inert: the oversubscribed "
                "int8 pool produced no preemptions")
        if not cand.get("qp_preempt_outputs_match", False):
            failures.append(
                "quantized-pool preemption-resume outputs diverged from "
                "the calm int8 run at temperature 0")
        if cand.get("qp_preempt_leaked_pages", 0) != 0:
            failures.append(
                "quantized-pool preemption run leaked pages "
                f"({cand.get('qp_preempt_leaked_pages')})")
        if not cand.get("qp_cow_outputs_match", False):
            failures.append(
                "quantized-pool CoW/prefix-sharing outputs diverged from "
                "exclusive ownership — shared-page scale rows are not "
                "copied with their pages")
        if not cand.get("qp_prefix_hits", 0) >= 1:
            failures.append(
                "quantized-pool CoW parity vacuous: the sharing engine "
                "recorded no prefix hits")
        if not cand.get("qp_decode_sync_free", True):
            failures.append("quantized-pool decode chunk performed a "
                            "device->host transfer")
        if cand.get("qp_decode_compiles", 1) != 1:
            failures.append(
                "quantized-pool workload retraced the decode chunk "
                f"({cand.get('qp_decode_compiles')} compiles) — 8-bit "
                "pools must reuse the one executable")
        print(f"quantized pool [{cand.get('qp_kv_dtype')}]: "
              f"greedy_match={cand.get('qp_greedy_match', 0.0):.4f} "
              f"logit_err={cand.get('qp_max_logit_err', 0.0):.4f} "
              f"slots x{cand.get('qp_equal_bytes_slot_ratio', 0.0):.1f} "
              f"({cand.get('qp_quant_pool_bytes')}B <= "
              f"{cand.get('qp_fp32_pool_bytes')}B) "
              f"preempt={cand.get('qp_preemptions')} "
              f"cow_match={cand.get('qp_cow_outputs_match')} "
              f"leaked={cand.get('qp_preempt_leaked_pages')}")
    elif "qp_greedy_match" in base:
        failures.append("candidate run dropped the quantized-pool "
                        "workload (qp_* fields missing)")

    # ---- SLO-scheduling gates (fig04 --slo-mix workload merged into the
    # same run).  The least-slack policy must actually buy interactive
    # latency on the oversubscribed mixed-class trace — strictly better
    # p99 TTFT than FIFO and no goodput regression — while staying
    # invisible in the tokens and structurally clean (deterministic
    # trace, zero leaks, one sync-free decode executable).
    if "slo_goodput" in cand:
        _require(cand, failures, "slo-scheduling", [
            "slo_outputs_match", "slo_trace_deterministic",
            "slo_interactive_ttft_p99", "slo_fifo_interactive_ttft_p99",
            "slo_fifo_goodput", "slo_leaked_pages",
            "slo_fifo_leaked_pages", "slo_decode_sync_free",
            "slo_decode_compiles", "slo_budget_throttles",
            "slo_pool_bytes_per_live_token", "slo_peak_live_slots"])
        if not cand.get("slo_outputs_match", False):
            failures.append(
                "slo-scheduling token parity regressed: the SLO policy "
                "changed request outputs vs FIFO on the same trace at "
                "temperature 0 — policy must only reorder, never rewrite")
        if not cand.get("slo_trace_deterministic", False):
            failures.append(
                "traffic trace not deterministic: two generators with "
                "the same seed produced different traces")
        slo_p99 = cand.get("slo_interactive_ttft_p99")
        fifo_p99 = cand.get("slo_fifo_interactive_ttft_p99")
        if slo_p99 is None or fifo_p99 is None:
            failures.append(
                "slo-scheduling interactive TTFT percentiles vacuous "
                f"(slo {slo_p99}, fifo {fifo_p99}) — no interactive "
                "request ever produced a first token")
        elif not slo_p99 < fifo_p99:
            failures.append(
                "SLO policy no longer beats FIFO on interactive p99 TTFT "
                f"({slo_p99} vs fifo {fifo_p99}) on the oversubscribed "
                "mixed-class trace")
        if cand.get("slo_goodput", 0.0) < cand.get("slo_fifo_goodput", 1.0):
            failures.append(
                "SLO policy goodput fell below FIFO "
                f"({cand.get('slo_goodput')} < "
                f"{cand.get('slo_fifo_goodput')}) — slack ordering is "
                "costing more SLOs than it saves")
        if cand.get("slo_leaked_pages", 0) != 0 \
                or cand.get("slo_fifo_leaked_pages", 0) != 0:
            failures.append(
                "slo-scheduling run leaked pages at drain (slo "
                f"{cand.get('slo_leaked_pages')}, fifo "
                f"{cand.get('slo_fifo_leaked_pages')})")
        if not cand.get("slo_decode_sync_free", True):
            failures.append("slo-scheduling decode chunk performed a "
                            "device->host transfer — policy must stay at "
                            "chunk boundaries")
        if cand.get("slo_decode_compiles", 1) != 1:
            failures.append(
                "slo-scheduling workload retraced the decode chunk "
                f"({cand.get('slo_decode_compiles')} compiles) — dynamic "
                "prefill budgets must be data, not shape")
        print(f"slo scheduling: interactive_ttft_p99="
              f"{cand.get('slo_interactive_ttft_p99')} vs fifo "
              f"{cand.get('slo_fifo_interactive_ttft_p99')} "
              f"(x{cand.get('slo_interactive_ttft_improvement', 0.0):.2f}) "
              f"goodput={cand.get('slo_goodput')}/"
              f"{cand.get('slo_fifo_goodput')} "
              f"throttles={cand.get('slo_budget_throttles')} "
              f"match={cand.get('slo_outputs_match')} "
              f"leaked={cand.get('slo_leaked_pages')}")
    elif "slo_goodput" in base:
        failures.append("candidate run dropped the slo-scheduling "
                        "workload (slo_* fields missing)")

    # ---- tracing-overhead gates (traced twin of the fig14 baseline
    # workload, same run).  Observability must be near-free: the traced
    # engine runs the same workload on the same machine in the same
    # process, so the ratio needs no normalization — and tracing must
    # not perturb the structural invariants it exists to observe.
    if "trace_tokens_per_s" in cand:
        _require(cand, failures, "tracing", [
            "trace_overhead_ratio", "trace_decode_sync_free",
            "trace_decode_compiles", "trace_events", "trace_dropped",
            "trace_schema_valid", "trace_complete_chains"])
        ratio = cand.get("trace_overhead_ratio", 0.0)
        if not ratio >= 0.95:
            failures.append(
                "tracing overhead > 5%: traced tokens/sec fell to "
                f"x{ratio:.3f} of the untraced engine "
                f"({cand.get('trace_tokens_per_s', 0.0):.0f} vs "
                f"{cand.get('new_tokens_per_s', 0.0):.0f}) — lifecycle "
                "events must stay host-side at chunk boundaries")
        if not cand.get("trace_decode_sync_free", True):
            failures.append(
                "traced decode chunk performed a device->host transfer "
                "— tracing added a sync to the fused executable")
        if cand.get("trace_decode_compiles", 1) != 1:
            failures.append(
                "traced workload retraced the decode chunk "
                f"({cand.get('trace_decode_compiles')} compiles) — "
                "tracing must not change traced shapes")
        if not cand.get("trace_events", 0) > 0:
            failures.append(
                "tracing vacuous: the traced workload recorded no "
                "lifecycle events")
        if cand.get("trace_dropped", 0) != 0:
            failures.append(
                "tracer ring dropped events on a workload sized to fit "
                f"it ({cand.get('trace_dropped')} dropped)")
        if not cand.get("trace_schema_valid", False):
            failures.append(
                "exported trace failed Chrome/Perfetto schema "
                "validation (benchmarks/check_trace)")
        if not cand.get("trace_complete_chains", False):
            failures.append(
                "trace lifecycle chains incomplete: a terminal request "
                "is missing its submit->terminal flow chain")
        print(f"tracing: overhead x{ratio:.3f} "
              f"({cand.get('trace_tokens_per_s', 0.0):.0f} tok/s) "
              f"events={cand.get('trace_events')} "
              f"dropped={cand.get('trace_dropped')} "
              f"schema_valid={cand.get('trace_schema_valid')} "
              f"chains={cand.get('trace_complete_chains')}")
    elif "trace_tokens_per_s" in base:
        failures.append("candidate run dropped the tracing-overhead "
                        "workload (trace_* fields missing)")

    # ---- trace-report gates (fig04 --trace-report merged into the same
    # run).  The replayed VirtualClock trace is the determinism anchor:
    # byte-identical fingerprints across runs, a schema-valid timeline,
    # real preemption pressure, and non-vacuous per-class phase
    # attribution.
    if "trep_events" in cand:
        phase_keys = [f"trep_{c}_{p}_s"
                      for c in ("interactive", "batch", "best_effort")
                      for p in ("queued", "running", "requeued")]
        preempt_keys = [f"trep_{c}_preemptions"
                        for c in ("interactive", "batch", "best_effort")]
        _require(cand, failures, "trace-report", [
            "trep_requests", "trep_dropped",
            "trep_fingerprint_deterministic", "trep_schema_valid",
            "trep_preemptions", "trep_explain_ok",
            *phase_keys, *preempt_keys])
        if not cand.get("trep_fingerprint_deterministic", False):
            failures.append(
                "trace-report fingerprint not deterministic: two "
                "VirtualClock replays of the same seeded trace produced "
                "different event streams")
        if not cand.get("trep_schema_valid", False):
            failures.append(
                "trace-report timeline failed Chrome/Perfetto schema "
                "validation (benchmarks/check_trace)")
        if cand.get("trep_dropped", 0) != 0:
            failures.append(
                "trace-report tracer ring dropped events "
                f"({cand.get('trep_dropped')})")
        if not cand.get("trep_preemptions", 0) >= 1:
            failures.append(
                "trace-report workload inert: the oversubscribed "
                "mixed-class trace produced no preemptions")
        busy = sum(cand.get(k, 0.0) or 0.0 for k in phase_keys)
        if not busy > 0.0:
            failures.append(
                "trace-report phase attribution vacuous: zero total "
                "queued/running/requeued seconds across all classes")
        if not cand.get("trep_explain_ok", False):
            failures.append(
                "Engine.explain stopped rendering causal chains (phase "
                "durations / terminal status missing from the text)")
        print(f"trace report: events={cand.get('trep_events')} "
              f"requests={cand.get('trep_requests')} "
              f"deterministic={cand.get('trep_fingerprint_deterministic')} "
              f"preemptions={cand.get('trep_preemptions')} "
              f"interactive_queued_s="
              f"{cand.get('trep_interactive_queued_s', 0.0):.3f} "
              f"explain_ok={cand.get('trep_explain_ok')}")
    elif "trep_events" in base:
        failures.append("candidate run dropped the trace-report "
                        "workload (trep_* fields missing)")

    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print("serve bench OK: sync-free, single decode + admission "
          "executables, tokens/sec within threshold, prefix sharing "
          "correct, paged-kernel decode gather-free and token-identical, "
          "speculative decode token-identical and >= 1.2x, "
          "fault tolerance preempts/resumes token-identically with "
          "goodput >= 0.8 and zero leaked pages, chunked prefill "
          "token-identical with >= 1.3x p99 decode-token latency under "
          "long-prompt arrivals, bounded TTFT, and zero prefill "
          "executables, quantized int8 pool token-parity >= 0.99 with "
          ">= 1.8x concurrent slots at equal HBM bytes and clean "
          "preemption/CoW fault paths, SLO policy beats FIFO on "
          "interactive p99 TTFT at token parity with goodput >= FIFO "
          "on a byte-identical seeded trace, tracing overhead <= 5% "
          "with a schema-valid deterministic Perfetto timeline and "
          "complete submit->terminal flow chains")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed fractional tokens/sec drop")
    ap.add_argument("--file", default="BENCH_serve.json")
    args = ap.parse_args()
    data = json.loads((REPO_ROOT / args.file).read_text())
    sys.exit(check(data.get("runs", []), args.threshold))


if __name__ == "__main__":
    main()
