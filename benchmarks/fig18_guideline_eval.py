"""Paper Fig. 18 + Table 2: the guideline vs TensorFlow / Intel recommended
settings vs the exhaustively-swept global optimum, across every assigned
architecture and shape (cost-model step times on the production mesh;
compiled-HLO validation for the hillclimbed cells lives in
EXPERIMENTS.md §Perf)."""

from benchmarks.common import emit
from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core import autotune, tuner


def main() -> None:
    gaps = []
    tf_sp, intel_sp = [], []
    tf_oom = intel_oom = 0
    for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
        shape = SHAPES[shape_name]
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            rows = autotune.compare_settings(cfg, shape)
            opt = rows["global_optimum"].step_s
            gl = rows["guideline"].step_s
            gaps.append(gl / opt)

            def score(r):
                """A setting that does not fit HBM is unusable (the paper's
                baselines never OOM'd; ours can at 100B+ scale)."""
                return r.step_s if r.fits else float("inf")

            tf = score(rows["tf_setting"])
            intel = score(rows["intel_setting"])
            if tf == float("inf"):
                tf_oom += 1
            else:
                tf_sp.append(tf / gl)
            if intel == float("inf"):
                intel_oom += 1
            else:
                intel_sp.append(intel / gl)
            emit(f"fig18.{shape_name}.{arch}", gl * 1e6,
                 f"vs_tf={'OOM' if tf == float('inf') else f'{tf / gl:.2f}x'},"
                 f"vs_intel={'OOM' if intel == float('inf') else f'{intel / gl:.2f}x'},"
                 f"pct_of_optimum={100 * opt / gl:.0f},"
                 f"pools={rows['guideline'].plan.pools}")
    n = len(gaps)
    emit("fig18.summary.geomean", 0.0,
         f"speedup_vs_tf={_geomean(tf_sp):.2f}x,"
         f"speedup_vs_intel={_geomean(intel_sp):.2f}x,"
         f"tf_oom_cells={tf_oom},intel_oom_cells={intel_oom},"
         f"worst_pct_of_optimum={100 / max(gaps):.0f},"
         f"cells={n}")


def _geomean(xs):
    p = 1.0
    for x in xs:
        p *= x
    return p ** (1 / len(xs))


if __name__ == "__main__":
    main()
