"""Shared benchmark helpers: timing, CSV output, JSON trajectories."""

from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict, List

import jax

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall seconds per call (post-warmup, block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times: List[float] = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def assert_clean_teardown(eng, requests=(), label: str = "workload") -> int:
    """End-of-workload invariants every gated fig14/fig04 serve workload
    must satisfy before its numbers enter the trajectory: zero leaked
    page references (``Engine.leaked_pages``), a drained admission
    queue, no slot still holding a live request, and every tracked
    request in a terminal status.  Returns the leak count (always 0 on
    success) so call sites can record it."""
    from repro.serve.scheduler import RequestStatus

    leaked = eng.leaked_pages()
    assert leaked == 0, f"{label}: {leaked} page refs leaked at teardown"
    assert not eng.queue, (
        f"{label}: {len(eng.queue)} requests still queued at teardown")
    live = [r.rid for r in eng._slot_req if r is not None]
    assert not live, f"{label}: slots still live at teardown: {live}"
    bad = [(r.rid, r.status) for r in requests
           if r.status not in RequestStatus.TERMINAL]
    assert not bad, f"{label}: non-terminal requests at teardown: {bad}"
    return leaked


def write_bench_json(filename: str, record: Dict) -> pathlib.Path:
    """Append ``record`` (stamped with wall time) to a repo-root trajectory
    file ``{"runs": [...]}`` so successive PRs accumulate a perf history."""
    path = REPO_ROOT / filename
    data = {"runs": []}
    if path.exists():
        loaded = None
        try:
            loaded = json.loads(path.read_text())
        except (ValueError, OSError):
            pass
        if isinstance(loaded, dict) and \
                isinstance(loaded.get("runs", []), list):
            data = loaded
        else:   # preserve the trajectory history, never clobber it
            bak = path.with_suffix(".corrupt")
            path.rename(bak)
            print(f"# {path.name} unreadable; preserved as {bak.name}")
    data.setdefault("runs", []).append({"ts": time.time(), **record})
    path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
    return path


def merge_into_last_run(filename: str, record: Dict) -> pathlib.Path:
    """Merge ``record`` into the LAST run of a trajectory file — for
    workloads that live in a separate benchmark module but belong to the
    same per-PR run (fig04 --slo-mix extends the fig14 serve record).
    Appends a fresh run if the file has none yet."""
    path = REPO_ROOT / filename
    data = {"runs": []}
    if path.exists():
        try:
            loaded = json.loads(path.read_text())
        except (ValueError, OSError):
            loaded = None
        if isinstance(loaded, dict) and \
                isinstance(loaded.get("runs", []), list):
            data = loaded
    if not data.get("runs"):
        return write_bench_json(filename, record)
    data["runs"][-1].update(record)
    path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
    return path
