"""Paper Fig. 6: performance heatmap over (inter-op pools x intra-op
threads).  Mesh analogue: step-time estimate over every (pools, intra)
factorization of the 16-wide model axis for dbrx-132b (the branch-rich
Inception analogue), train and prefill."""

from benchmarks.common import emit
from repro.configs import SHAPES, get_config
from repro.core import autotune, tuner


def main() -> None:
    cfg = get_config("dbrx-132b")
    for shape_name in ("train_4k", "prefill_32k"):
        shape = SHAPES[shape_name]
        best = None
        rows = []
        for pools in (1, 2, 4, 8, 16):
            plan = tuner.Plan(name=f"p{pools}", pools=pools,
                              intra=16 // pools, fsdp=True, seq_shard=False)
            r = autotune.evaluate(cfg, shape, plan)
            rows.append((pools, r))
            if r.fits and (best is None or r.step_s < best[1].step_s):
                best = (pools, r)
        for pools, r in rows:
            emit(f"fig06.dbrx.{shape_name}.p{pools}_i{16 // pools}",
                 r.step_s * 1e6,
                 f"dominant={r.cost.dominant},fits={r.fits},"
                 f"best={'*' if best and pools == best[0] else ''}")
        gl = tuner.guideline_plan(cfg, shape)
        emit(f"fig06.dbrx.{shape_name}.guideline_choice", 0.0,
             f"pools={gl.pools},matches_best={best is not None and gl.pools == best[0]}")


if __name__ == "__main__":
    main()
