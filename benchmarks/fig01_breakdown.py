"""Paper Fig. 1: time breakdown across the programming abstraction.

CPU-scale reproduction: one reduced-model train step decomposed into
trace (framework/python), compile (framework/XLA), and steady-state math,
plus the per-step python dispatch overhead — the 'programmability tax'
stack for a JAX framework."""

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_config, reduced
from repro.models import forward_train, model_defs
from repro.models import module as m


def main() -> None:
    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

    def step(p, b):
        loss, _ = forward_train(p, cfg, b)
        return loss

    t0 = time.perf_counter()
    lowered = jax.jit(step).lower(params, batch)
    t_trace = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    jax.block_until_ready(compiled(params, batch))
    t0 = time.perf_counter()
    iters = 20
    for _ in range(iters):
        out = compiled(params, batch)
    jax.block_until_ready(out)
    t_math = (time.perf_counter() - t0) / iters

    emit("fig01.trace_python", t_trace * 1e6, "one-time")
    emit("fig01.compile_xla", t_compile * 1e6, "one-time")
    emit("fig01.steady_step", t_math * 1e6,
         f"amortized_tax_pct_100steps="
         f"{100 * (t_trace + t_compile) / (t_trace + t_compile + 100 * t_math):.1f}")


if __name__ == "__main__":
    main()
