"""§Roofline report generator: aggregates results/dryrun/*.json into the
per-(arch x shape x mesh) three-term table (EXPERIMENTS.md §Roofline).

Raw terms use the assignment's formulas verbatim.  The adjusted collective
term halves f32 collective payloads: XLA:CPU's float normalization upcasts
every bf16 dot/convert to f32, so collectives that would move bf16 on a
real TPU move f32 in the CPU-lowered HLO (documented CPU-backend artifact,
EXPERIMENTS.md §Dry-run).
"""

import json
import sys
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_rows():
    rows = []
    for f in sorted(RESULTS.glob("*.json")):
        try:
            rows.append(json.load(open(f)))
        except Exception:
            pass
    return rows


def fmt_table(rows, mesh="single"):
    out = []
    hdr = (f"| arch | shape | plan | compute_s | memory_s | collective_s | "
           f"dominant | MODEL_FLOPS | useful | frac |")
    out.append(hdr)
    out.append("|" + "---|" * 10)
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh or r["setting"] not in ("guideline",):
            continue
        p = r["plan"]
        plan = f"p{p['pools']}i{p['intra']}" + ("f" if p["fsdp"] else "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {plan} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['dominant']} "
            f"| {r['model_flops_global']:.2e} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_frac']:.3f} |")
    return "\n".join(out)


def write_table(rows) -> None:
    out = Path(__file__).resolve().parents[1] / "results" / "roofline_table.md"
    lines = ["# §Roofline — per-cell three-term table (single-pod 16x16, "
             "guideline plan)", "",
             "terms in seconds/step; frac = ideal-compute / step estimate;",
             "adj_coll halves f32 collective payloads (CPU float-"
             "normalization artifact, EXPERIMENTS.md §Dry-run).", ""]
    hdr = ("| arch | shape | plan | compute_s | memory_s | collective_s | "
           "adj_coll_s | dominant | useful | frac | mem/dev GiB |")
    lines += [hdr, "|" + "---|" * 11]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["mesh"] != "single" or r["setting"] != "guideline":
            continue
        p_ = r["plan"]
        plan = f"p{p_['pools']}i{p_['intra']}" + ("f" if p_["fsdp"] else "")
        adj = r["collective_s"] * 0.55  # ~all f32 on this backend -> bf16
        lines.append(
            f"| {r['arch']} | {r['shape']} | {plan} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {adj:.3f} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} "
            f"| {r['memory_per_device_bytes'] / 2**30:.0f} |")
    lines += ["", "## multi-pod (2x16x16) cells", "", hdr, "|" + "---|" * 11]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "multi" or r["setting"] != "guideline":
            continue
        p_ = r["plan"]
        plan = f"p{p_['pools']}i{p_['intra']}" + ("f" if p_["fsdp"] else "")
        adj = r["collective_s"] * 0.55
        lines.append(
            f"| {r['arch']} | {r['shape']} | {plan} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {adj:.3f} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} "
            f"| {r['memory_per_device_bytes'] / 2**30:.0f} |")
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"# wrote {out}")


def main() -> None:
    rows = load_rows()
    print(f"# roofline rows: {len(rows)}")
    for r in sorted(rows, key=lambda r: (r["shape"], r["arch"], r["mesh"])):
        print(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}."
              f"{r['setting']},{r['step_s'] * 1e6:.1f},"
              f"dom={r['dominant']},frac={r['roofline_frac']:.3f},"
              f"useful={r['useful_ratio']:.2f},"
              f"mem_gib={r['memory_per_device_bytes'] / 2**30:.1f}")
    try:
        write_table(rows)
    except Exception as e:
        print(f"# table write failed: {e}")
    if "--markdown" in sys.argv:
        print()
        print(fmt_table(rows, "single"))


if __name__ == "__main__":
    main()
