"""Validate an ``Engine.export_trace`` JSON file against the Chrome
trace-event / Perfetto schema subset the serving tracer emits.

Checks (CI runs this on the ``launch/serve.py --trace`` smoke output,
and ``fig14`` runs :func:`validate` in-process on the traced baseline
workload):

* top-level shape: ``{"traceEvents": [...]}``;
* every event has a known ``ph``, integer ``pid``/``tid``, and a
  finite non-negative ``ts`` (metadata ``M`` events excepted);
* ``X`` complete events carry ``dur >= 0``;
* async ``b``/``e`` pairs (queue wait spans) are keyed by
  ``(cat, id)``, never close an unopened span, and all close by EOF;
* flow chains (``s``/``t``/``f`` keyed by ``(cat, id)``) start with
  exactly one ``s``, end with exactly one ``f`` (binding ``bp: "e"``),
  and run in non-decreasing ``ts`` order;
* request-lifecycle completeness: every rid with a terminal instant
  (``finish`` / ``reject``) also has a ``submit`` instant and a flow
  chain — the submit->terminal span chain the acceptance criteria
  gate.

Exit code 0 when the file passes, 1 with one line per failure when it
does not.
"""

import json
import math
import sys

#: Phases the serving exporter emits (trace.to_chrome_trace).
KNOWN_PH = frozenset("MXbeistfCi")

TERMINAL_NAMES = frozenset({"finish", "reject"})


def _num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(v)


def validate(obj) -> list:
    """All schema violations in ``obj`` (an ``export_trace`` result),
    as human-readable strings; empty means the trace is valid."""
    failures = []
    if not isinstance(obj, dict) \
            or not isinstance(obj.get("traceEvents"), list):
        return ["top level must be an object with a traceEvents list"]
    evs = obj["traceEvents"]
    if not evs:
        return ["traceEvents is empty"]

    async_depth = {}          # (cat, id) -> open b spans
    flows = {}                # (cat, id) -> [(ph, ts)] in file order
    submits, terminals = set(), {}

    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            failures.append(f"{where}: not an object")
            continue
        ph = e.get("ph")
        if ph not in KNOWN_PH:
            failures.append(f"{where}: unknown ph {ph!r}")
            continue
        if not isinstance(e.get("pid"), int) \
                or not isinstance(e.get("tid"), int):
            failures.append(f"{where}: ph={ph} needs integer pid and tid")
        if ph == "M":
            continue                      # metadata carries no ts
        if not _num(e.get("ts")) or e["ts"] < 0:
            failures.append(f"{where}: ph={ph} needs finite ts >= 0")
            continue
        if ph == "X" and (not _num(e.get("dur")) or e["dur"] < 0):
            failures.append(f"{where}: X event needs dur >= 0")
        if ph == "C" and not isinstance(e.get("args"), dict):
            failures.append(f"{where}: C event needs an args dict")
        if ph == "i" and e.get("s") not in ("g", "p", "t"):
            failures.append(f"{where}: i event needs scope s in g/p/t")
        if ph in "bestf":
            key = (e.get("cat"), e.get("id"))
            if key[0] is None or not isinstance(key[1], str):
                failures.append(
                    f"{where}: ph={ph} needs cat and string id")
                continue
            if ph == "b":
                async_depth[key] = async_depth.get(key, 0) + 1
            elif ph == "e":
                depth = async_depth.get(key, 0) - 1
                if depth < 0:
                    failures.append(
                        f"{where}: e closes unopened span {key}")
                async_depth[key] = max(depth, 0)
            else:                         # flow point
                if ph == "f" and e.get("bp") != "e":
                    failures.append(f"{where}: f event must bind bp='e'")
                flows.setdefault(key, []).append((ph, e["ts"]))
        if ph == "i":
            rid = (e.get("args") or {}).get("rid")
            if rid is not None:
                if e.get("name") == "submit":
                    submits.add(rid)
                elif e.get("name") in TERMINAL_NAMES:
                    terminals[rid] = e["name"]

    for key, depth in async_depth.items():
        if depth:
            failures.append(f"async span {key}: {depth} b without e")
    for key, points in flows.items():
        phs = [p for p, _ in points]
        if phs[0] != "s" or phs.count("s") != 1:
            failures.append(f"flow {key}: needs exactly one leading s")
        if phs[-1] != "f" or phs.count("f") != 1:
            failures.append(f"flow {key}: needs exactly one trailing f")
        ts = [t for _, t in points]
        if ts != sorted(ts):
            failures.append(f"flow {key}: ts not non-decreasing: {ts}")

    # flow ids are str(rid), or "rid#gen" when a benchmark harness
    # reused the rid across runs inside one tracer
    flow_rids = {fid.split("#", 1)[0]
                 for cat, fid in flows if cat == "lifecycle"}
    for rid, kind in sorted(terminals.items()):
        if rid not in submits:
            failures.append(
                f"rid {rid}: terminal {kind} without a submit instant")
        if str(rid) not in flow_rids:
            failures.append(
                f"rid {rid}: terminal {kind} without a lifecycle flow "
                "chain")
    return failures


def main(argv) -> int:
    if len(argv) != 2:
        print("usage: python -m benchmarks.check_trace TRACE.json")
        return 2
    try:
        obj = json.loads(open(argv[1]).read())
    except (OSError, ValueError) as exc:
        print(f"FAIL: cannot read {argv[1]}: {exc}")
        return 1
    failures = validate(obj)
    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        return 1
    evs = obj["traceEvents"]
    n_flow = sum(1 for e in evs if e.get("ph") == "f")
    print(f"check_trace: OK — {len(evs)} events, {n_flow} complete "
          "request flow chains")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
