"""Paper Fig. 4: speedup of asynchronous over synchronous scheduling.

Two measurements:
  * wall-clock on CPU for a reduced MoE layer: ``moe.apply`` (async-style
    dispatch) vs ``moe.apply_sync_schedule`` (one expert at a time);
  * production-mesh estimate from the cost model: pools=guideline vs pools=1
    for every arch (the Fig. 4 bar chart analogue).

``--slo-mix`` runs the serving-side half of the paper's scheduling
story instead (``slo_scheduling_comparison``): an oversubscribed
page pool under a seeded mixed-class Poisson trace
(``serve/traffic``), SLO least-slack policy vs FIFO on the SAME trace
and virtual clock.  Reports TTFT/TPOT p50/p99 per class + goodput
both ways and merges the ``slo_*`` record into the last
``BENCH_serve.json`` run (the fig14 run from the same CI job), where
``check_serve_regression.py`` gates it: interactive p99 TTFT strictly
better than FIFO, goodput >= FIFO, token parity across policies, a
byte-identical regenerated trace, zero leaked pages, one sync-free
decode executable.
"""

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, merge_into_last_run, time_fn
from repro.configs import ARCH_IDS, SHAPES, get_config, reduced
from repro.core import autotune, tuner
from repro.models import moe
from repro.models import module as m


def main() -> None:
    # --- wall clock, reduced scale
    cfg = reduced(get_config("dbrx-132b"), experts=8, d_model=128, d_ff=256)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, top_k=2))
    params = m.init_params(moe.moe_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 512, cfg.d_model))
    f_async = jax.jit(lambda p, v: moe.apply(p, v, cfg)[0])
    f_sync = jax.jit(lambda p, v: moe.apply_sync_schedule(p, v, cfg)[0])
    t_async = time_fn(f_async, params, x)
    t_sync = time_fn(f_sync, params, x)
    emit("fig04.moe_layer.async", t_async * 1e6,
         f"speedup_vs_sync={t_sync / t_async:.2f}x")
    emit("fig04.moe_layer.sync", t_sync * 1e6, "baseline")

    # --- production estimate per arch (train shape)
    shape = SHAPES["train_4k"]
    for arch in ARCH_IDS:
        acfg = get_config(arch)
        gl = tuner.guideline_plan(acfg, shape)
        sync = dataclasses.replace(gl, pools=1, intra=16, name="sync")
        t_gl = autotune.evaluate(acfg, shape, gl).step_s
        t_sync2 = autotune.evaluate(acfg, shape, sync).step_s
        emit(f"fig04.prod.{arch}", t_gl * 1e6,
             f"async_speedup={t_sync2 / t_gl:.2f}x,pools={gl.pools}")


def slo_scheduling_comparison(n_req: int = 24, seed: int = 11) -> dict:
    """SLO least-slack policy vs FIFO on one seeded mixed-class trace.

    The pool is oversubscribed two ways — 4 slots against 24 requests
    (queueing) and a 12-page budget below full-occupancy worst case
    (preemption pressure) — and both engines replay the SAME
    ``serve/traffic`` trace on the SAME virtual clock, so every latency
    number is a pure function of the schedule.  Interactive arrivals
    carry tight TTFT targets; under FIFO they wait behind earlier batch
    work, under the SLO policy they jump the admission queue and batch
    slots yield (class-aware victims + dynamic ``prefill_budget``
    throttling).  Gated keys (check_serve_regression): interactive p99
    TTFT strictly better than FIFO, goodput >= FIFO, token parity
    across policies, regenerated trace byte-identical, zero leaked
    pages both ways, ONE sync-free decode executable.  Batch-class
    percentiles are reported ungated — the price batch pays for
    yielding is part of the record."""
    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve import traffic
    from repro.serve.engine import Engine

    from benchmarks.fig14_dispatch_overhead import _pool_telemetry

    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    # rate >> service rate: the whole trace arrives within the first few
    # chunks, so a deep mixed-class queue forms and the two policies
    # drain it in genuinely different orders
    gen_kw = dict(rate=100.0, process="poisson",
                  class_mix={"interactive": 0.4, "batch": 0.4,
                             "best_effort": 0.2})
    trace = traffic.TrafficGenerator(seed, **gen_kw).generate(n_req)
    regen = traffic.TrafficGenerator(seed, **gen_kw).generate(n_req)
    trace_deterministic = (traffic.trace_fingerprint(trace)
                           == traffic.trace_fingerprint(regen))
    kw = dict(slots=4, max_len=64, page_size=8, num_pages=12,
              sync_interval=4, prefix_sharing=False, seed=0)

    def run(policy):
        clk = traffic.VirtualClock(dt=0.05)
        eng = Engine(cfg, params, policy=policy, clock=clk, **kw)
        eng.warmup()
        traffic.replay(eng, trace, clock=clk)
        ls = eng.latency_stats()
        toks = {r.rid: list(r.out_tokens) for r in eng.finished}
        return eng, ls, toks

    fifo, ls_fifo, toks_fifo = run("fifo")
    slo, ls_slo, toks_slo = run("slo")

    def cls(ls, name, key):
        c = ls["classes"].get(name)
        return c[key] if c else None

    rec = {
        "slo_requests": n_req,
        "slo_trace_seed": seed,
        "slo_trace_deterministic": trace_deterministic,
        "slo_num_pages": kw["num_pages"],
        "slo_outputs_match": toks_slo == toks_fifo,
        "slo_goodput": ls_slo["goodput"],
        "slo_fifo_goodput": ls_fifo["goodput"],
        "slo_interactive_ttft_p50": cls(ls_slo, "interactive", "ttft_p50"),
        "slo_interactive_ttft_p99": cls(ls_slo, "interactive", "ttft_p99"),
        "slo_fifo_interactive_ttft_p50":
            cls(ls_fifo, "interactive", "ttft_p50"),
        "slo_fifo_interactive_ttft_p99":
            cls(ls_fifo, "interactive", "ttft_p99"),
        "slo_interactive_tpot_p99": cls(ls_slo, "interactive", "tpot_p99"),
        "slo_fifo_interactive_tpot_p99":
            cls(ls_fifo, "interactive", "tpot_p99"),
        "slo_interactive_goodput": cls(ls_slo, "interactive", "goodput"),
        "slo_fifo_interactive_goodput":
            cls(ls_fifo, "interactive", "goodput"),
        "slo_batch_ttft_p99": cls(ls_slo, "batch", "ttft_p99"),
        "slo_fifo_batch_ttft_p99": cls(ls_fifo, "batch", "ttft_p99"),
        "slo_batch_goodput": cls(ls_slo, "batch", "goodput"),
        "slo_budget_throttles": ls_slo["budget_throttles"],
        "slo_preemptions": slo.fault_stats()["preemptions"],
        "slo_leaked_pages": slo.leaked_pages(),
        "slo_fifo_leaked_pages": fifo.leaked_pages(),
        "slo_decode_compiles": slo.decode_compiles,
    }
    rec["slo_interactive_ttft_improvement"] = (
        rec["slo_fifo_interactive_ttft_p99"]
        / rec["slo_interactive_ttft_p99"]
        if rec["slo_interactive_ttft_p99"] else float("inf"))

    sync_free = True
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            toks = slo.step_chunk()
    except Exception as e:  # noqa: BLE001 - classify, don't swallow
        if "transfer" not in str(e).lower():
            raise
        sync_free = False
    else:
        slo._drain(toks)
    rec["slo_decode_sync_free"] = sync_free
    rec.update(_pool_telemetry(slo, "slo_"))

    emit("fig04.slo_interactive_ttft_p99",
         rec["slo_interactive_ttft_p99"],
         f"fifo={rec['slo_fifo_interactive_ttft_p99']},"
         f"improvement={rec['slo_interactive_ttft_improvement']:.2f}x,"
         f"match={rec['slo_outputs_match']}")
    emit("fig04.slo_goodput", rec["slo_goodput"],
         f"fifo={rec['slo_fifo_goodput']:.3f},"
         f"throttles={rec['slo_budget_throttles']},"
         f"preemptions={rec['slo_preemptions']},"
         f"leaked={rec['slo_leaked_pages']}")
    return rec


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--slo-mix", action="store_true",
                    help="run the SLO-vs-FIFO serving workload and merge "
                         "its slo_* record into the last BENCH_serve.json "
                         "run instead of the MoE/cost-model figures")
    args, _ = ap.parse_known_args()
    if args.slo_mix:
        path = merge_into_last_run("BENCH_serve.json",
                                   slo_scheduling_comparison())
        print(f"# slo workload merged into {path}", flush=True)
    else:
        main()
