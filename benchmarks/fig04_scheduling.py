"""Paper Fig. 4: speedup of asynchronous over synchronous scheduling.

Two measurements:
  * wall-clock on CPU for a reduced MoE layer: ``moe.apply`` (async-style
    dispatch) vs ``moe.apply_sync_schedule`` (one expert at a time);
  * production-mesh estimate from the cost model: pools=guideline vs pools=1
    for every arch (the Fig. 4 bar chart analogue).

``--slo-mix`` runs the serving-side half of the paper's scheduling
story instead (``slo_scheduling_comparison``): an oversubscribed
page pool under a seeded mixed-class Poisson trace
(``serve/traffic``), SLO least-slack policy vs FIFO on the SAME trace
and virtual clock.  Reports TTFT/TPOT p50/p99 per class + goodput
both ways and merges the ``slo_*`` record into the last
``BENCH_serve.json`` run (the fig14 run from the same CI job), where
``check_serve_regression.py`` gates it: interactive p99 TTFT strictly
better than FIFO, goodput >= FIFO, token parity across policies, a
byte-identical regenerated trace, zero leaked pages, one sync-free
decode executable.
"""

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import (assert_clean_teardown, emit,
                               merge_into_last_run, time_fn)
from repro.configs import ARCH_IDS, SHAPES, get_config, reduced
from repro.core import autotune, tuner
from repro.models import moe
from repro.models import module as m


def main() -> None:
    # --- wall clock, reduced scale
    cfg = reduced(get_config("dbrx-132b"), experts=8, d_model=128, d_ff=256)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, top_k=2))
    params = m.init_params(moe.moe_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 512, cfg.d_model))
    f_async = jax.jit(lambda p, v: moe.apply(p, v, cfg)[0])
    f_sync = jax.jit(lambda p, v: moe.apply_sync_schedule(p, v, cfg)[0])
    t_async = time_fn(f_async, params, x)
    t_sync = time_fn(f_sync, params, x)
    emit("fig04.moe_layer.async", t_async * 1e6,
         f"speedup_vs_sync={t_sync / t_async:.2f}x")
    emit("fig04.moe_layer.sync", t_sync * 1e6, "baseline")

    # --- production estimate per arch (train shape)
    shape = SHAPES["train_4k"]
    for arch in ARCH_IDS:
        acfg = get_config(arch)
        gl = tuner.guideline_plan(acfg, shape)
        sync = dataclasses.replace(gl, pools=1, intra=16, name="sync")
        t_gl = autotune.evaluate(acfg, shape, gl).step_s
        t_sync2 = autotune.evaluate(acfg, shape, sync).step_s
        emit(f"fig04.prod.{arch}", t_gl * 1e6,
             f"async_speedup={t_sync2 / t_gl:.2f}x,pools={gl.pools}")


def slo_scheduling_comparison(n_req: int = 24, seed: int = 11) -> dict:
    """SLO least-slack policy vs FIFO on one seeded mixed-class trace.

    The pool is oversubscribed two ways — 4 slots against 24 requests
    (queueing) and a 12-page budget below full-occupancy worst case
    (preemption pressure) — and both engines replay the SAME
    ``serve/traffic`` trace on the SAME virtual clock, so every latency
    number is a pure function of the schedule.  Interactive arrivals
    carry tight TTFT targets; under FIFO they wait behind earlier batch
    work, under the SLO policy they jump the admission queue and batch
    slots yield (class-aware victims + dynamic ``prefill_budget``
    throttling).  Gated keys (check_serve_regression): interactive p99
    TTFT strictly better than FIFO, goodput >= FIFO, token parity
    across policies, regenerated trace byte-identical, zero leaked
    pages both ways, ONE sync-free decode executable.  Batch-class
    percentiles are reported ungated — the price batch pays for
    yielding is part of the record."""
    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve import traffic
    from repro.serve.engine import Engine

    from benchmarks.fig14_dispatch_overhead import _pool_telemetry

    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    # rate >> service rate: the whole trace arrives within the first few
    # chunks, so a deep mixed-class queue forms and the two policies
    # drain it in genuinely different orders
    gen_kw = dict(rate=100.0, process="poisson",
                  class_mix={"interactive": 0.4, "batch": 0.4,
                             "best_effort": 0.2})
    trace = traffic.TrafficGenerator(seed, **gen_kw).generate(n_req)
    regen = traffic.TrafficGenerator(seed, **gen_kw).generate(n_req)
    trace_deterministic = (traffic.trace_fingerprint(trace)
                           == traffic.trace_fingerprint(regen))
    kw = dict(slots=4, max_len=64, page_size=8, num_pages=12,
              sync_interval=4, prefix_sharing=False, seed=0)

    def run(policy):
        clk = traffic.VirtualClock(dt=0.05)
        eng = Engine(cfg, params, policy=policy, clock=clk, **kw)
        eng.warmup()
        traffic.replay(eng, trace, clock=clk)
        ls = eng.latency_stats()
        toks = {r.rid: list(r.out_tokens) for r in eng.finished}
        return eng, ls, toks

    fifo, ls_fifo, toks_fifo = run("fifo")
    slo, ls_slo, toks_slo = run("slo")
    fifo_reqs, slo_reqs = list(fifo.finished), list(slo.finished)

    def cls(ls, name, key):
        c = ls["classes"].get(name)
        return c[key] if c else None

    rec = {
        "slo_requests": n_req,
        "slo_trace_seed": seed,
        "slo_trace_deterministic": trace_deterministic,
        "slo_num_pages": kw["num_pages"],
        "slo_outputs_match": toks_slo == toks_fifo,
        "slo_goodput": ls_slo["goodput"],
        "slo_fifo_goodput": ls_fifo["goodput"],
        "slo_interactive_ttft_p50": cls(ls_slo, "interactive", "ttft_p50"),
        "slo_interactive_ttft_p99": cls(ls_slo, "interactive", "ttft_p99"),
        "slo_fifo_interactive_ttft_p50":
            cls(ls_fifo, "interactive", "ttft_p50"),
        "slo_fifo_interactive_ttft_p99":
            cls(ls_fifo, "interactive", "ttft_p99"),
        "slo_interactive_tpot_p99": cls(ls_slo, "interactive", "tpot_p99"),
        "slo_fifo_interactive_tpot_p99":
            cls(ls_fifo, "interactive", "tpot_p99"),
        "slo_interactive_goodput": cls(ls_slo, "interactive", "goodput"),
        "slo_fifo_interactive_goodput":
            cls(ls_fifo, "interactive", "goodput"),
        "slo_batch_ttft_p99": cls(ls_slo, "batch", "ttft_p99"),
        "slo_fifo_batch_ttft_p99": cls(ls_fifo, "batch", "ttft_p99"),
        "slo_batch_goodput": cls(ls_slo, "batch", "goodput"),
        "slo_budget_throttles": ls_slo["budget_throttles"],
        "slo_preemptions": slo.fault_stats()["preemptions"],
        "slo_leaked_pages": slo.leaked_pages(),
        "slo_fifo_leaked_pages": fifo.leaked_pages(),
        "slo_decode_compiles": slo.decode_compiles,
    }
    rec["slo_interactive_ttft_improvement"] = (
        rec["slo_fifo_interactive_ttft_p99"]
        / rec["slo_interactive_ttft_p99"]
        if rec["slo_interactive_ttft_p99"] else float("inf"))

    sync_free = True
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            toks = slo.step_chunk()
    except Exception as e:  # noqa: BLE001 - classify, don't swallow
        if "transfer" not in str(e).lower():
            raise
        sync_free = False
    else:
        slo._drain(toks)
    rec["slo_decode_sync_free"] = sync_free
    rec.update(_pool_telemetry(slo, "slo_"))
    assert_clean_teardown(fifo, fifo_reqs, label="slo_mix_fifo")
    assert_clean_teardown(slo, slo_reqs, label="slo_mix_slo")

    emit("fig04.slo_interactive_ttft_p99",
         rec["slo_interactive_ttft_p99"],
         f"fifo={rec['slo_fifo_interactive_ttft_p99']},"
         f"improvement={rec['slo_interactive_ttft_improvement']:.2f}x,"
         f"match={rec['slo_outputs_match']}")
    emit("fig04.slo_goodput", rec["slo_goodput"],
         f"fifo={rec['slo_fifo_goodput']:.3f},"
         f"throttles={rec['slo_budget_throttles']},"
         f"preemptions={rec['slo_preemptions']},"
         f"leaked={rec['slo_leaked_pages']}")
    return rec


def trace_report(n_req: int = 24, seed: int = 11) -> dict:
    """``--trace-report``: lifecycle-trace diagnostics on the SLO mix.

    Replays the same seeded mixed-class trace as ``--slo-mix`` on a
    **traced** SLO engine under a ``VirtualClock`` and renders the
    tracer's view into gated ``trep_*`` keys: per-class phase-time
    breakdown (seconds queued / running / requeued, summed over
    requests — the "where did my TTFT go" answer), the preemption
    timeline length, Chrome-trace schema validity
    (``benchmarks/check_trace.validate`` on ``Engine.export_trace``),
    and byte-determinism of the trace fingerprint across two replays
    (virtual timestamps flow into the events, so a replayed experiment
    reproduces its trace exactly).  Gated by check_serve_regression:
    schema valid, deterministic fingerprint, zero dropped events,
    >= 1 preemption observed in the trace, all phase totals present."""
    from benchmarks.check_trace import validate as validate_trace
    from benchmarks.fig14_dispatch_overhead import _pool_telemetry
    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve import traffic
    from repro.serve.engine import Engine
    from repro.serve.trace import _lifecycle_phases

    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    gen_kw = dict(rate=100.0, process="poisson",
                  class_mix={"interactive": 0.4, "batch": 0.4,
                             "best_effort": 0.2})
    trace = traffic.TrafficGenerator(seed, **gen_kw).generate(n_req)
    kw = dict(slots=4, max_len=64, page_size=8, num_pages=12,
              sync_interval=4, prefix_sharing=False, seed=0)

    def run_traced():
        clk = traffic.VirtualClock(dt=0.05)
        eng = Engine(cfg, params, policy="slo", clock=clk, trace=True,
                     **kw)
        eng.warmup()
        traffic.replay(eng, trace, clock=clk)
        return eng

    eng = run_traced()
    reqs = list(eng.finished)
    fp = eng.tracer.fingerprint()
    deterministic = fp == run_traced().tracer.fingerprint()

    evs = eng.tracer.events()
    cls_of = {e.rid: e.attrs.get("slo_class", "best_effort")
              for e in evs if e.kind == "submit"}
    by_rid = {}
    for e in evs:
        if e.rid is not None:
            by_rid.setdefault(e.rid, []).append(e)
    phase_s = {}                    # (class, phase) -> summed seconds
    for rid, revs in by_rid.items():
        for name, a, b, _slot in _lifecycle_phases(revs):
            end = revs[-1].ts if b is None else b
            key = (cls_of.get(rid, "best_effort"), name)
            phase_s[key] = phase_s.get(key, 0.0) + (end - a)
    preempts = [e for e in evs if e.kind == "preempt"]
    preempt_by_cls = {}
    for e in preempts:
        c = cls_of.get(e.rid, "best_effort")
        preempt_by_cls[c] = preempt_by_cls.get(c, 0) + 1

    failures = validate_trace(eng.export_trace())
    for f in failures:
        print(f"# trace schema failure: {f}")
    # a preempted request's explain must render its full causal chain
    sample = preempts[0].rid if preempts else reqs[0].rid
    txt = eng.explain(sample)
    explain_ok = "phase durations:" in txt and "terminal:" in txt

    rec = {
        "trep_requests": n_req,
        "trep_trace_seed": seed,
        "trep_events": len(eng.tracer),
        "trep_dropped": eng.tracer.dropped,
        "trep_fingerprint_deterministic": deterministic,
        "trep_schema_valid": not failures,
        "trep_preemptions": len(preempts),
        "trep_explain_ok": explain_ok,
    }
    for c in ("interactive", "batch", "best_effort"):
        for phase in ("queued", "running", "requeued"):
            rec[f"trep_{c}_{phase}_s"] = phase_s.get((c, phase), 0.0)
        rec[f"trep_{c}_preemptions"] = preempt_by_cls.get(c, 0)
    rec.update(_pool_telemetry(eng, "trep_"))
    assert_clean_teardown(eng, reqs, label="trace_report")

    emit("fig04.trep_schema_valid", float(rec["trep_schema_valid"]),
         f"events={rec['trep_events']},dropped={rec['trep_dropped']},"
         f"deterministic={deterministic}")
    emit("fig04.trep_interactive_queued_s",
         rec["trep_interactive_queued_s"],
         f"running={rec['trep_interactive_running_s']:.3f}s,"
         f"batch_queued={rec['trep_batch_queued_s']:.3f}s,"
         f"preempts={rec['trep_preemptions']}")
    return rec


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--slo-mix", action="store_true",
                    help="run the SLO-vs-FIFO serving workload and merge "
                         "its slo_* record into the last BENCH_serve.json "
                         "run instead of the MoE/cost-model figures")
    ap.add_argument("--trace-report", action="store_true",
                    help="replay the SLO mix on a traced engine and merge "
                         "trep_* lifecycle diagnostics (per-class phase "
                         "times, preemption timeline, schema validity, "
                         "fingerprint determinism) into the last "
                         "BENCH_serve.json run")
    args, _ = ap.parse_known_args()
    if args.slo_mix:
        path = merge_into_last_run("BENCH_serve.json",
                                   slo_scheduling_comparison())
        print(f"# slo workload merged into {path}", flush=True)
    if args.trace_report:
        path = merge_into_last_run("BENCH_serve.json", trace_report())
        print(f"# trace report merged into {path}", flush=True)
    if not (args.slo_mix or args.trace_report):
        main()
