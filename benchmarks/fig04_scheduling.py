"""Paper Fig. 4: speedup of asynchronous over synchronous scheduling.

Two measurements:
  * wall-clock on CPU for a reduced MoE layer: ``moe.apply`` (async-style
    dispatch) vs ``moe.apply_sync_schedule`` (one expert at a time);
  * production-mesh estimate from the cost model: pools=guideline vs pools=1
    for every arch (the Fig. 4 bar chart analogue).
"""

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import ARCH_IDS, SHAPES, get_config, reduced
from repro.core import autotune, tuner
from repro.models import moe
from repro.models import module as m


def main() -> None:
    # --- wall clock, reduced scale
    cfg = reduced(get_config("dbrx-132b"), experts=8, d_model=128, d_ff=256)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, top_k=2))
    params = m.init_params(moe.moe_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 512, cfg.d_model))
    f_async = jax.jit(lambda p, v: moe.apply(p, v, cfg)[0])
    f_sync = jax.jit(lambda p, v: moe.apply_sync_schedule(p, v, cfg)[0])
    t_async = time_fn(f_async, params, x)
    t_sync = time_fn(f_sync, params, x)
    emit("fig04.moe_layer.async", t_async * 1e6,
         f"speedup_vs_sync={t_sync / t_async:.2f}x")
    emit("fig04.moe_layer.sync", t_sync * 1e6, "baseline")

    # --- production estimate per arch (train shape)
    shape = SHAPES["train_4k"]
    for arch in ARCH_IDS:
        acfg = get_config(arch)
        gl = tuner.guideline_plan(acfg, shape)
        sync = dataclasses.replace(gl, pools=1, intra=16, name="sync")
        t_gl = autotune.evaluate(acfg, shape, gl).step_s
        t_sync2 = autotune.evaluate(acfg, shape, sync).step_s
        emit(f"fig04.prod.{arch}", t_gl * 1e6,
             f"async_speedup={t_sync2 / t_gl:.2f}x,pools={gl.pools}")


if __name__ == "__main__":
    main()
