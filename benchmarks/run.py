"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig04 fig18

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""

import sys
import time
import traceback

MODULES = [
    "fig01_breakdown",
    "fig04_scheduling",
    "fig06_heatmap",
    "fig09_operator_scaling",
    "fig11_fused_prep",
    "fig13_library",
    "fig14_dispatch_overhead",
    "fig16_multipod",
    "fig18_guideline_eval",
    "roofline_report",
]


def main() -> None:
    sel = [a for a in sys.argv[1:] if not a.startswith("-")]
    print("name,us_per_call,derived")
    failures = []
    for name in MODULES:
        if sel and not any(s in name for s in sel):
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        t0 = time.time()
        try:
            mod.main()
        except Exception as e:  # noqa: BLE001 - report, keep going
            failures.append((name, repr(e)))
            traceback.print_exc(limit=3)
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
