"""Paper Fig. 13: math-library GEMM comparison (MKL vs MKL-DNN vs Eigen).

Backend analogue on this box: XLA:CPU dot vs numpy (BLAS) vs a naive
jnp reference lowered without the dot fast path (explicit broadcast-
multiply-reduce).  Derived column reports GFLOP/s — the prefetch-quality
axis of the paper's study collapses into achieved bandwidth here.
"""

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn


def main() -> None:
    key = jax.random.PRNGKey(0)
    for n in (256, 512, 1024):
        a = jax.random.normal(key, (n, n), jnp.float32)
        b = jax.random.normal(jax.random.fold_in(key, 1), (n, n),
                              jnp.float32)
        an, bn = np.asarray(a), np.asarray(b)
        flops = 2 * n ** 3

        t_xla = time_fn(jax.jit(lambda x, y: x @ y), a, b)
        t_np = time_fn(lambda: np.dot(an, bn))
        naive = jax.jit(
            lambda x, y: jnp.sum(x[:, :, None] * y[None, :, :], axis=1))
        t_naive = time_fn(naive, a, b) if n <= 512 else float("nan")

        emit(f"fig13.xla_{n}", t_xla * 1e6,
             f"gflops={flops / t_xla / 1e9:.1f}")
        emit(f"fig13.numpy_{n}", t_np * 1e6,
             f"gflops={flops / t_np / 1e9:.1f}")
        if n <= 512:
            emit(f"fig13.naive_{n}", t_naive * 1e6,
                 f"gflops={flops / t_naive / 1e9:.1f}")


if __name__ == "__main__":
    main()
