"""Paper Fig. 15-17: beyond one socket — DP vs MP across the slow link.

Reads the dry-run artifacts: for each arch x shape present on both meshes,
reports the multi-pod collective-byte increase (the UPI-traffic analogue)
and the cost-model DP-vs-MP comparison across the pod axis (§7.2's
'MP helps only when similar-size parallel ops sit on the critical path')."""

import dataclasses
import json
from pathlib import Path

from benchmarks.common import emit
from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core import autotune, tuner

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def main() -> None:
    # measured: single vs multi wire bytes per device
    for f in sorted(RESULTS.glob("*__single__guideline.json")):
        g = RESULTS / f.name.replace("__single__", "__multi__")
        if not g.exists():
            continue
        a = json.load(open(f))
        b = json.load(open(g))
        if a["wire_bytes_per_device"] > 0:
            ratio = b["wire_bytes_per_device"] / a["wire_bytes_per_device"]
        else:
            ratio = float("nan")
        emit(f"fig16.measured.{a['arch']}.{a['shape']}",
             b["collective_s"] * 1e6,
             f"wire_ratio_multi_vs_single={ratio:.2f},"
             f"pod_mode={b['plan']['pod_mode']}")

    # model: DP vs MP pod axis for each arch (train)
    shape = SHAPES["train_4k"]
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        base = tuner.guideline_plan(cfg, shape, pods=2)
        dp = dataclasses.replace(base, pod_mode="dp", name="dp")
        mp = dataclasses.replace(base, pod_mode="mp", name="mp")
        t_dp = autotune.evaluate(cfg, shape, dp).step_s
        t_mp = autotune.evaluate(cfg, shape, mp).step_s
        pick = "mp" if t_mp < t_dp else "dp"
        emit(f"fig16.model.{arch}", min(t_dp, t_mp) * 1e6,
             f"dp_us={t_dp * 1e6:.0f},mp_us={t_mp * 1e6:.0f},best={pick},"
             f"guideline={base.pod_mode}")


if __name__ == "__main__":
    main()
