"""Paper Fig. 11/12: parallelizing data preparation (MatMul2 / intra-op
threads) — TPU translation: fusing the prep into the consumer kernel
removes the HBM round-trip.

Measured two ways:
  * wall clock (CPU): one jit with prep+dot fused by XLA vs two jits that
    materialize the prepared matrix in between (the framework-boundary
    case the paper measures);
  * structurally: 'bytes accessed' from cost_analysis for both programs —
    the fused one reads the int8 x once instead of writing+reading the f32
    prepared copy (the VMEM-fusion win the Pallas kernel realizes on TPU).
"""

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.fused_matmul.ref import matmul1, prep


def main() -> None:
    key = jax.random.PRNGKey(0)
    n = 1024
    x8 = jax.random.randint(key, (n, n), -127, 127, jnp.int8)
    w = jax.random.normal(jax.random.fold_in(key, 1), (n, n), jnp.float32)
    sc = jnp.abs(jax.random.normal(jax.random.fold_in(key, 2), (n, 1)))

    fused = jax.jit(lambda a, b, s: matmul1(a, b, s, out_dtype=jnp.float32))
    prep_j = jax.jit(prep)
    dot_j = jax.jit(lambda a, b: a @ b)

    def unfused(a, b, s):
        return dot_j(prep_j(a, s), b)

    t_fused = time_fn(fused, x8, w, sc)
    t_unfused = time_fn(unfused, x8, w, sc)

    ca_f = jax.jit(lambda a, b, s: matmul1(a, b, s, out_dtype=jnp.float32)) \
        .lower(x8, w, sc).compile().cost_analysis()
    ca_p = jax.jit(prep).lower(x8, sc).compile().cost_analysis()
    ca_d = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32), w).compile().cost_analysis()
    bytes_fused = ca_f["bytes accessed"]
    bytes_unfused = ca_p["bytes accessed"] + ca_d["bytes accessed"]

    emit("fig11.fused_prep", t_fused * 1e6,
         f"speedup={t_unfused / t_fused:.2f}x,bytes_saved_pct="
         f"{100 * (1 - bytes_fused / bytes_unfused):.1f}")
    emit("fig11.unfused_prep", t_unfused * 1e6,
         f"bytes={bytes_unfused:.3e}")


if __name__ == "__main__":
    main()
