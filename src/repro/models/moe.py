"""Mixture-of-Experts FFN: top-k router + capacity-based scatter dispatch +
batched expert matmuls.

This is the canonical *inter-operator parallelism* case of the paper on TPU:
the E expert FFNs of one layer are independent heavy operators.  The
tuner's "pools" decide whether the expert dim of the batched matmul is
sharded across device groups (async scheduling / expert parallelism), the
``d_ff`` dim is sharded (sync scheduling / pure intra-op), or a factored
mix.  The same model code supports all of them through the logical-axis
rules (``act_expert`` / ``act_mlp``).

Dispatch is scatter-based (sort-free positions via a cumsum rank trick), not
the GShard one-hot-einsum, so dispatch costs ~0 FLOPs and O(tokens) bytes.
Tokens are processed in G groups of g tokens (G sharded on ``data``) so all
shapes are static.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import module as m
from repro.parallel import sharding as sh

GROUP_TOKENS = 4096  # target tokens per dispatch group


def moe_defs(cfg: ModelConfig) -> Dict:
    e = cfg.moe.num_experts
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "router": m.ParamDef((d, e), (m.EMBED, m.EXPERT), dtype=jnp.float32),
        "w_gate": m.ParamDef((e, d, ff), (m.EXPERT, m.EMBED, m.MLP)),
        "w_up": m.ParamDef((e, d, ff), (m.EXPERT, m.EMBED, m.MLP)),
        "w_down": m.ParamDef((e, ff, d), (m.EXPERT, m.MLP, m.EMBED)),
    }


def _num_groups(total_tokens: int) -> int:
    g = max(1, total_tokens // GROUP_TOKENS)
    while total_tokens % g:
        g -= 1
    return g


def _capacity(g: int, moe: MoEConfig) -> int:
    cap = int(g * moe.top_k / moe.num_experts * moe.capacity_factor)
    return max(1, min(g, cap))


def route(params, x2d: jax.Array, moe: MoEConfig
          ) -> Tuple[jax.Array, jax.Array, Dict]:
    """x2d [T,d] -> (top-k probs [T,k], expert ids [T,k], aux)."""
    logits = jnp.dot(x2d.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, moe.top_k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], moe.num_experts, dtype=jnp.float32), axis=0)
    aux = {"load_balance_loss": moe.num_experts * jnp.sum(me * ce),
           "router_entropy": -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), -1))}
    return top_p, top_e, aux


def _dispatch_indices(top_e: jax.Array, e: int, cap: int
                      ) -> Tuple[jax.Array, jax.Array]:
    """Per group: top_e [g,k] -> (slot [g,k] in [0, e*cap), keep [g,k]).

    Position of each assignment inside its expert's queue via the
    cumsum-of-one-hot rank trick; overflow beyond ``cap`` is dropped.
    """
    g, k = top_e.shape
    flat = top_e.reshape(g * k)
    oh = jax.nn.one_hot(flat, e, dtype=jnp.int32)          # [g*k, e]
    ranks = jnp.cumsum(oh, axis=0) - oh                     # rank within expert
    pos = jnp.take_along_axis(ranks, flat[:, None], axis=1)[:, 0]
    keep = pos < cap
    slot = flat * cap + jnp.minimum(pos, cap - 1)
    return slot.reshape(g, k), keep.reshape(g, k)


def apply(params, x: jax.Array, cfg: ModelConfig, act: str = "silu",
          ) -> Tuple[jax.Array, Dict]:
    """x [B,S,d] -> (y [B,S,d], aux)."""
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.top_k
    ngroups = _num_groups(t)
    g = t // ngroups
    cap = _capacity(g, moe)

    x2d = x.reshape(t, d)
    top_p, top_e, aux = route(params, x2d, moe)

    xg = x2d.reshape(ngroups, g, d)
    pg = top_p.reshape(ngroups, g, k).astype(x.dtype)
    eg = top_e.reshape(ngroups, g, k)

    slot, keep = jax.vmap(lambda te: _dispatch_indices(te, e, cap))(eg)

    def scatter_group(xk, slots, keeps):
        # xk [g,d]; slots/keeps [g,k] -> buffer [e*cap, d]
        vals = jnp.repeat(xk, k, axis=0)                    # [g*k, d]
        vals = vals * keeps.reshape(-1, 1).astype(xk.dtype)
        buf = jnp.zeros((e * cap, d), xk.dtype)
        return buf.at[slots.reshape(-1)].add(vals)

    buf = jax.vmap(scatter_group)(xg, slot, keep)           # [G, e*cap, d]
    buf = buf.reshape(ngroups, e, cap, d)
    buf = sh.shard(buf, sh.GROUPS, sh.EXPERT, None, None)

    # batched expert SwiGLU
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    dt = x.dtype
    hg = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"].astype(dt))
    hu = jnp.einsum("gecd,edf->gecf", buf, params["w_up"].astype(dt))
    hg = sh.shard(hg, sh.GROUPS, sh.EXPERT, None, sh.MLP)
    hu = sh.shard(hu, sh.GROUPS, sh.EXPERT, None, sh.MLP)
    hidden = actf(hg) * hu
    out_buf = jnp.einsum("gecf,efd->gecd", hidden, params["w_down"].astype(dt))
    out_buf = sh.shard(out_buf, sh.GROUPS, sh.EXPERT, None, None)
    out_buf = out_buf.reshape(ngroups, e * cap, d)

    def gather_group(ob, slots, keeps, pk):
        # ob [e*cap, d] -> y [g, d]
        rows = ob[slots.reshape(-1)]                        # [g*k, d]
        wts = (pk * keeps.astype(pk.dtype)).reshape(-1, 1)
        return jnp.sum((rows * wts).reshape(g, k, d), axis=1)

    y = jax.vmap(gather_group)(out_buf, slot, keep, pg)     # [G, g, d]
    y = y.reshape(b, s, d)
    aux["dropped_fraction"] = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return sh.shard(y, sh.BATCH, sh.SEQ, sh.EMBED), aux


# ---------------------------------------------------------------------------
# Scheduling-mechanism study (paper §4): the same expert computation under
# explicitly *synchronous* scheduling — experts executed one at a time, each
# sharded over the full model axis.  Used by core/scheduler.py + fig04.
# ---------------------------------------------------------------------------

def apply_sync_schedule(params, x: jax.Array, cfg: ModelConfig,
                        act: str = "silu") -> Tuple[jax.Array, Dict]:
    """Numerically equivalent to ``apply`` (same dispatch, same FLOPs), but
    lowered as a sequential python loop over experts — one heavy op at a
    time, each sharded over the *full* model axis.  The paper's synchronous
    scheduling baseline."""
    moe = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = moe.num_experts, moe.top_k
    ngroups = _num_groups(t)
    g = t // ngroups
    cap = _capacity(g, moe)

    x2d = x.reshape(t, d)
    top_p, top_e, aux = route(params, x2d, moe)
    xg = x2d.reshape(ngroups, g, d)
    pg = top_p.reshape(ngroups, g, k).astype(x.dtype)
    eg = top_e.reshape(ngroups, g, k)
    slot, keep = jax.vmap(lambda te: _dispatch_indices(te, e, cap))(eg)

    def scatter_group(xk, slots, keeps):
        vals = jnp.repeat(xk, k, axis=0) * keeps.reshape(-1, 1).astype(xk.dtype)
        return jnp.zeros((e * cap, d), xk.dtype).at[slots.reshape(-1)].add(vals)

    buf = jax.vmap(scatter_group)(xg, slot, keep).reshape(ngroups, e, cap, d)
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    dt = x.dtype
    outs = []
    for ei in range(e):                          # static loop: sync schedule
        be = sh.shard(buf[:, ei], sh.GROUPS, None, None)
        h = actf(jnp.dot(be, params["w_gate"][ei].astype(dt))) * \
            jnp.dot(be, params["w_up"][ei].astype(dt))
        h = sh.shard(h, sh.GROUPS, None, sh.MLP)
        outs.append(jnp.dot(h, params["w_down"][ei].astype(dt)))
    out_buf = jnp.stack(outs, axis=1).reshape(ngroups, e * cap, d)

    def gather_group(ob, slots, keeps, pk):
        rows = ob[slots.reshape(-1)]
        wts = (pk * keeps.astype(pk.dtype)).reshape(-1, 1)
        return jnp.sum((rows * wts).reshape(g, k, d), axis=1)

    y = jax.vmap(gather_group)(out_buf, slot, keep, pg).reshape(b, s, d)
    return y, aux
