"""RWKV6 (Finch) blocks: time-mix with data-dependent decay + channel-mix.

The wkv6 recurrence per head (K = V = head_dim):

    y_t = r_t . (S_{t-1} + (u * k_t) (x) v_t)
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t          (w_t in (0,1), per channel)

Train/prefill use a chunked formulation (chunk ``CHUNK_Q``): intra-chunk
contributions via a factored decay matmul (all exponents <= 0 after the
chunk-start normalization thanks to the fla-style log-decay clamp of
``LOG_W_MIN``), inter-chunk state carried by an associative scan.  Decode is
the O(1) recurrence.  The naive step recurrence lives in
``repro.kernels.rwkv6_wkv.ref`` and is the oracle for both.

State per layer: {"tshift": [B,1,d], "wkv": [B,H,K,V], "cshift": [B,1,d]}.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import module as m
from repro.models.layers import groupnorm, groupnorm_defs
from repro.parallel import sharding as sh

LOG_W_MIN = -5.0   # fla-style clamp on per-step log decay
CHUNK_Q = 16       # keeps every factored exponent <= |LOG_W_MIN| * CHUNK_Q < 88

MIX_NAMES = ("w", "k", "v", "r", "g")


def hdims(cfg: ModelConfig) -> Tuple[int, int]:
    hd = cfg.rwkv.head_dim
    return cfg.d_model // hd, hd


def time_mix_defs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    r = cfg.rwkv
    nm = len(MIX_NAMES)
    return {
        "mu_inner": m.ParamDef((d,), (m.EMBED,), init="zeros"),
        "mu": m.ParamDef((nm, d), (None, m.EMBED), init="zeros"),
        "mix_a": m.ParamDef((nm, d, r.mix_lora), (None, m.EMBED, None)),
        "mix_b": m.ParamDef((nm, r.mix_lora, d), (None, None, m.EMBED),
                            init="zeros"),
        "wr": m.ParamDef((d, d), (m.EMBED, m.SSM_INNER)),
        "wk": m.ParamDef((d, d), (m.EMBED, m.SSM_INNER)),
        "wv": m.ParamDef((d, d), (m.EMBED, m.SSM_INNER)),
        "wg": m.ParamDef((d, d), (m.EMBED, m.SSM_INNER)),
        "wo": m.ParamDef((d, d), (m.SSM_INNER, m.EMBED)),
        "w0": m.ParamDef((d,), (m.SSM_INNER,), init="custom",
                         custom=lambda key: jnp.log(jnp.exp(
                             jax.random.uniform(key, (d,), minval=0.5,
                                                maxval=3.0)))),
        "decay_a": m.ParamDef((d, r.decay_lora), (m.EMBED, None)),
        "decay_b": m.ParamDef((r.decay_lora, d), (None, m.SSM_INNER),
                              init="zeros"),
        "bonus_u": m.ParamDef((d,), (m.SSM_INNER,), init="normal", scale=0.3),
        "ln_x": groupnorm_defs(d),
    }


def channel_mix_defs(cfg: ModelConfig) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "mu_k": m.ParamDef((d,), (m.EMBED,), init="zeros"),
        "mu_r": m.ParamDef((d,), (m.EMBED,), init="zeros"),
        "wk": m.ParamDef((d, ff), (m.EMBED, m.MLP)),
        "wv": m.ParamDef((ff, d), (m.MLP, m.EMBED)),
        "wr": m.ParamDef((d, d), (m.EMBED, m.EMBED)),
    }


def _token_shift(x: jax.Array, shift_state: Optional[jax.Array]) -> jax.Array:
    """Previous token's x (zeros / carried state at position 0)."""
    b, s, d = x.shape
    if s == 1:
        return shift_state if shift_state is not None else jnp.zeros_like(x)
    prev = x[:, :-1]
    first = shift_state if shift_state is not None else jnp.zeros((b, 1, d), x.dtype)
    return jnp.concatenate([first.astype(x.dtype), prev], axis=1)


def _ddlerp(params, x, xx, name_idx):
    """Finch data-dependent lerp for stream ``name_idx``."""
    inner = x + xx * params["mu_inner"].astype(x.dtype)
    lora = jnp.einsum("bsd,dr->bsr", jnp.tanh(
        jnp.einsum("bsd,dr->bsr", inner, params["mix_a"][name_idx].astype(x.dtype))),
        params["mix_b"][name_idx].astype(x.dtype))
    return x + xx * (params["mu"][name_idx].astype(x.dtype) + lora)


def wkv_chunked(r, k, v, lw, u, h0=None):
    """Chunked wkv6.

    r,k,v [B,S,H,K]; lw [B,S,H,K] log decays (<=0, clamped); u [H,K].
    Returns (y [B,S,H,K], final_state [B,H,K,V]).
    """
    b, s, h, kk = r.shape
    f32 = jnp.float32
    q = min(CHUNK_Q, s)
    while s % q:
        q //= 2
    nc = s // q
    rc = r.astype(f32).reshape(b, nc, q, h, kk)
    kc = k.astype(f32).reshape(b, nc, q, h, kk)
    vc = v.astype(f32).reshape(b, nc, q, h, kk)
    lwc = lw.astype(f32).reshape(b, nc, q, h, kk)

    cw = jnp.cumsum(lwc, axis=2)                       # inclusive
    cwx = cw - lwc                                     # exclusive
    cw_end = cw[:, :, -1]                              # [B,nc,H,K]

    # intra-chunk: A[t,j] = sum_K r_t exp(cwx_t - cw_j) k_j   (j <= t-1)
    r_tilde = rc * jnp.exp(cwx)                        # exponents <= 0
    k_tilde = kc * jnp.exp(-cw)                        # <= exp(|LOG_W_MIN|*Q)
    amat = jnp.einsum("bcihk,bcjhk->bchij", r_tilde, k_tilde)
    mask = jnp.tril(jnp.ones((q, q), bool), k=-1)      # strictly lower
    amat = jnp.where(mask[None, None, None], amat, 0.0)
    y_intra = jnp.einsum("bchij,bcjhv->bcihv", amat, vc)
    # diagonal bonus term: (r_t . (u * k_t)) v_t
    diag = jnp.einsum("bcihk,bcihk->bcih", rc, kc * u.astype(f32)[None, None, None])
    y_intra = y_intra + diag[..., None] * vc

    # chunk kv: sum_j exp(cw_end - cw_j) k_j (x) v_j
    kdec = kc * jnp.exp(cw_end[:, :, None] - cw)
    chunk_kv = jnp.einsum("bcjhk,bcjhv->bchkv", kdec, vc)

    aa = jnp.exp(cw_end)                               # [B,nc,H,K]
    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a2 * a1, s1 * a2[..., None] + s2
    a_pref, s_pref = jax.lax.associative_scan(combine, (aa, chunk_kv), axis=1)
    if h0 is None:
        h0 = jnp.zeros((b, h, kk, vc.shape[-1]), f32)
    else:
        h0 = h0.astype(f32)
    h_before = jnp.concatenate(
        [h0[:, None], s_pref[:, :-1] + h0[:, None] * a_pref[:, :-1][..., None]],
        axis=1)
    h_final = s_pref[:, -1] + h0 * a_pref[:, -1][..., None]

    y_inter = jnp.einsum("bcihk,bchkv->bcihv", r_tilde, h_before)
    y = (y_intra + y_inter).reshape(b, s, h, kk)
    return y.astype(r.dtype), h_final


def _state_at(x: jax.Array, length: Optional[jax.Array]) -> jax.Array:
    """Token-shift carry: x at the last *valid* position (right-padded
    prefill), zeros for empty prompts."""
    if length is None:
        return x[:, -1:]
    idx = jnp.clip(length - 1, 0)[:, None, None]
    picked = jnp.take_along_axis(x, jnp.broadcast_to(
        idx, (x.shape[0], 1, x.shape[2])), axis=1)
    return jnp.where((length > 0)[:, None, None], picked,
                     jnp.zeros_like(picked))


def time_mix(params, x: jax.Array, cfg: ModelConfig, *, mode: str,
             state: Optional[Dict] = None,
             length: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, Optional[Dict]]:
    """x [B,S,d] -> (y, new partial state {"tshift","wkv"}).

    ``length`` [B] (prefill only): right-padded true lengths.  Padded steps
    get k == 0 and log-decay 0 (w == 1), so the wkv state passes through
    them unchanged and the carried state is exact as of ``length - 1``."""
    nh, hd = hdims(cfg)
    b, s, d = x.shape
    dt = x.dtype
    prev = _token_shift(x, state["tshift"] if state else None)
    xx = prev - x
    xw = _ddlerp(params, x, xx, 0)
    xk = _ddlerp(params, x, xx, 1)
    xv = _ddlerp(params, x, xx, 2)
    xr = _ddlerp(params, x, xx, 3)
    xg = _ddlerp(params, x, xx, 4)

    r = jnp.dot(xr, params["wr"].astype(dt))
    k = jnp.dot(xk, params["wk"].astype(dt))
    v = jnp.dot(xv, params["wv"].astype(dt))
    g = jnp.dot(xg, params["wg"].astype(dt))
    r = sh.shard(r, sh.BATCH, None, sh.MLP)
    k = sh.shard(k, sh.BATCH, None, sh.MLP)
    v = sh.shard(v, sh.BATCH, None, sh.MLP)
    g = sh.shard(g, sh.BATCH, None, sh.MLP)

    # data-dependent decay (log space, clamped)
    dlora = jnp.einsum("bsr,rd->bsd",
                       jnp.tanh(jnp.einsum("bsd,dr->bsr", xw,
                                           params["decay_a"].astype(dt))),
                       params["decay_b"].astype(dt))
    lw = -jnp.exp(jnp.clip(params["w0"].astype(jnp.float32) +
                           dlora.astype(jnp.float32), -8.0, 2.0))
    lw = jnp.clip(lw, LOG_W_MIN, 0.0)                  # [B,S,d]

    rh = r.reshape(b, s, nh, hd)
    kh = k.reshape(b, s, nh, hd)
    vh = v.reshape(b, s, nh, hd)
    lwh = lw.reshape(b, s, nh, hd)
    if length is not None:
        smask = (jnp.arange(s)[None, :] < length[:, None])[..., None, None]
        kh = kh * smask.astype(kh.dtype)
        lwh = lwh * smask.astype(lwh.dtype)
    uh = params["bonus_u"].astype(jnp.float32).reshape(nh, hd)

    new_state = None
    if mode == "decode":
        assert state is not None
        h_prev = state["wkv"].astype(jnp.float32)       # [B,H,K,V]
        r1 = rh[:, 0].astype(jnp.float32)
        k1 = kh[:, 0].astype(jnp.float32)
        v1 = vh[:, 0].astype(jnp.float32)
        w1 = jnp.exp(lwh[:, 0])
        kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
        y = jnp.einsum("bhk,bhkv->bhv", r1, h_prev + uh[None][..., None] * kv)
        h_new = w1[..., None] * h_prev + kv
        y = y[:, None].astype(dt).reshape(b, 1, d)
        new_state = {"tshift": x[:, -1:], "wkv": h_new}
    else:
        h0 = state["wkv"] if state else None
        yh, h_final = wkv_chunked(rh, kh, vh, lwh, uh, h0)
        y = yh.reshape(b, s, d)
        if mode == "prefill":
            new_state = {"tshift": _state_at(x, length), "wkv": h_final}

    y = groupnorm(params["ln_x"], y, nh, eps=64e-5)
    y = y * jax.nn.silu(g)
    out = jnp.dot(y, params["wo"].astype(dt))
    return sh.shard(out, sh.BATCH, sh.SEQ, sh.EMBED), new_state


def channel_mix(params, x: jax.Array, cfg: ModelConfig, *, mode: str,
                state: Optional[Dict] = None,
                length: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[Dict]]:
    dt = x.dtype
    prev = _token_shift(x, state["cshift"] if state else None)
    xx = prev - x
    xk = x + xx * params["mu_k"].astype(dt)
    xr = x + xx * params["mu_r"].astype(dt)
    k = jnp.dot(xk, params["wk"].astype(dt))
    k = sh.shard(k, sh.BATCH, None, sh.MLP)
    kk = jnp.square(jax.nn.relu(k))
    v = jnp.dot(kk, params["wv"].astype(dt))
    out = jax.nn.sigmoid(jnp.dot(xr, params["wr"].astype(dt))) * v
    if mode == "decode":
        new_state = {"cshift": x[:, -1:]}
    elif mode == "prefill":
        new_state = {"cshift": _state_at(x, length)}
    else:
        new_state = None
    return sh.shard(out, sh.BATCH, sh.SEQ, sh.EMBED), new_state


def state_shapes(cfg: ModelConfig, batch: int) -> Dict:
    nh, hd = hdims(cfg)
    d = cfg.d_model
    return {
        "tshift": ((batch, 1, d), (sh.BATCH, None, None)),
        "wkv": ((batch, nh, hd, hd), (sh.BATCH, sh.HEADS, None, None)),
        "cshift": ((batch, 1, d), (sh.BATCH, None, None)),
    }
