from repro.models import (attention, layers, mamba2, module, moe, rwkv6,
                          transformer)
from repro.models.transformer import (cache_structure, forward_decode,
                                      forward_dense_logits,
                                      forward_prefill, forward_train,
                                      forward_verify, model_defs,
                                      prepare_decode_cache)

__all__ = ["attention", "layers", "mamba2", "module", "moe", "rwkv6",
           "transformer", "model_defs", "forward_train", "forward_prefill",
           "forward_decode", "forward_verify", "forward_dense_logits",
           "cache_structure", "prepare_decode_cache"]
