"""Basic layers: norms, rotary embeddings, token embeddings, dense MLPs."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import module as m
from repro.parallel import sharding as sh


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_defs(dim: int):
    return {"scale": m.ParamDef((dim,), (m.EMBED,), init="ones")}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def groupnorm_defs(dim: int):
    return {"scale": m.ParamDef((dim,), (m.SSM_INNER,), init="ones"),
            "bias": m.ParamDef((dim,), (m.SSM_INNER,), init="zeros")}


def groupnorm(params, x, num_groups: int, eps: float = 1e-5):
    """GroupNorm over the last dim (RWKV per-head norm)."""
    dt = x.dtype
    *lead, d = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, num_groups, d // num_groups)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, d)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, dh]; positions [..., S] (broadcastable)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = (1.0 / theta) ** (jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------

def embedding_defs(cfg: ModelConfig):
    # 1/sqrt(d) keeps tied-embedding logits O(1) at init (gemma's
    # embed_scale multiplies sqrt(d) back in the forward pass)
    defs = {"table": m.ParamDef((cfg.vocab_size, cfg.d_model),
                                (m.VOCAB, m.EMBED), init="embed",
                                scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        defs["head"] = m.ParamDef((cfg.d_model, cfg.vocab_size),
                                  (m.EMBED, m.VOCAB), init="fan_in")
    return defs


def embed(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    h = jnp.take(params["table"], tokens, axis=0)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return sh.shard(h, sh.BATCH, sh.SEQ, sh.EMBED)


def grad_fence(x):
    """Identity whose cotangent is cast back to x.dtype.  Placed where an
    f32-preferred consumer (LM head) would otherwise push f32 cotangents
    into the bf16 residual stream."""
    dtype = x.dtype

    @jax.custom_vjp
    def f(y):
        return y

    f.defvjp(lambda y: (y, None), lambda _, ct: (ct.astype(dtype),))
    return f(x)


def logits(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    h = grad_fence(h)
    if cfg.tie_embeddings:
        w = params["table"].T
    else:
        w = params["head"]
    out = jnp.dot(h, w.astype(h.dtype),
                  preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        out = jnp.tanh(out / c) * c
    return sh.shard(out, sh.BATCH, sh.SEQ, sh.VOCAB)


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    return {
        "w_gate": m.ParamDef((d, cfg.d_ff), (m.EMBED, m.MLP)),
        "w_up": m.ParamDef((d, cfg.d_ff), (m.EMBED, m.MLP)),
        "w_down": m.ParamDef((cfg.d_ff, d), (m.MLP, m.EMBED)),
    }


def mlp(params, x, act: str = "silu"):
    actf = jax.nn.silu if act == "silu" else jax.nn.gelu
    g = jnp.dot(x, params["w_gate"].astype(x.dtype))
    u = jnp.dot(x, params["w_up"].astype(x.dtype))
    g = sh.shard(g, sh.BATCH, None, sh.MLP)
    u = sh.shard(u, sh.BATCH, None, sh.MLP)
    h = actf(g) * u
    out = jnp.dot(h, params["w_down"].astype(x.dtype))
    return sh.shard(out, sh.BATCH, sh.SEQ, sh.EMBED)


def cross_entropy(logits_: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy; logits may be vocab-sharded (GSPMD handles
    the cross-shard max/sum reductions)."""
    lf = logits_.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        maskf = mask.astype(jnp.float32)
        return jnp.sum(nll * maskf) / jnp.maximum(jnp.sum(maskf), 1.0)
    return jnp.mean(nll)
