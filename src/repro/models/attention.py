"""GQA attention: chunked (flash-style) train/prefill path, seq-sharded
decode path, KV caches, sliding windows, softcaps, cross-attention.

Two XLA-level implementations (the Pallas flash kernel in
``repro.kernels.flash_attention`` is the TPU hot path; these are the
lower-&-compile-friendly references that the dry-run uses):

* ``chunked_attention`` — query-chunked online attention.  The chunk loop is
  a *python* loop (static), so HLO FLOPs are exact and peak memory is one
  chunk of scores, not the full S x S matrix.  Sliding windows slice the KV
  statically per chunk.
* ``decode_attention`` — one-token attention against a KV cache laid out
  ``[B, Hkv, S, dh]`` with S sharded over the *model* mesh axis.  Softmax
  and the PV contraction reduce over the sharded S dim; GSPMD turns those
  into the flash-decode all-reduce pattern automatically.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import module as m
from repro.models.layers import rope
from repro.parallel import sharding as sh

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig, d_in: Optional[int] = None) -> Dict:
    d = d_in or cfg.d_model
    dh = cfg.resolved_head_dim
    return {
        "wq": m.ParamDef((d, cfg.num_heads, dh), (m.EMBED, m.HEADS, m.HEAD_DIM)),
        "wk": m.ParamDef((d, cfg.num_kv_heads, dh), (m.EMBED, m.KV_HEADS, m.HEAD_DIM)),
        "wv": m.ParamDef((d, cfg.num_kv_heads, dh), (m.EMBED, m.KV_HEADS, m.HEAD_DIM)),
        "wo": m.ParamDef((cfg.num_heads, dh, d), (m.HEADS, m.HEAD_DIM, m.EMBED)),
    }


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def _softcap(scores: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return scores
    return jnp.tanh(scores / cap) * cap


def _pick_q_chunk(sq: int, q_chunk: Optional[int]) -> int:
    if q_chunk is None:
        q_chunk = 2048
    q_chunk = min(q_chunk, sq)
    while sq % q_chunk:
        q_chunk //= 2
    return max(q_chunk, 1)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      q_chunk: Optional[int] = None) -> jax.Array:
    """q [B,Sq,H,dh]; k,v [B,Skv,Hkv,dh] -> [B,Sq,H,dh].

    For causal self-attention we assume query i sits at absolute position i
    with Skv == Sq (train / prefill).  ``causal=False, window=None`` is the
    encoder / cross-attention case.
    """
    b, sq, h, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = sh.shard(k, sh.BATCH, None, sh.HEADS, None)
        v = sh.shard(v, sh.BATCH, None, sh.HEADS, None)
    scale = dh ** -0.5
    cq = _pick_q_chunk(sq, q_chunk)
    outs = []
    for qs in range(0, sq, cq):  # static python loop: exact HLO flops
        qe = qs + cq
        qc = q[:, qs:qe]
        if causal:
            klo = 0 if window is None else max(0, qs - window + 1)
            khi = min(qe, skv)
        else:
            klo, khi = 0, skv
        ks, vs = k[:, klo:khi], v[:, klo:khi]
        # bf16-out dot (f32 MXU accumulation); upcast for the softmax only so
        # the *cotangent* of qc/ks stays bf16 (f32 cotangents would double
        # every backward activation and collective, see EXPERIMENTS.md §Perf)
        scores = jnp.einsum("bqhd,bkhd->bhqk", qc, ks)
        scores = scores.astype(jnp.float32) * scale
        scores = _softcap(scores, softcap)
        if causal:
            qpos = jnp.arange(qs, qe)[:, None]
            kpos = jnp.arange(klo, khi)[None, :]
            mask = kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            scores = jnp.where(mask[None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        outs.append(jnp.einsum("bhqk,bkhd->bqhd", p, vs))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def cp_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool, window: Optional[int] = None,
                 softcap: Optional[float] = None) -> jax.Array:
    """Context-parallel attention: q stays seq-sharded on the model axis
    (explicit shard_map so XLA cannot replicate it), KV is gathered once.
    Windowed layers dynamic-slice only ``window + s_loc`` keys, so gemma3's
    5:1 local layers keep their flops savings under CP."""
    import functools

    from jax.sharding import PartitionSpec as P

    rules = sh.current_rules()
    mesh = rules.mesh
    ax = rules.table[sh.SEQ]
    b, sq, h, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scale = dh ** -0.5
    nshards = rules.mesh_size(ax)
    s_loc = sq // nshards
    klen = min((window or skv) + s_loc, skv) if causal else skv
    axname = ax if isinstance(ax, str) else ax[0]

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(P(None, ax, None, None), P(), P()),
        out_specs=P(None, ax, None, None), check_vma=False)
    def f(q_loc, k_full, v_full):
        idx = jax.lax.axis_index(axname)
        offset = idx * s_loc
        if causal and klen < skv:
            start = jnp.clip(offset + s_loc - klen, 0, skv - klen)
            k_sl = jax.lax.dynamic_slice_in_dim(k_full, start, klen, 1)
            v_sl = jax.lax.dynamic_slice_in_dim(v_full, start, klen, 1)
            kpos0 = start
        else:
            k_sl, v_sl, kpos0 = k_full, v_full, 0
        s_ = jnp.einsum("bqhd,bkhd->bhqk", q_loc, k_sl)
        s_ = s_.astype(jnp.float32) * scale
        s_ = _softcap(s_, softcap)
        if causal:
            qpos = offset + jnp.arange(s_loc)[:, None]
            kpos = kpos0 + jnp.arange(k_sl.shape[1])[None, :]
            mask = kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            s_ = jnp.where(mask[None, None], s_, NEG_INF)
        p = jax.nn.softmax(s_, axis=-1).astype(v_full.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v_sl)

    return f(q, k, v)


def decode_attention(q: jax.Array, ck: jax.Array, cv: jax.Array,
                     cache_len: jax.Array, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     valid: Optional[jax.Array] = None) -> jax.Array:
    """q [B,Sq,H,dh]; cache [B,Hkv,S,dh] (S model-sharded); cache_len counts
    valid entries *including* the newest query token.

    ``valid`` [B,S] overrides the default position-order mask — the paged
    path passes ``ring_valid`` because its KV rows are in ring order, not
    absolute order.  ``Sq > 1`` is the speculative-verify case (several
    drafted query rows per slot in one dispatch); there ``valid`` must be
    given per query row as [B,Sq,S], because each drafted query may only
    attend to cache entries at or before its own position."""
    b, sq, h, dh = q.shape
    _, hkv, s, _ = ck.shape
    g = h // hkv
    scale = dh ** -0.5
    if sq == 1:
        q2 = q[:, 0].reshape(b, hkv, g, dh)
        scores = jnp.einsum("bkgd,bksd->bkgs", q2,
                            ck).astype(jnp.float32) * scale
        scores = _softcap(scores, softcap)
        if valid is None:
            pos = jnp.arange(s)
            valid = pos[None, :] < cache_len[:, None]      # [B, S]
            if window is not None:
                valid &= pos[None, :] >= cache_len[:, None] - window
        scores = jnp.where(valid[:, None, None], scores, NEG_INF)
        p = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)  # GSPMD reduces
        out = jnp.einsum("bkgs,bksd->bkgd", p, cv)
        return out.reshape(b, 1, h, dh)
    assert valid is not None and valid.ndim == 3, \
        "multi-query decode attention needs a per-query [B,Sq,S] mask"
    q2 = q.reshape(b, sq, hkv, g, dh)
    scores = jnp.einsum("bqkgd,bksd->bkgqs", q2, ck).astype(jnp.float32)
    scores = _softcap(scores * scale, softcap)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgqs,bksd->bqkgd", p, cv)
    return out.reshape(b, sq, h, dh)


# ---------------------------------------------------------------------------
# Paged KV decode (serve/cache.py block-paged pools)
# ---------------------------------------------------------------------------

def ring_token_positions(cache_len: jax.Array, ring: int) -> jax.Array:
    """Absolute token position held by each ring slot.

    The paged/ring write rule puts token ``t`` at ring index ``t % ring``,
    so slot ``r`` holds the *latest* token ``u <= t_cur`` with ``u === r
    (mod ring)``; a negative ``u`` means the slot was never written.
    ``cache_len`` [B] counts tokens *including* the current one.
    Returns [B, ring] int32."""
    t = (cache_len - 1)[:, None]                       # [B,1] current token
    r = jnp.arange(ring)[None, :]                      # [1,R]
    return t - ((t - r) % ring)


def ring_valid(cache_len: jax.Array, ring: int,
               window: Optional[int]) -> jax.Array:
    """[B, ring] attention validity for a ring-ordered KV layout: written
    slots only, window-masked by *absolute* position (a ring rounded up to
    page granularity may physically retain a few tokens older than the
    window — they must not be attended)."""
    u = ring_token_positions(cache_len, ring)
    valid = u >= 0
    if window is not None:
        valid &= u > (cache_len - 1)[:, None] - window
    return valid


def paged_ring_blocks(window: Optional[int], max_blocks: int,
                      page_size: int, spec_slack: int = 0) -> int:
    """Logical ring width in pages for a paged attention layer — must match
    ``serve/cache.CacheSpec``'s per-layer ``ring_blocks`` (it does:
    ``ceil(min(max_len, window)/P) == min(ceil(max_len/P), ceil(window/P))``
    and ``max_blocks == ceil(max_len/P)``).

    ``spec_slack`` widens *windowed* rings by the speculative draft length
    ``K`` (``serve/spec``): a verify step writes up to ``K`` tokens past
    the newest committed one, and without the slack those writes would
    ring-wrap onto tokens still inside the window of the earliest query
    row.  Full-attention rings never wrap, so they take no slack."""
    if window is None:
        return max_blocks
    return min(max_blocks, -(-(window + spec_slack) // page_size))


def page_group_key(ring_blocks: int) -> str:
    """Stable pytree key of the pool group with the given ring width.

    Paged layers are grouped by ring width into independently-budgeted
    pools (``serve/cache.PoolGroup``); the decode path recovers each
    layer's group from its ring width alone, so the key must be a pure
    function of it."""
    return f"ring{ring_blocks}"


def kv_pool_qmax(pool_dtype) -> Optional[float]:
    """Symmetric quantization range of an 8-bit pool dtype.

    ``None`` means the pool is not quantized (fp32/bf16 pools store K/V
    directly and carry no scale pool)."""
    dt = jnp.dtype(pool_dtype)
    if dt == jnp.dtype(jnp.int8):
        return 127.0
    if hasattr(jnp, "float8_e4m3fn") and dt == jnp.dtype(jnp.float8_e4m3fn):
        return 448.0
    return None


def quantize_pages(x: jax.Array, pool_dtype) -> Tuple[jax.Array, jax.Array]:
    """Quantize full pages to an 8-bit pool dtype with per-(page, kv-head)
    symmetric amax scales.

    x [..., P, Hkv, dh] fp32 -> (q [..., P, Hkv, dh] ``pool_dtype``,
    scale [..., Hkv] fp32) with ``x ~= q * scale``.  The scale floor keeps
    all-zero pages (and the trash page) at a finite, tiny scale so the
    dequantized pool never produces inf/nan — zero pages round-trip to
    exact zeros."""
    qmax = kv_pool_qmax(pool_dtype)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(-3, -1))
    scale = jnp.maximum(amax, 1e-30) / qmax
    y = x.astype(jnp.float32) / scale[..., None, :, None]
    y = jnp.clip(y, -qmax, qmax)     # pre-clip: round(127.49) must not hit 128
    if jnp.dtype(pool_dtype) == jnp.dtype(jnp.int8):
        y = jnp.round(y)
    return y.astype(pool_dtype), scale


def dequantize_pages(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_pages`: q [..., P, Hkv, dh] 8-bit,
    scale [..., Hkv] -> fp32 [..., P, Hkv, dh]."""
    return q.astype(jnp.float32) * scale[..., None, :, None]


def rmw_quantized_pages(pool: jax.Array, scales: jax.Array,
                        phys: jax.Array, new_vals: jax.Array,
                        wrote: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Re-quantizing read-modify-write of whole pages.

    Gathers the ``phys`` pages [...] from ``pool`` [npg+1, P, Hkv, dh],
    dequantizes them with ``scales`` [npg+1, Hkv], overlays ``new_vals``
    [..., P, Hkv, dh] where ``wrote`` [..., P] is set, recomputes each
    page's amax scale and scatters pages + scales back.  Partial-page
    writes therefore re-quantize the whole page — the only correct RMW
    when the page's amax may have changed.

    Distinct non-trash entries of ``phys`` must name distinct pages (the
    scheduler's exclusive-write invariant; shared pages go copy-on-write
    at admission).  Duplicate *trash* entries race benignly: the trash
    page's contents are never attended (every consumer masks table
    entries equal to the trash id) and its scale stays finite."""
    ex = dequantize_pages(pool[phys], scales[phys])
    merged = jnp.where(wrote[..., None, None], new_vals.astype(jnp.float32),
                       ex)
    q, s = quantize_pages(merged, pool.dtype)
    return pool.at[phys].set(q), scales.at[phys].set(s)


def prefix_prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             ck: jax.Array, cv: jax.Array, off: jax.Array,
                             *, softcap: Optional[float] = None
                             ) -> jax.Array:
    """Suffix-prefill attention against a shared-prefix KV context.

    q/k/v [B,S,H(kv),dh] carry the *suffix* tokens at absolute positions
    ``off + i`` (rope already applied); ck/cv [B,C,Hkv,dh] are the prefix
    KV gathered from the paged pool in block order, so ctx token ``j``
    sits at absolute position ``j`` and is valid iff ``j < off`` (the
    tail of the gathered ctx is trash-page padding).  Used by the prefix-
    sharing admission path: prefill runs only on the suffix, attending to
    the prefix through pages it never recomputes."""
    b, s, h, dh = q.shape
    c = ck.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    if g > 1:
        # constrain once after each repeat, like chunked_attention: GSPMD
        # otherwise reshards the partially-sharded kv on every constraint
        k = sh.shard(jnp.repeat(k, g, axis=2), sh.BATCH, None, sh.HEADS, None)
        v = sh.shard(jnp.repeat(v, g, axis=2), sh.BATCH, None, sh.HEADS, None)
        ck = sh.shard(jnp.repeat(ck, g, axis=2),
                      sh.BATCH, None, sh.HEADS, None)
        cv = sh.shard(jnp.repeat(cv, g, axis=2),
                      sh.BATCH, None, sh.HEADS, None)
    kall = jnp.concatenate([ck.astype(q.dtype), k], axis=1)
    vall = jnp.concatenate([cv.astype(q.dtype), v], axis=1)
    scale = dh ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kall)
    scores = scores.astype(jnp.float32) * scale
    scores = _softcap(scores, softcap)
    qpos = off + jnp.arange(s)[:, None]                     # [S,1]
    kpos = jnp.concatenate([jnp.arange(c), off + jnp.arange(s)])
    kvalid = jnp.concatenate([jnp.arange(c) < off,
                              jnp.ones((s,), bool)])
    mask = (kpos[None, :] <= qpos) & kvalid[None, :]        # [S,C+S]
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(vall.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vall)


def paged_decode_step(q: jax.Array, kk: jax.Array, vv: jax.Array,
                      cache: Dict, cache_len: jax.Array, *,
                      window: Optional[int],
                      softcap: Optional[float],
                      paged_kernel: bool = False
                      ) -> Tuple[jax.Array, Dict]:
    """``S``-token attention against a block-paged KV pool (``S == 1`` is
    the plain decode step; ``S == K+1`` is the speculative verify step).

    cache: {"pk","pv": [num_pages+1, P, Hkv, dh], "pt": [B, ring_blocks],
    optional "wm": [B] bool write mask, optional "ks","vs":
    [num_pages+1, Hkv] per-page per-kv-head scales when the pool is
    8-bit quantized — writes then re-quantize whole pages (RMW) and the
    read side dequantizes, so fp32 K/V never exists at pool width}.
    Writes the new KV through the
    page table (write-then-attend, so every query token attends to
    itself and the drafted tokens before it), then either gathers the
    slot's logical ring and masks by ring validity (default), or — with
    ``paged_kernel=True`` — reads the pool *directly* through
    ``kernels/paged_attention`` (Pallas page streaming on TPU, pool-wide
    masked attention elsewhere) so the gathered ``[B, ring, Hkv, dh]``
    buffer never exists.  All shapes are static: the compiled decode
    chunk only indexes the table the host populated at admission.

    ``wm`` (the engine passes its ``active`` slot mask) redirects the
    writes of finished/idle slots to the trash page.  A slot that
    finishes mid-chunk keeps "decoding" until the next drain with its
    position still advancing — without the mask those dead writes would
    ring-wrap past the table into real pages, which under prefix sharing
    may be pages other slots (or the radix index) still read.

    Multi-token steps (``S > 1``) additionally trash-redirect any write
    whose absolute position falls outside the ring *unless the ring
    legitimately wraps* (``ring >= window + S - 1`` — the spec-slack
    sizing from ``serve/cache.CacheSpec``): a full-attention ring never
    wraps, so a draft written past the table must be discarded rather
    than alias block 0 (which may be a shared prefix page), and an
    under-sized windowed ring must likewise refuse the wrap because it
    would overwrite tokens still inside an earlier query's window.
    Rollback of rejected drafts is free: the accept step simply does not
    advance ``len`` past them, the ring-validity mask hides positions
    beyond ``len``, and the next step's writes land on the same (page,
    offset) cells."""
    pool_k, pool_v, pt = cache["pk"], cache["pv"], cache["pt"]
    ks, vs = cache.get("ks"), cache.get("vs")    # per-page scales (8-bit pool)
    quant = ks is not None
    b, s = q.shape[0], q.shape[1]
    page_size = pool_k.shape[1]

    def _result(out, pool_k, pool_v, ks, vs):
        new = {"pk": pool_k, "pv": pool_v}
        if quant:
            new["ks"], new["vs"] = ks, vs
        return out, new

    if s == 1:
        blocks = paged_ring_blocks(window, pt.shape[1], page_size)
        ring = blocks * page_size
        t = cache_len - 1                               # [B] current position
        lb = (t // page_size) % blocks                  # logical block
        phys = jnp.take_along_axis(pt[:, :blocks], lb[:, None], axis=1)[:, 0]
        wm = cache.get("wm")
        if wm is not None:
            phys = jnp.where(wm, phys, pool_k.shape[0] - 1)   # dead -> trash
        off = t % page_size
        k_new = kk[:, 0]                                # [B, Hkv, dh]
        v_new = vv[:, 0]
        # distinct live slots own every page they write (host invariant:
        # shared pages go copy-on-write at admission); idle/dead slots map
        # to the shared trash page where last-write-wins races are harmless
        if quant:
            bi = jnp.arange(b)
            wrote = jnp.zeros((b, page_size), bool).at[bi, off].set(True)
            shape = (b, page_size) + k_new.shape[1:]
            nk = jnp.zeros(shape, jnp.float32).at[bi, off].set(
                k_new.astype(jnp.float32))
            nv = jnp.zeros(shape, jnp.float32).at[bi, off].set(
                v_new.astype(jnp.float32))
            pool_k, ks = rmw_quantized_pages(pool_k, ks, phys, nk, wrote)
            pool_v, vs = rmw_quantized_pages(pool_v, vs, phys, nv, wrote)
        else:
            pool_k = pool_k.at[phys, off].set(k_new.astype(pool_k.dtype))
            pool_v = pool_v.at[phys, off].set(v_new.astype(pool_v.dtype))
        if paged_kernel:
            from repro.kernels.paged_attention import paged_attention
            out = paged_attention(q[:, 0], pool_k, pool_v, pt[:, :blocks],
                                  cache_len, window=window, softcap=softcap,
                                  k_scale=ks, v_scale=vs)
            return _result(out[:, None], pool_k, pool_v, ks, vs)
        gk = pool_k[pt[:, :blocks]]        # [B, blocks, P, Hkv, dh]
        gv = pool_v[pt[:, :blocks]]
        if quant:
            gk = dequantize_pages(gk, ks[pt[:, :blocks]])
            gv = dequantize_pages(gv, vs[pt[:, :blocks]])
        ck = jnp.moveaxis(gk.reshape(b, ring, *gk.shape[3:]), 1, 2)
        cv = jnp.moveaxis(gv.reshape(b, ring, *gv.shape[3:]), 1, 2)
        valid = ring_valid(cache_len, ring, window)
        out = decode_attention(q, ck, cv, cache_len, softcap=softcap,
                               valid=valid)
        return _result(out, pool_k, pool_v, ks, vs)
    # ---- multi-token verify step (speculative decoding); the table is
    # the layer's own group table, so its width IS the ring width
    blocks = pt.shape[1]
    ring = blocks * page_size
    trash = pool_k.shape[0] - 1
    g_pos = (cache_len - s)[:, None] + jnp.arange(s)[None, :]   # [B,S] abs
    lb = (g_pos // page_size) % blocks
    phys = jnp.take_along_axis(pt, lb, axis=1)                  # [B,S]
    ok = jnp.ones(g_pos.shape, bool)
    if not (window is not None and ring >= window + s - 1):
        ok &= g_pos < ring              # non-wrapping ring: no write aliasing
    wm = cache.get("wm")
    if wm is not None:
        # [B] slot mask (verify step) or [B,S] per-row mask (fused mixed
        # prefill+decode chunk: leading pad rows write to trash)
        ok &= wm if wm.ndim == 2 else wm[:, None]
    off = g_pos % page_size
    if quant:
        # page-granular RMW: the S tokens of a row touch at most
        # J = ceil((S-1)/P) + 1 consecutive logical pages starting at the
        # page of the earliest token.  Scatter tokens into per-page
        # overlays, then re-quantize each touched page once.
        J = (s - 1) // page_size + 2
        base = g_pos[:, :1] // page_size                 # [B,1] earliest page
        jtok = g_pos // page_size - base                 # [B,S] in [0, J)
        lp = base + jnp.arange(J)[None, :]               # [B,J] logical pages
        bi = jnp.arange(b)[:, None]
        page_live = jnp.zeros((b, J), bool).at[bi, jtok].max(ok)
        if J > blocks:
            # a ring narrower than the touched span aliases: of logical
            # pages congruent mod `blocks`, only the newest may be written
            page_live &= jnp.arange(J)[None, :] + blocks >= J
        pphys = jnp.take_along_axis(pt, lp % blocks, axis=1)     # [B,J]
        pphys = jnp.where(page_live, pphys, trash)
        wrote = jnp.zeros((b, J, page_size), bool).at[bi, jtok, off].max(ok)
        shape = (b, J, page_size) + kk.shape[2:]
        nk = jnp.zeros(shape, jnp.float32).at[bi, jtok, off].set(
            kk.astype(jnp.float32))
        nv = jnp.zeros(shape, jnp.float32).at[bi, jtok, off].set(
            vv.astype(jnp.float32))
        pool_k, ks = rmw_quantized_pages(pool_k, ks, pphys, nk, wrote)
        pool_v, vs = rmw_quantized_pages(pool_v, vs, pphys, nv, wrote)
    else:
        phys = jnp.where(ok, phys, trash)
        pool_k = pool_k.at[phys, off].set(kk.astype(pool_k.dtype))
        pool_v = pool_v.at[phys, off].set(vv.astype(pool_v.dtype))
    if paged_kernel:
        from repro.kernels.paged_attention import paged_attention
        out = paged_attention(q, pool_k, pool_v, pt, cache_len,
                              window=window, softcap=softcap,
                              k_scale=ks, v_scale=vs)
        return _result(out, pool_k, pool_v, ks, vs)
    gk = pool_k[pt]                    # [B, blocks, P, Hkv, dh]
    gv = pool_v[pt]
    if quant:
        gk = dequantize_pages(gk, ks[pt])
        gv = dequantize_pages(gv, vs[pt])
    ck = jnp.moveaxis(gk.reshape(b, ring, *gk.shape[3:]), 1, 2)
    cv = jnp.moveaxis(gv.reshape(b, ring, *gv.shape[3:]), 1, 2)
    u = ring_token_positions(cache_len, ring)                   # [B, ring]
    valid = (u >= 0)[:, None, :] & (u[:, None, :] <= g_pos[:, :, None])
    if window is not None:
        valid &= u[:, None, :] > g_pos[:, :, None] - window
    out = decode_attention(q, ck, cv, cache_len, softcap=softcap,
                           valid=valid)
    return _result(out, pool_k, pool_v, ks, vs)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def init_cache_shape(cfg: ModelConfig, batch: int, max_len: int
                     ) -> Tuple[Tuple[int, int, int, int], Tuple]:
    shape = (batch, cfg.num_kv_heads, max_len, cfg.resolved_head_dim)
    axes = (sh.BATCH, None, sh.KV_SEQ, None)
    return shape, axes


def apply(params: Dict, x: jax.Array, *, cfg: ModelConfig,
          window: Optional[int], positions: jax.Array,
          mode: str, cache: Optional[Dict] = None,
          cache_len: Optional[jax.Array] = None,
          causal: bool = True,
          q_chunk: Optional[int] = None,
          ctx: Optional[Dict] = None,
          paged_kernel: bool = False
          ) -> Tuple[jax.Array, Optional[Dict]]:
    """x [B,S,d] -> (y [B,S,d], new_cache | None).

    mode: "dense" (train / encoder: no cache), "prefill" (returns cache),
    "decode" (S==1; reads+updates cache; cache_len includes current token).

    ``paged_kernel`` (paged decode only): read KV straight from the page
    pool via ``kernels/paged_attention`` instead of gather-then-attend.

    ``ctx`` (prefill only): shared-prefix context for a *suffix* prefill —
    ``{"pk","pv": pool, "row": [Cb] page ids, "off": scalar}``.  The
    layer's queries sit at absolute positions ``off + i`` (``positions``
    must already carry the offset) and attend to the ``off`` prefix
    tokens gathered from the paged pool without recomputing them.
    """
    dt = x.dtype
    rules = sh.current_rules()
    cp = bool(rules and rules.context_parallel)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    kk = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    vv = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if cp and mode != "decode":
        # context parallelism: q stays seq-sharded (chunk slices align with
        # the shards); the narrow GQA kv is gathered across the model axis
        q = sh.shard(q, sh.BATCH, sh.SEQ, None, None)
        kk = sh.shard(kk, sh.BATCH, None, None, None)
        vv = sh.shard(vv, sh.BATCH, None, None, None)
    else:
        q = sh.shard(q, sh.BATCH, None, sh.HEADS, None)
        if mode != "decode" and cfg.num_kv_heads < cfg.num_heads:
            # GQA: replicate the narrow kv ONCE here; otherwise GSPMD
            # reshards the partially-sharded kv on every repeat/constraint
            # (4 gathers/layer measured on gemma3 — EXPERIMENTS.md §Perf)
            kk = sh.shard(kk, sh.BATCH, None, None, None)
            vv = sh.shard(vv, sh.BATCH, None, None, None)
    q = rope(q, positions, cfg.rope_theta)
    kk = rope(kk, positions, cfg.rope_theta)

    new_cache = None
    if mode == "prefill" and ctx is not None:
        # prefix sharing: gather the matched prefix KV from the paged pool
        # (block order == position order for the non-wrapping full-
        # attention group) and prefill only the suffix against it.
        gk = ctx["pk"][ctx["row"]]              # [Cb, P, Hkv, dh]
        gv = ctx["pv"][ctx["row"]]
        if ctx.get("ks") is not None:           # quantized pool: dequant the
            gk = dequantize_pages(gk, ctx["ks"][ctx["row"]])  # gathered pages
            gv = dequantize_pages(gv, ctx["vs"][ctx["row"]])
        cb, psz = gk.shape[0], gk.shape[1]
        ck = gk.reshape(1, cb * psz, *gk.shape[2:])
        cv = gv.reshape(1, cb * psz, *gv.shape[2:])
        out = prefix_prefill_attention(q, kk, vv, ck, cv, ctx["off"],
                                       softcap=cfg.attn_softcap)
        ck_new = sh.shard(jnp.swapaxes(kk, 1, 2),
                          sh.BATCH, None, sh.KV_SEQ, None)
        cv_new = sh.shard(jnp.swapaxes(vv, 1, 2),
                          sh.BATCH, None, sh.KV_SEQ, None)
        new_cache = {"k": ck_new, "v": cv_new}
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
        return sh.shard(y, sh.BATCH, sh.SEQ, sh.EMBED), new_cache
    if mode == "decode" and cache is not None and "pk" in cache:
        # block-paged KV (serve/cache.py): pool + page-table indirection
        out, new_cache = paged_decode_step(
            q, kk, vv, cache, cache_len, window=window,
            softcap=cfg.attn_softcap, paged_kernel=paged_kernel)
        y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
        return sh.shard(y, sh.BATCH, sh.SEQ, sh.EMBED), new_cache
    if mode == "decode":
        assert cache is not None and cache_len is not None
        if x.shape[1] != 1:
            raise NotImplementedError(
                "multi-token decode (speculative verify) needs a paged "
                "cache; the dense ring-buffer path is single-token only")
        k_new = jnp.swapaxes(kk, 1, 2)  # [B,Hkv,1,dh]
        v_new = jnp.swapaxes(vv, 1, 2)
        size = cache["k"].shape[2]
        # windowed layers keep a ring buffer of `window` slots; keys carry
        # absolute rope positions, so slot order does not matter and ring
        # occupancy enforces the window mask for free.
        idx = ((cache_len - 1) % size).astype(jnp.int32)
        ck = _update_cache(cache["k"], k_new, idx)
        cv = _update_cache(cache["v"], v_new, idx)
        ring = window is not None and size <= window
        out = decode_attention(q, ck, cv, cache_len,
                               window=None if ring else window,
                               softcap=cfg.attn_softcap)
        new_cache = {"k": ck, "v": cv}
    elif cp and x.shape[1] % max(rules.mesh_size(rules.table.get(sh.SEQ)), 1) == 0 \
            and rules.mesh_size(rules.table.get(sh.SEQ)) > 1:
        out = cp_attention(q, kk, vv, causal=causal, window=window,
                           softcap=cfg.attn_softcap)
        if mode == "prefill":
            ck = sh.shard(jnp.swapaxes(kk, 1, 2), sh.BATCH, None, sh.KV_SEQ, None)
            cv = sh.shard(jnp.swapaxes(vv, 1, 2), sh.BATCH, None, sh.KV_SEQ, None)
            new_cache = {"k": ck, "v": cv}
    else:
        out = chunked_attention(q, kk, vv, causal=causal, window=window,
                                softcap=cfg.attn_softcap, q_chunk=q_chunk)
        if mode == "prefill":
            ck = sh.shard(jnp.swapaxes(kk, 1, 2), sh.BATCH, None, sh.KV_SEQ, None)
            cv = sh.shard(jnp.swapaxes(vv, 1, 2), sh.BATCH, None, sh.KV_SEQ, None)
            new_cache = {"k": ck, "v": cv}
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return sh.shard(y, sh.BATCH, sh.SEQ, sh.EMBED), new_cache


def _update_cache(cache: jax.Array, new: jax.Array, idx: jax.Array) -> jax.Array:
    """Write ``new`` [B,Hkv,1,dh] at sequence position ``idx`` [B]."""
    # one-hot masked update keeps the S dim sharded (no gather/scatter resharding)
    s = cache.shape[2]
    onehot = (jnp.arange(s)[None, :] == idx[:, None])  # [B,S]
    onehot = onehot[:, None, :, None]
    return jnp.where(onehot, new.astype(cache.dtype), cache)


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_defs(cfg: ModelConfig) -> Dict:
    return attn_defs(cfg)


def cross_apply(params: Dict, x: jax.Array, enc_kv: Dict, *,
                cfg: ModelConfig) -> jax.Array:
    """x [B,S,d]; enc_kv {"k","v": [B,Henc_kv,Senc,dh]} precomputed."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    q = sh.shard(q, sh.BATCH, None, sh.HEADS, None)
    k = jnp.swapaxes(enc_kv["k"], 1, 2)  # [B,Senc,Hkv,dh]
    v = jnp.swapaxes(enc_kv["v"], 1, 2)
    out = chunked_attention(q, k.astype(dt), v.astype(dt), causal=False)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return sh.shard(y, sh.BATCH, sh.SEQ, sh.EMBED)


def encode_kv(params: Dict, enc_out: jax.Array, *, cfg: ModelConfig) -> Dict:
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"].astype(dt))
    return {"k": jnp.swapaxes(k, 1, 2), "v": jnp.swapaxes(v, 1, 2)}
