"""Model assembly: every assigned architecture from one block vocabulary.

The per-layer python loop is deliberately *unrolled* (see DESIGN.md S6):
HLO flops are exact for the roofline and heterogeneous stacks (zamba2's
shared blocks, gemma's window alternation) need no scan gymnastics.

Public entry points (all pure functions of (params, batch)):
    forward_train(params, cfg, batch)            -> (loss, metrics)
    forward_prefill(params, cfg, batch)          -> (logits_last, caches)
    forward_decode(params, cfg, tokens, caches, cache_len) -> (logits, caches)
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, FFN_DENSE, FFN_MOE, FFN_NONE, FFN_RWKV,
                                MAMBA2, RWKV6, SHARED_ATTN, BlockSpec,
                                ModelConfig)
from repro.models import attention, layers, mamba2, moe, module as m, rwkv6
from repro.parallel import sharding as sh


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------

def _block_defs(cfg: ModelConfig, block: BlockSpec) -> Dict:
    defs: Dict[str, Any] = {"ln1": layers.rmsnorm_defs(cfg.d_model)}
    if block.mixer == ATTN:
        defs["mixer"] = attention.attn_defs(cfg)
    elif block.mixer == MAMBA2:
        defs["mixer"] = mamba2.mamba2_defs(cfg)
    elif block.mixer == RWKV6:
        defs["mixer"] = rwkv6.time_mix_defs(cfg)
    elif block.mixer == SHARED_ATTN:
        pass  # parameters live in the shared groups
    else:
        raise ValueError(block.mixer)
    if block.ffn != FFN_NONE and block.mixer != SHARED_ATTN:
        defs["ln2"] = layers.rmsnorm_defs(cfg.d_model)
        if block.ffn == FFN_DENSE:
            defs["ffn"] = layers.mlp_defs(cfg)
        elif block.ffn == FFN_MOE:
            defs["ffn"] = moe.moe_defs(cfg)
        elif block.ffn == FFN_RWKV:
            defs["ffn"] = rwkv6.channel_mix_defs(cfg)
        else:
            raise ValueError(block.ffn)
    return defs


def _shared_group_defs(cfg: ModelConfig) -> Dict:
    """zamba2 shared transformer block: operates on concat(h, h0) -> d."""
    d = cfg.d_model
    return {
        "proj_in": m.ParamDef((2 * d, d), (m.EMBED, None)),
        "ln_attn": layers.rmsnorm_defs(d),
        "attn": attention.attn_defs(cfg),
        "ln_mlp": layers.rmsnorm_defs(d),
        "mlp": layers.mlp_defs(cfg),
    }


def _encoder_block_defs(cfg: ModelConfig) -> Dict:
    return {
        "ln1": layers.rmsnorm_defs(cfg.d_model),
        "attn": attention.attn_defs(cfg),
        "ln2": layers.rmsnorm_defs(cfg.d_model),
        "ffn": layers.mlp_defs(cfg),
    }


def _decoder_cross_defs(cfg: ModelConfig) -> Dict:
    return {
        "ln_cross": layers.rmsnorm_defs(cfg.d_model),
        "cross": attention.cross_attn_defs(cfg),
    }


def model_defs(cfg: ModelConfig) -> Dict:
    defs: Dict[str, Any] = {
        "embed": layers.embedding_defs(cfg),
        "final_ln": layers.rmsnorm_defs(cfg.d_model),
        "layers": [_block_defs(cfg, b) for b in cfg.blocks],
    }
    if cfg.num_shared_groups:
        defs["shared"] = [_shared_group_defs(cfg)
                          for _ in range(cfg.num_shared_groups)]
    if cfg.cross_attention:
        for i in range(cfg.num_layers):
            defs["layers"][i].update(_decoder_cross_defs(cfg))
    if cfg.enc_layers:
        defs["encoder"] = {
            "pos": m.ParamDef((cfg.frontend_len, cfg.d_model),
                              (None, m.EMBED), init="normal", scale=0.02),
            "layers": [_encoder_block_defs(cfg) for _ in range(cfg.enc_layers)],
            "final_ln": layers.rmsnorm_defs(cfg.d_model),
        }
    return defs


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _apply_block(lp: Dict, shared_params: Optional[List[Dict]], h: jax.Array,
                 h0: jax.Array, cfg: ModelConfig, block: BlockSpec, *,
                 mode: str, positions: jax.Array,
                 cache: Optional[Dict], cache_len: Optional[jax.Array],
                 enc_kv: Optional[Dict], q_chunk: Optional[int],
                 length: Optional[jax.Array] = None,
                 ctx: Optional[Dict] = None,
                 paged_kernel: bool = False
                 ) -> Tuple[jax.Array, Optional[Dict], Dict]:
    """One decoder layer. Returns (h, new_cache, aux).

    ``length`` [B]: true lengths of right-padded prefill inputs (bucketed
    prefill).  Attention needs no masking for right padding (causality
    already hides later positions); the recurrent mixers use it to carry
    state as of the last valid token.

    ``ctx``: shared-prefix context for a suffix prefill (prefix sharing);
    only full-attention layers can consume it — the capability gate in
    ``serve/cache.CacheSpec.share_group_key`` guarantees no other layer
    kind is present when it is set."""
    aux: Dict[str, jax.Array] = {}
    new_cache: Optional[Dict] = None

    if block.mixer == SHARED_ATTN:
        sp = shared_params[block.shared_group]
        xin = jnp.concatenate([h, h0], axis=-1)
        x = jnp.dot(xin, sp["proj_in"].astype(h.dtype))
        x = sh.shard(x, sh.BATCH, sh.SEQ, sh.EMBED)
        y, new_cache = attention.apply(
            sp["attn"], layers.rmsnorm(sp["ln_attn"], sh.sp_boundary(x),
                                       cfg.norm_eps),
            cfg=cfg, window=block.window, positions=positions, mode=mode,
            cache=cache, cache_len=cache_len, q_chunk=q_chunk,
            paged_kernel=paged_kernel)
        x = x + y
        x = x + layers.mlp(sp["mlp"],
                           layers.rmsnorm(sp["ln_mlp"], sh.sp_boundary(x),
                                          cfg.norm_eps))
        return h + x, new_cache, aux

    if ctx is not None and block.mixer != ATTN:
        raise ValueError(
            f"prefix-sharing suffix prefill reached a {block.mixer} layer; "
            "only pure full-attention stacks are sharing-capable")
    xn = layers.rmsnorm(lp["ln1"], sh.sp_boundary(h), cfg.norm_eps)
    if block.mixer == ATTN:
        y, new_cache = attention.apply(
            lp["mixer"], xn, cfg=cfg, window=block.window,
            positions=positions, mode=mode, cache=cache, cache_len=cache_len,
            q_chunk=q_chunk, ctx=ctx, paged_kernel=paged_kernel)
    elif block.mixer == MAMBA2:
        y, new_cache = mamba2.apply(lp["mixer"], xn, cfg, mode=mode,
                                    state=cache, length=length)
    elif block.mixer == RWKV6:
        y, tm_state = rwkv6.time_mix(lp["mixer"], xn, cfg, mode=mode,
                                     state=cache, length=length)
        new_cache = tm_state
    else:
        raise ValueError(block.mixer)
    h = h + y

    if cfg.cross_attention and enc_kv is not None:
        y = attention.cross_apply(
            lp["cross"], layers.rmsnorm(lp["ln_cross"], sh.sp_boundary(h),
                                        cfg.norm_eps),
            enc_kv, cfg=cfg)
        h = h + y

    if block.ffn != FFN_NONE:
        xn = layers.rmsnorm(lp["ln2"], sh.sp_boundary(h), cfg.norm_eps)
        if block.ffn == FFN_DENSE:
            y = layers.mlp(lp["ffn"], xn)
        elif block.ffn == FFN_MOE:
            y, moe_aux = moe.apply(lp["ffn"], xn, cfg)
            aux.update(moe_aux)
        elif block.ffn == FFN_RWKV:
            y, cm_state = rwkv6.channel_mix(lp["ffn"], xn, cfg, mode=mode,
                                            state=cache, length=length)
            if cm_state is not None:
                new_cache = {**(new_cache or {}), **cm_state}
        else:
            raise ValueError(block.ffn)
        h = h + y
    return h, new_cache, aux


def _decoder(params, cfg: ModelConfig, h: jax.Array, *, mode: str,
             positions: jax.Array, caches: Optional[List],
             cache_len: Optional[jax.Array], enc_kv_list: Optional[List],
             q_chunk: Optional[int], remat: bool = False,
             length: Optional[jax.Array] = None,
             ctx_list: Optional[List] = None,
             paged_kernel: bool = False
             ) -> Tuple[jax.Array, Optional[List], Dict]:
    h0 = h
    shared = params.get("shared")
    new_caches: List = []
    aux_all: Dict[str, jax.Array] = {}
    for i, block in enumerate(cfg.blocks):
        cache_i = caches[i] if caches is not None else None
        ctx_i = ctx_list[i] if ctx_list is not None else None
        enc_kv = enc_kv_list[i] if enc_kv_list is not None else None
        if remat and mode == "dense":
            def blockfn(lp_, shared_, h_, h0_, enc_kv_, pos_, _block=block):
                return _apply_block(lp_, shared_, h_, h0_, cfg, _block,
                                    mode=mode, positions=pos_, cache=None,
                                    cache_len=None, enc_kv=enc_kv_,
                                    q_chunk=q_chunk)
            h, nc, aux = jax.checkpoint(blockfn)(
                params["layers"][i], shared, h, h0, enc_kv, positions)
        else:
            h, nc, aux = _apply_block(
                params["layers"][i], shared, h, h0, cfg, block, mode=mode,
                positions=positions, cache=cache_i, cache_len=cache_len,
                enc_kv=enc_kv, q_chunk=q_chunk, length=length, ctx=ctx_i,
                paged_kernel=paged_kernel)
        new_caches.append(nc)
        for k_, v_ in aux.items():
            aux_all[k_] = aux_all.get(k_, 0.0) + v_ / cfg.num_layers
    h = layers.rmsnorm(params["final_ln"], h, cfg.norm_eps)
    return h, (new_caches if mode in ("prefill", "decode") else None), aux_all


def _encoder(params, cfg: ModelConfig, frames: jax.Array,
             q_chunk: Optional[int]) -> jax.Array:
    """Whisper encoder over stub frame embeddings [B,F,d]."""
    enc = params["encoder"]
    h = frames + enc["pos"].astype(frames.dtype)[None, :frames.shape[1]]
    h = sh.shard(h, sh.BATCH, sh.SEQ, sh.EMBED)
    positions = jnp.arange(frames.shape[1])
    for i in range(cfg.enc_layers):
        lp = enc["layers"][i]
        xn = layers.rmsnorm(lp["ln1"], sh.sp_boundary(h), cfg.norm_eps)
        y, _ = attention.apply(lp["attn"], xn, cfg=cfg, window=None,
                               positions=positions, mode="dense",
                               causal=False, q_chunk=q_chunk)
        h = h + y
        xn2 = layers.rmsnorm(lp["ln2"], sh.sp_boundary(h), cfg.norm_eps)
        h = h + layers.mlp(lp["ffn"], xn2)
    return layers.rmsnorm(enc["final_ln"], h, cfg.norm_eps)


def _embed_with_frontend(params, cfg: ModelConfig, tokens: jax.Array,
                         frontend: Optional[jax.Array]) -> jax.Array:
    h = layers.embed(params["embed"], cfg, tokens)
    if frontend is not None and cfg.frontend and cfg.family != "audio":
        f = frontend.shape[1]
        prefix = frontend.astype(h.dtype)
        h = jnp.concatenate([prefix, h[:, f:]], axis=1)
        h = sh.shard(h, sh.BATCH, sh.SEQ, sh.EMBED)
    return h


def _cross_kv_list(params, cfg: ModelConfig, enc_out: jax.Array) -> List[Dict]:
    return [attention.encode_kv(params["layers"][i]["cross"], enc_out, cfg=cfg)
            for i in range(cfg.num_layers)]


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def forward_train(params, cfg: ModelConfig, batch: Dict, *,
                  q_chunk: Optional[int] = None, remat: bool = False
                  ) -> Tuple[jax.Array, Dict]:
    tokens = batch["tokens"]
    labels = batch["labels"]
    positions = jnp.arange(tokens.shape[1])
    enc_kv_list = None
    if cfg.family == "audio":
        enc_out = _encoder(params, cfg, batch["frames"], q_chunk)
        enc_kv_list = _cross_kv_list(params, cfg, enc_out)
    h = _embed_with_frontend(params, cfg, tokens, batch.get("frontend"))
    h, _, aux = _decoder(params, cfg, h, mode="dense", positions=positions,
                         caches=None, cache_len=None,
                         enc_kv_list=enc_kv_list, q_chunk=q_chunk,
                         remat=remat)
    lg = layers.logits(params["embed"], cfg, h)
    mask = batch.get("loss_mask")
    if mask is None and cfg.frontend and cfg.family != "audio":
        mask = (jnp.arange(tokens.shape[1]) >= cfg.frontend_len)[None, :]
        mask = jnp.broadcast_to(mask, labels.shape)
    loss = layers.cross_entropy(lg, labels, mask)
    if "load_balance_loss" in aux:
        loss = loss + 0.01 * aux["load_balance_loss"]
    metrics = {"loss": loss, **aux}
    return loss, metrics


def forward_dense_logits(params, cfg: ModelConfig, batch: Dict, *,
                         q_chunk: Optional[int] = None) -> jax.Array:
    """Full-sequence logits (teacher-forced), for tests/evaluation."""
    tokens = batch["tokens"]
    positions = jnp.arange(tokens.shape[1])
    enc_kv_list = None
    if cfg.family == "audio":
        enc_out = _encoder(params, cfg, batch["frames"], q_chunk)
        enc_kv_list = _cross_kv_list(params, cfg, enc_out)
    h = _embed_with_frontend(params, cfg, tokens, batch.get("frontend"))
    h, _, _ = _decoder(params, cfg, h, mode="dense", positions=positions,
                       caches=None, cache_len=None, enc_kv_list=enc_kv_list,
                       q_chunk=q_chunk)
    return layers.logits(params["embed"], cfg, h)


def forward_prefill(params, cfg: ModelConfig, batch: Dict, *,
                    q_chunk: Optional[int] = None,
                    length: Optional[jax.Array] = None,
                    ctx: Optional[Dict] = None
                    ) -> Tuple[jax.Array, Dict]:
    """Returns (last-token logits [B,vocab], cache pytree).

    ``length`` [B] int32: true prompt lengths when ``tokens`` is
    right-padded to a shape bucket.  Logits are taken at position
    ``length - 1`` and the cache records ``length`` valid tokens, so a
    small fixed set of padded shapes serves every prompt length with no
    retrace (serve/engine.py's bucketed prefill).

    ``ctx`` turns this into a *suffix* prefill for prefix sharing:
    ``{"off": scalar int32, "row": [Cb] int32, "layers": [per-layer
    {"pk","pv"} pools]}``.  ``tokens`` then holds only the suffix (at
    absolute positions ``off + i``); each attention layer attends to the
    ``off`` matched prefix tokens by gathering the shared pages named in
    ``row`` from its pool.  The returned cache carries suffix KV only —
    the caller splices it at token offset ``off``."""
    tokens = batch["tokens"]
    positions = jnp.arange(tokens.shape[1])
    ctx_list = None
    if ctx is not None:
        positions = ctx["off"] + positions
        ctx_list = [None if lc is None else
                    {"pk": lc["pk"], "pv": lc["pv"],
                     "ks": lc.get("ks"), "vs": lc.get("vs"),
                     "row": ctx["row"], "off": ctx["off"]}
                    for lc in ctx["layers"]]
    enc_kv_list = None
    if cfg.family == "audio":
        enc_out = _encoder(params, cfg, batch["frames"], q_chunk)
        enc_kv_list = _cross_kv_list(params, cfg, enc_out)
    h = _embed_with_frontend(params, cfg, tokens, batch.get("frontend"))
    h, caches, _ = _decoder(params, cfg, h, mode="prefill",
                            positions=positions, caches=None, cache_len=None,
                            enc_kv_list=enc_kv_list, q_chunk=q_chunk,
                            length=length, ctx_list=ctx_list)
    if length is None:
        h_last = h[:, -1:]
        clen = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
    else:
        idx = jnp.clip(length - 1, 0)[:, None, None]
        h_last = jnp.take_along_axis(
            h, jnp.broadcast_to(idx, (h.shape[0], 1, h.shape[2])), axis=1)
        clen = length.astype(jnp.int32)
    lg = layers.logits(params["embed"], cfg, h_last)
    cache = {"layers": caches, "enc_kv": enc_kv_list, "len": clen}
    return lg[:, 0], cache


def _thread_page_tables(cfg: ModelConfig, cache: Dict,
                        write_mask: Optional[jax.Array],
                        spec_slack: int = 0) -> List:
    """Thread each paged layer's pool-group page table (keyed by ring
    width — ``attention.page_group_key``) and the optional write mask
    into its cache view.  ``spec_slack`` must match the ``spec_tokens``
    the ``serve/cache.CacheSpec`` was built with, so the ring width
    derived here agrees with the width the splice used."""
    page_tables = cache.get("page_tables")
    layer_caches = cache["layers"]
    if not page_tables:
        return layer_caches
    widest = max(t.shape[1] for t in page_tables.values())
    threaded = []
    for block, c in zip(cfg.blocks, layer_caches):
        if c is not None and "pk" in c:
            ring = attention.paged_ring_blocks(
                block.window, widest, c["pk"].shape[1], spec_slack)
            c = dict(c, pt=page_tables[attention.page_group_key(ring)])
            if write_mask is not None:
                c["wm"] = write_mask
        threaded.append(c)
    return threaded


def forward_decode(params, cfg: ModelConfig, tokens: jax.Array,
                   cache: Dict, write_mask: Optional[jax.Array] = None,
                   paged_kernel: bool = False
                   ) -> Tuple[jax.Array, Dict]:
    """tokens [B,1]; cache from prefill (or abstract).  cache["len"] is the
    number of tokens already in the cache (excluding this one).

    A cache carrying ``page_tables`` uses the block-paged KV layout from
    ``serve/cache.py``: one table per pool group, keyed by ring width
    (``attention.page_group_key``).  Each paged layer's table is threaded
    into its cache view (``pt``) on the way in — the layer's group is
    recovered from its window and the widest table's width — and the
    tables are owned once at the top level on the way out, so the
    scan-carry structure stays stable.

    ``write_mask`` [B] bool (paged path only): rows that may write KV
    this step; the serving engine passes its ``active`` slot mask so the
    dead tail of a fused chunk (finished slots keep stepping until the
    drain) lands on the trash page instead of wrapping into pages that
    may now be shared with other slots or the radix prefix index.

    ``paged_kernel`` (paged caches only): attention layers read KV
    straight from the page pools via ``kernels/paged_attention`` —
    Pallas page streaming on TPU, pool-wide masked attention elsewhere —
    instead of gathering each slot's ring into a contiguous buffer."""
    b = tokens.shape[0]
    cache_len = cache["len"] + 1         # including current token
    positions = cache["len"][:, None]    # 0-based position of current token
    layer_caches = _thread_page_tables(cfg, cache, write_mask)
    h = layers.embed(params["embed"], cfg, tokens)
    h, new_caches, _ = _decoder(params, cfg, h, mode="decode",
                                positions=positions, caches=layer_caches,
                                cache_len=cache_len,
                                enc_kv_list=cache.get("enc_kv"), q_chunk=None,
                                paged_kernel=paged_kernel)
    lg = layers.logits(params["embed"], cfg, h)
    new_cache = {"layers": new_caches, "enc_kv": cache.get("enc_kv"),
                 "len": cache_len}
    page_tables = cache.get("page_tables")
    if page_tables is not None:   # {} for stateless archs: keep structure
        new_cache["page_tables"] = page_tables
    return lg[:, 0], new_cache


def forward_verify(params, cfg: ModelConfig, tokens: jax.Array,
                   cache: Dict, write_mask: Optional[jax.Array] = None,
                   paged_kernel: bool = False, spec_slack: int = 0,
                   n_rows: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, Dict]:
    """Speculative verify: run the target model on ``S = K+1`` tokens per
    slot — the current token plus ``K`` drafted continuations — in ONE
    dispatch.  tokens [B,S]; token ``i`` sits at absolute position
    ``cache["len"] + i`` and its KV is written through the page table
    (write-then-attend with a per-query causal ring mask, see
    ``models/attention.paged_decode_step``).  Returns logits for *all*
    ``S`` positions ([B,S,V] — logits[i] is the target distribution of
    the token after input ``i``) and the cache with ``len`` left
    UNCHANGED: the accept/reject step (``serve/sampling.spec_accept``)
    owns the length update, which is also how rejected drafts roll back
    — positions past the accepted length are invisible to the ring
    validity mask and are simply overwritten by later steps.

    Only paged, attention-only stacks support this (``serve/spec``
    gates): recurrent STATE layers cannot rewind a multi-token state
    update without materializing every intermediate state.

    ``spec_slack`` must equal the draft length ``K`` the serving
    ``CacheSpec`` was built with (windowed rings carry ``K`` tokens of
    slack so in-flight drafts never wrap onto in-window history).

    ``n_rows`` [B] int (fused mixed prefill+decode chunks): per-slot
    count of *real* query rows, **right-aligned** — slot ``b``'s live
    tokens occupy rows ``S - n_rows[b] .. S - 1`` and the leading rows
    are padding.  ``cache_len`` becomes ``len + n_rows`` per slot, and
    positions are shifted so row ``S-1`` sits at ``len + n_rows - 1``
    (pad rows clip to position 0 and must be write-masked via a 2-D
    ``write_mask``).  Right alignment keeps every real position strictly
    below the slot's logical length, so ring-validity masks never see a
    phantom wrap from uniform-``S`` padding."""
    b, s = tokens.shape
    if n_rows is None:
        cache_len = cache["len"] + s     # including all s query tokens
        positions = cache["len"][:, None] + jnp.arange(s)[None, :]
    else:
        cache_len = cache["len"] + n_rows
        positions = jnp.clip(
            cache["len"][:, None] + jnp.arange(s)[None, :]
            - (s - n_rows)[:, None], 0)
    layer_caches = _thread_page_tables(cfg, cache, write_mask, spec_slack)
    h = layers.embed(params["embed"], cfg, tokens)
    h, new_caches, _ = _decoder(params, cfg, h, mode="decode",
                                positions=positions, caches=layer_caches,
                                cache_len=cache_len,
                                enc_kv_list=cache.get("enc_kv"),
                                q_chunk=None, paged_kernel=paged_kernel)
    lg = layers.logits(params["embed"], cfg, h)
    return lg, dict(cache, layers=new_caches)


def prepare_decode_cache(cfg: ModelConfig, cache: Dict, max_len: int) -> Dict:
    """Grow a prefill cache (seq dims sized to the prompt) into a decode
    cache sized for ``max_len`` steps.  Windowed layers keep their ring
    size; if the prompt exceeded the ring, keep the last ``window`` tokens
    rolled so token t sits at slot ``t % size`` (the decode write rule)."""
    plen = int(cache["len"][0]) if cache["len"].shape else int(cache["len"])

    def grow(x, target):
        if x is None:
            return None
        size = x.shape[2]
        if size >= target:
            return x
        pad = [(0, 0)] * x.ndim
        pad[2] = (0, target - size)
        return jnp.pad(x, pad)

    new_layers = []
    for block, entry in zip(cfg.blocks, cache["layers"]):
        if entry is not None and "k" in entry:
            ring = min(max_len, block.window or max_len)
            e = dict(entry)
            for key in ("k", "v"):
                x = e[key]
                if x.shape[2] > ring:  # prompt longer than the window ring
                    x = x[:, :, -ring:]
                    x = jnp.roll(x, plen % ring, axis=2)
                e[key] = grow(x, ring)
            new_layers.append(e)
        else:
            new_layers.append(entry)
    out = dict(cache)
    out["layers"] = new_layers
    return out


# ---------------------------------------------------------------------------
# Cache / state structure (shapes + logical axes) for input_specs
# ---------------------------------------------------------------------------

def cache_structure(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Nested {name: (shape, logical_axes)} mirroring the runtime cache."""
    per_layer: List[Optional[Dict]] = []
    for block in cfg.blocks:
        if block.mixer in (ATTN, SHARED_ATTN):
            shape, axes = attention.init_cache_shape(
                cfg, batch, min(max_len, block.window or max_len))
            entry = {"k": (shape, axes), "v": (shape, axes)}
        elif block.mixer == MAMBA2:
            entry = {k: v for k, v in mamba2.state_shapes(cfg, batch).items()}
        elif block.mixer == RWKV6:
            entry = {k: v for k, v in rwkv6.state_shapes(cfg, batch).items()}
        else:
            entry = None
        per_layer.append(entry)
    out: Dict[str, Any] = {"layers": per_layer,
                           "len": ((batch,), (sh.BATCH,))}
    if cfg.cross_attention:
        kv_shape = (batch, cfg.num_kv_heads, cfg.frontend_len,
                    cfg.resolved_head_dim)
        kv_axes = (sh.BATCH, None, None, None)
        out["enc_kv"] = [{"k": (kv_shape, kv_axes), "v": (kv_shape, kv_axes)}
                         for _ in range(cfg.num_layers)]
    return out
