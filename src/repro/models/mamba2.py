"""Mamba2 (SSD) mixer for the zamba2 backbone.

Train/prefill use the chunked SSD algorithm (intra-chunk "attention-like"
matmuls + inter-chunk state recurrence via an associative scan), which is
both the HLO-friendly XLA path and the blueprint for the Pallas kernel in
``repro.kernels.mamba2_scan``.  Decode is the O(1) recurrent update.

State layout per layer:
  conv:  [B, W-1, d_conv]     (last conv_width-1 inputs)
  ssm:   [B, H, N, P]         (per-head state matrix)
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import module as m
from repro.models.layers import rmsnorm, rmsnorm_defs
from repro.parallel import sharding as sh


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    nheads = d_inner // ssm.head_dim
    return d_inner, nheads, ssm.state_dim, ssm.head_dim


def mamba2_defs(cfg: ModelConfig) -> Dict:
    ssm = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, n, p = dims(cfg)
    # Projections are split (z / x / BC / dt) so every output dim shards
    # cleanly on the model axis without boundary-crossing slices.
    return {
        "wz": m.ParamDef((d, d_inner), (m.EMBED, m.SSM_INNER)),
        "wx": m.ParamDef((d, d_inner), (m.EMBED, m.SSM_INNER)),
        "wbc": m.ParamDef((d, 2 * n), (m.EMBED, None)),
        "wdt": m.ParamDef((d, nheads), (m.EMBED, m.HEADS)),
        "conv_w": m.ParamDef((ssm.conv_width, d_inner), (None, m.SSM_INNER),
                             init="normal", scale=0.5),
        "conv_b": m.ParamDef((d_inner,), (m.SSM_INNER,), init="zeros"),
        "conv_w_bc": m.ParamDef((ssm.conv_width, 2 * n), (None, None),
                                init="normal", scale=0.5),
        "conv_b_bc": m.ParamDef((2 * n,), (None,), init="zeros"),
        "a_log": m.ParamDef((nheads,), (m.HEADS,), init="custom",
                            custom=lambda k: jnp.log(
                                jax.random.uniform(k, (nheads,), minval=1.0,
                                                   maxval=16.0))),
        "dt_bias": m.ParamDef((nheads,), (m.HEADS,), init="zeros"),
        "d_skip": m.ParamDef((nheads,), (m.HEADS,), init="ones"),
        "norm": rmsnorm_defs(d_inner),
        "out_proj": m.ParamDef((d_inner, d), (m.SSM_INNER, m.EMBED)),
    }


def _conv(w: jax.Array, b: jax.Array, x: jax.Array,
          conv_state: Optional[jax.Array], width: int,
          length: Optional[jax.Array] = None
          ) -> Tuple[jax.Array, jax.Array]:
    """Causal depthwise conv, width taps, via static shifted adds.

    x [B,S,C] -> (y [B,S,C], new_state [B,W-1,C]).  When ``length`` [B] is
    given (right-padded prefill), the carried state is the last ``W-1``
    inputs *before* the padding, so decode resumes from the true prompt
    end rather than from pad garbage."""
    bsz, s, c = x.shape
    if conv_state is None:
        conv_state = jnp.zeros((bsz, width - 1, c), x.dtype)
    full = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = b.astype(x.dtype)[None, None]
    for i in range(width):  # static taps
        y = y + full[:, i:i + s] * w[i].astype(x.dtype)
    if length is None:
        new_state = full[:, -(width - 1):]
    else:
        # token t sits at full[:, (W-1)+t]; want tokens length-W+1..length-1,
        # i.e. full[:, length : length+W-1] (length==0 recovers the initial
        # state slice full[:, :W-1] exactly).
        idx = length[:, None] + jnp.arange(width - 1)[None, :]
        new_state = jnp.take_along_axis(full, idx[..., None], axis=1)
    return jax.nn.silu(y), new_state


def ssd_chunked(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                b_in: jax.Array, c_in: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x [B,S,H,P], dt [B,S,H] (pre-softplus'd, >0), a_log [H],
    b_in/c_in [B,S,N] (n_groups=1, shared across heads).
    Returns (y [B,S,H,P], final_state [B,H,N,P]).
    """
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    f32 = jnp.float32

    a = -jnp.exp(a_log.astype(f32))                     # [H], negative
    da = dt.astype(f32) * a                             # [B,S,H] log decays
    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h).astype(f32)
    dac = da.reshape(bsz, nc, q, h)
    bc = b_in.reshape(bsz, nc, q, n).astype(f32)
    cc = c_in.reshape(bsz, nc, q, n).astype(f32)

    cum = jnp.cumsum(dac, axis=2)                       # [B,nc,Q,H] inclusive
    cum_end = cum[:, :, -1]                             # [B,nc,H]

    # dt folded into x up-front: one fewer elementwise pass over the big
    # [B,nc,Q,Q,H] intra-chunk tensor (EXPERIMENTS.md §Perf, zamba2 climb)
    xdt = xc.astype(f32) * dtc[..., None]               # [B,nc,Q,H,P]

    # ---- intra-chunk: y[t] += sum_{j<=t} exp(cum_t - cum_j) * (c_t.b_j) dt_j x_j
    lmat = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # [B,nc,Q,Q,H]
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    # clamp masked (i<j) entries BEFORE exp: exp(+large) would be inf and
    # the where() cotangent would produce 0 * inf = NaN in the backward
    decay = jnp.where(mask, jnp.exp(jnp.where(mask, lmat, -60.0)), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)           # [B,nc,Q,Q]
    mt = scores[..., None] * decay                           # [B,nc,Q,Q,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", mt, xdt)

    # ---- chunk states: S_c = sum_j exp(cum_end - cum_j) dt_j b_j x_j^T
    kdec = jnp.exp(cum_end[:, :, None] - cum)                # [B,nc,Q,H]
    chunk_kv = jnp.einsum("bcjn,bcjh,bcjhp->bchnp",
                          bc, kdec, xdt)                     # [B,nc,H,N,P]

    # ---- inter-chunk recurrence via associative scan
    aa = jnp.exp(cum_end)                                    # [B,nc,H]
    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a2 * a1, s1 * a2[..., None, None] + s2
    a_pref, s_pref = jax.lax.associative_scan(combine, (aa, chunk_kv), axis=1)
    # state *before* each chunk (shift right; h0 feeds chunk 0):
    # h_before[c] = s_pref[c-1] + h0 * a_pref[c-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, h, n, p), f32)
    else:
        h0 = h0.astype(f32)
    h_before = jnp.concatenate(
        [h0[:, None],
         s_pref[:, :-1] + h0[:, None] * a_pref[:, :-1][..., None, None]],
        axis=1)
    h_final = s_pref[:, -1] + h0 * a_pref[:, -1][..., None, None]

    # ---- inter-chunk contribution: y[t] += exp(cum_t) * c_t . h_before
    y_inter = jnp.einsum("bcin,bchnp->bcihp", cc, h_before) * \
        jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(x.dtype), h_final


def apply(params, x: jax.Array, cfg: ModelConfig, *, mode: str = "dense",
          state: Optional[Dict] = None,
          length: Optional[jax.Array] = None
          ) -> Tuple[jax.Array, Optional[Dict]]:
    """x [B,S,d] -> (y [B,S,d], new_state | None).

    ``length`` [B] (prefill only): true prompt lengths for right-padded
    inputs.  Padded steps get dt == 0, i.e. exp(dt*a) == 1 decay and zero
    input contribution, so the carried SSM state is exactly the state as of
    position ``length - 1`` while all shapes stay bucket-padded."""
    ssm = cfg.ssm
    d_inner, nheads, n, p = dims(cfg)
    dt_ = x.dtype
    z = jnp.dot(x, params["wz"].astype(dt_))
    z = sh.shard(z, sh.BATCH, None, sh.MLP)
    xs_raw = jnp.dot(x, params["wx"].astype(dt_))
    xs_raw = sh.shard(xs_raw, sh.BATCH, None, sh.MLP)
    bc_raw = jnp.dot(x, params["wbc"].astype(dt_))
    dt_raw = jnp.dot(x, params["wdt"].astype(dt_))

    cs = state["conv"] if state is not None else None
    cs_x = cs[..., :d_inner] if cs is not None else None
    cs_bc = cs[..., d_inner:] if cs is not None else None
    xs, new_conv_x = _conv(params["conv_w"], params["conv_b"], xs_raw,
                           cs_x, ssm.conv_width, length)
    bc, new_conv_bc = _conv(params["conv_w_bc"], params["conv_b_bc"], bc_raw,
                            cs_bc, ssm.conv_width, length)
    new_conv = jnp.concatenate([new_conv_x, new_conv_bc], axis=-1)
    b_in = bc[..., :n]
    c_in = bc[..., n:]

    bsz, s, _ = x.shape
    xh = xs.reshape(bsz, s, nheads, p)
    xh = sh.shard(xh, sh.BATCH, None, sh.HEADS, None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    if length is not None:
        smask = jnp.arange(s)[None, :] < length[:, None]        # [B,S]
        dt = dt * smask[..., None].astype(dt.dtype)

    new_state = None
    if mode == "decode":
        assert state is not None
        h_prev = state["ssm"]                               # [B,H,N,P]
        f32 = jnp.float32
        a = -jnp.exp(params["a_log"].astype(f32))
        da = jnp.exp(dt[:, 0] * a)                          # [B,H]
        bx = jnp.einsum("bn,bh,bhp->bhnp", b_in[:, 0].astype(f32),
                        dt[:, 0], xh[:, 0].astype(f32))
        h_new = h_prev.astype(f32) * da[..., None, None] + bx
        y = jnp.einsum("bn,bhnp->bhp", c_in[:, 0].astype(f32), h_new)
        y = y[:, None]                                      # [B,1,H,P]
        new_state = {"conv": new_conv, "ssm": h_new}
    else:
        h0 = state["ssm"] if state is not None else None
        y, h_final = ssd_chunked(xh, dt, params["a_log"], b_in, c_in,
                                 ssm.chunk, h0)
        if mode == "prefill":
            new_state = {"conv": new_conv, "ssm": h_final}
    y = y.astype(dt_) + xh * params["d_skip"].astype(dt_)[None, None, :, None]
    y2 = y.reshape(bsz, s, d_inner)
    y2 = rmsnorm(params["norm"], y2, cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.dot(y2, params["out_proj"].astype(dt_))
    return sh.shard(out, sh.BATCH, sh.SEQ, sh.EMBED), new_state


def state_shapes(cfg: ModelConfig, batch: int) -> Dict:
    ssm = cfg.ssm
    d_inner, nheads, n, p = dims(cfg)
    return {
        "conv": ((batch, ssm.conv_width - 1, d_inner + 2 * n),
                 (sh.BATCH, None, None)),
        "ssm": ((batch, nheads, n, p), (sh.BATCH, sh.HEADS, None, None)),
    }
