"""Minimal pure-JAX module system.

No flax on this box (and the task wants the substrate built from scratch),
so parameters are plain pytrees of ``jnp`` arrays described by declarative
``ParamDef`` tables.  Each layer module exposes

    defs(cfg, ...)  -> nested {name: ParamDef}           (static description)
    apply(params, x, ...)                                 (pure function)

From a defs tree we derive three parallel pytrees:
    * real parameters           (``init_params`` — smoke tests / examples)
    * logical sharding axes     (``axes_tree`` — fed to parallel.sharding)
    * abstract parameters       (``abstract_params`` — dry-run, 0 bytes)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

# Logical axis names.  parallel/sharding.py maps these to mesh axes.
EMBED = "embed"        # d_model
VOCAB = "vocab"        # vocabulary
HEADS = "heads"        # query heads
KV_HEADS = "kv_heads"  # kv heads (may be replicated when not divisible)
HEAD_DIM = "head_dim"  # per-head feature dim
MLP = "mlp"            # d_ff
EXPERT = "expert"      # MoE expert dim -> "pool" under the paper's tuner
SSM_INNER = "ssm_inner"  # mamba d_inner / rwkv channel dim
STATE = "state"        # ssm state dim
LAYERS = "layers"      # stacked-layer leading dim (never sharded)
NONE = None


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"     # fan_in | zeros | ones | normal | embed | custom
    dtype: Any = None        # None -> model param dtype
    scale: float = 1.0       # extra multiplier on the init
    custom: Optional[Callable[[jax.Array], jax.Array]] = None  # key -> array

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_one(key: jax.Array, d: ParamDef, dtype) -> jax.Array:
    dt = d.dtype or dtype
    if d.custom is not None:
        return d.custom(key).astype(dt)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    if d.init == "normal":
        return (jax.random.normal(key, d.shape) * d.scale).astype(dt)
    if d.init == "embed":
        return (jax.random.normal(key, d.shape) * d.scale).astype(dt)
    if d.init == "fan_in":
        fan_in = d.shape[0] if len(d.shape) == 1 else int(np.prod(d.shape[:-1]))
        # stacked defs put layers first; treat a leading "layers" axis as batch
        if d.axes and d.axes[0] == LAYERS and len(d.shape) > 2:
            fan_in = int(np.prod(d.shape[1:-1]))
        std = d.scale / max(fan_in, 1) ** 0.5
        return (jax.random.normal(key, d.shape) * std).astype(dt)
    raise ValueError(f"unknown init {d.init!r}")


def init_params(defs: Pytree, key: jax.Array, dtype=jnp.float32) -> Pytree:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_one(k, d, dtype) for k, d in zip(keys, leaves)])


def axes_tree(defs: Pytree) -> Pytree:
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_def)


def shapes_tree(defs: Pytree) -> Pytree:
    return jax.tree.map(lambda d: d.shape, defs, is_leaf=is_def)


def abstract_params(defs: Pytree, dtype=jnp.bfloat16,
                    shardings: Optional[Pytree] = None) -> Pytree:
    """ShapeDtypeStruct stand-ins (dry-run: zero allocation)."""
    def mk(d: ParamDef, s=None):
        return jax.ShapeDtypeStruct(d.shape, d.dtype or dtype, sharding=s)
    if shardings is None:
        return jax.tree.map(mk, defs, is_leaf=is_def)
    return jax.tree.map(mk, defs, shardings, is_leaf=is_def)


def param_count(defs: Pytree) -> int:
    return sum(int(np.prod(d.shape))
               for d in jax.tree.leaves(defs, is_leaf=is_def))


def stack_defs(d: ParamDef, n: int) -> ParamDef:
    """Prepend a stacked-layers axis to a ParamDef."""
    return dataclasses.replace(d, shape=(n,) + d.shape, axes=(LAYERS,) + d.axes)


def tree_stack_defs(defs: Pytree, n: int) -> Pytree:
    return jax.tree.map(lambda d: stack_defs(d, n), defs, is_leaf=is_def)
