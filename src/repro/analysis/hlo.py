"""Parse compiled HLO text for collective traffic.

``compiled.cost_analysis()`` has no collective-bytes entry, so we scan
``compiled.as_text()`` for all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops, pull operand shapes + replica groups,
and convert to per-device *wire bytes* with ring formulas:

    all-reduce       2 * S * (n-1)/n
    all-gather           S * (n-1)/n        (S = gathered output)
    reduce-scatter       S * (n-1)          (S = scattered output)
    all-to-all           S * (n-1)/n
    collective-permute   S

The SPMD module is a per-device program, so totals are per-device —
consistent with ``cost_analysis()['flops']``.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<shape>\([^)]*\)|[\w\[\],{}\s/]+?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
    re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class Collective:
    op: str
    bytes_payload: float     # sum of operand-shape bytes (per device)
    group_size: int
    wire_bytes: float        # ring-model bytes on the wire per device
    line: str = ""


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    mo = _GROUPS_IOTA_RE.search(line)
    if mo:
        return int(mo.group(2))
    mo = _GROUPS_LIST_RE.search(line)
    if mo:
        ids = [x for x in mo.group(1).split(",") if x.strip() != ""]
        return max(len(ids), 1)
    return 1


def _wire_bytes(op: str, payload: float, n: int) -> float:
    if n <= 1:
        return payload if op == "collective-permute" else 0.0
    if op == "all-reduce":
        return 2.0 * payload * (n - 1) / n
    if op == "all-gather":
        return payload * (n - 1) / n
    if op == "reduce-scatter":
        return payload * (n - 1)
    if op == "all-to-all":
        return payload * (n - 1) / n
    if op == "collective-permute":
        return payload
    return 0.0


def parse_collectives(hlo_text: str) -> List[Collective]:
    out: List[Collective] = []
    seen_done = set()
    for mo in _COLL_RE.finditer(hlo_text):
        line = hlo_text[mo.start():hlo_text.find("\n", mo.start())]
        if "-done(" in line.split("=", 1)[1][:120]:
            continue  # bytes counted at the -start op
        op = mo.group("op")
        payload = _shape_bytes(mo.group("shape"))
        n = _group_size(line)
        out.append(Collective(op, payload, n, _wire_bytes(op, payload, n),
                              line.strip()[:200]))
    return out


def summarize(colls: List[Collective]) -> Dict:
    by_op: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "payload_bytes": 0.0, "wire_bytes": 0.0})
    for c in colls:
        e = by_op[c.op]
        e["count"] += 1
        e["payload_bytes"] += c.bytes_payload
        e["wire_bytes"] += c.wire_bytes
    total_wire = sum(e["wire_bytes"] for e in by_op.values())
    return {"by_op": dict(by_op), "total_wire_bytes": total_wire,
            "num_collectives": len(colls)}


def collective_bytes(hlo_text: str) -> float:
    return summarize(parse_collectives(hlo_text))["total_wire_bytes"]
