"""Three-term roofline from a compiled dry-run artifact (EXPERIMENTS.md
§Roofline).

    compute_s    = HLO_FLOPs_per_device / peak_FLOP/s
    memory_s     = HLO_bytes_per_device / HBM_bw
    collective_s = wire_bytes_per_device / link_bw

plus MODEL_FLOPS = 6*N_active*D (2*N_active*D inference) and the
MODEL_FLOPS / HLO_FLOPs usefulness ratio.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.analysis import hlo
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import cost_model


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    setting: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)
    step_s: float
    roofline_frac: float         # min-possible / estimated step time
    memory_per_device_bytes: float = 0.0
    collectives: Optional[Dict] = None
    note: str = ""

    def row(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(cfg: ModelConfig, shape: ShapeConfig, *, arch: str,
            mesh_name: str, setting: str, chips: int,
            cost: Dict, hlo_text: str,
            memory_stats: Optional[Dict] = None,
            hw: cost_model.Hardware = cost_model.V5E,
            note: str = "") -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    colls = hlo.summarize(hlo.parse_collectives(hlo_text))
    wire = colls["total_wire_bytes"]

    compute_s = flops / hw.peak_flops
    memory_s = byts / hw.hbm_bw
    collective_s = wire / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    model_fl = cost_model.model_flops(cfg, shape) + \
        cost_model.attention_flops(cfg, shape)
    useful = model_fl / max(flops * chips, 1.0)

    # step estimate: max(compute, memory) + collectives (no-overlap,
    # conservative); roofline fraction = ideal compute-only time over it,
    # with *useful* flops as the numerator so padding/remat waste counts
    # against us.
    step_s = max(compute_s, memory_s) + collective_s
    ideal = (model_fl / chips) / hw.peak_flops
    frac = ideal / step_s if step_s > 0 else 0.0

    mem_bytes = 0.0
    if memory_stats:
        mem_bytes = (memory_stats.get("argument_size_in_bytes", 0)
                     + memory_stats.get("output_size_in_bytes", 0)
                     + memory_stats.get("temp_size_in_bytes", 0)
                     - memory_stats.get("alias_size_in_bytes", 0))

    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, setting=setting,
        chips=chips, flops_per_device=flops, bytes_per_device=byts,
        wire_bytes_per_device=wire, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant,
        model_flops_global=model_fl, useful_ratio=useful, step_s=step_s,
        roofline_frac=frac, memory_per_device_bytes=mem_bytes,
        collectives=colls, note=note)


def memory_stats_dict(ma) -> Dict:
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes")
    return {k: int(getattr(ma, k, 0)) for k in keys}


def save(path: str, roof: Roofline) -> None:
    with open(path, "w") as f:
        json.dump(roof.row(), f, indent=1)
