"""AdamW in pure JAX, with the distributed-memory tricks the big configs
need to fit 16 GB/chip HBM:

  * ZeRO-1: optimizer states carry the *param* logical axes but their rules
    always map the param d_model axis to the data axis, so m/v are sharded
    over data even when the params are not (GSPMD inserts the gather on the
    way back to the replicated param — exactly ZeRO-1's update semantics).
  * int8 second moment (optional): block-quantized ``v`` with per-block f32
    scales (block = last-dim 128), 4x smaller than f32 state.
  * f32 master weights are optional; by default the update is applied in
    f32 and cast back to the param dtype (stochastic-rounding-free bf16
    training is fine for the dry-run and smoke scale).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_v: bool = False     # int8 second moment
    dtype: Any = jnp.float32     # first-moment dtype


class QTensor(NamedTuple):
    q: jax.Array       # int8 payload, padded to QBLOCK on the last dim
    scale: jax.Array   # f32 per-block scales


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    last = x.shape[-1]
    pad = (-last) % QBLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, last


def quantize(x: jax.Array) -> QTensor:
    xp, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = xp.reshape(*xp.shape[:-1], xp.shape[-1] // QBLOCK, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QTensor(q.reshape(xp.shape), scale[..., 0])


def dequantize(qt: QTensor, orig_last: int) -> jax.Array:
    q = qt.q.astype(jnp.float32)
    blocks = q.reshape(*q.shape[:-1], q.shape[-1] // QBLOCK, QBLOCK)
    x = (blocks * qt.scale[..., None]).reshape(q.shape)
    return x[..., :orig_last]


def init(params, cfg: AdamWConfig):
    def mk_m(p):
        return jnp.zeros(p.shape, cfg.dtype)

    def mk_v(p):
        if cfg.quantize_v and p.ndim >= 1 and p.shape[-1] >= QBLOCK:
            return quantize(jnp.zeros(p.shape, jnp.float32))
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree.map(mk_m, params),
        "v": jax.tree.map(mk_v, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(grads, state, params, cfg: AdamWConfig,
           lr: Optional[jax.Array] = None):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr_t = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(p, g, mo, vo):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * mo.astype(jnp.float32) + (1 - cfg.b1) * g
        is_q = isinstance(vo, QTensor)
        v_f = dequantize(vo, p.shape[-1]) if is_q else vo
        v = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr_t * step).astype(p.dtype)
        new_v = quantize(v) if is_q else v
        return new_p, m.astype(cfg.dtype), new_v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = jax.tree.flatten(state["v"],
                              is_leaf=lambda x: isinstance(x, QTensor))[0]
    out = [upd(p, g, mo, vo)
           for p, g, mo, vo in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Abstract state (dry-run: shapes only)
# ---------------------------------------------------------------------------

def abstract_state(abstract_params, cfg: AdamWConfig,
                   m_sharding_fn=None, v_sharding_fn=None):
    """ShapeDtypeStruct tree mirroring ``init`` without allocation.

    ``*_sharding_fn(path_leaf) -> sharding`` hooks let the launcher apply
    ZeRO-1 shardings."""
    def mk_m(p):
        s = m_sharding_fn(p) if m_sharding_fn else None
        return jax.ShapeDtypeStruct(p.shape, cfg.dtype, sharding=s)

    def mk_v(p):
        s = v_sharding_fn(p) if v_sharding_fn else (
            m_sharding_fn(p) if m_sharding_fn else None)
        if cfg.quantize_v and len(p.shape) >= 1 and p.shape[-1] >= QBLOCK:
            last = p.shape[-1]
            padded = last + ((-last) % QBLOCK)
            qshape = p.shape[:-1] + (padded,)
            sshape = p.shape[:-1] + (padded // QBLOCK,)
            return QTensor(
                jax.ShapeDtypeStruct(qshape, jnp.int8, sharding=s),
                jax.ShapeDtypeStruct(sshape, jnp.float32))
        return jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=s)

    return {
        "m": jax.tree.map(mk_m, abstract_params),
        "v": jax.tree.map(mk_v, abstract_params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
