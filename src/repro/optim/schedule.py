"""LR schedules (pure functions of step)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_cosine(step, *, peak_lr: float, warmup: int = 100,
                         total: int = 10_000, floor: float = 0.1):
    stepf = jnp.asarray(step, jnp.float32)
    warm = stepf / jnp.maximum(warmup, 1)
    frac = jnp.clip((stepf - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return peak_lr * jnp.where(stepf < warmup, warm, cos)


def constant(step, *, peak_lr: float, **_):
    return jnp.full((), peak_lr, jnp.float32)
