"""Deterministic synthetic token pipeline with host sharding + prefetch.

Determinism is a fault-tolerance feature: batch(step) is a pure function of
(seed, step), so a restarted or rescheduled worker replays the exact stream
(DESIGN.md S7).  The generator is a counter-based hash (splitmix64-style),
so random access by step costs O(1) — no state to checkpoint beyond the
step counter itself.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass
class SyntheticLM:
    """Markov-ish synthetic LM data: learnable (next token depends on the
    current one) so smoke training shows a falling loss."""

    cfg: ModelConfig
    batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        b, s, v = self.batch, self.seq_len, self.cfg.vocab_size
        idx = (np.uint64(self.seed) * np.uint64(0x1000003)
               + np.uint64(step) * np.uint64(b * (s + 1) + 7)
               + np.arange(b * (s + 1), dtype=np.uint64))
        noise = _splitmix64(idx).reshape(b, s + 1)
        stream = np.empty((b, s + 1), np.int64)
        stream[:, 0] = noise[:, 0] % v
        # next = f(current) with occasional resets: compressible structure
        for t in range(1, s + 1):
            det = (stream[:, t - 1] * 31 + 17) % v
            rnd = noise[:, t] % v
            take_rnd = (noise[:, t] >> np.uint64(32)) % np.uint64(4) == 0
            stream[:, t] = np.where(take_rnd, rnd, det)
        out = {"tokens": stream[:, :-1].astype(np.int32),
               "labels": stream[:, 1:].astype(np.int32)}
        if self.cfg.frontend:
            fl = self.cfg.frontend_len
            f = _splitmix64(np.uint64(self.seed * 7 + 3)
                            + np.uint64(step) * np.uint64(b * fl)
                            + np.arange(b * fl, dtype=np.uint64))
            frames = (f.astype(np.float64) / 2**64 - 0.5).astype(np.float32)
            frames = np.broadcast_to(frames.reshape(b, fl, 1),
                                     (b, fl, self.cfg.d_model)) * 0.2
            key = "frames" if self.cfg.family == "audio" else "frontend"
            out[key] = np.ascontiguousarray(frames, np.float32)
        return out


class DevicePrefetcher:
    """Double-buffered host->device prefetch on a background thread."""

    def __init__(self, source: SyntheticLM, shardings: Optional[Dict] = None,
                 depth: int = 2, start_step: int = 0):
        self.source = source
        self.shardings = shardings or {}
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _put_device(self, batch: Dict[str, np.ndarray]) -> Dict[str, Any]:
        out = {}
        for k_, v_ in batch.items():
            s = self.shardings.get(k_)
            out[k_] = jax.device_put(v_, s) if s is not None \
                else jax.device_put(v_)
        return out

    def _worker(self):
        while not self._stop.is_set():
            try:
                batch = self.source.batch_at(self._step)
                self._q.put((self._step, self._put_device(batch)), timeout=10)
                self._step += 1
            except queue.Full:
                continue
            except Exception as e:  # surface errors to the consumer
                self._q.put(e)
                return

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
