"""Analytic cost model: parameter counts, MODEL_FLOPS, and a three-term
(compute / HBM / interconnect) step-time estimator.

Two consumers:
  * ``core.autotune`` ranks candidate mesh plans with it (the "global
    optimum by exhaustive search" of paper Fig. 18, at mesh-plan granularity);
  * ``analysis.roofline`` cross-checks compiled-HLO numbers against it
    (the MODEL_FLOPS / HLO_FLOPs ratio of EXPERIMENTS.md §Roofline).

All estimates are *per device* to match ``compiled.cost_analysis()``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.configs.base import (ATTN, FFN_DENSE, FFN_MOE, FFN_RWKV, MAMBA2,
                                RWKV6, SHARED_ATTN, ModelConfig, ShapeConfig)


# ---------------------------------------------------------------------------
# Hardware model (TPU v5e per assignment)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per ICI link
    dcn_bw: float = 6.25e9            # bytes/s per chip across pods (50 Gbps)
    hbm_bytes: float = 16e9           # capacity per chip


V5E = Hardware()


# ---------------------------------------------------------------------------
# Parameter counts (exact: derived from the ParamDef tables)
# ---------------------------------------------------------------------------

def model_param_count(cfg: ModelConfig) -> int:
    from repro.models import module as m
    from repro.models.transformer import model_defs
    return m.param_count(model_defs(cfg))


def _moe_param_count(cfg: ModelConfig) -> int:
    if cfg.moe is None:
        return 0
    n_moe_layers = sum(1 for b in cfg.blocks if b.ffn == FFN_MOE)
    return n_moe_layers * cfg.moe.num_experts * 3 * cfg.d_model * cfg.d_ff


def _embed_param_count(cfg: ModelConfig) -> int:
    n = cfg.vocab_size * cfg.d_model
    return n if cfg.tie_embeddings else 2 * n


def model_active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token: MoE experts scaled by top_k/E."""
    total = model_param_count(cfg)
    moe = _moe_param_count(cfg)
    active_moe = moe * (cfg.moe.top_k / cfg.moe.num_experts) if cfg.moe else 0
    return int(total - moe + active_moe)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS per step, global: 6*N_active*D for training, 2*N_active*D
    for inference (D = tokens processed this step)."""
    n_active = model_active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def attention_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global attention-score/PV flops on top of the 6ND matmul count."""
    dh = cfg.resolved_head_dim
    total = 0.0
    for b in cfg.blocks:
        if b.mixer not in (ATTN, SHARED_ATTN):
            continue
        if shape.kind == "decode":
            kv = min(shape.seq_len, b.window or shape.seq_len)
            per_seq = 2 * 2 * cfg.num_heads * dh * kv
            total += per_seq * shape.global_batch
        else:
            s = shape.seq_len
            w = b.window or s
            # causal: sum over positions of min(pos, w)
            visible = (s * w - w * (w - 1) / 2) if w < s else s * (s + 1) / 2
            per_seq = 2 * 2 * cfg.num_heads * dh * visible
            mult = 3.0 if shape.kind == "train" else 1.0  # bwd re-does qk/pv
            total += per_seq * shape.global_batch * mult
    return total


# ---------------------------------------------------------------------------
# Per-plan step-time estimate
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CostBreakdown:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def step_s(self) -> float:
        # compute and HBM overlap poorly on the dominant op class; take max
        # with collectives partially overlapped (conservative: no overlap).
        return max(self.compute_s, self.memory_s) + self.collective_s

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)


def estimate(cfg: ModelConfig, shape: ShapeConfig, *, data: int, pools: int,
             intra: int, fsdp: bool, hw: Hardware = V5E,
             pod_axis_dp: bool = True, pods: int = 1,
             dtype_bytes: int = 2, seq_shard: bool = True) -> CostBreakdown:
    """Analytic three-term estimate for a (data, pools, intra) mesh plan.

    ``pools`` = expert/branch parallel degree, ``intra`` = tensor-parallel
    degree (pools * intra = model-axis size), mirroring the paper's
    inter-op-pools / intra-op-threads split.
    """
    chips = data * pools * intra * pods
    n_params = model_param_count(cfg)
    n_active = model_active_param_count(cfg)
    flops_global = model_flops(cfg, shape) + attention_flops(cfg, shape)

    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    train = shape.kind == "train"

    # ---- compute: assume near-even split when the plan's parallel degrees
    # match the graph's parallelism; penalize expert imbalance when pools
    # exceed usable width.
    e = cfg.moe.num_experts if cfg.moe else 1
    eff_pools = min(pools, e)
    imbalance = pools / eff_pools
    compute = flops_global / chips * imbalance / hw.peak_flops

    # ---- memory: weights read once per step (per device share) + act traffic
    weight_bytes = n_params * dtype_bytes / (pools * intra) / (data if fsdp else 1)
    if shape.kind == "decode":
        # decode is weight-bound: every active weight is read per token-step
        weight_read = n_active * dtype_bytes / (pools * intra)
    else:
        weight_read = weight_bytes
    act_bytes = tokens / max(data * pods, 1) * cfg.d_model * dtype_bytes
    act_traffic = act_bytes * (12 if train else 4) * cfg.num_layers / max(intra, 1)
    memory = (weight_read + act_traffic) / hw.hbm_bw

    # ---- collectives
    coll_bytes = 0.0
    b_loc = tokens / max(data * pods, 1)
    # TP per layer: with sequence sharding the all-reduce becomes
    # all-gather + reduce-scatter (same ring bytes); without SP the *input*
    # of each sharded matmul is replicated but the output partial-sum
    # all-reduce still moves 2(n-1)/n of the act.
    n_moe = sum(1 for bl in cfg.blocks if bl.ffn == FFN_MOE)
    n_dense_ffn = cfg.num_layers - n_moe
    if intra > 1:
        per_layer = 2 * b_loc * cfg.d_model * dtype_bytes
        coll_bytes += ((n_dense_ffn + cfg.num_layers) * per_layer
                       * (intra - 1) / intra * (3 if train else 1))
    if cfg.moe and n_moe:
        k = cfg.moe.top_k
        capf = cfg.moe.capacity_factor
        if pools > 1:
            # EP all-to-all: dispatch + combine move top_k*d per token
            tok_dev = b_loc / (pools * intra if seq_shard else 1)
            per = 2 * tok_dev * k * cfg.d_model * dtype_bytes \
                * (pools - 1) / pools
        else:
            # pure TP replicates the [*, E, cap, d] dispatch buffer over the
            # model axis: all-gather on the way in, partial-sum all-reduce of
            # the combine buffer on the way out — 3x the EP payload.
            per = 3 * b_loc * k * capf * cfg.d_model * dtype_bytes \
                * (intra - 1) / intra
        coll_bytes += n_moe * per * (3 if train else 1)
    # FSDP all-gather (+reduce-scatter in training)
    if fsdp:
        coll_bytes += (n_params * dtype_bytes / (pools * intra)
                       * (data - 1) / data * (3 if train else 1))
    # gradient all-reduce over data axis
    if train:
        coll_bytes += (2 * n_params * dtype_bytes / (pools * intra)
                       * (data - 1) / data) if not fsdp else 0.0
    collective = coll_bytes / hw.ici_bw
    # pod axis (DCN or slower ICI): gradient sync for DP, activations for MP
    if pods > 1:
        if train and pod_axis_dp:
            collective += (2 * n_params * dtype_bytes / (pools * intra * data)
                           / hw.dcn_bw)
        elif not pod_axis_dp:
            collective += (cfg.num_layers * 2 * b_loc * cfg.d_model
                           * dtype_bytes / hw.dcn_bw)
    return CostBreakdown(compute, memory, collective)


def fits_memory(cfg: ModelConfig, shape: ShapeConfig, *, data: int,
                pools: int, intra: int, fsdp: bool, hw: Hardware = V5E,
                train_state_bytes: int = 12) -> bool:
    """Coarse per-chip HBM feasibility check for the autotuner."""
    n_params = model_param_count(cfg)
    shard = (pools * intra) * (data if fsdp else 1)
    per_chip = n_params * 2 / shard
    if shape.kind == "train":
        per_chip += n_params * train_state_bytes / (pools * intra * data)
        tokens_loc = shape.global_batch * shape.seq_len / data
        per_chip += tokens_loc * cfg.d_model * 2 * cfg.num_layers / intra * 0.1
    elif shape.kind == "decode":
        kv = sum(min(shape.seq_len, b.window or shape.seq_len)
                 for b in cfg.blocks if b.mixer in (ATTN, SHARED_ATTN))
        per_chip += (shape.global_batch / data * kv * cfg.num_kv_heads
                     * cfg.resolved_head_dim * 2 * 2 / max(intra, 1))
    return per_chip < hw.hbm_bytes * 0.9
