from repro.core import autotune, cost_model, graph, scheduler, tuner
from repro.core.graph import OpGraph, build_graph
from repro.core.tuner import (Plan, guideline_plan, intel_setting,
                              make_rules, tf_setting)

__all__ = ["autotune", "cost_model", "graph", "scheduler", "tuner",
           "OpGraph", "build_graph", "Plan", "guideline_plan",
           "intel_setting", "make_rules", "tf_setting"]
