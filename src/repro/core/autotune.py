"""Exhaustive plan sweep = the paper's "global optimum" baseline (Fig. 18).

The paper sweeps 96^3 thread-count triples; the mesh analogue sweeps every
(pools, intra, fsdp, seq_shard, pod_mode) factorization and ranks by the
analytic three-term cost model (validated against compiled HLO in
EXPERIMENTS.md §Roofline).  ``sweep`` returns every feasible plan with its
cost so benchmarks can report guideline-vs-optimum gaps, like Fig. 18.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import cost_model, tuner


@dataclasses.dataclass
class RankedPlan:
    plan: tuner.Plan
    cost: cost_model.CostBreakdown
    fits: bool

    @property
    def step_s(self) -> float:
        return self.cost.step_s


def evaluate(cfg: ModelConfig, shape: ShapeConfig, plan: tuner.Plan,
             hw: cost_model.Hardware = cost_model.V5E) -> RankedPlan:
    cost = cost_model.estimate(
        cfg, shape, data=plan.data, pools=plan.pools, intra=plan.intra,
        fsdp=plan.fsdp, hw=hw, pod_axis_dp=(plan.pod_mode == "dp"),
        pods=plan.pods, seq_shard=plan.seq_shard)
    fits = cost_model.fits_memory(cfg, shape, data=plan.data,
                                  pools=plan.pools, intra=plan.intra,
                                  fsdp=plan.fsdp, hw=hw)
    return RankedPlan(plan, cost, fits)


def sweep(cfg: ModelConfig, shape: ShapeConfig, *, model_axis: int = 16,
          data_axis: int = 16, pods: int = 1,
          hw: cost_model.Hardware = cost_model.V5E,
          seq_shard: Optional[bool] = None) -> List[RankedPlan]:
    plans = tuner.enumerate_plans(cfg, shape, model_axis=model_axis,
                                  data_axis=data_axis, pods=pods)
    if seq_shard is not None:
        plans = [p for p in plans if p.seq_shard == seq_shard]
    ranked = [evaluate(cfg, shape, p, hw) for p in plans]
    ranked.sort(key=lambda r: (not r.fits, r.step_s))
    return ranked


def global_optimum(cfg: ModelConfig, shape: ShapeConfig, **kw
                   ) -> Optional[RankedPlan]:
    ranked = sweep(cfg, shape, **kw)
    feasible = [r for r in ranked if r.fits]
    return feasible[0] if feasible else (ranked[0] if ranked else None)


def compare_settings(cfg: ModelConfig, shape: ShapeConfig, *,
                     model_axis: int = 16, data_axis: int = 16,
                     pods: int = 1,
                     hw: cost_model.Hardware = cost_model.V5E):
    """Fig. 18 row: guideline vs TF vs Intel vs swept optimum."""
    kw = dict(model_axis=model_axis, data_axis=data_axis, pods=pods)
    rows = {
        "guideline": evaluate(cfg, shape, tuner.guideline_plan(cfg, shape, **kw), hw),
        "tf_setting": evaluate(cfg, shape, tuner.tf_setting(cfg, shape, **kw), hw),
        "intel_setting": evaluate(cfg, shape, tuner.intel_setting(cfg, shape, **kw), hw),
        # SP held fixed at the guideline's choice; it is studied as its own
        # knob in EXPERIMENTS.md §Perf (CPU-backend GSPMD artifact)
        "global_optimum": global_optimum(cfg, shape, hw=hw,
                                         seq_shard=False, **kw),
    }
    return rows
