"""OpGraph IR: the computational-graph view the paper's guideline reads.

Paper §8: *"The average width of a model is the floor of the ratio of the
total number of (heavy) operators divided by the maximum number of layers.
A heavy operator is a compute-intensive or embedding operator."*

Every ``ModelConfig`` compiles to an ``OpGraph`` of heavy operators (matmul-
class ops, embedding lookups, SSM scans) with dataflow edges.  Light ops
(norms, activations, reshapes, masks) are excluded, per the paper.  From the
graph we derive:

  * ``max_width``   — widest antichain by depth level (paper Fig. 4 table);
  * ``avg_width``   — ``floor(num_heavy_ops / depth)`` (paper §8);
  * per-level structure used by the fig04/fig06 benchmarks.

Training graphs are widened x2 (independent gradient + weight-update ops per
layer, paper §4.1) unless the batch is large (the paper's observed
grad/weight-sum imbalance at large batch, §4.1/§7.2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.configs.base import (ATTN, FFN_DENSE, FFN_MOE, FFN_NONE, FFN_RWKV,
                                MAMBA2, RWKV6, SHARED_ATTN, ModelConfig,
                                ShapeConfig)

LARGE_BATCH = 128  # paper §4.1: training widening stops paying off here


@dataclasses.dataclass
class OpNode:
    uid: int
    kind: str                  # matmul | embedding | scan | attention | conv
    name: str
    flops: float               # per-token flops estimate (relative weights)
    deps: Tuple[int, ...] = ()
    level: int = -1            # filled by _levelize


@dataclasses.dataclass
class OpGraph:
    nodes: List[OpNode]
    name: str = ""

    # ------------------------------------------------------------- metrics
    def _levelize(self) -> None:
        lv: Dict[int, int] = {}
        for nd in self.nodes:  # nodes are topo-ordered by construction
            lv[nd.uid] = (max((lv[d] for d in nd.deps), default=-1) + 1)
            nd.level = lv[nd.uid]

    @property
    def depth(self) -> int:
        self._levelize()
        return max((nd.level for nd in self.nodes), default=-1) + 1

    @property
    def num_heavy_ops(self) -> int:
        return len(self.nodes)

    @property
    def max_width(self) -> int:
        self._levelize()
        counts: Dict[int, int] = {}
        for nd in self.nodes:
            counts[nd.level] = counts.get(nd.level, 0) + 1
        return max(counts.values(), default=0)

    @property
    def avg_width(self) -> int:
        """Paper §8 definition."""
        d = self.depth
        return max(1, self.num_heavy_ops // max(d, 1))

    def level_sizes(self) -> List[int]:
        self._levelize()
        out = [0] * self.depth
        for nd in self.nodes:
            out[nd.level] += 1
        return out

    def level_flops(self) -> List[List[float]]:
        """Per level, the flops of each parallel op (fig06 imbalance study)."""
        self._levelize()
        out: List[List[float]] = [[] for _ in range(self.depth)]
        for nd in self.nodes:
            out[nd.level].append(nd.flops)
        return out


class _Builder:
    def __init__(self, name: str):
        self.nodes: List[OpNode] = []
        self.name = name

    def add(self, kind: str, name: str, flops: float, deps=()) -> int:
        uid = len(self.nodes)
        self.nodes.append(OpNode(uid, kind, name, flops,
                                 tuple(d for d in deps if d is not None)))
        return uid

    def graph(self) -> OpGraph:
        g = OpGraph(self.nodes, self.name)
        g._levelize()
        return g


def _attn_ops(b: _Builder, cfg: ModelConfig, li: int, prev: Optional[int],
              tag: str = "") -> int:
    d, h, kv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    q = b.add("matmul", f"L{li}{tag}.wq", 2 * d * h * dh, (prev,))
    k = b.add("matmul", f"L{li}{tag}.wk", 2 * d * kv * dh, (prev,))
    v = b.add("matmul", f"L{li}{tag}.wv", 2 * d * kv * dh, (prev,))
    s = b.add("attention", f"L{li}{tag}.qk", 2 * h * dh, (q, k))
    pv = b.add("attention", f"L{li}{tag}.pv", 2 * h * dh, (s, v))
    return b.add("matmul", f"L{li}{tag}.wo", 2 * h * dh * d, (pv,))


def _mlp_ops(b: _Builder, cfg: ModelConfig, li: int, prev: Optional[int],
             tag: str = "") -> int:
    d, ff = cfg.d_model, cfg.d_ff
    g = b.add("matmul", f"L{li}{tag}.w_gate", 2 * d * ff, (prev,))
    u = b.add("matmul", f"L{li}{tag}.w_up", 2 * d * ff, (prev,))
    return b.add("matmul", f"L{li}{tag}.w_down", 2 * ff * d, (g, u))


def _moe_ops(b: _Builder, cfg: ModelConfig, li: int, prev: Optional[int]) -> int:
    d, ff = cfg.d_model, cfg.d_ff
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    r = b.add("matmul", f"L{li}.router", 2 * d * e, (prev,))
    outs = []
    frac = k / e  # expected per-expert token share of the layer's tokens
    for ei in range(e):
        g = b.add("matmul", f"L{li}.e{ei}.gate", 2 * d * ff * frac, (r,))
        u = b.add("matmul", f"L{li}.e{ei}.up", 2 * d * ff * frac, (r,))
        o = b.add("matmul", f"L{li}.e{ei}.down", 2 * ff * d * frac, (g, u))
        outs.append(o)
    return b.add("matmul", f"L{li}.combine", 2 * d * k, tuple(outs))


def _mamba_ops(b: _Builder, cfg: ModelConfig, li: int, prev: Optional[int]) -> int:
    d = cfg.d_model
    di = cfg.ssm.expand * d
    n = cfg.ssm.state_dim
    z = b.add("matmul", f"L{li}.wz", 2 * d * di, (prev,))
    x = b.add("matmul", f"L{li}.wx", 2 * d * di, (prev,))
    c = b.add("conv", f"L{li}.conv", 2 * cfg.ssm.conv_width * di, (x,))
    s = b.add("scan", f"L{li}.ssd", 2 * di * (cfg.ssm.chunk + 2 * n), (c,))
    return b.add("matmul", f"L{li}.out", 2 * di * d, (s, z))


def _rwkv_ops(b: _Builder, cfg: ModelConfig, li: int, prev: Optional[int]) -> int:
    d = cfg.d_model
    rr = b.add("matmul", f"L{li}.wr", 2 * d * d, (prev,))
    kk = b.add("matmul", f"L{li}.wk", 2 * d * d, (prev,))
    vv = b.add("matmul", f"L{li}.wv", 2 * d * d, (prev,))
    gg = b.add("matmul", f"L{li}.wg", 2 * d * d, (prev,))
    s = b.add("scan", f"L{li}.wkv", 2 * d * (cfg.rwkv.chunk + cfg.rwkv.head_dim),
              (rr, kk, vv))
    o = b.add("matmul", f"L{li}.wo", 2 * d * d, (s, gg))
    # channel mix
    ck = b.add("matmul", f"L{li}.cmix_k", 2 * d * cfg.d_ff, (o,))
    cr = b.add("matmul", f"L{li}.cmix_r", 2 * d * d, (o,))
    return b.add("matmul", f"L{li}.cmix_v", 2 * cfg.d_ff * d, (ck, cr))


def build_graph(cfg: ModelConfig, *, training: bool = False,
                global_batch: int = 1) -> OpGraph:
    b = _Builder(cfg.name)
    prev = b.add("embedding", "embed", 0.0)
    if cfg.enc_layers:
        # encoder runs concurrently with nothing at train time but its output
        # is a dependency of every decoder cross-attention; in *batched
        # serving* the encoder of request i+1 overlaps the decoder of request
        # i, which is why whisper's serving width is 2 (DESIGN.md S5).
        eprev = b.add("embedding", "enc_embed", 0.0)
        for li in range(cfg.enc_layers):
            a = _attn_ops(b, cfg, li, eprev, tag="enc")
            eprev = _mlp_ops(b, cfg, li, a, tag="enc_mlp")
    for li, block in enumerate(cfg.blocks):
        if block.mixer in (ATTN, SHARED_ATTN):
            prev = _attn_ops(b, cfg, li, prev,
                             tag=".shared" if block.mixer == SHARED_ATTN else "")
        elif block.mixer == MAMBA2:
            prev = _mamba_ops(b, cfg, li, prev)
        elif block.mixer == RWKV6:
            prev = _rwkv_ops(b, cfg, li, prev)
            continue  # rwkv ffn is inside _rwkv_ops
        if block.ffn == FFN_DENSE:
            prev = _mlp_ops(b, cfg, li, prev)
        elif block.ffn == FFN_MOE:
            prev = _moe_ops(b, cfg, li, prev)
    b.add("matmul", "lm_head", 2 * cfg.d_model * cfg.vocab_size, (prev,))
    g = b.graph()
    if training and global_batch < LARGE_BATCH:
        g = widen_for_training(g)
    return g


def widen_for_training(g: OpGraph) -> OpGraph:
    """Paper §4.1: gradient + weight-update ops double the parallel heavy
    ops of each level."""
    b = _Builder(g.name + "+train")
    for nd in g.nodes:
        b.add(nd.kind, nd.name, nd.flops, nd.deps)
    base = len(g.nodes)
    for nd in g.nodes:  # mirrored gradient ops, same dependency skeleton
        b.add(nd.kind, nd.name + ".grad", nd.flops,
              tuple(d + base for d in nd.deps))
    return b.graph()
