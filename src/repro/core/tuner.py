"""The paper's tuning guideline, re-derived for TPU meshes.

Paper §8 collapses five framework knobs into one: the number of inter-op
pools ``p`` = the *average model width*; intra-op threads follow as
``cores / p``.  Here the mesh's model-parallel capacity plays the role of
the cores: ``p`` device groups run independent heavy ops (MoE experts /
parallel branches) and each group tensor-shards its operator ``intra`` ways,
with ``pools * intra = model-axis size``.

``guideline_plan`` is the paper's rule; ``tf_setting`` / ``intel_setting``
are the two recommended-settings baselines of Fig. 18, translated to meshes;
``enumerate_plans`` spans the exhaustive-search space the paper compares
against.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import cost_model
from repro.core.graph import build_graph
from repro.models import module as m
from repro.parallel import sharding as sh


@dataclasses.dataclass(frozen=True)
class Plan:
    name: str
    data: int = 16
    pools: int = 1            # inter-op pools: expert/branch parallel degree
    intra: int = 16           # intra-op threads: tensor-parallel degree
    pods: int = 1
    pod_mode: str = "dp"      # "dp" | "mp" (paper §7: DP vs MP across UPI)
    fsdp: bool = False        # ZeRO-3-style param sharding over data axis
    seq_shard: bool = True    # Megatron-SP activation sharding on model axis
    cp: bool = False          # context parallelism: seq on the model axis,
                              # weights fully sharded + gathered per layer
    notes: str = ""

    @property
    def model_axis(self) -> int:
        return self.pools * self.intra

    @property
    def chips(self) -> int:
        return self.data * self.model_axis * self.pods


# ---------------------------------------------------------------------------
# Plan -> logical-axis rules
# ---------------------------------------------------------------------------

def make_rules(plan: Plan, mesh) -> sh.Rules:
    """Map logical axes onto the axes that ``mesh`` actually has.

    Works with both the spec-mandated meshes (("data","model") and
    ("pod","data","model")) and the tuner's factored meshes
    (("data","pool","intra") / ("pod","data","pool","intra")).
    """
    names = tuple(mesh.axis_names)
    has_pod = "pod" in names
    factored = "pool" in names

    dp: Tuple[str, ...] = (("pod", "data") if (has_pod and plan.pod_mode == "dp")
                           else ("data",))
    model_all: Tuple[str, ...] = (("pool", "intra") if factored else ("model",))
    if has_pod and plan.pod_mode == "mp":
        model_all = ("pod",) + model_all
    pool_ax: Optional[Tuple[str, ...]] = None
    if plan.pools > 1:
        pool_ax = ("pool",) if factored else model_all

    def t(ax):  # 1-tuples -> plain names
        if ax is None:
            return None
        return ax if len(ax) > 1 else ax[0]

    if plan.cp:
        # context parallelism: tokens (not features) ride the model axis;
        # weights are fully sharded over every axis and gathered per layer
        full = dp + model_all
        table: Dict[str, sh.MeshAxis] = {
            m.VOCAB: None, m.EMBED: t(full), m.HEADS: None,
            m.KV_HEADS: None, m.MLP: None, m.SSM_INNER: None,
            m.EXPERT: t(pool_ax), m.HEAD_DIM: None, m.STATE: None,
            sh.BATCH: t(dp), sh.SEQ: t(model_all), sh.KV_SEQ: t(model_all),
            sh.EMBED: None, sh.HEADS: None, sh.MLP: None,
            sh.EXPERT: t(pool_ax), sh.GROUPS: t(dp), sh.VOCAB: None,
        }
        return sh.Rules(table=table, mesh=mesh, context_parallel=True)
    table: Dict[str, sh.MeshAxis] = {
        # parameter axes
        m.VOCAB: t(model_all),
        m.EMBED: t(dp) if plan.fsdp else None,
        m.HEADS: t(model_all),
        m.KV_HEADS: t(model_all),
        m.MLP: t(model_all),
        m.SSM_INNER: t(model_all),
        m.EXPERT: t(pool_ax),
        m.HEAD_DIM: None,
        m.STATE: None,
        # activation axes
        sh.BATCH: t(dp),
        sh.SEQ: t(model_all) if plan.seq_shard else None,
        sh.KV_SEQ: t(model_all),
        sh.EMBED: None,
        sh.HEADS: t(model_all),
        sh.MLP: t(model_all),
        sh.EXPERT: t(pool_ax),
        sh.GROUPS: t(dp),
        sh.VOCAB: t(model_all),
    }
    return sh.Rules(table=table, mesh=mesh)


# ---------------------------------------------------------------------------
# The guideline (paper §8) and the Fig. 18 baseline settings
# ---------------------------------------------------------------------------

def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def model_width(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[int, int]:
    g = build_graph(cfg, training=(shape.kind == "train"),
                    global_batch=shape.global_batch)
    return g.avg_width, g.max_width


def guideline_plan(cfg: ModelConfig, shape: ShapeConfig, *,
                   model_axis: int = 16, data_axis: int = 16,
                   pods: int = 1, hw: cost_model.Hardware = cost_model.V5E
                   ) -> Plan:
    avg_w, max_w = model_width(cfg, shape)
    # pools = avg width, clamped to (a) what the mesh can factor, (b) the
    # realizable branch count (experts for MoE; 1 otherwise — width >1 from
    # qkv/grad parallelism is scheduled by XLA inside each group, see
    # DESIGN.md S3).
    realizable = cfg.moe.num_experts if cfg.moe else 1
    target = min(avg_w, max_w, realizable)
    pools = max(d for d in _divisors(model_axis) if d <= target)
    intra = model_axis // pools
    # memory rule: FSDP when TP-only sharding does not fit HBM
    fsdp = not cost_model.fits_memory(cfg, shape, data=data_axis, pools=pools,
                                      intra=intra, fsdp=False, hw=hw)
    # paper §7: model parallelism across the slow link only when parallel
    # heavy ops of similar size sit on the critical path (width >= 2)
    pod_mode = "mp" if (pods > 1 and pools >= 2 and
                        cfg.moe and cfg.moe.num_experts % (2 * pools) == 0)\
        else "dp"
    # seq_shard (Megatron-SP) stays opt-in: on the CPU dry-run backend the
    # GSPMD resharding it induces is measurably worse (EXPERIMENTS.md §Perf
    # studies it explicitly); on-TPU it is a memory lever, not a default.
    return Plan(name="guideline", data=data_axis, pools=pools, intra=intra,
                pods=pods, pod_mode=pod_mode, fsdp=fsdp, seq_shard=False,
                notes=f"avg_width={avg_w} max_width={max_w} "
                      f"realizable={realizable}")


def tf_setting(cfg: ModelConfig, shape: ShapeConfig, *, model_axis: int = 16,
               data_axis: int = 16, pods: int = 1) -> Plan:
    """TensorFlow guide analogue: intra-op = all cores, pools = #sockets ->
    pure TP over the model axis, pods as extra data parallelism, no FSDP,
    no sequence sharding."""
    return Plan(name="tf_setting", data=data_axis, pools=1, intra=model_axis,
                pods=pods, pod_mode="dp", fsdp=False, seq_shard=False,
                notes="TF guide: max intra-op, pools=#sockets")


def intel_setting(cfg: ModelConfig, shape: ShapeConfig, *,
                  model_axis: int = 16, data_axis: int = 16,
                  pods: int = 1) -> Plan:
    """Intel guide analogue: threads-per-socket, pools = #sockets -> model
    parallelism across the pod axis when there are 2 'sockets'."""
    return Plan(name="intel_setting", data=data_axis, pools=1,
                intra=model_axis, pods=pods,
                pod_mode=("mp" if pods > 1 else "dp"), fsdp=False,
                seq_shard=False, notes="Intel guide: per-socket intra-op")


def enumerate_plans(cfg: ModelConfig, shape: ShapeConfig, *,
                    model_axis: int = 16, data_axis: int = 16,
                    pods: int = 1) -> List[Plan]:
    """The exhaustive design space (paper: 96^3 points; here the mesh-plan
    cross-product) for the global-optimum comparison."""
    plans = []
    realizable = cfg.moe.num_experts if cfg.moe else 1
    for pools in _divisors(model_axis):
        if pools > 1 and pools > realizable:
            continue
        for fsdp in (False, True):
            for seq_shard in ((False, True) if shape.kind != "decode"
                              else (False,)):
                for pod_mode in (("dp", "mp") if pods > 1 else ("dp",)):
                    plans.append(Plan(
                        name=f"p{pools}_i{model_axis // pools}"
                             f"{'_fsdp' if fsdp else ''}"
                             f"{'_sp' if seq_shard else ''}"
                             f"{'_' + pod_mode if pods > 1 else ''}",
                        data=data_axis, pools=pools,
                        intra=model_axis // pools, pods=pods,
                        pod_mode=pod_mode, fsdp=fsdp, seq_shard=seq_shard))
    return plans
