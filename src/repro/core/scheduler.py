"""Synchronous vs asynchronous operator scheduling on an SPMD mesh
(paper §4, Fig. 3).

On a CPU framework the scheduler picks which *thread pool* runs each ready
operator.  Under SPMD there is no runtime scheduler to tune — the schedule
is determined by how independent heavy ops are *sharded*:

  * synchronous  = every heavy op sharded over the whole model axis, ops
    strictly sequential (one op at a time on all "cores");
  * asynchronous = independent ops assigned to disjoint device groups along
    a ``pool`` axis via ``shard_map``, executing simultaneously.

``run_sync`` / ``run_async`` express both schedules for a generic set of
branches (stacked params + one function), so tests can assert numerical
equivalence and benchmarks can compare lowered HLO cost.  The MoE layer has
dedicated variants in ``repro.models.moe``.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def run_sync(branch_fn: Callable, stacked_params, x: jax.Array) -> jax.Array:
    """Sequential (synchronous) schedule: sum_i f(params_i, x).

    Lowered as a static python loop: one heavy op at a time, each free to
    use every device (the paper's one-big-pool baseline)."""
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    out = None
    for i in range(n):
        pi = jax.tree.map(lambda a: a[i], stacked_params)
        y = branch_fn(pi, x)
        out = y if out is None else out + y
    return out


def run_async(branch_fn: Callable, stacked_params, x: jax.Array, *,
              mesh: Mesh, pool_axis: str = "pool") -> jax.Array:
    """Asynchronous schedule: branch i runs on device group i of the
    ``pool_axis``; results are summed with a psum.

    Requires the leading (branch) dim of ``stacked_params`` to equal the
    pool-axis size."""
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    assert n == mesh.shape[pool_axis], (n, dict(mesh.shape))
    other = tuple(a for a in mesh.axis_names if a != pool_axis)

    pspec = P(pool_axis)
    xspec = P()

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: pspec, stacked_params), xspec),
        out_specs=xspec, check_vma=False)
    def _run(params_loc, x_loc):
        pi = jax.tree.map(lambda a: a[0], params_loc)   # this pool's branch
        y = branch_fn(pi, x_loc)
        return jax.lax.psum(y, pool_axis)

    return _run(stacked_params, x)


def hybrid_pools(branch_fn: Callable, stacked_params, x: jax.Array, *,
                 mesh: Mesh, pool_axis: str = "pool",
                 inner: Optional[Callable] = None) -> jax.Array:
    """Paper Fig. 6's middle ground: p pools, each pool tensor-sharding its
    branch over the remaining (intra) axes.  ``branch_fn`` may contain
    logical-axis annotations; inside the shard_map the intra axes are still
    visible to GSPMD through nested sharding constraints."""
    groups = jax.tree.leaves(stacked_params)[0].shape[0]
    p = mesh.shape[pool_axis]
    assert groups % p == 0
    per = groups // p
    pspec = P(pool_axis)

    @functools.partial(
        jax.shard_map, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: pspec, stacked_params), P()),
        out_specs=P(), check_vma=False)
    def _run(params_loc, x_loc):
        out = None
        for i in range(per):  # this pool's share of branches, sequentially
            pi = jax.tree.map(lambda a: a[i], params_loc)
            y = branch_fn(pi, x_loc)
            out = y if out is None else out + y
        return jax.lax.psum(out, pool_axis)

    return _run(stacked_params, x)
