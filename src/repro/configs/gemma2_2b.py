"""gemma2-2b [arXiv:2408.00118].

26 layers, d_model=2304, 8 heads (GQA kv=4, head_dim=256), d_ff=9216,
vocab=256000.  Local(4096)/global alternating attention, attention and
final-logit softcaps, tied + scaled embeddings.
"""

from repro.configs.base import ModelConfig, alternating_windows, validate


def config() -> ModelConfig:
    n = 26
    return validate(ModelConfig(
        name="gemma2-2b",
        family="dense",
        num_layers=n,
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        blocks=alternating_windows(n, [4096, None]),
        sliding_window=4096,
        attn_softcap=50.0,
        logit_softcap=30.0,
        tie_embeddings=True,
        embed_scale=True,
        rope_theta=10_000.0,
    ))
