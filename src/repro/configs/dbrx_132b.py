"""dbrx-132b: fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base].

40 layers, d_model=6144, 48 heads (GQA kv=8), d_ff=10752 per expert,
vocab=100352.
"""

from repro.configs.base import (FFN_MOE, ModelConfig, MoEConfig,
                                uniform_blocks, validate)


def config() -> ModelConfig:
    n = 40
    return validate(ModelConfig(
        name="dbrx-132b",
        family="moe",
        num_layers=n,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=10752,
        vocab_size=100352,
        blocks=uniform_blocks(n, ffn=FFN_MOE),
        moe=MoEConfig(num_experts=16, top_k=4, capacity_factor=1.25),
        rope_theta=500_000.0,
    ))
