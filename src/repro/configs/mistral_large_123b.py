"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407].

88 layers, d_model=12288, 96 heads (GQA kv=8, head_dim=128), d_ff=28672,
vocab=32768.  Dense; full attention => long_500k skipped (DESIGN.md S5).
"""

from repro.configs.base import ModelConfig, uniform_blocks, validate


def config() -> ModelConfig:
    n = 88
    return validate(ModelConfig(
        name="mistral-large-123b",
        family="dense",
        num_layers=n,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=32768,
        blocks=uniform_blocks(n),
        rope_theta=1_000_000.0,
    ))
