"""internlm2-1.8b [arXiv:2403.17297].

24 layers, d_model=2048, 16 heads (GQA kv=8), d_ff=8192, vocab=92544.
"""

from repro.configs.base import ModelConfig, uniform_blocks, validate


def config() -> ModelConfig:
    n = 24
    return validate(ModelConfig(
        name="internlm2-1.8b",
        family="dense",
        num_layers=n,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=92544,
        blocks=uniform_blocks(n),
        rope_theta=1_000_000.0,
    ))
