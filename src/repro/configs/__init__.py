"""Architecture registry: ``get_config("dbrx-132b")`` etc."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig, reduced,
                                shape_applicable)

_ARCH_MODULES = {
    "zamba2-7b": "repro.configs.zamba2_7b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "internlm2-1.8b": "repro.configs.internlm2_1p8b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "whisper-medium": "repro.configs.whisper_medium",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)

_cache: Dict[str, ModelConfig] = {}


def get_config(arch: str) -> ModelConfig:
    if arch not in _cache:
        if arch not in _ARCH_MODULES:
            raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
        _cache[arch] = importlib.import_module(_ARCH_MODULES[arch]).config()
    return _cache[arch]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def live_cells():
    """All (arch, shape) dry-run cells that apply (DESIGN.md S5)."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            if ok:
                cells.append((arch, shape.name))
    return cells


__all__ = [
    "ARCH_IDS", "SHAPES", "ModelConfig", "ShapeConfig", "get_config",
    "get_shape", "live_cells", "reduced", "shape_applicable",
]
