"""grok-1-314b: MoE, 8 experts top-2 [hf:xai-org/grok-1].

64 layers, d_model=6144, 48 heads (GQA kv=8), d_ff=32768 per expert,
vocab=131072.  Grok clips attention logits (softcap 30).
"""

from repro.configs.base import (FFN_MOE, ModelConfig, MoEConfig,
                                uniform_blocks, validate)


def config() -> ModelConfig:
    n = 64
    return validate(ModelConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=n,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        blocks=uniform_blocks(n, ffn=FFN_MOE),
        moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25),
        attn_softcap=30.0,
        embed_scale=True,
        rope_theta=10_000.0,
    ))
