"""whisper-medium [arXiv:2212.04356]: encoder-decoder, conv frontend STUB.

24 encoder + 24 decoder layers, d_model=1024, 16 heads (kv=16), d_ff=4096,
vocab=51865.  The conv/mel frontend is stubbed: input_specs() provides 1500
precomputed frame embeddings as the encoder input.  Decoder shapes lower
``serve_step`` like the other archs; long_500k is skipped (full attention).
"""

from repro.configs.base import ModelConfig, uniform_blocks, validate

NUM_FRAMES = 1500  # 30 s of audio after the conv frontend


def config() -> ModelConfig:
    n = 24
    return validate(ModelConfig(
        name="whisper-medium",
        family="audio",
        num_layers=n,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=4096,
        vocab_size=51865,
        blocks=uniform_blocks(n),
        enc_layers=n,
        enc_blocks=uniform_blocks(n),
        cross_attention=True,
        frontend="frames",
        frontend_len=NUM_FRAMES,
        rope_theta=10_000.0,
    ))
