"""rwkv6-7b (Finch): attention-free RNN with data-dependent decay
[arXiv:2404.05892].

32 layers, d_model=4096, d_ff=14336, vocab=65536.  Heads are d_model/64
wkv heads; the time-mix plays the mixer role and the channel-mix the FFN
role.  O(1) recurrent state => long_500k applies.
"""

from repro.configs.base import (FFN_RWKV, RWKV6, BlockSpec, ModelConfig,
                                RWKVConfig, validate)


def config() -> ModelConfig:
    n = 32
    d = 4096
    head_dim = 64
    return validate(ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        num_layers=n,
        d_model=d,
        num_heads=d // head_dim,
        num_kv_heads=d // head_dim,
        d_ff=14336,
        vocab_size=65536,
        blocks=tuple(BlockSpec(mixer=RWKV6, ffn=FFN_RWKV) for _ in range(n)),
        rwkv=RWKVConfig(head_dim=head_dim, decay_lora=64, mix_lora=32,
                        chunk=256),
    ))
