"""pixtral-12b: pixtral-ViT frontend + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409].

Backbone only per assignment: 40 layers, d_model=5120, 32 heads (GQA kv=8,
head_dim=128), d_ff=14336, vocab=131072.  The ViT is a STUB: input_specs()
provides precomputed patch embeddings (1024 patches) prepended to the token
sequence.
"""

from repro.configs.base import ModelConfig, uniform_blocks, validate


def config() -> ModelConfig:
    n = 40
    return validate(ModelConfig(
        name="pixtral-12b",
        family="vlm",
        num_layers=n,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        blocks=uniform_blocks(n),
        frontend="patches",
        frontend_len=1024,
        rope_theta=1_000_000.0,
    ))
