"""gemma3-12b [hf:google/gemma-3-1b-pt family, 12B point].

48 layers, d_model=3840, 16 heads (GQA kv=8, head_dim=256), d_ff=15360,
vocab=262144.  5:1 local(1024):global attention pattern, 128k context.
"""

from repro.configs.base import ModelConfig, alternating_windows, validate


def config() -> ModelConfig:
    n = 48
    return validate(ModelConfig(
        name="gemma3-12b",
        family="dense",
        num_layers=n,
        d_model=3840,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262144,
        blocks=alternating_windows(n, [1024, 1024, 1024, 1024, 1024, None]),
        sliding_window=1024,
        tie_embeddings=True,
        embed_scale=True,
        rope_theta=1_000_000.0,
    ))
