"""Model + shape configuration system.

Every assigned architecture compiles down to a single ``ModelConfig``
describing a stack of blocks.  A block is ``(mixer, ffn)`` where the mixer is
one of {attention, mamba2, rwkv6_time_mix} and the ffn is one of
{dense, moe, rwkv6_channel_mix}.  Encoder-decoder models (whisper) carry a
second stack for the encoder.

Shapes (``train_4k`` etc.) are global-batch x sequence points that select
which step function (train / prefill / decode) the launcher lowers.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block descriptors
# ---------------------------------------------------------------------------

ATTN = "attn"            # GQA attention mixer
MAMBA2 = "mamba2"        # Mamba2 SSD mixer
RWKV6 = "rwkv6"          # RWKV6 time-mix mixer
SHARED_ATTN = "shared_attn"  # zamba2-style shared transformer block

FFN_DENSE = "dense"
FFN_MOE = "moe"
FFN_RWKV = "rwkv_cmix"
FFN_NONE = "none"        # mixer-only layer (mamba backbone layers)


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer of the stack."""

    mixer: str = ATTN
    ffn: str = FFN_DENSE
    # attention variants
    window: Optional[int] = None       # sliding-window size; None = global
    # zamba2: index of the shared block parameter group to apply (-1 = own)
    shared_group: int = -1


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    # number of token groups used for static-shape dispatch (sharded on data)
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64          # N, the SSM state size per head
    head_dim: int = 64           # P, channels per head
    conv_width: int = 4
    chunk: int = 256             # SSD chunk length
    expand: int = 2              # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64         # rank of the data-dependent decay LoRA
    mix_lora: int = 32           # rank of the token-shift mix LoRA
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    blocks: Tuple[BlockSpec, ...] = ()      # len == num_layers (decoder stack)
    # encoder stack (whisper); empty for decoder-only models
    enc_layers: int = 0
    enc_blocks: Tuple[BlockSpec, ...] = ()
    cross_attention: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # attention details
    rope_theta: float = 10_000.0
    logit_softcap: Optional[float] = None   # gemma2 final-logit softcap
    attn_softcap: Optional[float] = None    # gemma2 attention softcap
    sliding_window: Optional[int] = None    # default window for local layers
    # embeddings
    tie_embeddings: bool = False
    embed_scale: bool = False               # gemma multiplies by sqrt(d)
    # modality frontend stub: extra embedding sequence prepended to tokens
    frontend: Optional[str] = None          # None | "patches" | "frames"
    frontend_len: int = 0                   # stub sequence length
    # zamba2 shared blocks
    num_shared_groups: int = 0
    # norm
    norm_eps: float = 1e-5
    max_position: int = 1 << 20

    # ---------------------------------------------------------------- helpers
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attn_free(self) -> bool:
        return all(b.mixer in (MAMBA2, RWKV6) for b in self.blocks)

    @property
    def supports_long_context(self) -> bool:
        """True when decoding at >=512k tokens is sub-quadratic / O(1)-state.

        SSM / linear-attention mixers keep O(1) state.  Attention mixers
        qualify only when every attention layer is sliding-window bounded.
        """
        for b in self.blocks:
            if b.mixer in (ATTN, SHARED_ATTN) and b.window is None:
                return False
        return True

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model flops)."""
        from repro.core.cost_model import model_param_count

        return model_param_count(self)

    def active_param_count(self) -> int:
        from repro.core.cost_model import model_active_param_count

        return model_active_param_count(self)


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

TRAIN = "train"
PREFILL = "prefill"
DECODE = "decode"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", TRAIN, 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", PREFILL, 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", DECODE, 32_768, 128),
    "long_500k": ShapeConfig("long_500k", DECODE, 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a live dry-run cell; see DESIGN.md S5."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "full-attention layers make 512k-token decode quadratic/"
            "unbounded-KV; skipped per assignment rule (DESIGN.md S5)"
        )
    return True, ""


# ---------------------------------------------------------------------------
# Block-pattern helpers used by the per-arch config files
# ---------------------------------------------------------------------------

def uniform_blocks(n: int, mixer: str = ATTN, ffn: str = FFN_DENSE,
                   window: Optional[int] = None) -> Tuple[BlockSpec, ...]:
    return tuple(BlockSpec(mixer=mixer, ffn=ffn, window=window) for _ in range(n))


def alternating_windows(n: int, pattern: Sequence[Optional[int]],
                        ffn: str = FFN_DENSE) -> Tuple[BlockSpec, ...]:
    """gemma-style local:global alternation. ``pattern`` repeats, e.g.
    [4096, None] for gemma2 (1:1) or [1024]*5+[None] for gemma3 (5:1)."""
    return tuple(
        BlockSpec(mixer=ATTN, ffn=ffn, window=pattern[i % len(pattern)])
        for i in range(n)
    )


def zamba2_blocks(n: int, shared_every: int, num_shared_groups: int,
                  window: Optional[int]) -> Tuple[BlockSpec, ...]:
    """Mamba2 backbone with a shared attention+MLP block applied every
    ``shared_every`` layers, cycling through ``num_shared_groups`` parameter
    groups (zamba2 uses 2)."""
    blocks = []
    shared_i = 0
    for i in range(n):
        if shared_every and (i % shared_every == shared_every - 1):
            blocks.append(BlockSpec(mixer=SHARED_ATTN, ffn=FFN_DENSE,
                                    window=window,
                                    shared_group=shared_i % max(num_shared_groups, 1)))
            shared_i += 1
        else:
            blocks.append(BlockSpec(mixer=MAMBA2, ffn=FFN_NONE))
    return tuple(blocks)


def validate(cfg: ModelConfig) -> ModelConfig:
    assert len(cfg.blocks) == cfg.num_layers, (cfg.name, len(cfg.blocks), cfg.num_layers)
    assert cfg.num_heads % cfg.num_kv_heads == 0, cfg.name
    if cfg.enc_layers:
        assert len(cfg.enc_blocks) == cfg.enc_layers
    if any(b.ffn == FFN_MOE for b in cfg.blocks):
        assert cfg.moe is not None
    if any(b.mixer == MAMBA2 for b in cfg.blocks):
        assert cfg.ssm is not None
    if any(b.mixer == RWKV6 for b in cfg.blocks):
        assert cfg.rwkv is not None
    return cfg


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            heads: int = 4, kv_heads: Optional[int] = None, d_ff: int = 128,
            vocab: int = 256, experts: int = 4, frontend_len: int = 8) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kv = kv_heads or max(1, heads // max(1, cfg.num_heads // cfg.num_kv_heads))
    # rebuild the block pattern at the reduced depth, preserving structure
    if cfg.blocks:
        stride = max(1, cfg.num_layers // layers)
        blocks = tuple(cfg.blocks[min(i * stride, cfg.num_layers - 1)]
                       for i in range(layers))
        # shrink windows so masks stay meaningful at tiny seq lens
        blocks = tuple(
            dataclasses.replace(b, window=(16 if b.window else None))
            for b in blocks
        )
        # zamba2 reduced: keep at least one shared block
        if cfg.family == "hybrid" and not any(b.mixer == SHARED_ATTN for b in blocks):
            blocks = blocks[:-1] + (BlockSpec(mixer=SHARED_ATTN, ffn=FFN_DENSE,
                                              window=16, shared_group=0),)
    else:
        blocks = uniform_blocks(layers)
    moe = None
    if cfg.moe is not None:
        top_k = min(cfg.moe.top_k, experts)
        # dropless at smoke scale so decode == teacher-forcing exactly
        moe = dataclasses.replace(cfg.moe, num_experts=experts, top_k=top_k,
                                  capacity_factor=experts / top_k + 0.01)
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=16, chunk=8)
    rwkv = None
    if cfg.rwkv is not None:
        rwkv = dataclasses.replace(cfg.rwkv, head_dim=16, decay_lora=8,
                                   mix_lora=8, chunk=8)
    enc_blocks = ()
    enc_layers = 0
    if cfg.enc_layers:
        enc_layers = layers
        enc_blocks = uniform_blocks(layers)
    return validate(dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers, d_model=d_model, num_heads=heads,
        num_kv_heads=kv, d_ff=d_ff, vocab_size=vocab, head_dim=None,
        blocks=blocks, enc_layers=enc_layers, enc_blocks=enc_blocks,
        moe=moe, ssm=ssm, rwkv=rwkv,
        frontend_len=(frontend_len if cfg.frontend else 0),
        num_shared_groups=(1 if cfg.family == "hybrid" else 0),
    ))
