"""zamba2-7b: Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 layers, d_model=3584, 32 heads (kv=32), d_ff=14336, vocab=32000,
ssm_state=64.  Every 6th layer applies one of 2 *shared* transformer blocks
(attention + MLP with shared parameters across applications).

TPU adaptation (DESIGN.md S5): the shared attention runs with a 4096-token
sliding window so the 512k-decode cell stays O(1)-state + bounded-KV.  At
train_4k the window covers the full sequence, so training semantics match
full attention.
"""

from repro.configs.base import (ModelConfig, SSMConfig, validate,
                                zamba2_blocks)

SHARED_EVERY = 6
NUM_SHARED_GROUPS = 2
WINDOW = 4096


def config() -> ModelConfig:
    n = 81
    return validate(ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        num_layers=n,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        d_ff=14336,
        vocab_size=32000,
        blocks=zamba2_blocks(n, SHARED_EVERY, NUM_SHARED_GROUPS, WINDOW),
        ssm=SSMConfig(state_dim=64, head_dim=64, conv_width=4, chunk=256,
                      expand=2),
        num_shared_groups=NUM_SHARED_GROUPS,
        sliding_window=WINDOW,
        rope_theta=10_000.0,
    ))
