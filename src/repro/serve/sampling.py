"""On-device token sampling for the serving fast path — including the
speculative-decoding accept/reject sampler.

Everything here is shape-stable and jit-friendly: no host round trips, no
data-dependent shapes.  Greedy vs. stochastic is selected *per slot* with a
``temperature`` vector (0 == greedy) via ``jnp.where``, so one compiled
decode step serves mixed greedy/sampled batches.  The PRNG key is threaded
through the engine's device-side slot state — the host never touches it.

The speculative half (``spec_probs`` / ``spec_accept`` / ``spec_update``)
implements standard rejection sampling over ``K`` drafted tokens verified
by one multi-token target dispatch (``models/transformer.forward_verify``):
draft ``d_i`` is accepted with probability ``min(1, p(d_i)/q(d_i))``; the
first rejection resamples from the residual ``norm(max(p - q, 0))``, and a
fully-accepted draft earns a bonus token from the target's last-position
distribution.  At temperature 0 both ``p`` and ``q`` collapse to point
masses, so the rule degenerates to "accept while the draft matches the
target argmax, then emit the target argmax" — output is token-identical to
non-speculative greedy decoding; at temperature > 0 the emitted
distribution equals the target's (the standard speculative-sampling
guarantee), whatever the drafter proposes."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, key: jax.Array, *,
           temperature: jax.Array, top_k: int = 0) -> jax.Array:
    """Sample next tokens from ``logits`` [B, V] -> [B] int32.

    temperature: [B] float32, 0.0 selects argmax for that row.
    top_k: static int; 0 disables the top-k filter.  Rows share one key but
    draw independent categoricals (jax.random.categorical is per-row).
    """
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    temperature = jnp.asarray(temperature, jnp.float32)
    # safe divisor for greedy rows (their sampled value is discarded)
    safe_t = jnp.where(temperature > 0.0, temperature, 1.0)[:, None]
    if top_k and top_k < logits.shape[-1]:
        vals, idx = jax.lax.top_k(logits, top_k)        # [B,K], [B,K]
        draw = jax.random.categorical(key, vals / safe_t, axis=-1)
        sampled = jnp.take_along_axis(idx, draw[:, None], axis=-1)[:, 0]
    else:
        sampled = jax.random.categorical(key, logits / safe_t, axis=-1)
    sampled = sampled.astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)


def make_slot_state(slots: int, seed: int = 0, hist_cap: int = 0,
                    spec: bool = False, prompt_cap: int = 0,
                    prefill_budget: int = 0) -> dict:
    """Device-side per-slot bookkeeping for the fused decode step.

    tokens:   last token fed/emitted per slot (decode input)
    out_len:  generated tokens so far (incl. the prefill-sampled one)
    max_new:  generation budget per slot
    eos:      per-slot EOS id, -1 for none
    active:   slot is decoding a live request
    temp:     per-slot sampling temperature (0 == greedy)
    key:      threaded PRNG key (split inside the compiled step)

    ``spec`` adds the speculative telemetry counters (``spec_steps``
    active slot-steps, ``spec_drafted`` proposed tokens,
    ``spec_accepted`` accepted drafts, ``spec_emitted`` delivered
    tokens).  ``hist_cap > 0`` (n-gram drafter only — a model drafter
    has no use for it) adds ``hist`` [slots, hist_cap + 1], each slot's
    full token history (prompt + emitted — the lookup corpus; the extra
    column is a spill cell that absorbs masked/overflow scatter writes)
    with ``hist_len`` valid entries.

    ``prompt_cap > 0`` (fused chunked-prefill engines) adds ``prompt``
    [slots, prompt_cap] — the slot's full (effective) prompt, fed to the
    fused chunk a budgeted slice at a time — and ``plen``, its length.
    The prefill cursor itself is the cache ``len``; a slot is mid-prefill
    while ``len < plen``.  ``prefill_budget > 0`` additionally adds
    ``pbudget`` [slots] — the per-slot cap on prompt tokens per
    micro-step, initialized to the compiled chunk width.  The fused chunk
    clamps it to ``[1, S]``, so the SLO policy can shrink a batch slot's
    budget at a chunk boundary (one host->device value update) without
    retracing: ``S`` stays the static shape."""
    zi = jnp.zeros((slots,), jnp.int32)
    state = {
        "tokens": zi,
        "out_len": zi,
        "max_new": zi,
        "eos": jnp.full((slots,), -1, jnp.int32),
        "active": jnp.zeros((slots,), bool),
        "temp": jnp.zeros((slots,), jnp.float32),
        "key": jax.random.PRNGKey(seed),
    }
    if spec or hist_cap:
        for c in ("spec_steps", "spec_drafted", "spec_accepted",
                  "spec_emitted"):
            state[c] = jnp.int32(0)
    if hist_cap:
        state["hist"] = jnp.zeros((slots, hist_cap + 1), jnp.int32)
        state["hist_len"] = jnp.zeros((slots,), jnp.int32)
    if prompt_cap:
        state["prompt"] = jnp.zeros((slots, prompt_cap), jnp.int32)
        state["plen"] = jnp.zeros((slots,), jnp.int32)
    if prefill_budget:
        state["pbudget"] = jnp.full((slots,), prefill_budget, jnp.int32)
    return state


def decode_update(state: dict, nxt: jax.Array, new_key: jax.Array,
                  commit: Optional[jax.Array] = None) -> tuple:
    """One step of on-device slot bookkeeping.

    ``nxt`` [B] are freshly sampled tokens.  Returns ``(state', emitted)``
    where ``emitted`` is ``nxt`` for committing slots and -1 elsewhere —
    the host decodes the batched [T, B] history after the fact, so no
    per-token sync is needed for EOS/max-token termination.

    ``commit`` [B] bool narrows which slots take the token this step
    (default: every active slot).  The fused chunked-prefill step passes
    ``active & (pure decode | prefill just completed)`` so mid-prefill
    slots — whose row-``S-1`` logits predict a mid-prompt continuation,
    not an output token — advance their cursor without emitting.  When a
    drafting history buffer is present the committed token is appended to
    it (the fused path has no separate admission-time seeding step for
    the first sampled token)."""
    active = state["active"]
    if commit is None:
        commit = active
    out_len = state["out_len"] + commit.astype(jnp.int32)
    hit_eos = commit & (nxt == state["eos"])
    exhausted = out_len >= state["max_new"]
    done = commit & (hit_eos | exhausted)
    tokens = jnp.where(commit, nxt, state["tokens"])
    emitted = jnp.where(commit, nxt, -1)
    new_state = dict(state, tokens=tokens, out_len=out_len,
                     active=active & ~done, key=new_key)
    if "hist" in state:    # n-gram drafter corpus: append committed token
        hist, cap = state["hist"], state["hist"].shape[1] - 1
        b = hist.shape[0]
        pos = jnp.where(commit, jnp.minimum(state["hist_len"], cap), cap)
        new_state["hist"] = hist.at[jnp.arange(b), pos].set(
            jnp.maximum(jnp.where(commit, nxt, 0), 0))
        new_state["hist_len"] = state["hist_len"] + commit.astype(jnp.int32)
    return new_state, emitted


# ---------------------------------------------------------------------------
# Speculative decoding: accept/reject sampler + multi-token bookkeeping
# ---------------------------------------------------------------------------

def spec_probs(logits: jax.Array, temperature: jax.Array,
               top_k: int = 0) -> jax.Array:
    """Per-position sampling distributions the engine's ``sample`` would
    draw from: logits [B,S,V] -> probs [B,S,V].

    Greedy rows (temperature 0) yield a one-hot point mass at the argmax,
    which is what makes the rejection-sampling rule degenerate to exact
    greedy equivalence; sampled rows yield ``softmax(logits/T)`` over the
    ``top_k``-filtered support (the same support ``sample`` uses)."""
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    temperature = jnp.asarray(temperature, jnp.float32)
    safe_t = jnp.where(temperature > 0.0, temperature, 1.0)[:, None, None]
    z = logits / safe_t
    if top_k and top_k < v:
        kth = jax.lax.top_k(z, top_k)[0][..., -1:]
        z = jnp.where(z >= kth, z, -jnp.inf)
    p = jax.nn.softmax(z, axis=-1)
    greedy = jax.nn.one_hot(jnp.argmax(logits, axis=-1), v,
                            dtype=jnp.float32)
    return jnp.where(temperature[:, None, None] > 0.0, p, greedy)


def spec_accept(logits: jax.Array, drafts: jax.Array,
                qprobs: Optional[jax.Array], temperature: jax.Array,
                top_k: int, key: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """Rejection-sample ``K`` drafted tokens against the target's verify
    logits.

    logits [B,K+1,V] from ``forward_verify`` — ``logits[:, i]`` is the
    target distribution of the token *after* verify input ``i`` (input 0
    is the committed current token, input ``i >= 1`` is draft ``i``).
    drafts [B,K]; qprobs [B,K,V] is the drafter's per-position proposal
    distribution, or None for a deterministic (point-mass) drafter such
    as the n-gram lookup.  Returns ``(cand [B,K+1], n_acc [B])``: position
    ``j < n_acc`` of ``cand`` holds accepted draft ``j+1``, position
    ``n_acc`` holds the resampled correction (or the bonus token when all
    ``K`` drafts were accepted); entries past ``n_acc`` are meaningless —
    ``spec_update`` masks them via its emit count."""
    b, s, v = logits.shape
    k = s - 1
    p = spec_probs(logits, temperature, top_k)            # [B,K+1,V]
    q = (jax.nn.one_hot(drafts, v, dtype=jnp.float32) if qprobs is None
         else qprobs.astype(jnp.float32))
    pd = jnp.take_along_axis(p[:, :k], drafts[..., None], axis=-1)[..., 0]
    qd = jnp.take_along_axis(q, drafts[..., None], axis=-1)[..., 0]
    kacc, kcorr = jax.random.split(key)
    u = jax.random.uniform(kacc, (b, k))
    accept = u * qd < pd                    # u < min(1, p/q), div-free
    acc_prefix = jnp.cumprod(accept.astype(jnp.int32), axis=1)
    n_acc = jnp.sum(acc_prefix, axis=1)                   # [B] in 0..K
    resid = jnp.maximum(p[:, :k] - q, 0.0)
    rsum = jnp.sum(resid, axis=-1, keepdims=True)
    # a rejection implies the residual has mass; the fallback to the raw
    # target distribution only guards numerics on never-taken branches
    resid = jnp.where(rsum > 1e-9, resid / jnp.maximum(rsum, 1e-30),
                      p[:, :k])
    dists = jnp.concatenate([resid, p[:, k:]], axis=1)    # [B,K+1,V]
    corr = jnp.take_along_axis(dists, n_acc[:, None, None], axis=1)[:, 0]
    sampled = jax.random.categorical(
        kcorr, jnp.log(corr + 1e-30), axis=-1).astype(jnp.int32)
    greedy = jnp.argmax(corr, axis=-1).astype(jnp.int32)
    tok_corr = jnp.where(jnp.asarray(temperature) > 0.0, sampled, greedy)
    idx = jnp.arange(k + 1)[None, :]
    cand = jnp.concatenate(
        [drafts, jnp.zeros((b, 1), drafts.dtype)], axis=1)
    cand = jnp.where(idx == n_acc[:, None], tok_corr[:, None], cand)
    return cand.astype(jnp.int32), n_acc


def spec_update(state: dict, cand: jax.Array, n_acc: jax.Array,
                new_key: jax.Array,
                commit: Optional[jax.Array] = None) -> tuple:
    """Multi-token analogue of ``decode_update``: commit up to ``n_acc+1``
    tokens per active slot, clamped to the remaining generation budget and
    truncated at the first EOS.  Appends the committed tokens to the
    drafting history and advances the telemetry counters.  Returns
    ``(state', emitted [B,K+1], n_emit [B])`` where ``emitted`` carries the
    committed tokens left-aligned with -1 padding (what the scan stacks
    for the host drain) and ``n_emit`` is how far the cache ``len`` may
    advance — rejected drafts roll back simply by not being counted.

    ``commit`` [B] bool narrows which slots take this round's verdict
    (default: every active slot).  The fused chunked-prefill step passes
    ``active & ~prefilling`` — drafting stays disabled for a slot until
    its prefill cursor reaches the prompt end, and a mid-prefill slot
    contributes nothing to the speculative telemetry counters."""
    active = state["active"]
    if commit is None:
        commit = active
    b, k1 = cand.shape
    idx = jnp.arange(k1)[None, :]
    rem = jnp.maximum(state["max_new"] - state["out_len"], 0)
    n0 = jnp.where(commit, jnp.minimum(n_acc + 1, rem), 0)
    iseos = (cand == state["eos"][:, None]) & (idx < n0[:, None])
    big = k1 + 1
    epos = jnp.min(jnp.where(iseos, idx, big), axis=1)
    n_emit = jnp.minimum(n0, epos + 1)
    emitted = jnp.where(idx < n_emit[:, None], cand, -1)
    out_len = state["out_len"] + n_emit
    hit_eos = epos + 1 <= n0
    done = commit & (hit_eos | (out_len >= state["max_new"]))
    last = jnp.take_along_axis(
        cand, jnp.clip(n_emit - 1, 0)[:, None], axis=1)[:, 0]
    tokens = jnp.where(commit & (n_emit > 0), last, state["tokens"])
    n_active = jnp.sum(commit.astype(jnp.int32))
    # acceptance accounting over USABLE drafts: a budget-clamped final
    # step can emit at most ``rem`` tokens, so drafts past that could
    # never be used and should not count as rejections
    usable = jnp.where(commit, jnp.minimum(k1 - 1, rem), 0)
    new_state = dict(
        state, tokens=tokens, out_len=out_len, active=active & ~done,
        key=new_key,
        spec_steps=state["spec_steps"] + n_active,
        spec_drafted=state["spec_drafted"] + jnp.sum(usable),
        spec_accepted=state["spec_accepted"]
        + jnp.sum(jnp.where(commit, jnp.minimum(n_acc, n_emit), 0)),
        spec_emitted=state["spec_emitted"] + jnp.sum(n_emit))
    if "hist" in state:    # n-gram drafter: append to the lookup corpus
        hist, cap = state["hist"], state["hist"].shape[1] - 1
        pos = jnp.where(idx < n_emit[:, None],
                        state["hist_len"][:, None] + idx, cap)
        pos = jnp.minimum(pos, cap)         # overflow -> spill column
        new_state["hist"] = hist.at[jnp.arange(b)[:, None], pos].set(
            jnp.maximum(emitted, 0))
        new_state["hist_len"] = state["hist_len"] + n_emit
    return new_state, emitted, n_emit
