"""On-device token sampling for the serving fast path.

Everything here is shape-stable and jit-friendly: no host round trips, no
data-dependent shapes.  Greedy vs. stochastic is selected *per slot* with a
``temperature`` vector (0 == greedy) via ``jnp.where``, so one compiled
decode step serves mixed greedy/sampled batches.  The PRNG key is threaded
through the engine's device-side slot state — the host never touches it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, key: jax.Array, *,
           temperature: jax.Array, top_k: int = 0) -> jax.Array:
    """Sample next tokens from ``logits`` [B, V] -> [B] int32.

    temperature: [B] float32, 0.0 selects argmax for that row.
    top_k: static int; 0 disables the top-k filter.  Rows share one key but
    draw independent categoricals (jax.random.categorical is per-row).
    """
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    temperature = jnp.asarray(temperature, jnp.float32)
    # safe divisor for greedy rows (their sampled value is discarded)
    safe_t = jnp.where(temperature > 0.0, temperature, 1.0)[:, None]
    if top_k and top_k < logits.shape[-1]:
        vals, idx = jax.lax.top_k(logits, top_k)        # [B,K], [B,K]
        draw = jax.random.categorical(key, vals / safe_t, axis=-1)
        sampled = jnp.take_along_axis(idx, draw[:, None], axis=-1)[:, 0]
    else:
        sampled = jax.random.categorical(key, logits / safe_t, axis=-1)
    sampled = sampled.astype(jnp.int32)
    return jnp.where(temperature > 0.0, sampled, greedy)


def make_slot_state(slots: int, seed: int = 0) -> dict:
    """Device-side per-slot bookkeeping for the fused decode step.

    tokens:   last token fed/emitted per slot (decode input)
    out_len:  generated tokens so far (incl. the prefill-sampled one)
    max_new:  generation budget per slot
    eos:      per-slot EOS id, -1 for none
    active:   slot is decoding a live request
    temp:     per-slot sampling temperature (0 == greedy)
    key:      threaded PRNG key (split inside the compiled step)
    """
    zi = jnp.zeros((slots,), jnp.int32)
    return {
        "tokens": zi,
        "out_len": zi,
        "max_new": zi,
        "eos": jnp.full((slots,), -1, jnp.int32),
        "active": jnp.zeros((slots,), bool),
        "temp": jnp.zeros((slots,), jnp.float32),
        "key": jax.random.PRNGKey(seed),
    }


def decode_update(state: dict, nxt: jax.Array, new_key: jax.Array) -> tuple:
    """One step of on-device slot bookkeeping.

    ``nxt`` [B] are freshly sampled tokens.  Returns ``(state', emitted)``
    where ``emitted`` is ``nxt`` for active slots and -1 elsewhere — the
    host decodes the batched [T, B] history after the fact, so no per-token
    sync is needed for EOS/max-token termination.
    """
    active = state["active"]
    out_len = state["out_len"] + active.astype(jnp.int32)
    hit_eos = active & (nxt == state["eos"])
    exhausted = out_len >= state["max_new"]
    done = active & (hit_eos | exhausted)
    tokens = jnp.where(active, nxt, state["tokens"])
    emitted = jnp.where(active, nxt, -1)
    new_state = {
        "tokens": tokens,
        "out_len": out_len,
        "max_new": state["max_new"],
        "eos": state["eos"],
        "active": active & ~done,
        "temp": state["temp"],
        "key": new_key,
    }
    return new_state, emitted
