"""Speculative-decoding configuration + capability gate.

``SpecConfig`` is the one knob surface: which drafter proposes tokens
(``"ngram"`` — the model-free prompt-lookup drafter — or the name/config
of a small draft model), how many tokens it drafts per verify step
(``k``), and the n-gram order for the lookup drafter.  The serving
engine accepts it as ``Engine(spec=...)``; ``launch/serve.py`` maps
``--spec-draft {off,ngram,<config>} --spec-k N`` onto it.

Speculative decoding rewrites the decode inner loop as
draft-``K``/verify-``K+1``/accept, which requires the target cache to
support *positional rollback*: un-accepting a token must be as cheap as
not advancing ``len``.  Block-paged attention KV has that property
(token ``t`` always lives at page ``(t // P) mod ring``, offset ``t mod
P`` — a rejected token's cell is simply overwritten by the real token
later), but recurrent STATE layers (mamba2 / rwkv6) do not: their state
update is a fold, and rewinding it would need every intermediate state
materialized.  ``spec_unsupported_reason`` is therefore the same flavour
of structural gate as ``CacheSpec.share_group_key``: attention-only
stacks, no modality frontend, no cross-attention.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.configs.base import ATTN, ModelConfig


@dataclasses.dataclass
class SpecConfig:
    """Speculative-decoding settings for ``serve/engine.Engine``.

    draft:        ``"ngram"`` (prompt-lookup, no second model) or the name
                  of a draft model config; ``draft_cfg``/``draft_params``
                  override/supply the actual model when given.
    k:            drafted tokens per verify step (the verify dispatch runs
                  ``k + 1`` query rows).
    ngram:        n-gram order for the lookup drafter.
    draft_cfg:    resolved draft ``ModelConfig`` (model drafter only).
    draft_params: draft model parameters; initialized from the engine seed
                  when left None.
    """

    draft: str = "ngram"
    k: int = 4
    ngram: int = 3
    draft_cfg: Optional[ModelConfig] = None
    draft_params: Any = None


def spec_unsupported_reason(cfg: ModelConfig) -> Optional[str]:
    """Why ``cfg`` cannot serve speculatively, or None when it can.

    The verify step needs every layer's decode state to roll back by
    *not advancing a position counter*; only block-paged attention KV
    behaves that way."""
    if cfg.cross_attention:
        return "cross-attention decoders are not served by Engine"
    if cfg.frontend:
        return ("modality-frontend archs prepend non-token state the "
                "drafters cannot model")
    bad = sorted({b.mixer for b in cfg.blocks if b.mixer != ATTN})
    if bad:
        return (f"{'/'.join(bad)} layers keep recurrent state that cannot "
                "roll back rejected drafts without materializing every "
                "intermediate state")
    return None


def check_spec_capable(cfg: ModelConfig, what: str = "speculative "
                       "decoding") -> None:
    """Raise with an actionable message when ``cfg`` cannot run ``what``."""
    reason = spec_unsupported_reason(cfg)
    if reason is not None:
        raise ValueError(f"{cfg.name} does not support {what}: {reason}")
