"""Draft proposers for the speculative serving path.

Both drafters are pure jit-traceable functions of device-side state — a
draft step never touches the host, so the fused decode chunk stays
sync-free with speculation enabled.  The contract is::

    drafts, qprobs, cache = drafter.propose(draft_params, cache, state,
                                            key, top_k)

``drafts`` [B, K] are proposed continuations of ``state["tokens"]``;
``qprobs`` is the per-position proposal distribution [B, K, V] (or None
for a deterministic proposer — the accept rule then treats the proposal
as a point mass); ``cache`` is returned because a model drafter advances
its own draft cache in place.

**NGramDrafter** (prompt-lookup decoding): finds the most recent earlier
occurrence of the last ``n`` tokens in the slot's history buffer
(``state["hist"]`` — prompt plus everything emitted) and proposes the
``K`` tokens that followed it.  Free of any second model, and exact for
the repetitive tails (cycles, copied spans) where greedy decoding spends
most of its tokens.  A wrong draft costs nothing but wasted verify
compute — the accept rule rejects it.

**ModelDrafter**: a small attention-only model (any reduced ``configs/``
arch) decoded ``K`` steps ahead on its own *dense* per-slot KV cache
(``cache["draft"]``).  The draft cache needs no paging or careful
rollback: positions past the committed length are overwritten by later
writes, exactly like the target's pages, and any imperfection can only
lower the acceptance rate, never corrupt the verified output.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ModelConfig
from repro.models import attention, forward_decode
from repro.serve import sampling


def ngram_propose(hist: jax.Array, hist_len: jax.Array, *, k: int,
                  n: int) -> jax.Array:
    """Prompt-lookup proposal: continue the most recent earlier match of
    the trailing ``n``-gram.

    hist [B, C(+1)] (the final spill column, if present, is excluded);
    hist_len [B] valid entries.  Returns drafts [B, k].  When no earlier
    match exists the last token is repeated — a deliberately cheap
    fallback whose drafts simply get rejected."""
    h = hist[:, :-1]
    b, c = h.shape
    gpos = hist_len[:, None] - n + jnp.arange(n)[None, :]
    gram = jnp.take_along_axis(h, jnp.clip(gpos, 0, c - 1), axis=1)
    # all length-n windows of the history: [B, C-n+1, n]
    win = jnp.stack([h[:, i:c - n + i + 1] for i in range(n)], axis=-1)
    jidx = jnp.arange(c - n + 1)
    match = jnp.all(win == gram[:, None, :], axis=-1)
    # an eligible start must have a continuation inside the history and
    # must not be the trailing gram itself
    ok = match & (jidx[None, :] + n < hist_len[:, None]) \
        & (gpos[:, :1] >= 0)
    # rank matches by USABLE continuation length first (a match right at
    # the history tail can only contribute one token before running off
    # the written region — e.g. in a constant run the most recent match
    # is always one token from the end), recency second
    avail = jnp.minimum(hist_len[:, None] - (jidx[None, :] + n), k)
    score = jnp.where(ok, avail * (c + 1) + jidx[None, :], -1)
    best = jnp.argmax(score, axis=1)
    found = jnp.max(score, axis=1) >= 0
    j = jidx[best]
    # continuation positions past the written history wrap by the match
    # period (distance from the matched gram to the trailing one), so a
    # cyclic tail — constant runs, short cycles, the bread and butter of
    # greedy decoding — drafts a full K tokens instead of trailing off
    p = jnp.maximum(hist_len - n - j, 1)[:, None]
    i = jnp.arange(k)[None, :]
    cpos = j[:, None] + n + i
    cpos = jnp.where(cpos >= hist_len[:, None],
                     j[:, None] + n + i % p, cpos)
    drafts = jnp.take_along_axis(h, jnp.clip(cpos, 0, c - 1), axis=1)
    last = jnp.take_along_axis(
        h, jnp.clip(hist_len - 1, 0, c - 1)[:, None], axis=1)
    return jnp.where(found[:, None], drafts, last).astype(jnp.int32)


class NGramDrafter:
    """Model-free prompt-lookup drafter (see module docstring)."""

    kind = "ngram"

    def __init__(self, k: int, n: int = 3):
        self.k = int(k)
        self.n = int(n)

    def propose(self, draft_params: Any, cache: Dict, state: Dict,
                key: jax.Array, top_k: int
                ) -> Tuple[jax.Array, Optional[jax.Array], Dict]:
        """Traced inside the fused chunk; ignores params and PRNG key."""
        drafts = ngram_propose(state["hist"], state["hist_len"],
                               k=self.k, n=self.n)
        return drafts, None, cache


class ModelDrafter:
    """Small-model drafter over a dense per-slot draft KV cache."""

    kind = "model"

    def __init__(self, cfg: ModelConfig, k: int, cache_tokens: int):
        bad = sorted({b.mixer for b in cfg.blocks if b.mixer != ATTN})
        if bad or cfg.frontend or cfg.cross_attention:
            raise ValueError(
                f"draft model {cfg.name} must be a plain attention-only "
                f"decoder (got {bad or 'frontend/cross-attention'})")
        self.cfg = cfg
        self.k = int(k)
        self.cache_tokens = int(cache_tokens)

    def init_cache(self, slots: int) -> List[Optional[Dict]]:
        """Zeroed dense draft KV: one ``cache_tokens`` row per slot per
        draft layer (small model — paging buys nothing)."""
        shape, _ = attention.init_cache_shape(self.cfg, slots,
                                              self.cache_tokens)
        return [{"k": jnp.zeros(shape, jnp.float32),
                 "v": jnp.zeros(shape, jnp.float32)}
                for _ in self.cfg.blocks]

    def propose(self, draft_params: Any, cache: Dict, state: Dict,
                key: jax.Array, top_k: int
                ) -> Tuple[jax.Array, Optional[jax.Array], Dict]:
        """``K`` sequential draft-model decode steps (traced, on device).

        Draft tokens are *sampled* from the draft distribution at the
        slot's temperature (greedy at 0) — the proposal distribution the
        accept rule requires — and the same distribution is returned as
        ``qprobs``."""
        dc = {"layers": cache["draft"], "len": cache["len"]}
        tok = state["tokens"]
        temp = state["temp"]
        drafts, qlogits = [], []
        for _ in range(self.k):
            lg, dc = forward_decode(draft_params, self.cfg, tok[:, None],
                                    dc)
            key, sub = jax.random.split(key)
            tok = sampling.sample(lg, sub, temperature=temp, top_k=top_k)
            drafts.append(tok)
            qlogits.append(lg)
        # one extra forward purely to write the LAST draft's KV: a fully
        # accepted round commits through that position, and without this
        # write the next round's draft steps would attend stale garbage
        # there (rejected rounds overwrite it — only acceptance cares)
        _, dc = forward_decode(draft_params, self.cfg, tok[:, None], dc)
        qprobs = sampling.spec_probs(jnp.stack(qlogits, axis=1), temp,
                                     top_k)
        return (jnp.stack(drafts, axis=1), qprobs,
                dict(cache, draft=dc["layers"]))
