from repro.serve.spec.config import (SpecConfig, check_spec_capable,
                                     spec_unsupported_reason)
from repro.serve.spec.drafter import (ModelDrafter, NGramDrafter,
                                      ngram_propose)

__all__ = ["SpecConfig", "check_spec_capable", "spec_unsupported_reason",
           "NGramDrafter", "ModelDrafter", "ngram_propose"]
