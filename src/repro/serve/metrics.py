"""Typed counters/gauges registry unifying the engine's telemetry.

Nine PRs of serving work accumulated five disconnected stats dicts --
``memory_stats`` / ``fault_stats`` / ``latency_stats`` /
``spec_stats`` / ``prefix_stats`` -- each with its own key spellings.
This module absorbs them behind one flat snapshot with **stable dotted
metric names** (``pool.pages_in_use``, ``sched.preemptions.pressure``,
``spec.acceptance``, ``latency.goodput``, ...), the registry every
dashboard, gate, and future ROADMAP item reports through.

:data:`REGISTRY` declares each stable name with a metric kind
(``counter`` monotonically increases over an engine's lifetime;
``gauge`` samples a level).  Dynamic families (per-SLO-class latency,
per-pool-group occupancy, chaos counters) are declared as prefix
rules.  :func:`snapshot` flattens a live engine into ``{name: value}``
and refuses to emit a name the registry does not know -- renaming a
metric is an API change, not a drive-by edit.  ``Engine.observe()`` is
the public entry point; ``docs/observability.md`` lists every name.
"""

import dataclasses
from typing import Any, Dict, Optional, Tuple

Number = Any  # int | float | bool


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One stable metric name: its kind and one-line meaning."""

    name: str
    kind: str       # "counter" | "gauge"
    help: str = ""


def _c(name: str, help: str = "") -> MetricSpec:
    return MetricSpec(name, "counter", help)


def _g(name: str, help: str = "") -> MetricSpec:
    return MetricSpec(name, "gauge", help)


#: The stable name registry (exact names).
REGISTRY: Tuple[MetricSpec, ...] = (
    # engine driver
    _c("engine.steps", "decode steps issued (sync_interval per chunk)"),
    _c("engine.host_syncs", "batched device->host drains"),
    _c("engine.chunks", "drained chunk count (the chunk sequence id)"),
    _g("engine.queue_depth", "requests waiting for a slot"),
    # page pools
    _g("pool.pages_in_use", "currently referenced pages, all groups"),
    _g("pool.peak_pages_in_use", "high-water referenced pages"),
    _g("pool.live_slots", "slots currently running"),
    _g("pool.peak_live_slots", "high-water concurrent slots"),
    # scheduler / fault machinery
    _c("sched.admissions", "admissions planned (incl. resumes)"),
    _c("sched.preemptions.total", "slot evictions, all causes"),
    _c("sched.preemptions.pressure", "evictions for page pressure"),
    _c("sched.preemptions.chaos", "evictions injected by chaos"),
    _c("sched.preemptions.watchdog", "evictions of stalled slots"),
    _c("sched.resumes", "re-admissions of preempted requests"),
    _c("sched.timed_out", "requests reaped past their deadline"),
    _c("sched.cancelled", "requests reaped after cancel()"),
    _c("sched.rejected.total", "requests shed at submit"),
    _c("sched.rejected.infeasible", "reservation exceeds pool budget"),
    _c("sched.rejected.queue_full", "queue_limit hit, policy=reject"),
    _c("sched.rejected.shed_lower_class", "displaced by a higher class"),
    _g("sched.resume.recovered_prefill_fraction",
       "prefill tokens recovered from the radix index on resume"),
    _c("sched.budget_throttles", "prefill-budget throttle decisions"),
    # latency rollup
    _g("latency.goodput", "fraction of terminal requests meeting SLO"),
    # tracing
    _g("trace.events", "events currently buffered in the tracer"),
    _c("trace.dropped", "non-terminal events evicted at capacity"),
)

#: Dynamic name families: (prefix, kind).
DYNAMIC_PREFIXES: Tuple[Tuple[str, str], ...] = (
    ("pool.", "gauge"),            # pool.group.<g>.<k>, byte accounting
    ("latency.class.", "gauge"),   # per-SLO-class percentiles/goodput
    ("latency.", "gauge"),         # overall percentiles
    ("prefix.", "gauge"),          # radix sharing telemetry
    ("spec.", "gauge"),            # speculative decoding telemetry
    ("chaos.", "counter"),         # injected-fault schedule counts
    ("sched.resume.", "counter"),  # resume_* recovery counters
)

_BY_NAME: Dict[str, MetricSpec] = {m.name: m for m in REGISTRY}


def kind_of(name: str) -> Optional[str]:
    """Metric kind for ``name``, or None if the registry rejects it."""
    spec = _BY_NAME.get(name)
    if spec is not None:
        return spec.kind
    for prefix, kind in DYNAMIC_PREFIXES:
        if name.startswith(prefix):
            return kind
    return None


def _put(out: Dict[str, Number], name: str, value: Any) -> None:
    if value is None or isinstance(value, (dict, list, tuple, str)):
        return
    if kind_of(name) is None:
        raise KeyError(f"metric name {name!r} is not in the registry; "
                       "declare it in repro.serve.metrics first")
    out[name] = value


def _flatten(out: Dict[str, Number], prefix: str, d: Dict[str, Any]) -> None:
    for k, v in d.items():
        if isinstance(v, dict):
            _flatten(out, f"{prefix}{k}.", v)
        else:
            _put(out, f"{prefix}{k}", v)


# fault_stats() keys -> stable dotted names.
_FAULT_RENAMES = {
    "preemptions": "sched.preemptions.total",
    "pressure_preemptions": "sched.preemptions.pressure",
    "chaos_preemptions": "sched.preemptions.chaos",
    "watchdog_preemptions": "sched.preemptions.watchdog",
    "resumes": "sched.resumes",
    "timed_out": "sched.timed_out",
    "cancelled": "sched.cancelled",
    "rejected": "sched.rejected.total",
    "rejected_infeasible": "sched.rejected.infeasible",
    "rejected_queue_full": "sched.rejected.queue_full",
    "rejected_shed_lower_class": "sched.rejected.shed_lower_class",
    "recovered_prefill_fraction":
        "sched.resume.recovered_prefill_fraction",
}


def snapshot(engine: Any, *, spec: bool = True) -> Dict[str, Number]:
    """Flatten a live engine into ``{dotted_name: value}``.

    ``spec=False`` skips ``spec_stats()`` (the one stats call that
    reads device memory) for strictly host-side sampling.
    """
    out: Dict[str, Number] = {}
    _put(out, "engine.steps", engine.steps)
    _put(out, "engine.host_syncs", engine.host_syncs)
    _put(out, "engine.chunks", getattr(engine, "chunks", 0))
    _put(out, "engine.queue_depth", len(engine.queue))

    mem = engine.memory_stats()
    _put(out, "pool.live_slots", mem.pop("live_slots", None))
    _put(out, "pool.peak_live_slots", mem.pop("peak_live_slots", None))
    _flatten(out, "pool.", mem)
    pages_now = getattr(engine.scheduler, "pages_in_use", None)
    if pages_now is not None:
        _put(out, "pool.pages_in_use", pages_now)
    _put(out, "sched.admissions",
         getattr(engine.scheduler, "admissions_total", None))

    faults = dict(engine.fault_stats())
    chaos = faults.pop("chaos", None)
    for k, v in faults.items():
        name = _FAULT_RENAMES.get(k)
        if name is None:
            name = f"sched.resume.{k[len('resume_'):]}" \
                if k.startswith("resume_") else f"chaos.{k}"
        _put(out, name, v)
    if isinstance(chaos, dict):
        _flatten(out, "chaos.", chaos)

    lat = dict(engine.latency_stats())
    _put(out, "latency.goodput", lat.pop("goodput", None))
    _put(out, "sched.budget_throttles", lat.pop("budget_throttles", None))
    for cls, stats in lat.pop("classes", {}).items():
        _flatten(out, f"latency.class.{cls}.", stats)
    _flatten(out, "latency.", lat.pop("overall", {}))

    _flatten(out, "prefix.", engine.prefix_stats())

    if spec:
        sp = dict(engine.spec_stats())
        if "acceptance_rate" in sp:
            _put(out, "spec.acceptance", sp.pop("acceptance_rate"))
        _flatten(out, "spec.", sp)

    tracer = getattr(engine, "tracer", None)
    if tracer is not None:
        _put(out, "trace.events", len(tracer))
        _put(out, "trace.dropped", tracer.dropped)
    return out
