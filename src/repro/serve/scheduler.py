"""Host-side serving policy layer: queue, admission, eviction, paging.

The serving runtime is layered (paper §2.2.3: scheduling and memory
management, not math, bound serving throughput once kernels are tuned):

* **Scheduler** (this module) — pure-Python policy: FIFO queue, slot
  assignment, page-budget reservation, eviction.  No jax arrays, no
  device work; decisions are made from state the host already knows, so
  the policy layer adds zero device synchronization.
* **Executor** (``serve/engine.Executor``) — the compiled layer: bucketed
  prefill, page-granular admission splice, the fused decode chunk.
* **Driver** (``serve/engine.Engine``) — glues the two: drains tokens once
  per chunk, reports finishes to the scheduler, applies its admissions.

Continuous batching falls out of the layering: at every chunk boundary the
driver reports finished slots (eviction → pages back to the free list) and
asks for admissions (a freed slot is re-leased to the queue head without
recompiling anything — all compiled shapes are slot-count-stable).

Pages are reserved *worst-case at admission* (``CacheSpec.blocks_needed``),
which makes mid-run pool exhaustion impossible for admitted requests: the
failure mode surfaces as clean backpressure (the queue head waits for
pages) or as ``PagePoolExhausted`` when a request can never fit, instead
of as silent corruption of a neighbour's pages.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.serve.cache import CacheSpec


class PagePoolExhausted(RuntimeError):
    """Raised when a request's worst-case page reservation can never be
    satisfied by the pool (the clean backpressure signal — nothing was
    admitted, no cache state was touched)."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    temperature: Optional[float] = None   # None -> engine default
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class PagePool:
    """Free-list allocator over physical page ids ``0..num_pages-1``.

    Page ``num_pages`` is the trash page — never allocated; unreserved
    page-table entries point at it so stray writes are discarded."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self.trash = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self.peak_in_use = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Lease ``n`` pages, or None (backpressure) if not enough free."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def free(self, pages: List[int]) -> None:
        self._free.extend(pages)


class Scheduler:
    """FIFO continuous-batching policy over ``slots`` cache slots and a
    shared page budget."""

    def __init__(self, spec: CacheSpec):
        self.spec = spec
        self.pool = PagePool(spec.num_pages if spec.has_paged else 0)
        self.queue: List[Request] = []
        self._leases: Dict[int, List[int]] = {}

    # ---------------------------------------------------------- admission
    def submit(self, req: Request) -> None:
        need = self.spec.blocks_needed(len(req.prompt), req.max_new_tokens)
        if need > self.pool.num_pages and self.spec.has_paged:
            raise PagePoolExhausted(
                f"request rid={req.rid} needs {need} pages "
                f"({len(req.prompt)} prompt + {req.max_new_tokens} new "
                f"tokens at page_size={self.spec.page_size}) but the pool "
                f"only has {self.pool.num_pages}; raise --num-pages")
        self.queue.append(req)

    def admissions(self, free_slots: List[int]
                   ) -> Iterator[Tuple[int, Request, np.ndarray]]:
        """Yield ``(slot, request, page_table_row)`` while the queue head
        fits.  Strictly FIFO: when the head's reservation does not fit,
        later (smaller) requests do NOT jump it — head-of-line
        backpressure keeps admission order fair."""
        free_slots = list(free_slots)
        while self.queue and free_slots:
            req = self.queue[0]
            need = self.spec.blocks_needed(len(req.prompt),
                                           req.max_new_tokens)
            pages = self.pool.alloc(need)
            if pages is None:
                return                       # wait for an eviction
            self.queue.pop(0)
            slot = free_slots.pop(0)
            self._leases[slot] = pages
            row = np.full((self.spec.max_blocks,), self.pool.trash, np.int32)
            row[:len(pages)] = pages
            yield slot, req, row

    # ----------------------------------------------------------- eviction
    def release(self, slot: int) -> None:
        """Return a finished slot's pages to the free list."""
        self.pool.free(self._leases.pop(slot, []))

    def can_progress(self, live_slots: int) -> bool:
        """False when the engine is wedged: nothing is running and the
        queue head still cannot be admitted (should be impossible given
        the submit() capacity check — a guard, not a policy)."""
        if not self.queue or live_slots:
            return True
        need = self.spec.blocks_needed(len(self.queue[0].prompt),
                                       self.queue[0].max_new_tokens)
        return need <= self.pool.free_pages

    # ---------------------------------------------------------- telemetry
    @property
    def pages_in_use(self) -> int:
        return self.pool.in_use

    @property
    def peak_pages_in_use(self) -> int:
        return self.pool.peak_in_use
