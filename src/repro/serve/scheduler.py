"""Host-side serving policy layer: queue, admission, eviction, paging,
prefix sharing.

The serving runtime is layered (paper §2.2.3: scheduling and memory
management, not math, bound serving throughput once kernels are tuned):

* **Scheduler** (this module) — pure-Python policy: FIFO queue, slot
  assignment, per-group page-budget reservation, refcounted page
  sharing, radix-indexed prefix matching, LRU prefix eviction.  No jax
  arrays, no device work; decisions are made from state the host already
  knows, so the policy layer adds zero device synchronization.
* **Executor** (``serve/engine.Executor``) — the compiled layer: bucketed
  full/suffix prefill, page-granular admission splice, copy-on-write
  page duplication, the fused decode chunk.
* **Driver** (``serve/engine.Engine``) — glues the two: drains tokens once
  per chunk, reports finishes to the scheduler, applies its admissions.

Continuous batching falls out of the layering: at every chunk boundary the
driver reports finished slots (release → refcounts drop, exclusive pages
back to the free list) and asks for admissions (a freed slot is re-leased
to the queue head without recompiling anything — all compiled shapes are
slot-count-stable).

Pages are reserved *worst-case at admission* (``CacheSpec.blocks_needed``,
now a per-pool-group map), which makes mid-run pool exhaustion impossible
for admitted requests: the failure mode surfaces as clean backpressure
(the queue head waits for pages) or as ``PagePoolExhausted`` when a
request can never fit, instead of as silent corruption of a neighbour's
pages.

**Prefix sharing** (sharing-capable specs only — pure full-attention
stacks, see ``CacheSpec.share_group_key``): full prompt pages are indexed
in a radix tree keyed by page content.  Admission walks the tree page-by-
page over the incoming prompt; matched pages are attached to the new
slot's table with a refcount bump and *prefill is skipped for those
tokens* — the Executor prefillls only the suffix, attending to the prefix
through the shared pages.  A slot about to write into a shared page (a
partially-matched page, or the final page of a fully-matched prompt —
the last prompt token is always re-prefilled to produce first-token
logits) gets a private copy first: the admission carries a
``cow=(block, src, dst)`` directive the Executor turns into a jitted
page copy.  The tree itself holds one reference per indexed page, so
popular prefixes survive their originating request; when allocation runs
dry the scheduler evicts **only refcount-1 leaves** (pages no live slot
references) in LRU order, cascading up the tree as parents become
leaves.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.serve.cache import CacheSpec


class PagePoolExhausted(RuntimeError):
    """Raised when a request's worst-case page reservation can never be
    satisfied by the pool (the clean backpressure signal — nothing was
    admitted, no cache state was touched)."""


class RequestStatus:
    """Typed terminal/lifecycle states a ``Request`` moves through.

    ``QUEUED -> RUNNING -> FINISHED`` is the happy path; ``PREEMPTED``
    loops back to ``QUEUED -> RUNNING`` (capped by ``max_preemptions``);
    ``TIMED_OUT`` / ``CANCELLED`` / ``REJECTED`` are terminal."""

    QUEUED = "QUEUED"
    RUNNING = "RUNNING"
    PREEMPTED = "PREEMPTED"
    FINISHED = "FINISHED"
    TIMED_OUT = "TIMED_OUT"
    CANCELLED = "CANCELLED"
    REJECTED = "REJECTED"

    TERMINAL = frozenset({FINISHED, TIMED_OUT, CANCELLED, REJECTED})


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One service class: admission priority (lower = more urgent) plus
    the latency contract its requests are graded against — TTFT (submit
    to first token) and TPOT (mean per-token delta after the first), in
    engine-clock units.  ``None`` targets always pass (best-effort)."""

    name: str
    priority: int
    ttft_target: Optional[float]
    tpot_target: Optional[float]


#: Built-in multi-tenant service classes.  ``interactive`` outranks
#: ``batch`` outranks ``best_effort`` at admission and is preempted
#: last under pool pressure; per-request ``ttft_target``/``tpot_target``
#: override the class defaults (which are wall-seconds on a real clock,
#: virtual units under ``serve/traffic.VirtualClock``).
SLO_CLASSES: Dict[str, SLOClass] = {
    "interactive": SLOClass("interactive", 0, 1.0, 0.1),
    "batch": SLOClass("batch", 1, 20.0, 1.0),
    "best_effort": SLOClass("best_effort", 2, None, None),
}


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    temperature: Optional[float] = None   # None -> engine default
    # --- deadline / cancellation (engine-clock units; ttl is relative
    # and resolved to an absolute deadline at Engine.submit) ---
    deadline: Optional[float] = None
    ttl: Optional[float] = None
    max_preemptions: int = 3
    # --- SLO class + latency contract (None target -> class default;
    # a class absent from SLO_CLASSES grades as best_effort) ---
    slo_class: str = "best_effort"
    ttft_target: Optional[float] = None
    tpot_target: Optional[float] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = RequestStatus.QUEUED
    preemptions: int = 0
    cancel_requested: bool = False
    reject_reason: Optional[str] = None
    # --- latency telemetry, host-stamped (submit at Engine.submit; first
    # token and per-token times at the chunk-boundary drain, so no new
    # device syncs).  submit_time survives preemption: TTFT is measured
    # from the ORIGINAL submit, never from a resume. ---
    submit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    # Parallel to token_times: the engine chunk sequence number whose
    # drain emitted each token.  Tokens drained by the same chunk share
    # one host clock read, so token_times alone aliases them — the
    # chunk id disambiguates TPOT attribution and cross-references the
    # admission_log / trace events (repro.serve.trace).
    token_chunks: List[int] = dataclasses.field(default_factory=list)
    _seq: int = 0   # scheduler-assigned arrival order (slack tiebreak)

    def cancel(self) -> None:
        """Request cooperative cancellation; the engine reaps the slot
        (or drops the queue entry) at the next chunk boundary."""
        self.cancel_requested = True

    @property
    def slo(self) -> SLOClass:
        return SLO_CLASSES.get(self.slo_class, SLO_CLASSES["best_effort"])

    @property
    def priority(self) -> int:
        """Admission priority (lower = more urgent)."""
        return self.slo.priority

    @property
    def resolved_ttft_target(self) -> Optional[float]:
        return self.ttft_target if self.ttft_target is not None \
            else self.slo.ttft_target

    @property
    def resolved_tpot_target(self) -> Optional[float]:
        return self.tpot_target if self.tpot_target is not None \
            else self.slo.tpot_target

    def ttft_slack(self, now: float) -> float:
        """Time remaining until this request's TTFT target is blown
        (negative = already late; +inf when it has no target).  Least
        slack first is the SLO admission order within a priority band."""
        target = self.resolved_ttft_target
        if target is None:
            return float("inf")
        submitted = self.submit_time if self.submit_time is not None else 0.0
        return target - (now - submitted)

    # A preempted request resumes by replaying everything it has already
    # emitted as prompt tail: prefill of ``prompt + out_tokens`` samples
    # the next new token from the last emitted token's logits, which at
    # temperature 0 is exactly the token the uncontended run would have
    # decoded.  Fresh requests (empty ``out_tokens``) reduce to the
    # plain prompt, so admission has ONE representation for both.
    @property
    def effective_prompt(self) -> List[int]:
        return list(self.prompt) + list(self.out_tokens)

    @property
    def effective_max_new(self) -> int:
        return self.max_new_tokens - len(self.out_tokens)


@dataclasses.dataclass
class RequestRejected:
    """Typed load-shedding result from ``Engine.submit``: the request was
    not enqueued.  ``kind`` is ``"infeasible"`` (worst-case reservation
    exceeds the pool budget — it can never run at this config) or
    ``"queue_full"`` (the bounded admission queue shed it)."""

    req: Request
    kind: str
    reason: str


@dataclasses.dataclass
class Admission:
    """One scheduler admission decision, consumed by the Engine driver.

    ``rows`` maps pool-group key -> page-table row (trash-padded).
    ``suffix_start`` counts prompt tokens whose prefill is skipped (they
    ride on shared pages); 0 means a plain full prefill.  ``cow`` names a
    copy-on-write the Executor must perform *before* the splice:
    ``(block, src_page, dst_page)`` in the sharing group."""

    slot: int
    req: Request
    rows: Dict[str, np.ndarray]
    suffix_start: int = 0
    cow: Optional[Tuple[int, int, int]] = None
    # pages this admission holds one reference to, per group (consumed by
    # Scheduler.release when the slot finishes)
    lease: Dict[str, List[int]] = dataclasses.field(default_factory=dict)


class PagePool:
    """Refcounted free-list allocator over physical page ids
    ``0..num_pages-1``.

    Page ``num_pages`` is the trash page — never allocated; unreserved
    page-table entries point at it so stray writes are discarded.  A page
    may be referenced by several slot tables at once (prefix sharing) and
    by the radix index; it returns to the free list only when the last
    reference drops."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self.trash = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._rc: List[int] = [0] * num_pages
        self.peak_in_use = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    def refcount(self, page: int) -> int:
        return self._rc[page]

    def alloc(self, n: int) -> Optional[List[int]]:
        """Lease ``n`` fresh pages at refcount 1, or None (backpressure)
        if not enough free."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._rc[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def retain(self, page: int) -> None:
        """Add a reference to an already-leased page (sharing)."""
        assert self._rc[page] > 0, f"retain of free page {page}"
        self._rc[page] += 1

    def release(self, page: int) -> bool:
        """Drop one reference; returns True when the page was freed."""
        assert self._rc[page] > 0, f"release of free page {page}"
        self._rc[page] -= 1
        if self._rc[page] == 0:
            self._free.append(page)
            return True
        return False

    def free(self, pages: List[int]) -> None:
        """Drop one reference on each of ``pages``."""
        for p in pages:
            self.release(p)


class _RadixNode:
    __slots__ = ("tokens", "page", "children", "parent", "last_use")

    def __init__(self, tokens: Tuple[int, ...], page: int,
                 parent: Optional["_RadixNode"]):
        self.tokens = tokens
        self.page = page
        self.children: Dict[Tuple[int, ...], "_RadixNode"] = {}
        self.parent = parent
        self.last_use = 0


class RadixIndex:
    """Page-granular radix tree over cached prompt prefixes.

    Each node is one *full* physical page (``page_size`` prompt tokens)
    keyed by its token content; a root-to-node path spells a cached
    prompt prefix.  The tree holds one pool reference per node, so
    indexed pages outlive the request that prefilled them; eviction
    (LRU, leaves only, refcount-1 only) is how that memory comes back."""

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = _RadixNode((), -1, None)
        self._tick = 0
        self.node_count = 0

    def _touch(self, node: _RadixNode) -> None:
        self._tick += 1
        node.last_use = self._tick

    # ------------------------------------------------------------- match
    def match(self, prompt: List[int]) -> List[Tuple[int, int, int]]:
        """Longest cached prefix of ``prompt``, page-by-page.

        Returns ``[(block, page, matched_tokens)]``: every entry but the
        last matches a full page (``matched_tokens == page_size``); the
        last may be a *partial-page match* — a cached page whose first
        ``matched_tokens < page_size`` tokens agree with the prompt's
        remainder (its KV prefix is still exact, but the slot must
        copy-on-write before writing its own divergent tokens into the
        block)."""
        P = self.page_size
        out: List[Tuple[int, int, int]] = []
        node = self.root
        nblocks = -(-len(prompt) // P) if prompt else 0
        for b in range(nblocks):
            page_toks = tuple(prompt[b * P:(b + 1) * P])
            child = (node.children.get(page_toks)
                     if len(page_toks) == P else None)
            if child is not None:
                self._touch(child)
                out.append((b, child.page, P))
                node = child
                continue
            # partial match: the cached page with the longest common
            # prefix against the prompt's remainder (most recent on ties)
            best, best_n = None, 0
            for key, cand in node.children.items():
                n = 0
                for a, c in zip(page_toks, key):
                    if a != c:
                        break
                    n += 1
                if n > best_n or (n == best_n and n and best is not None
                                  and cand.last_use > best.last_use):
                    best, best_n = cand, n
            if best is not None and best_n > 0:
                self._touch(best)
                out.append((b, best.page, best_n))
            break
        return out

    # ------------------------------------------------------------ insert
    def insert(self, prompt: List[int], row: np.ndarray,
               pool: PagePool) -> int:
        """Index every *full* page of ``prompt`` (partial tail pages are
        still written by their owner, so they are never shared).  New
        nodes take a pool reference; existing nodes just refresh LRU.
        Returns the number of nodes created."""
        P = self.page_size
        node, created = self.root, 0
        for b in range(len(prompt) // P):
            key = tuple(prompt[b * P:(b + 1) * P])
            child = node.children.get(key)
            if child is None:
                child = _RadixNode(key, int(row[b]), node)
                node.children[key] = child
                pool.retain(child.page)
                self.node_count += 1
                created += 1
            self._touch(child)
            node = child
        return created

    # ---------------------------------------------------------- eviction
    def _leaves(self) -> Iterator[_RadixNode]:
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                yield n

    def evict_one(self, pool: PagePool) -> Optional[int]:
        """Drop the least-recently-used *leaf* whose page has no live
        slot reference (refcount 1 — the tree's own).  Shared nodes are
        denied until every borrowing slot releases.  Returns the freed
        page id, or None when nothing is evictable."""
        victim: Optional[_RadixNode] = None
        for leaf in self._leaves():
            if pool.refcount(leaf.page) != 1:
                continue
            if victim is None or leaf.last_use < victim.last_use:
                victim = leaf
        if victim is None:
            return None
        victim.parent.children.pop(victim.tokens)
        self.node_count -= 1
        pool.release(victim.page)
        return victim.page

    def reclaimable(self, pool: PagePool) -> int:
        """Pages the eviction loop could recover right now (refcount-1
        nodes; a chain of them frees leaf-by-leaf as parents become
        leaves)."""
        stack = list(self.root.children.values())
        n = 0
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if pool.refcount(node.page) == 1:
                n += 1
        return n


class Scheduler:
    """Continuous-batching policy over ``slots`` cache slots and
    per-pool-group page budgets, with radix-indexed prefix sharing.

    ``policy`` selects the admission order:

    * ``"fifo"`` (default) — strict arrival order; when the head's
      reservation does not fit, later requests do not jump it.
    * ``"slo"`` — priority then least-TTFT-slack-first: at every chunk
      boundary the queue is ordered by ``(SLO-class priority, ttft
      slack, arrival)`` at the boundary's ``now``, so an interactive
      request running out of slack jumps queued batch work while two
      same-class requests keep FIFO order.  The first candidate that
      does not fit still blocks admission (pages it is waiting on must
      not be nibbled away by lower-priority work); victim selection for
      pressure preemption is the Engine's, also class-aware."""

    def __init__(self, spec: CacheSpec, *, prefix_sharing: bool = True,
                 defer_radix_insert: bool = False, policy: str = "fifo"):
        if policy not in ("fifo", "slo"):
            raise ValueError(
                f"policy must be 'fifo' or 'slo', got {policy!r}")
        self.policy = policy
        self.spec = spec
        self.pools: Dict[str, PagePool] = {
            g.key: PagePool(g.num_pages) for g in spec.groups
        } if spec.has_paged else {}
        # fused chunked prefill defers radix indexing to prefill
        # COMPLETION (Engine calls index_slot): at admission time none of
        # the prompt's pages are written yet, so inserting then would let
        # a same-boundary match attend to garbage
        self.defer_radix_insert = bool(defer_radix_insert)
        self.share_key: Optional[str] = (
            spec.share_group_key
            if prefix_sharing and spec.prefix_sharing_capable else None)
        self.radix: Optional[RadixIndex] = (
            RadixIndex(spec.page_size) if self.share_key else None)
        self.queue: List[Request] = []
        self._leases: Dict[int, Dict[str, List[int]]] = {}
        self._rows: Dict[int, Dict[str, np.ndarray]] = {}
        # fault-injection hook (serve/chaos.ChaosMonkey); a sharing_fault
        # degrades a plan to exclusive pages — the recovery path a real
        # CoW/splice failure would take
        self.chaos = None
        # --- telemetry ---
        self._peak_pages = 0
        self.admissions_total = 0
        self.prefix_hits = 0
        self.prefix_tokens_skipped = 0
        self.shared_page_attaches = 0
        self.cow_copies = 0
        self.radix_evictions = 0
        self.resume_admissions = 0
        self.resume_recovered_tokens = 0
        self.resume_replayed_tokens = 0
        # arrival-order sequence for slack ties + admission-order log
        # [(boundary, rid, priority, slack, chunk)] the property tests
        # replay; ``chunk`` is the engine chunk sequence number current
        # at the boundary (Engine sets ``current_chunk`` before calling
        # admissions), cross-referencing trace events and the per-token
        # ``Request.token_chunks`` telemetry.
        self._seq = 0
        self._boundary = 0
        self.current_chunk = 0
        self.admission_log: List[Tuple[int, int, int, float, int]] = []

    # ------------------------------------------------------------ compat
    @property
    def pool(self) -> PagePool:
        """The widest group's pool (the budget knob / backpressure
        source)."""
        return self.pools[self.spec.widest_group.key]

    # ---------------------------------------------------------- admission
    def validate(self, req: Request) -> None:
        """Raise ``PagePoolExhausted`` when the request's worst-case page
        reservation exceeds a pool's TOTAL budget — it can never run at
        this config, so queueing it would wedge the head of the line."""
        need = self.spec.blocks_needed(len(req.prompt), req.max_new_tokens)
        for key, n in need.items():
            budget = self.pools[key].num_pages
            if n > budget:
                raise PagePoolExhausted(
                    f"request rid={req.rid} needs {n} pages of pool group "
                    f"{key} ({len(req.prompt)} prompt + "
                    f"{req.max_new_tokens} new tokens at page_size="
                    f"{self.spec.page_size}) but that pool only has "
                    f"{budget}; raise --num-pages")

    def submit(self, req: Request) -> None:
        self.validate(req)   # may raise PagePoolExhausted
        req.status = RequestStatus.QUEUED
        self._seq += 1
        req._seq = self._seq
        self.queue.append(req)

    def requeue(self, req: Request) -> None:
        """Return a preempted request to the BACK of the queue: the
        preemption was made to admit the blocked head, so the victim
        resumes once pressure subsides (its ``max_preemptions`` cap keeps
        repeated victimhood bounded)."""
        req.status = RequestStatus.PREEMPTED
        self.queue.append(req)

    def _alloc(self, key: str, n: int) -> Optional[List[int]]:
        """Group alloc with radix eviction pressure: when the sharing
        group runs dry, evict LRU refcount-1 leaves until the request
        fits or nothing more is evictable."""
        pool = self.pools[key]
        pages = pool.alloc(n)
        while pages is None and self.radix is not None \
                and key == self.share_key:
            if self.radix.evict_one(pool) is None:
                return None
            self.radix_evictions += 1
            pages = pool.alloc(n)
        return pages

    def _plan(self, req: Request) -> Optional[Admission]:
        """Build the admission (match, retain, allocate, rows) for the
        queue head, or None on backpressure.  On None every side effect
        is rolled back.

        The sharing attempt runs first; if the *fresh* allocation then
        fails, the plan retries as a miss — the match's own retains can
        pin exactly the refcount-1 radix pages eviction would need, so
        insisting on the match could wedge an admission that plain
        ownership (evicting the matched prefix) can still satisfy.

        An injected sharing fault (chaos) skips the sharing attempt
        outright — the graceful-degradation path a CoW/splice failure
        takes: exclusive pages, full prefill, identical tokens."""
        share = self.radix is not None
        if share and self.chaos is not None and self.chaos.sharing_fault():
            share = False
        adm = self._plan_once(req, use_sharing=share)
        if adm is None and share:
            adm = self._plan_once(req, use_sharing=False)
        return adm

    def _plan_once(self, req: Request,
                   use_sharing: bool) -> Optional[Admission]:
        # a resumed (preempted) request replays its generated-so-far
        # tokens as prompt tail; total pages needed are invariant under
        # preemption (orig prompt + orig max_new), so a request that fit
        # at submit always fits again here
        prompt = req.effective_prompt
        plen = len(prompt)
        need = self.spec.blocks_needed(plen, req.effective_max_new)
        P = self.spec.page_size

        shared: List[Tuple[int, int]] = []      # (block, page) attach
        cow_src: Optional[Tuple[int, int]] = None
        s = 0
        spool = self.pools.get(self.share_key) if self.share_key else None
        if use_sharing and self.radix is not None \
                and need.get(self.share_key):
            matched = self.radix.match(prompt)
            m = sum(nt for _, _, nt in matched)
            # always re-prefill >= 1 token: first-token logits come from
            # the suffix prefill, so a fully-matched prompt keeps its
            # last token (and the shared page holding it goes CoW)
            s = min(m, plen - 1) if m else 0
            if s > 0:
                wb = s // P                      # first block written
                shared = [(b, p) for b, p, _ in matched if b < wb]
                over = [(b, p) for b, p, _ in matched if b >= wb]
                assert len(over) <= 1, over      # only the final page
                if over and s % P:
                    # the slot writes into the matched page mid-block, so
                    # the copy's head tokens are genuinely reused
                    cow_src = over[0]
                # s page-aligned with a matched page at wb: the suffix
                # rewrites that block from offset 0 and the ctx gather
                # stops before it — a copy would never be read, so block
                # wb just gets a fresh page instead
                for _, p in shared:
                    spool.retain(p)
                if cow_src is not None:
                    # pin the source across the copy; dropped after the
                    # Executor has issued the page copy (post-yield)
                    spool.retain(cow_src[1])
            else:
                shared, cow_src = [], None

        allocs: Dict[str, List[int]] = {}
        for key, n in need.items():
            n_fresh = n - (len(shared) if key == self.share_key else 0)
            pages = self._alloc(key, n_fresh)
            if pages is None:                    # rollback, backpressure
                for k2, ps in allocs.items():
                    self.pools[k2].free(ps)
                if spool is not None:
                    for _, p in shared:
                        spool.release(p)
                    if cow_src is not None:
                        spool.release(cow_src[1])
                return None
            allocs[key] = pages

        rows: Dict[str, np.ndarray] = {}
        cow: Optional[Tuple[int, int, int]] = None
        lease: Dict[str, List[int]] = {}
        for key, n in need.items():
            g = self.spec.group_of(key)
            row = np.full((g.ring_blocks,), g.trash_page, np.int32)
            fresh = list(allocs[key])
            if key == self.share_key and s > 0:
                wb = s // P
                for b, p in shared:
                    row[b] = p
                nxt = wb
                if cow_src is not None:
                    dst = fresh[0]
                    row[wb] = dst
                    cow = (wb, cow_src[1], dst)
                    nxt = wb + 1
                for i, p in enumerate(fresh[1 if cow_src else 0:]):
                    row[nxt + i] = p
                lease[key] = [p for _, p in shared] + fresh
            else:
                row[:len(fresh)] = fresh
                lease[key] = fresh
            rows[key] = row

        if self.radix is not None and self.share_key in rows \
                and not self.defer_radix_insert:
            self.radix.insert(prompt, rows[self.share_key],
                              self.pools[self.share_key])

        self.admissions_total += 1
        self._peak_pages = max(self._peak_pages, self.pages_in_use)
        if s > 0:
            self.prefix_hits += 1
            self.prefix_tokens_skipped += s
            self.shared_page_attaches += len(shared)
            if cow is not None:
                self.cow_copies += 1
        if req.preemptions > 0:
            # recovered-prefill telemetry: of the replayed effective
            # prompt, how much rode on radix pages instead of recompute
            self.resume_admissions += 1
            self.resume_recovered_tokens += s
            self.resume_replayed_tokens += plen
        return Admission(slot=-1, req=req, rows=rows, suffix_start=s,
                         cow=cow, lease=lease)

    def admission_order(self, now: float) -> List[Request]:
        """The queue in this boundary's admission order: FIFO under the
        default policy; ``(priority, ttft slack, arrival)`` under
        ``"slo"``.  Slack is evaluated once at ``now`` so the order is a
        consistent snapshot even while yields interleave."""
        if self.policy != "slo":
            return list(self.queue)
        return sorted(self.queue,
                      key=lambda r: (r.priority, r.ttft_slack(now), r._seq))

    def admissions(self, free_slots: List[int],
                   now: float = 0.0) -> Iterator[Admission]:
        """Yield admissions while the next request in admission order
        fits.  When it does not fit, later (smaller) requests do NOT
        jump it — head-of-line backpressure keeps the order fair (FIFO)
        and keeps lower-priority work from nibbling away the pages a
        blocked urgent request is waiting on (SLO)."""
        free_slots = list(free_slots)
        self._boundary += 1
        order = self.admission_order(now)
        while order and free_slots:
            head = order[0]
            adm = self._plan(head)
            if adm is None:
                return                       # wait for an eviction
            order.pop(0)
            self.queue.remove(head)
            self.admission_log.append(
                (self._boundary, head.rid, head.priority,
                 head.ttft_slack(now), self.current_chunk))
            adm.slot = free_slots.pop(0)
            self._leases[adm.slot] = adm.lease
            self._rows[adm.slot] = adm.rows
            adm.req.status = RequestStatus.RUNNING
            try:
                yield adm
            finally:
                # the Engine has now issued the CoW page copy (device ops
                # on the pool are program-ordered), so the source's
                # admission pin can drop — the tree's own reference still
                # protects it from re-lease unless evicted.
                if adm.cow is not None and self.share_key is not None:
                    self.pools[self.share_key].release(adm.cow[1])

    # ----------------------------------------------------------- eviction
    def release(self, slot: int) -> None:
        """Drop a finished slot's page references.  Exclusive pages go
        straight back to the free list; shared/indexed pages survive
        until their refcount drains (other slots, then the radix tree)."""
        self._rows.pop(slot, None)
        for key, pages in self._leases.pop(slot, {}).items():
            self.pools[key].free(pages)

    def preserve(self, slot: int, req: Request,
                 upto: Optional[int] = None) -> int:
        """Index a slot's pages in the radix tree just before a
        preemption releases them, so re-admission recovers the work via
        suffix prefill instead of recomputing it.  Only tokens whose KV
        has actually been written are indexed: every prompt token, plus
        every generated token except the last emitted one (its KV is
        written by the decode step that *consumes* it, which has not run
        from the host's point of view).  ``upto`` overrides that rule
        with an explicit written-token count — fused chunked prefill
        passes its prefill cursor when preempting a slot mid-prefill.
        Returns radix nodes created."""
        if self.radix is None:
            return 0
        rows = self._rows.get(slot)
        if rows is None or self.share_key not in rows:
            return 0
        valid = req.effective_prompt
        if upto is not None:
            valid = valid[:upto]
        elif req.out_tokens:
            valid = valid[:-1]
        return self.radix.insert(valid, rows[self.share_key],
                                 self.pools[self.share_key])

    def index_slot(self, slot: int, req: Request, plen: int) -> int:
        """Deferred radix indexing for fused chunked prefill: called by
        the Engine at the drain that observes a slot's prefill cursor
        reach its prompt end — the instant every prompt page is actually
        written.  Indexes exactly the admission-time effective prompt
        (``plen`` tokens: later decoded tokens ride the same pages but
        are not prefix-stable).  Returns radix nodes created."""
        if self.radix is None:
            return 0
        rows = self._rows.get(slot)
        if rows is None or self.share_key not in rows:
            return 0
        return self.radix.insert(req.effective_prompt[:plen],
                                 rows[self.share_key],
                                 self.pools[self.share_key])

    def can_progress(self, live_slots: int, now: float = 0.0) -> bool:
        """False when the engine is wedged: nothing is running and the
        admission-order head still cannot be admitted even after draining
        every evictable radix page (should be impossible given the
        submit() capacity check — a guard, not a policy)."""
        if not self.queue or live_slots:
            return True
        head = self.admission_order(now)[0]
        need = self.spec.blocks_needed(len(head.effective_prompt),
                                       head.effective_max_new)
        for key, n in need.items():
            avail = self.pools[key].free_pages
            if self.radix is not None and key == self.share_key:
                avail += self.radix.reclaimable(self.pools[key])
            if n > avail:
                return False
        return True

    # ---------------------------------------------------------- telemetry
    @property
    def pages_in_use(self) -> int:
        return sum(p.in_use for p in self.pools.values())

    @property
    def pages_in_use_by_group(self) -> Dict[str, int]:
        return {k: p.in_use for k, p in self.pools.items()}

    @property
    def peak_pages_in_use(self) -> int:
        """True global peak (sampled after every admission — occupancy
        only rises there, so sampling per-pool peaks taken at different
        instants would overstate multi-group archs)."""
        return self._peak_pages

    def prefix_stats(self) -> Dict[str, float]:
        """Prefix-sharing telemetry for BENCH_serve.json / launch logs."""
        return {
            "prefix_sharing": self.radix is not None,
            "admissions": self.admissions_total,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_rate": (self.prefix_hits / self.admissions_total
                                if self.admissions_total else 0.0),
            "prefill_tokens_skipped": self.prefix_tokens_skipped,
            "shared_page_attaches": self.shared_page_attaches,
            "cow_copies": self.cow_copies,
            "radix_evictions": self.radix_evictions,
            "radix_pages": (self.radix.node_count
                            if self.radix is not None else 0),
        }
