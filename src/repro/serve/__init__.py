from repro.serve import engine, reference, sampling
from repro.serve.engine import Engine, Request
from repro.serve.reference import ReferenceEngine

__all__ = ["engine", "reference", "sampling", "Engine", "Request",
           "ReferenceEngine"]
