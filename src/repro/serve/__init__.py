from repro.serve import cache, engine, reference, sampling, scheduler, spec
from repro.serve.cache import CacheSpec
from repro.serve.engine import Engine, Request
from repro.serve.reference import ReferenceEngine
from repro.serve.scheduler import PagePool, PagePoolExhausted, Scheduler
from repro.serve.spec import SpecConfig

__all__ = ["cache", "engine", "reference", "sampling", "scheduler", "spec",
           "CacheSpec", "Engine", "Request", "ReferenceEngine",
           "PagePool", "PagePoolExhausted", "Scheduler", "SpecConfig"]
