from repro.serve import engine

__all__ = ["engine"]
