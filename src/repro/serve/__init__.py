from repro.serve import cache, engine, reference, sampling, scheduler
from repro.serve.cache import CacheSpec
from repro.serve.engine import Engine, Request
from repro.serve.reference import ReferenceEngine
from repro.serve.scheduler import PagePool, PagePoolExhausted, Scheduler

__all__ = ["cache", "engine", "reference", "sampling", "scheduler",
           "CacheSpec", "Engine", "Request", "ReferenceEngine",
           "PagePool", "PagePoolExhausted", "Scheduler"]
