"""Reference (pre-fast-path) serving engine, kept as the measurement
baseline for ``benchmarks/fig14_dispatch_overhead.py`` and as an oracle for
engine-equivalence tests.

This is the anti-pattern the paper's §2.2.3 / Fig. 14 analysis warns about,
preserved deliberately: every decode step round-trips tokens through NumPy
plus per-slot ``int()`` host syncs, every admitted request retraces the
prefill jit for its exact prompt length, and the cache splice is a Python
``tree.map``/``.at[].set`` chain.  ``host_syncs`` counts device->host
transfers so the benchmark can report the overhead it pays.
"""

from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import forward_decode, forward_prefill
from repro.serve.cache import empty_batch_cache
from repro.serve.scheduler import Request

__all__ = ["ReferenceEngine", "Request"]


class ReferenceEngine:
    """Slot-based continuous batching with per-token host synchronization."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True):
        if cfg.cross_attention:
            raise NotImplementedError(
                "Engine serves decoder-only archs; whisper uses "
                "examples/whisper_transcribe.py's direct loop")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self._prefill = jax.jit(
            lambda p, b: forward_prefill(p, cfg, b))
        self._decode = jax.jit(
            lambda p, t, c: forward_decode(p, cfg, t, c))
        self._slot_req: List[Optional[Request]] = [None] * slots
        self.cache = self._empty_cache()
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.steps = 0
        self.host_syncs = 0

    # -------------------------------------------------------------- setup
    def _empty_cache(self):
        return empty_batch_cache(self.cfg, self.slots, self.max_len)

    # ------------------------------------------------------------ serving
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @property
    def prefill_compiles(self) -> int:
        return self._prefill._cache_size()

    @property
    def decode_compiles(self) -> int:
        return self._decode._cache_size()

    def _splice(self, slot: int, one_cache) -> None:
        """Copy a batch-1 prefill cache into slot ``slot``."""
        plen = int(one_cache["len"][0])
        self.host_syncs += 1

        def sp(big, small):
            if big is None or small is None:
                return big
            if small.shape != big[slot:slot + 1].shape:
                size = big.shape[-2]
                if small.shape[-2] > size:
                    # windowed ring buffer: keep the last `size` tokens and
                    # roll so token t sits at slot t % size (the decode
                    # write rule), keeping ring overwrites oldest-first.
                    small = small[..., -size:, :]
                    small = jnp.roll(small, plen % size, axis=-2)
                else:
                    pad = [(0, 0)] * small.ndim
                    pad[-2] = (0, size - small.shape[-2])
                    small = jnp.pad(small, pad)
            return big.at[slot:slot + 1].set(small.astype(big.dtype))

        self.cache["layers"] = jax.tree.map(
            sp, self.cache["layers"], one_cache["layers"],
            is_leaf=lambda x: x is None)
        self.cache["len"] = self.cache["len"].at[slot].set(plen)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self._slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray([req.prompt], jnp.int32)
            batch = {"tokens": prompt}
            if self.cfg.frontend:
                key = "frames" if self.cfg.family == "audio" else "frontend"
                batch[key] = jnp.zeros(
                    (1, self.cfg.frontend_len, self.cfg.d_model), jnp.float32)
            logits, one_cache = self._prefill(self.params, batch)
            tok = self._sample(logits)[0]
            req.out_tokens.append(int(tok))
            self.host_syncs += 1
            self._slot_req[slot] = req
            self._splice(slot, one_cache)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1))
        raise NotImplementedError

    def step(self) -> None:
        self._admit()
        live = [i for i, r in enumerate(self._slot_req) if r is not None]
        if not live:
            return
        tokens = np.zeros((self.slots, 1), np.int32)
        for i in live:
            tokens[i, 0] = self._slot_req[i].out_tokens[-1]
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache)
        nxt = self._sample(logits)
        self.host_syncs += 1
        self.steps += 1
        for i in live:
            req = self._slot_req[i]
            req.out_tokens.append(int(nxt[i]))
            hit_eos = (req.eos_id is not None
                       and req.out_tokens[-1] == req.eos_id)
            if len(req.out_tokens) >= req.max_new_tokens or hit_eos:
                req.done = True
                self.finished.append(req)
                self._slot_req[i] = None
                self.cache["len"] = self.cache["len"].at[i].set(0)

    def run(self, max_steps: int = 1000) -> List[Request]:
        while (self.queue or any(r is not None for r in self._slot_req)) \
                and self.steps < max_steps:
            self.step()
        return self.finished
