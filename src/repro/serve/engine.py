"""Layered, shape-stable, sync-free serving runtime (paper §2.2.3, Fig. 14).

The paper's central measurement is that framework overhead — dispatch,
scheduling, synchronization, and memory management — dominates serving
once the math is tuned.  The runtime is split into three layers so each
overhead has exactly one owner:

* **Scheduler** (``serve/scheduler.Scheduler``) — host-side policy: FIFO
  queue, slot admission, per-group page-budget reservation, refcounted
  prefix sharing over a radix index, LRU prefix eviction.  Continuous
  batching: slots free and re-admit at chunk boundaries without
  recompiling anything.
* **Executor** (``Executor`` below) — the compiled layer: bucketed
  prefill (full and shared-prefix *suffix* variants; overlong prompts
  run as several suffix segments), the batched page-granular admission
  splice (every admission a chunk boundary produces lands in ONE
  dispatch), the copy-on-write page duplication, and the fused decode
  chunk (``sync_interval`` decode steps + on-device sampling + slot
  bookkeeping in ONE ``lax.scan`` executable, zero host<->device syncs
  inside).  With ``Engine(spec=...)`` each chunk step is a speculative
  draft/verify/accept round (``serve/spec``, docs/speculative.md):
  a drafter proposes K tokens per slot, one multi-query dispatch
  verifies K+1 positions, and on-device rejection sampling commits a
  variable number — token-identical at temperature 0.
* **Driver** (``Engine``) — glues them: one batched device->host token
  drain per chunk, finish reporting, admission application.

The decode cache is the refcounted block-paged subsystem from
``serve/cache.py``: attention KV lives in per-ring-width page pools with
independent budgets behind per-slot page tables (sliding-window layers
pay window-sized pools, capacity bounded by the page budgets, not
``slots x max_len``), while mamba2/rwkv6 recurrent state stays dense.
``CacheSpec`` carries logical sharding axes for every buffer, so a
``parallel/sharding.Rules`` table mapping ``BATCH``/``PAGES`` to the data
mesh axis serves multi-device via the existing ``launch/mesh.py``
machinery.

``ReferenceEngine`` in ``repro.serve.reference`` preserves the dense
per-token-sync loop as the measurement baseline and equivalence oracle for
``benchmarks/fig14_dispatch_overhead.py``.
"""

from __future__ import annotations

import contextlib
import json
import math
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import (forward_decode, forward_prefill, forward_verify,
                          model_defs)
from repro.models import module as m
from repro.parallel import sharding as sh
from repro.serve import cache as cache_mod
from repro.serve import metrics as metrics_mod
from repro.serve import sampling
from repro.serve import trace as trace_mod
from repro.serve.cache import CacheSpec, empty_batch_cache  # noqa: F401
from repro.serve.chaos import ChaosMonkey, GarbageDrafter  # noqa: F401
from repro.serve.scheduler import (SLO_CLASSES, Admission,  # noqa: F401
                                   PagePoolExhausted, Request,
                                   RequestRejected, RequestStatus,
                                   Scheduler, SLOClass)
from repro.serve.spec import (ModelDrafter, NGramDrafter, SpecConfig,
                              check_spec_capable, spec_unsupported_reason)


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


# ---------------------------------------------------------------------------
# Latency telemetry: percentile / goodput math over host-stamped requests.
# Pure functions of Request timestamp fields — no device, no engine — so the
# oracle tests in tests/test_latency_stats.py can grade them by hand.
# ---------------------------------------------------------------------------

def percentile(values: List[float], q: float) -> Optional[float]:
    """Nearest-rank percentile (the hand-computable definition): the
    ``ceil(q/100 * n)``-th smallest value.  None on an empty sample —
    an undefined percentile must never masquerade as 0.0."""
    if not values:
        return None
    vals = sorted(values)
    n = len(vals)
    rank = max(1, math.ceil(q * n / 100.0))
    return vals[min(rank, n) - 1]


def request_ttft(req: Request) -> Optional[float]:
    """Submit -> first token, from the ORIGINAL submit time (preemption
    and resume never reset it).  None until a first token is drained."""
    if req.first_token_time is None or req.submit_time is None:
        return None
    return req.first_token_time - req.submit_time


def request_tpot(req: Request) -> Optional[float]:
    """Mean per-token delta after the first token (TPOT).  Tokens
    drained in one chunk share a stamp, so this is the chunk-boundary
    average, not a per-dispatch measurement.  None below 2 tokens."""
    if len(req.token_times) < 2:
        return None
    span = req.token_times[-1] - req.token_times[0]
    return span / (len(req.token_times) - 1)


def request_slo_met(req: Request) -> bool:
    """Did this request deliver its SLO?  Only FINISHED requests can;
    a measured latency over target — or a target with no measurement —
    is a miss, while an absent target (best-effort) always passes."""
    if req.status != RequestStatus.FINISHED:
        return False
    for target, got in ((req.resolved_ttft_target, request_ttft(req)),
                        (req.resolved_tpot_target, request_tpot(req))):
        if target is None:
            continue
        if got is None or got > target:
            return False
    return True


def compute_latency_stats(requests: List[Request]) -> Dict[str, Any]:
    """TTFT/TPOT p50/p99 per SLO class + goodput over ``requests``.

    Percentiles cover every request with the relevant measurement (a
    still-running request's drained first token counts toward TTFT);
    goodput is the fraction of TERMINAL requests that FINISHED meeting
    their class (or per-request) targets — timed-out, cancelled, and
    shed requests are SLO misses by definition, while requests still in
    flight are not graded yet.  Classes with no samples report None
    percentiles and goodput 0.0; so does an empty request list."""
    by_class: Dict[str, List[Request]] = {}
    for req in requests:
        by_class.setdefault(req.slo_class, []).append(req)

    def _summary(reqs: List[Request]) -> Dict[str, Any]:
        ttfts = [t for t in (request_ttft(r) for r in reqs)
                 if t is not None]
        tpots = [t for t in (request_tpot(r) for r in reqs)
                 if t is not None]
        terminal = [r for r in reqs
                    if r.status in RequestStatus.TERMINAL]
        met = sum(request_slo_met(r) for r in terminal)
        return {
            "count": len(reqs),
            "terminal": len(terminal),
            "finished": sum(r.status == RequestStatus.FINISHED
                            for r in reqs),
            "slo_met": met,
            "goodput": met / len(terminal) if terminal else 0.0,
            "ttft_p50": percentile(ttfts, 50),
            "ttft_p99": percentile(ttfts, 99),
            "tpot_p50": percentile(tpots, 50),
            "tpot_p99": percentile(tpots, 99),
        }

    stats: Dict[str, Any] = {
        "classes": {cls: _summary(reqs)
                    for cls, reqs in sorted(by_class.items())},
        "overall": _summary(list(requests)),
    }
    stats["goodput"] = stats["overall"]["goodput"]
    return stats


class Executor:
    """Compiled serving layer: every function here is a jit with stable
    shapes (one executable per prefill bucket — plus one per (suffix
    bucket, ctx-block bucket) pair on the prefix-sharing path; exactly
    one batched admission splice; exactly one decode chunk).  The cache
    and slot state are donated through the chunk and the splice on
    backends that implement donation (not CPU).

    With a speculative config (``spec_cfg`` + ``drafter``) the fused
    chunk becomes ``sync_interval`` draft/verify/accept steps: the
    drafter proposes ``K`` tokens per slot on device, the target model
    verifies all ``K+1`` positions in one multi-query paged dispatch
    (``models/transformer.forward_verify``), and the jitted rejection
    sampler (``serve/sampling.spec_accept``) commits a variable number
    of tokens per slot per step — still zero host syncs and one decode
    executable."""

    def __init__(self, cfg: ModelConfig, spec: CacheSpec, *, top_k: int,
                 sync_interval: int, donate: bool,
                 rules: Optional[sh.Rules] = None,
                 paged_kernel: bool = False,
                 spec_cfg: Optional[SpecConfig] = None,
                 drafter=None, hist_cap: int = 0,
                 prefill_budget: int = 0):
        self.cfg = cfg
        self.spec = spec
        self.top_k = int(top_k)
        self.sync_interval = int(sync_interval)
        self.paged_kernel = bool(paged_kernel)
        self.spec_cfg = spec_cfg
        self.drafter = drafter
        self.hist_cap = int(hist_cap)
        # fused chunked prefill (Sarathi-style mixed chunks): > 0 selects
        # the one-executable mode — no prefill executables exist at all;
        # each chunk step runs every decode row plus up to
        # ``prefill_budget`` prompt tokens per admitting slot, and prompt
        # KV is written through the page tables by the SAME dispatch that
        # decodes (context reads stay pool-direct under ``paged_kernel``)
        self.prefill_budget = int(prefill_budget)
        self.chunk_rows = max(self.prefill_budget,
                              spec_cfg.k + 1 if spec_cfg else 1)
        self._rules = rules
        if self.prefill_budget:
            # satellite of the fused design: the per-bucket prefill,
            # suffix-prefill, and draft-prefill executables are simply
            # never built — steady-state compile count is 1 fused chunk
            # (+ 1 admission bookkeeping dispatch)
            self._prefill_fn = None
            self._suffix_fn = None
            self._draft_prefill_fn = None
            admit_impl = self._fused_admit_impl
        else:
            self._prefill_fn = jax.jit(self._prefill_impl,
                                       static_argnums=(5,))
            # suffix prefill READS the live pools (shared-prefix gather),
            # so its cache argument is never donated
            self._suffix_fn = jax.jit(self._prefill_suffix_impl,
                                      static_argnums=(8,))
            self._draft_prefill_fn = jax.jit(self._draft_prefill_impl,
                                             static_argnums=(3,))
            admit_impl = self._admit_impl
        if donate:
            self._admit_fn = jax.jit(admit_impl, donate_argnums=(0, 1))
            self._splice_fn = jax.jit(self._splice_impl,
                                      donate_argnums=(0,))
            self._chunk_fn = jax.jit(self._chunk_impl, donate_argnums=(2, 3))
            self._free_fn = jax.jit(self._free_impl, donate_argnums=(0,))
            self._copy_fn = jax.jit(self._copy_impl, donate_argnums=(0,),
                                    static_argnums=(3,))
            self._deact_fn = jax.jit(self._deact_impl, donate_argnums=(0,))
        else:
            self._admit_fn = jax.jit(admit_impl)
            self._splice_fn = jax.jit(self._splice_impl)
            self._chunk_fn = jax.jit(self._chunk_impl)
            self._free_fn = jax.jit(self._free_impl)
            self._copy_fn = jax.jit(self._copy_impl, static_argnums=(3,))
            self._deact_fn = jax.jit(self._deact_impl)

    def _ctx(self):
        """Sharding rules are a tracing-time thread-local; enter them for
        every compiled call so retraces see the same table."""
        if self._rules is None:
            return contextlib.nullcontext()
        return sh.axis_rules(self._rules)

    # ------------------------------------------------------ impls (traced)
    @staticmethod
    def _pad_kv(entry, pad_to: int):
        """Pad one {k, v} KV entry's seq axis (2) to ``pad_to``."""
        e = dict(entry)
        for k in ("k", "v"):
            pad = pad_to - e[k].shape[2]
            if pad > 0:
                cfgp = [(0, 0)] * e[k].ndim
                cfgp[2] = (0, pad)
                e[k] = jnp.pad(e[k], cfgp)
        return e

    def _pad_prefill_cache(self, cache, pad_to: int):
        """Pad every attention-KV seq axis to ``pad_to`` inside the
        prefill executable, so admission sees ONE shape whatever bucket
        produced the cache — that is what lets a chunk boundary's
        admissions share a single batched splice executable."""
        layers = []
        for ls, entry in zip(self.spec.layers, cache["layers"]):
            if ls is not None and ls.kind == cache_mod.PAGED_KV \
                    and entry is not None and "k" in entry:
                layers.append(self._pad_kv(entry, pad_to))
            else:
                layers.append(entry)
        return dict(cache, layers=layers)

    def _prefill_impl(self, params, tokens, length, key, temp, pad_to):
        """Padded prefill + on-device first-token sampling.

        tokens [1, bucket], length [1].  One compile per bucket shape;
        the returned cache is padded to ``pad_to`` (the largest bucket)
        so every bucket feeds the same admission executable."""
        batch = {"tokens": tokens}
        if self.cfg.frontend:
            k = "frames" if self.cfg.family == "audio" else "frontend"
            batch[k] = jnp.zeros(
                (1, self.cfg.frontend_len, self.cfg.d_model), jnp.float32)
        logits, cache = forward_prefill(params, self.cfg, batch,
                                        length=length)
        tok = sampling.sample(logits, key, temperature=temp,
                              top_k=self.top_k)
        return tok, self._pad_prefill_cache(cache, pad_to)

    def _prefill_suffix_impl(self, params, tokens, length, off, ctx_row,
                             layer_pools, key, temp, pad_to):
        """Shared-prefix suffix prefill: tokens [1, bucket] hold only the
        un-matched prompt tail at absolute positions ``off + i``; the
        matched prefix is attended through the pool pages named in
        ``ctx_row`` (the new slot's own table row — shared pages plus any
        copy-on-write duplicate) without being recomputed.  One compile
        per (suffix bucket, ctx-block bucket) shape pair.  Also the
        chunked-prefill workhorse: a prompt longer than the largest
        bucket runs as several suffix calls, each attending to the pages
        the previous segments spliced."""
        ctx = {"off": off, "row": ctx_row, "layers": layer_pools}
        logits, cache = forward_prefill(params, self.cfg,
                                        {"tokens": tokens},
                                        length=length, ctx=ctx)
        tok = sampling.sample(logits, key, temperature=temp,
                              top_k=self.top_k)
        return tok, self._pad_prefill_cache(cache, pad_to)

    def _draft_prefill_impl(self, draft_params, tokens, length, pad_to):
        """Draft-model prefill for the model drafter: same bucketed
        tokens, dense KV out (padded to ``pad_to``), logits discarded —
        the draft's first proposal comes from its decode step."""
        _, cache = forward_prefill(draft_params, self.drafter.cfg,
                                   {"tokens": tokens}, length=length)
        return [self._pad_kv(entry, pad_to) for entry in cache["layers"]]

    def _splice_draft(self, draft_layers, one_layers, slot, enabled):
        """Write a batch-1 draft prefill into row ``slot`` of the dense
        draft cache (positions 0..n-1; the pad tail beyond the prompt is
        overwritten by later draft decode writes)."""
        out = []
        for big, small in zip(draft_layers, one_layers):
            e = {}
            for k in ("k", "v"):
                b_, s_ = big[k], small[k]
                n = min(s_.shape[2], b_.shape[2])
                s_ = s_[:, :, :n]
                cur = jax.lax.dynamic_slice(
                    b_, (slot, 0, 0, 0), (1,) + b_.shape[1:])[:, :, :n]
                s_ = jnp.where(enabled, s_.astype(b_.dtype), cur)
                e[k] = jax.lax.dynamic_update_slice(b_, s_,
                                                    (slot, 0, 0, 0))
            out.append(e)
        return out

    def _admit_impl(self, cache, state, one_caches, draft_caches, slots_v,
                    starts, plens, rows, first_toks, out_lens, max_news,
                    eoss, temps, valids, hist_toks):
        """Batched jitted admission: ONE splice dispatch applies every
        admission a chunk boundary produced.  All per-admission operands
        are padded to ``spec.slots`` entries (``valids`` masks the
        padding — a disabled entry's pool writes land on trash pages and
        its table/len/state keep their prior values), and every prefill
        cache arrives padded to the largest bucket, so the executable
        count stays at exactly 1 however many slots fill at once.

        ``out_lens`` is the slot's initial generated-token count: 1 for a
        fresh request, ``len(out_tokens) + 1`` for a preempted request
        being resumed (its replayed tokens already count against
        ``max_new``, so the budget check needs no special casing)."""
        st = dict(state)
        for i in range(self.spec.slots):
            sl = slots_v[i]
            en = valids[i]
            cache = cache_mod.admit_cache(
                self.spec, cache, one_caches[i], sl, starts[i], plens[i],
                {k: rows[k][i] for k in rows}, enabled=en)
            if draft_caches is not None:
                cache["draft"] = self._splice_draft(
                    cache["draft"], draft_caches[i], sl, en)

            def setv(vec, new):
                return vec.at[sl].set(jnp.where(en, new, vec[sl]))

            st["tokens"] = setv(st["tokens"], first_toks[i][0])
            st["out_len"] = setv(st["out_len"], out_lens[i])
            st["max_new"] = setv(st["max_new"], max_news[i])
            st["eos"] = setv(st["eos"], eoss[i])
            st["temp"] = setv(st["temp"], temps[i])
            # active only while budget remains past the prefill-sampled
            # token: a resume whose pending token is its last (and a
            # fresh max_new=1 request) must not decode a step beyond it
            st["active"] = setv(st["active"], out_lens[i] < max_news[i])
            if hist_toks is not None:
                cap = self.hist_cap
                row = jnp.where(jnp.arange(cap) < plens[i], hist_toks[i], 0)
                row = jnp.concatenate(
                    [row, jnp.zeros((1,), jnp.int32)])
                row = row.at[jnp.minimum(plens[i], cap)].set(
                    first_toks[i][0])
                cur = jax.lax.dynamic_slice(st["hist"], (sl, 0),
                                            (1, cap + 1))
                st["hist"] = jax.lax.dynamic_update_slice(
                    st["hist"], jnp.where(en, row[None], cur), (sl, 0))
                st["hist_len"] = setv(st["hist_len"], plens[i] + 1)
        return cache, st

    def _fused_admit_impl(self, cache, state, slots_v, starts, plens, rows,
                          prompts, out_lens, max_news, eoss, temps, valids):
        """Batched admission for fused chunked prefill: pure bookkeeping.
        No prefill KV exists yet — the fused chunk step itself writes
        prompt KV through the page tables — so admission only installs
        the slot's table rows, rewinds ``len`` to the prefill cursor
        (``starts``: 0 fresh, the shared-prefix / resume boundary
        otherwise), stages the prompt row + ``plen`` target, and arms the
        slot.  ``out_lens`` is ``len(out_tokens)`` with NO +1: the first
        sampled token flows through the chunk's emitted history like any
        other, instead of being staged host-side at admission."""
        st = dict(state)
        for i in range(self.spec.slots):
            sl = slots_v[i]
            en = valids[i]
            cache = cache_mod.install_slot_rows(
                self.spec, cache, sl, starts[i],
                {k: rows[k][i] for k in rows}, enabled=en)

            def setv(vec, new):
                return vec.at[sl].set(jnp.where(en, new, vec[sl]))

            st["tokens"] = setv(st["tokens"], 0)
            st["out_len"] = setv(st["out_len"], out_lens[i])
            st["max_new"] = setv(st["max_new"], max_news[i])
            st["eos"] = setv(st["eos"], eoss[i])
            st["temp"] = setv(st["temp"], temps[i])
            st["active"] = setv(st["active"], out_lens[i] < max_news[i])
            st["plen"] = setv(st["plen"], plens[i])
            cur = jax.lax.dynamic_slice(
                st["prompt"], (sl, 0), (1, st["prompt"].shape[1]))
            st["prompt"] = jax.lax.dynamic_update_slice(
                st["prompt"], jnp.where(en, prompts[i][None], cur), (sl, 0))
            if "hist" in st:
                cap = self.hist_cap
                prom = prompts[i]
                if prom.shape[0] < cap + 1:
                    prom = jnp.concatenate(
                        [prom, jnp.zeros((cap + 1 - prom.shape[0],),
                                         jnp.int32)])
                row = jnp.where(jnp.arange(cap + 1) < plens[i],
                                prom[:cap + 1], 0)
                curh = jax.lax.dynamic_slice(st["hist"], (sl, 0),
                                             (1, cap + 1))
                st["hist"] = jax.lax.dynamic_update_slice(
                    st["hist"], jnp.where(en, row[None], curh), (sl, 0))
                st["hist_len"] = setv(st["hist_len"], plens[i])
        return cache, st

    def _splice_impl(self, cache, one_cache, slot, start, plen, rows):
        """Cache-only splice for intermediate chunked-prefill segments:
        writes segment KV through the slot's pages at token offset
        ``start`` without touching slot bookkeeping (the final segment
        goes through the batched admission)."""
        return cache_mod.admit_cache(self.spec, cache, one_cache, slot,
                                     start, plen, rows)

    def _chunk_impl(self, params, draft_params, cache, state):
        """``sync_interval`` fused decode steps: forward (with paged KV
        lookup) + sample + slot bookkeeping, all on device.  Returns the
        [T, slots] token history (-1 where a slot was idle) — the only
        thing the host ever reads.  With speculation each of the ``T``
        steps is a draft/verify/accept round committing up to ``K+1``
        tokens per slot, and the history is [T*(K+1), slots]."""
        if self.prefill_budget:
            return self._fused_chunk_impl(params, draft_params, cache,
                                          state)
        if self.spec_cfg is None:
            def body(carry, _):
                cache, state = carry
                # active as write mask: a finished slot's dead-tail steps
                # must not wrap KV writes into pages now shared with other
                # slots or the radix prefix index
                logits, cache = forward_decode(
                    params, self.cfg, state["tokens"][:, None], cache,
                    write_mask=state["active"],
                    paged_kernel=self.paged_kernel)
                cache.pop("enc_kv", None)   # decoder-only: keep structure
                key, sub = jax.random.split(state["key"])
                nxt = sampling.sample(logits, sub,
                                      temperature=state["temp"],
                                      top_k=self.top_k)
                state, emitted = sampling.decode_update(state, nxt, key)
                return (cache, state), emitted

            (cache, state), toks = jax.lax.scan(
                body, (cache, state), None, length=self.sync_interval)
            return toks, cache, state

        def body(carry, _):
            cache, state = carry
            kd, ka, knext = jax.random.split(state["key"], 3)
            drafts, qprobs, cache = self.drafter.propose(
                draft_params, cache, state, kd, self.top_k)
            toks = jnp.concatenate([state["tokens"][:, None], drafts],
                                   axis=1)
            logits, cache = forward_verify(
                params, self.cfg, toks, cache,
                write_mask=state["active"],
                paged_kernel=self.paged_kernel,
                spec_slack=self.spec_cfg.k)
            cache.pop("enc_kv", None)
            cand, n_acc = sampling.spec_accept(
                logits, drafts, qprobs, state["temp"], self.top_k, ka)
            state, emitted, n_emit = sampling.spec_update(
                state, cand, n_acc, knext)
            cache = dict(cache, len=cache["len"] + n_emit)
            return (cache, state), emitted

        (cache, state), toks = jax.lax.scan(
            body, (cache, state), None, length=self.sync_interval)
        # [T, slots, K+1] -> time-major [T*(K+1), slots] for the drain
        toks = jnp.swapaxes(toks, 1, 2).reshape(-1, toks.shape[1])
        return toks, cache, state

    def _fused_chunk_impl(self, params, draft_params, cache, state):
        """Fused mixed prefill+decode chunk (Sarathi-style chunked
        prefill): ONE executable serves the whole slot population.  Each
        of the ``sync_interval`` micro-steps builds a right-aligned
        [slots, S] token matrix (S = ``chunk_rows``): a mid-prefill slot
        contributes its next ``n = min(plen - len, S)`` prompt tokens, a
        decoding slot its pending token (+ drafts under speculation), and
        every row block sits flush against column S-1 so leading pad rows
        have write masks off (KV lands on trash) and sampling always
        reads the static last row.  Per-slot ``cache_len = len + n``
        keeps the causal/ring masks exact per row — no kernel changes,
        and the prompt's context reads stream pool-direct through the
        paged attention path like any decode.

        A slot whose prefill completes this step (``rem <= S``) samples
        its first token from row S-1 — exactly the logits the legacy
        prefill executable sampled — and starts decoding next micro-step;
        until then nothing is committed for it (and under speculation
        drafting stays disabled for it: its draft rows are write-masked
        and its accept verdicts discarded)."""
        S = self.chunk_rows
        k1 = self.spec_cfg.k + 1 if self.spec_cfg is not None else 1
        col = jnp.arange(S)[None, :]

        def split_rows(cache, state):
            len_ = cache["len"]
            active = state["active"]
            rem = state["plen"] - len_
            prefilling = active & (rem > 0)
            # per-slot dynamic prefill budget: the SLO policy shrinks a
            # lower-priority slot's prompt slice at chunk boundaries
            # (host->device value update, never a retrace — S stays the
            # compiled static width and pbudget is clamped into [1, S])
            budget = jnp.clip(state["pbudget"], 1, S) \
                if "pbudget" in state else S
            n = jnp.where(prefilling, jnp.minimum(rem, budget), k1)
            completing = prefilling & (rem <= S)
            gidx = len_[:, None] + col - (S - n)[:, None]
            pcap = state["prompt"].shape[1]
            ptoks = jnp.take_along_axis(
                state["prompt"], jnp.clip(gidx, 0, pcap - 1), axis=1)
            wm = active[:, None] & (col >= (S - n)[:, None])
            return len_, active, prefilling, completing, n, ptoks, wm

        if self.spec_cfg is None:
            def body(carry, _):
                cache, state = carry
                (len_, active, prefilling, completing, n, ptoks,
                 wm) = split_rows(cache, state)
                toks = jnp.where(
                    prefilling[:, None], ptoks,
                    jnp.where(col == S - 1, state["tokens"][:, None], 0))
                logits, cache = forward_verify(
                    params, self.cfg, toks, cache, write_mask=wm,
                    paged_kernel=self.paged_kernel,
                    spec_slack=self.spec.spec_tokens, n_rows=n)
                cache.pop("enc_kv", None)
                key, sub = jax.random.split(state["key"])
                nxt = sampling.sample(logits[:, -1], sub,
                                      temperature=state["temp"],
                                      top_k=self.top_k)
                # commit the sample for decoding slots and for slots whose
                # prefill just completed (their first token); mid-prefill
                # slots commit nothing
                commit = active & (~prefilling | completing)
                state, emitted = sampling.decode_update(state, nxt, key,
                                                        commit=commit)
                cache = dict(cache, len=len_ + jnp.where(
                    prefilling, n, active.astype(jnp.int32)))
                return (cache, state), emitted

            (cache, state), toks = jax.lax.scan(
                body, (cache, state), None, length=self.sync_interval)
            return toks, cache, state

        def body(carry, _):
            cache, state = carry
            (len_, active, prefilling, completing, n, ptoks,
             wm) = split_rows(cache, state)
            decoding = active & ~prefilling
            kd, ka, kf, kmid, knext = jax.random.split(state["key"], 5)
            drafts, qprobs, cache = self.drafter.propose(
                draft_params, cache, state, kd, self.top_k)
            dtoks = jnp.concatenate([state["tokens"][:, None], drafts],
                                    axis=1)
            if S > k1:
                dtoks = jnp.concatenate(
                    [jnp.zeros((dtoks.shape[0], S - k1), jnp.int32),
                     dtoks], axis=1)
            toks = jnp.where(prefilling[:, None], ptoks, dtoks)
            logits, cache = forward_verify(
                params, self.cfg, toks, cache, write_mask=wm,
                paged_kernel=self.paged_kernel,
                spec_slack=self.spec.spec_tokens, n_rows=n)
            cache.pop("enc_kv", None)
            cand, n_acc = sampling.spec_accept(
                logits[:, S - k1:], drafts, qprobs, state["temp"],
                self.top_k, ka)
            first = sampling.sample(logits[:, -1], kf,
                                    temperature=state["temp"],
                                    top_k=self.top_k)
            # prefill-completing slots commit exactly their first token
            # (drafting for them begins next micro-step); decoding slots
            # commit their accepted draft run as usual
            state, _ = sampling.decode_update(state, first, kmid,
                                              commit=completing)
            state, emitted, n_emit = sampling.spec_update(
                state, cand, n_acc, knext, commit=decoding)
            idx1 = jnp.arange(k1)[None, :]
            emitted = jnp.where(completing[:, None] & (idx1 == 0),
                                first[:, None], emitted)
            cache = dict(cache, len=len_ + jnp.where(
                prefilling, n, n_emit))
            return (cache, state), emitted

        (cache, state), toks = jax.lax.scan(
            body, (cache, state), None, length=self.sync_interval)
        toks = jnp.swapaxes(toks, 1, 2).reshape(-1, toks.shape[1])
        return toks, cache, state

    def _free_impl(self, cache, slot):
        return cache_mod.free_slot_cache(self.spec, cache, slot)

    def _deact_impl(self, state, slot):
        """Clear a slot's active flag (preemption / reaping at a chunk
        boundary): its dead-tail decode steps stop sampling and — with
        the table rows re-trashed by ``free_slot`` — cannot write KV
        anywhere that matters."""
        return dict(state, active=state["active"].at[slot].set(False))

    def _copy_impl(self, cache, src, dst, group_key):
        """Copy-on-write: duplicate page ``src`` into ``dst`` across the
        sharing group's layer pools before the owner slot writes."""
        return cache_mod.copy_shared_page(self.spec, cache, group_key,
                                          src, dst)

    # -------------------------------------------------------- public calls
    def prefill(self, params, tokens, length, key, temp, pad_to):
        with self._ctx():
            return self._prefill_fn(params, tokens, length, key, temp,
                                    pad_to)

    def prefill_suffix(self, params, tokens, length, off, ctx_row,
                       layer_pools, key, temp, pad_to):
        with self._ctx():
            return self._suffix_fn(params, tokens, length, off, ctx_row,
                                   layer_pools, key, temp, pad_to)

    def draft_prefill(self, draft_params, tokens, length, pad_to):
        with self._ctx():
            return self._draft_prefill_fn(draft_params, tokens, length,
                                          pad_to)

    def admit(self, cache, state, *args):
        with self._ctx():
            return self._admit_fn(cache, state, *args)

    def splice(self, cache, one_cache, slot, start, plen, rows):
        with self._ctx():
            return self._splice_fn(cache, one_cache, slot, start, plen,
                                   rows)

    def copy_page(self, cache, src, dst, group_key):
        with self._ctx():
            return self._copy_fn(cache, src, dst, group_key)

    def chunk(self, params, draft_params, cache, state):
        with self._ctx():
            return self._chunk_fn(params, draft_params, cache, state)

    def free_slot(self, cache, slot):
        with self._ctx():
            return self._free_fn(cache, slot)

    def deactivate(self, state, slot):
        with self._ctx():
            return self._deact_fn(state, slot)

    # ----------------------------------------------------------- telemetry
    @property
    def prefill_compiles(self) -> int:
        if self._prefill_fn is None:     # fused mode: no prefill exec
            return 0
        return self._prefill_fn._cache_size()

    @property
    def suffix_prefill_compiles(self) -> int:
        if self._suffix_fn is None:      # fused mode: no prefill exec
            return 0
        return self._suffix_fn._cache_size()

    @property
    def admit_compiles(self) -> int:
        return self._admit_fn._cache_size()

    @property
    def decode_compiles(self) -> int:
        return self._chunk_fn._cache_size()


class Engine:
    """Host driver: composes Scheduler (policy) + Executor (compiled) over
    the refcounted paged cache.  ``max_len`` is the *logical* per-slot
    token cap (the widest page-table width x page_size); physical
    capacity is per pool group — ``num_pages x page_size`` tokens for the
    widest (full-attention) group (default: the old dense ``slots x
    max_len`` token capacity), ``slots x window`` tokens for each
    sliding-window group (sized to the window, no flat-pool byte
    overhead).  ``prefix_sharing`` (on by default, auto-disabled for
    archs whose prefix state cannot live in pages) admits requests with a
    cached prompt prefix onto shared pages and prefillls only the
    suffix.  ``paged_kernel`` selects how decode attention reads the
    pools: ``True`` = pool-direct (``kernels/paged_attention``: Pallas
    page streaming on TPU, pool-wide masked attention elsewhere — the
    gather buffer never exists), ``False`` = gather-then-attend, and
    ``"auto"`` = kernel on a probe-passing TPU toolchain, gather
    elsewhere.  ``kv_dtype`` selects the pool storage precision
    (``"fp32"`` | ``"int8"`` | ``"fp8_e4m3"``; ``"auto"`` == fp32): 8-bit
    pools carry per-page, per-kv-head scales and dequantize inside the
    attention path — ~4x page-pool capacity at a bounded logit error,
    with an fp32 fallback when the capability gate fails.  ``spec``
    turns on speculative decoding (``"ngram"``, a
    draft-config name, or a ``serve/spec.SpecConfig``): drafted
    multi-token steps verified in the fused chunk, output
    token-identical at temperature 0 — attention-only archs only
    (``serve/spec/config.py`` documents the gate)."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 sync_interval: int = 8, min_bucket: int = 8,
                 buckets: Optional[List[int]] = None,
                 page_size: int = 8, num_pages: Optional[int] = None,
                 prefix_sharing: bool = True,
                 paged_kernel: Any = "auto",
                 spec: Any = None,
                 rules: Optional[sh.Rules] = None,
                 donate: Any = "auto",
                 preemption: bool = True,
                 queue_limit: Optional[int] = None,
                 shed_policy: str = "reject",
                 policy: str = "fifo",
                 clock: Optional[Callable[[], float]] = None,
                 stall_patience: int = 0,
                 chaos: Optional[ChaosMonkey] = None,
                 trace: Any = None,
                 chunked_prefill: Any = "auto",
                 prefill_budget: int = 32,
                 kv_dtype: str = "auto"):
        if cfg.cross_attention:
            raise NotImplementedError(
                "Engine serves decoder-only archs; whisper uses "
                "examples/whisper_transcribe.py's direct loop")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        if temperature > 0.0:
            self.default_temp = float(temperature)
        else:
            self.default_temp = 0.0 if greedy else 1.0
        self.top_k = int(top_k)
        self.sync_interval = int(sync_interval)
        if buckets is None:
            b, buckets = min_bucket, []
            while b < _next_pow2(max_len):
                buckets.append(b)
                b *= 2
            buckets.append(b)
        self.buckets = sorted(set(int(b) for b in buckets))
        if donate == "auto":
            donate = jax.default_backend() != "cpu"
        self._donate = bool(donate)
        self._rules = rules

        # ---- speculative decoding config + drafter resolution
        if spec in (None, False, "off"):
            spec_cfg = None
        elif isinstance(spec, SpecConfig):
            spec_cfg = spec
        elif isinstance(spec, str):
            spec_cfg = SpecConfig(draft=spec)
        else:
            raise TypeError(f"spec must be None, 'ngram', a draft config "
                            f"name, or a SpecConfig; got {spec!r}")
        self.spec_config = spec_cfg
        self.drafter = None
        self.draft_params = None
        if spec_cfg is not None:
            check_spec_capable(cfg)
            if spec_cfg.k < 1:
                raise ValueError(f"spec.k must be >= 1, got {spec_cfg.k}")
            if spec_cfg.draft == "ngram":
                self.drafter = NGramDrafter(spec_cfg.k, spec_cfg.ngram)
            else:
                dcfg = spec_cfg.draft_cfg
                if dcfg is None:
                    from repro.configs import get_config, reduced
                    dcfg = reduced(get_config(spec_cfg.draft))
                self.drafter = ModelDrafter(
                    dcfg, spec_cfg.k,
                    cache_tokens=max_len + spec_cfg.k + 1)
                self.draft_params = spec_cfg.draft_params
                if self.draft_params is None:
                    self.draft_params = m.init_params(
                        model_defs(dcfg), jax.random.PRNGKey(seed + 17),
                        jnp.float32)
        if chaos is not None and chaos.garbage_drafter \
                and self.drafter is not None:
            # fault isolation: rejection sampling keeps output
            # token-identical however bad the drafts are
            self.drafter = GarbageDrafter(self.drafter)
        # the token-history buffer is the n-gram drafter's lookup corpus;
        # a model drafter never reads it, so it pays neither the buffer
        # nor the per-step scatter
        self._hist_cap = (max_len + spec_cfg.k + 2
                          if spec_cfg is not None
                          and spec_cfg.draft == "ngram" else 0)

        # ---- fused chunked prefill (Sarathi-style mixed chunks)
        # "auto": on whenever the fused chunk can serve the arch — paged
        # KV throughout, attention-only mixer stack, and no model drafter
        # (its separate draft cache still needs a draft-prefill pass).
        # The fused mode deletes every prefill executable: prompts stream
        # through the SAME chunk step that decodes, prefill_budget tokens
        # per slot per micro-step.
        fused_capable = (
            not cfg.cross_attention
            and spec_unsupported_reason(cfg) is None
            and not (spec_cfg is not None and spec_cfg.draft != "ngram"))
        if chunked_prefill == "auto":
            chunked_prefill = fused_capable
        elif chunked_prefill and not fused_capable:
            raise ValueError(
                f"{cfg.name}: chunked_prefill needs paged KV for every "
                "mixer (attention-only stack) and no model drafter; "
                f"reason: {spec_unsupported_reason(cfg) or 'model drafter'}")
        self.chunked_prefill = bool(chunked_prefill)
        if prefill_budget < 1:
            raise ValueError(
                f"prefill_budget must be >= 1, got {prefill_budget}")
        self.prefill_budget = int(prefill_budget) if self.chunked_prefill \
            else 0
        # rows per fused micro-step: prefill slices and draft/verify rows
        # share the one [slots, S] token matrix
        chunk_rows = max(self.prefill_budget,
                         spec_cfg.k + 1 if spec_cfg else 1)
        # windowed rings need ring >= window + S - 1 so a full-width
        # prefill slice can write-wrap legitimately; spec_tokens is
        # exactly that slack (max_len-capped inside CacheSpec)
        cache_slack = (max(spec_cfg.k if spec_cfg else 0, chunk_rows - 1)
                       if self.chunked_prefill
                       else (spec_cfg.k if spec_cfg else 0))
        # ---- pool precision (quantized KV page pool).  "auto" == fp32.
        # An explicitly requested 8-bit dtype falls back to fp32 pools
        # when the capability gate fails (jax build without fp8, or the
        # arch has no paged layers to quantize) instead of erroring —
        # precision is a perf knob, not a correctness contract.
        requested = "fp32" if kv_dtype == "auto" else kv_dtype
        if requested not in cache_mod.KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be 'auto' or one of {cache_mod.KV_DTYPES}, "
                f"got {kv_dtype!r}")
        self.requested_kv_dtype = requested
        if not cache_mod.kv_dtype_supported(requested):
            requested = "fp32"
        self.kv_dtype = requested
        self.spec = CacheSpec.from_config(
            cfg, slots, max_len, page_size=page_size, num_pages=num_pages,
            spec_tokens=cache_slack, kv_dtype=self.kv_dtype)
        if paged_kernel == "auto":
            # pool-direct attention is the TPU hot path (compiled Pallas
            # kernel, gated on the runtime toolchain probe).  Off-TPU the
            # default stays gather-then-attend: at smoke scale XLA's
            # fused gather+softmax wins, and the pool-wide lowering only
            # pays off once the pool is oversubscribed — opt in with
            # paged_kernel=True (fig14 measures both).
            from repro.kernels import paged_attention as paged_ops
            paged_kernel = (self.spec.has_paged
                            and jax.default_backend() == "tpu"
                            and paged_ops.supported(self.spec.kv_dtype))
        self.paged_kernel = bool(paged_kernel) and self.spec.has_paged
        if spec_cfg is not None and not self.spec.has_paged:
            raise ValueError(
                f"{cfg.name}: speculative decoding needs the paged decode "
                "cache (rollback by position)")
        if self.chunked_prefill and not self.spec.has_paged:
            raise ValueError(
                f"{cfg.name}: chunked_prefill needs the paged decode cache")
        # admission policy: "fifo" (arrival order) or "slo" (priority +
        # least-TTFT-slack-first; class-aware preemption victims/shed)
        self.policy = policy
        self.scheduler = Scheduler(self.spec, prefix_sharing=prefix_sharing,
                                   defer_radix_insert=self.chunked_prefill,
                                   policy=policy)
        self.executor = Executor(cfg, self.spec, top_k=self.top_k,
                                 sync_interval=self.sync_interval,
                                 donate=self._donate, rules=rules,
                                 paged_kernel=self.paged_kernel,
                                 spec_cfg=spec_cfg, drafter=self.drafter,
                                 hist_cap=self._hist_cap,
                                 prefill_budget=self.prefill_budget)

        self._slot_req: List[Optional[Request]] = [None] * slots
        self._slot_first_tok: List[Optional[jax.Array]] = [None] * slots
        # True while a slot's prefill-sampled token sits on device but
        # has not been drained into req.out_tokens yet (resumed requests
        # arrive with a non-empty out_tokens, so "is out_tokens empty"
        # cannot stand in for this flag)
        self._slot_first_pending: List[bool] = [False] * slots
        self._slot_stale: List[int] = [0] * slots
        # fused chunked prefill: tokens of the slot's effective prompt
        # already covered by past chunks (the host-visible prefill
        # cursor, trailing the device's cache["len"] by one drain) and
        # the admission-time prompt length it is counting toward
        self._slot_seen_len: List[int] = [0] * slots
        self._slot_plen: List[int] = [0] * slots
        self.cache = self._empty_cache()
        self.state = sampling.make_slot_state(
            slots, seed, hist_cap=self._hist_cap,
            spec=spec_cfg is not None,
            prompt_cap=max_len if self.chunked_prefill else 0,
            prefill_budget=(self.executor.chunk_rows
                            if self.chunked_prefill else 0))
        # host mirror of state["pbudget"]: the SLO boundary policy only
        # dispatches a device update when the desired vector changes
        self._budget_vec: Optional[List[int]] = (
            [self.executor.chunk_rows] * slots
            if self.chunked_prefill else None)
        self.budget_throttles = 0
        self._key = jax.random.PRNGKey(seed + 1)
        self.finished: List[Request] = []
        self.rejected: List[Request] = []
        self.steps = 0
        self.host_syncs = 0
        # high-water mark of concurrently occupied slots (the capacity
        # metric the quantized-pool bench reports per workload)
        self.peak_live_slots = 0

        # ---- robustness: preemption / deadlines / admission control
        self.preemption = bool(preemption)
        self.queue_limit = queue_limit
        if shed_policy not in ("reject", "block", "evict-lru-prefix",
                               "shed-lowest-class"):
            raise ValueError(f"shed_policy must be 'reject', 'block', "
                             f"'evict-lru-prefix' or 'shed-lowest-class', "
                             f"got {shed_policy!r}")
        self.shed_policy = shed_policy
        self._clock = clock if clock is not None else time.monotonic
        # ---- observability (serve/trace.py, serve/metrics.py): a
        # bounded lifecycle tracer recorded at chunk boundaries only.
        # None/False disables it entirely (the default: zero overhead);
        # True builds a default-capacity Tracer, an int sets the ring
        # capacity, and a Tracer instance is used as-is.
        if trace in (None, False):
            self.tracer = None
        elif isinstance(trace, trace_mod.Tracer):
            self.tracer = trace
        elif trace is True:
            self.tracer = trace_mod.Tracer()
        elif isinstance(trace, int):
            self.tracer = trace_mod.Tracer(capacity=trace)
        else:
            raise TypeError(f"trace must be None/bool/int/Tracer, "
                            f"got {trace!r}")
        # chunk sequence number: incremented once per drain, stamped on
        # every drained token (Request.token_chunks), every admission
        # (admission_log 5th element), and every trace event at the
        # boundary — the cross-reference key between all three.
        self.chunks = 0
        self.chaos = chaos
        self.scheduler.chaos = chaos
        if chaos is not None and self.tracer is not None:
            chaos.on_event = self._chaos_event
        if chaos is not None and chaos.p_stall > 0 and stall_patience <= 0:
            stall_patience = 4   # a stall must end in watchdog recovery
        self.stall_patience = int(stall_patience)
        self.fault_counters: Dict[str, int] = {
            "preemptions": 0, "pressure_preemptions": 0,
            "chaos_preemptions": 0, "watchdog_preemptions": 0,
            "resumes": 0, "timed_out": 0, "cancelled": 0,
            "rejected": 0, "rejected_infeasible": 0,
            "rejected_queue_full": 0, "rejected_shed_lower_class": 0,
        }
        # every preemption event, in order: the victim's class plus the
        # classes of the OTHER preemptable slots live at that instant —
        # the chaos/SLO tests assert interactive is only ever evicted
        # when no lower-priority victim existed
        self.preemption_log: List[Dict[str, Any]] = []

    # -------------------------------------------------------------- setup
    def _empty_cache(self):
        cache = self.spec.init_paged_cache()
        if self._rules is not None and self._rules.mesh is not None:
            shardings = self.spec.shardings(self._rules)
            cache = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                cache, shardings)
        if self.drafter is not None and self.drafter.kind == "model":
            cache["draft"] = self.drafter.init_cache(self.slots)
        return cache

    # ---------------------------------------------------------- telemetry
    @property
    def queue(self) -> List[Request]:
        return self.scheduler.queue

    @property
    def prefill_compiles(self) -> int:
        return self.executor.prefill_compiles

    @property
    def suffix_prefill_compiles(self) -> int:
        return self.executor.suffix_prefill_compiles

    @property
    def admit_compiles(self) -> int:
        return self.executor.admit_compiles

    @property
    def decode_compiles(self) -> int:
        return self.executor.decode_compiles

    def memory_stats(self) -> Dict[str, Any]:
        """Paged-cache memory telemetry (per-group page occupancy + HBM
        bytes per live generated token at the current instant)."""
        live = sum(len(r.out_tokens) + len(r.prompt)
                   for r in self._slot_req if r is not None)
        stats = self.spec.memory_stats(
            self.scheduler.pages_in_use_by_group, live)
        stats["peak_pages_in_use"] = self.scheduler.peak_pages_in_use
        stats["live_slots"] = sum(r is not None for r in self._slot_req)
        stats["peak_live_slots"] = self.peak_live_slots
        return stats

    def prefix_stats(self) -> Dict[str, Any]:
        """Prefix-sharing telemetry (hit rate, skipped prefill tokens,
        shared-page attaches, CoW copies, radix evictions)."""
        return self.scheduler.prefix_stats()

    def fault_stats(self) -> Dict[str, Any]:
        """Robustness telemetry: preemption / resume / timeout /
        cancellation / rejection counters, the recovered-prefill fraction
        of resumed admissions (replayed tokens that rode on radix pages
        instead of being recomputed), and the chaos schedule's own event
        counts when fault injection is active."""
        sched = self.scheduler
        stats: Dict[str, Any] = dict(self.fault_counters)
        stats["resume_admissions"] = sched.resume_admissions
        stats["resume_replayed_tokens"] = sched.resume_replayed_tokens
        stats["resume_recovered_tokens"] = sched.resume_recovered_tokens
        stats["recovered_prefill_fraction"] = (
            sched.resume_recovered_tokens / sched.resume_replayed_tokens
            if sched.resume_replayed_tokens else 0.0)
        if self.chaos is not None:
            stats["chaos"] = self.chaos.stats()
        return stats

    def latency_stats(self) -> Dict[str, Any]:
        """TTFT/TPOT p50/p99 per SLO class + goodput, from the host-side
        timestamps the chunk-boundary drain stamps on every request
        (``compute_latency_stats`` holds the math — pure, so the oracle
        tests grade it by hand).  Covers every request this engine has
        seen: finished, rejected, running, and still queued.  Also
        reports the dynamic-``prefill_budget`` throttle count (SLO
        policy on fused-chunk engines; 0 elsewhere)."""
        reqs = (list(self.finished) + list(self.rejected)
                + [r for r in self._slot_req if r is not None]
                + list(self.scheduler.queue))
        stats = compute_latency_stats(reqs)
        stats["budget_throttles"] = self.budget_throttles
        return stats

    def leaked_pages(self) -> int:
        """Pages still leased beyond what live slots and the radix index
        legitimately hold — at full drain (no live slots, empty queue)
        anything nonzero is a refcount leak.  The CI chaos smoke asserts
        this is 0 after every fault schedule."""
        sched = self.scheduler
        leaked = 0
        for key, pool in sched.pools.items():
            accounted = set()
            for lease in sched._leases.values():
                accounted.update(lease.get(key, ()))
            if sched.radix is not None and key == sched.share_key:
                stack = list(sched.radix.root.children.values())
                while stack:
                    node = stack.pop()
                    stack.extend(node.children.values())
                    accounted.add(node.page)
            leaked += pool.in_use - len(accounted)
        return leaked

    def spec_stats(self) -> Dict[str, Any]:
        """Speculative-decoding telemetry: acceptance rate (accepted
        drafts / proposed drafts) and committed tokens per verify step,
        from the device-side counters ``serve/sampling.spec_update``
        maintains.  Reading them is one host transfer — call between
        runs, not inside the serving loop."""
        if self.spec_config is None:
            return {"spec": False}
        steps, drafted, accepted, emitted = jax.device_get(
            (self.state["spec_steps"], self.state["spec_drafted"],
             self.state["spec_accepted"], self.state["spec_emitted"]))
        return {
            "spec": True,
            "drafter": self.drafter.kind,
            "spec_k": self.spec_config.k,
            "spec_steps": int(steps),
            "drafted_tokens": int(drafted),
            "accepted_tokens": int(accepted),
            "acceptance_rate": (float(accepted) / float(drafted)
                                if drafted else 0.0),
            "emitted_tokens": int(emitted),
            "tokens_per_step": (float(emitted) / float(steps)
                                if steps else 0.0),
        }

    # ------------------------------------------------------ observability
    def _trace(self, kind: str, rid: Optional[int] = None,
               slot: Optional[int] = None, ts: Optional[float] = None,
               **attrs: Any) -> None:
        """Record one lifecycle event when tracing is on.  Host-only:
        called at chunk boundaries with the boundary's existing clock
        read where one exists (``ts``), so the decode chunk stays
        sync-free and traced runs stay token-identical."""
        if self.tracer is None:
            return
        self.tracer.record(kind, self._clock() if ts is None else ts,
                           rid=rid, slot=slot, **attrs)

    def _chaos_event(self, fault: str, **attrs: Any) -> None:
        slot = attrs.pop("slot", None)
        self._trace("chaos", slot=slot, fault=fault, **attrs)

    def observe(self, *, spec: bool = True) -> Dict[str, Any]:
        """One flat snapshot of every stats surface — ``memory_stats`` /
        ``fault_stats`` / ``latency_stats`` / ``spec_stats`` /
        ``prefix_stats`` — under the stable dotted metric names declared
        in ``repro.serve.metrics`` (``pool.pages_in_use``,
        ``sched.preemptions.pressure``, ``spec.acceptance``, ...).
        ``spec=False`` skips the one device read behind
        ``spec_stats``."""
        return metrics_mod.snapshot(self, spec=spec)

    def export_trace(self, path: Optional[str] = None) -> Dict[str, Any]:
        """Chrome-trace / Perfetto JSON of the buffered lifecycle events
        (per-slot tracks, per-request flow arrows across preempt/resume,
        counter tracks for pool occupancy and queue depth).  Writes to
        ``path`` when given; returns the trace object either way.
        ``benchmarks/check_trace.py`` validates the schema in CI."""
        if self.tracer is None:
            raise ValueError("tracing is disabled; construct the Engine "
                             "with trace=True (or a capacity / Tracer)")
        obj = trace_mod.to_chrome_trace(self.tracer.events())
        if path is not None:
            with open(path, "w") as f:
                json.dump(obj, f)
        return obj

    def explain(self, rid: int) -> str:
        """Per-request text explain: the causal chain from submit to
        terminal with per-phase durations, from the lifecycle trace."""
        if self.tracer is None:
            raise ValueError("tracing is disabled; construct the Engine "
                             "with trace=True (or a capacity / Tracer)")
        return trace_mod.explain(self.tracer.events(), rid)

    # ------------------------------------------------------------ serving
    def submit(self, req: Request) -> Optional[RequestRejected]:
        """Enqueue a request, or shed it with a typed result.

        Never raises ``PagePoolExhausted``: a request whose worst-case
        reservation exceeds the pool's total budget gets an
        ``"infeasible"`` ``RequestRejected`` (queueing it would wedge the
        head of the line), and one arriving at a full bounded queue is
        handled by ``shed_policy`` — ``"reject"`` sheds it immediately,
        ``"block"`` drives the engine until the queue drains (submission
        backpressure), ``"evict-lru-prefix"`` first reclaims unreferenced
        radix prefix pages and drains the queue into freed slots, then
        sheds only if the queue is still full.  Returns ``None`` when the
        request was accepted.  ``ValueError`` for requests violating the
        ``max_len`` contract still raises — that is a caller bug, not
        load."""
        if len(req.prompt) + req.max_new_tokens > self.max_len \
                and not self.cfg.supports_long_context:
            # full-attention page tables cap at max_len tokens; a longer
            # prompt (or a generation budget running past the table)
            # would silently mod-wrap like a ring, overwriting the
            # oldest KV — including prefix pages other slots or the
            # radix index may reference
            raise ValueError(
                f"prompt length {len(req.prompt)} + max_new_tokens "
                f"{req.max_new_tokens} exceeds max_len={self.max_len} "
                f"and {self.cfg.name} has non-windowed attention; raise "
                "max_len or lower max_new_tokens")
        if self.chunked_prefill:
            if not req.prompt:
                raise ValueError("chunked_prefill requires a non-empty "
                                 "prompt")
            if len(req.prompt) + req.max_new_tokens > self.max_len:
                # the fused chunk streams prompt tokens from a per-slot
                # [max_len] staging buffer; a preempted request replays
                # its generated tail as prompt on resume, so the whole
                # prompt+generation span must fit even for windowed archs
                raise ValueError(
                    f"prompt length {len(req.prompt)} + max_new_tokens "
                    f"{req.max_new_tokens} exceeds max_len={self.max_len}: "
                    "chunked_prefill stages prompts in a max_len-sized "
                    "buffer; raise max_len or pass chunked_prefill=False")
        try:
            self.scheduler.validate(req)
        except PagePoolExhausted as e:
            return self._reject(req, "infeasible", str(e))
        if req.submit_time is None:       # TTFT clock starts here; a
            req.submit_time = self._clock()   # resume keeps the original
        if req.deadline is None and req.ttl is not None:
            req.deadline = self._clock() + req.ttl
        self._trace("submit", rid=req.rid, ts=req.submit_time,
                    slo_class=req.slo_class, plen=len(req.prompt),
                    max_new=req.max_new_tokens)
        if self.queue_limit is not None \
                and len(self.scheduler.queue) >= self.queue_limit:
            shed = self._shed(req)
            if shed is not None:
                return shed
        self.scheduler.submit(req)
        return None

    def _reject(self, req: Request, kind: str,
                reason: str) -> RequestRejected:
        req.status = RequestStatus.REJECTED
        req.reject_reason = reason
        req.done = True
        if req.finish_time is None:
            req.finish_time = self._clock()
        self.fault_counters["rejected"] += 1
        self.fault_counters[f"rejected_{kind}"] += 1
        self.rejected.append(req)
        self._trace("reject", rid=req.rid, ts=req.finish_time,
                    why=kind, status=req.status)
        return RequestRejected(req=req, kind=kind, reason=reason)

    def _shed(self, req: Request) -> Optional[RequestRejected]:
        """Apply the shed policy to a submission hitting a full queue.
        Returns the rejection, or None once there is room."""
        def room() -> bool:
            return len(self.scheduler.queue) < self.queue_limit

        if self.shed_policy == "block":
            # submission backpressure: run the engine until the queue
            # drains (bounded — every step finishes or reaps work)
            for _ in range(100_000):
                if room():
                    return None
                if not (self.scheduler.queue or self._live()):
                    break
                self.step()
            if room():
                return None
        elif self.shed_policy == "evict-lru-prefix":
            sched = self.scheduler
            if sched.radix is not None:
                pool = sched.pools[sched.share_key]
                while sched.radix.evict_one(pool) is not None:
                    sched.radix_evictions += 1
            self._reap()
            self._admit()
            if room():
                return None
        elif self.shed_policy == "shed-lowest-class":
            # class-aware load shedding: drop the queued request of the
            # STRICTLY lowest-priority class (worst slack on ties) to
            # make room for a more urgent arrival; when nothing queued
            # outranks the arrival downward, the arrival itself sheds
            now = self._clock()
            queue = self.scheduler.queue
            victim = max(
                (r for r in queue if r.priority > req.priority),
                key=lambda r: (r.priority, -r.ttft_slack(now)),
                default=None)
            if victim is not None:
                queue.remove(victim)
                self.fault_counters["rejected_shed_lower_class"] += 1
                self._reject(
                    victim, "queue_full",
                    f"shed for higher-priority rid={req.rid} "
                    f"({req.slo_class} over {victim.slo_class})")
                return None
        return self._reject(
            req, "queue_full",
            f"admission queue full ({self.queue_limit} waiting, "
            f"shed_policy={self.shed_policy})")

    def bucket_for(self, plen: int) -> int:
        for b in self.buckets:
            if b >= plen:
                return b
        b = _next_pow2(max(plen, 1))
        self.buckets.append(b)   # keep the ≤ len(buckets) compile invariant
        self.buckets.sort()
        return b

    def _ctx_bucket(self, nblocks: int) -> int:
        """Pad the shared-prefix ctx gather to a power-of-two block count
        (capped at the sharing group's table width), so the suffix
        prefill compiles O(log^2) executables, not one per match."""
        ring = self.spec.group_of(self.spec.share_group_key).ring_blocks
        return min(_next_pow2(max(nblocks, 1)), ring)

    def _ctx_row(self, adm: Admission, s: int) -> np.ndarray:
        """Trash-padded page row naming the ``ceil(s/P)`` context pages a
        suffix prefill at offset ``s`` gathers from the slot's table."""
        gkey = self.spec.share_group_key
        nctx = -(-s // self.spec.page_size)
        cb = self._ctx_bucket(nctx)
        row = np.full((cb,), self.spec.group_of(gkey).trash_page, np.int32)
        row[:nctx] = adm.rows[gkey][:nctx]
        return row

    def _batched_admit(self, entries: List[Dict], valids: List[bool]):
        """Apply up to ``slots`` admissions in ONE splice dispatch.  The
        entry list is padded to the slot count by aliasing the first
        entry with its valid flag off (trash-routed writes, bookkeeping
        untouched), so the executable count stays 1 for any batch size."""
        if not entries:
            return
        ent = entries + [entries[0]] * (self.slots - len(entries))
        vf = list(valids) + [False] * (self.slots - len(valids))
        rows = {g.key: jnp.asarray(
            np.stack([en["rows"][g.key] for en in ent]).astype(np.int32))
            for g in self.spec.groups}
        if self.chunked_prefill:
            # fused admission is pure bookkeeping: table rows + prompt
            # staging; the chunk step itself prefills
            self.cache, self.state = self.executor.admit(
                self.cache, self.state,
                jnp.asarray([en["slot"] for en in ent], jnp.int32),
                jnp.asarray([en["start"] for en in ent], jnp.int32),
                jnp.asarray([en["plen"] for en in ent], jnp.int32),
                rows,
                jnp.asarray(np.stack([en["prompt"] for en in ent]),
                            jnp.int32),
                jnp.asarray([en["out_len0"] for en in ent], jnp.int32),
                jnp.asarray([en["max_new"] for en in ent], jnp.int32),
                jnp.asarray([en["eos"] for en in ent], jnp.int32),
                jnp.asarray([en["temp"] for en in ent], jnp.float32),
                jnp.asarray(vf))
            return
        drafts = None
        if self.drafter is not None and self.drafter.kind == "model":
            drafts = tuple(en["draft"] for en in ent)
        hist = None
        if self._hist_cap:
            hist = jnp.asarray(np.stack([en["hist"] for en in ent]),
                               jnp.int32)
        self.cache, self.state = self.executor.admit(
            self.cache, self.state,
            tuple(en["one_cache"] for en in ent), drafts,
            jnp.asarray([en["slot"] for en in ent], jnp.int32),
            jnp.asarray([en["start"] for en in ent], jnp.int32),
            jnp.asarray([en["plen"] for en in ent], jnp.int32),
            rows,
            tuple(en["tok"] for en in ent),
            jnp.asarray([en["out_len0"] for en in ent], jnp.int32),
            jnp.asarray([en["max_new"] for en in ent], jnp.int32),
            jnp.asarray([en["eos"] for en in ent], jnp.int32),
            jnp.asarray([en["temp"] for en in ent], jnp.float32),
            jnp.asarray(vf),
            hist)

    def warmup(self) -> None:
        """Pre-compile every prefill bucket, the batched admission
        splice, and the decode chunk so serving never pays a compile
        inside the hot loop.  Semantically inert: admissions use trash
        page-table rows with their valid flag off, and the PRNG key is
        restored afterwards, so seeded sampled runs are identical with or
        without warmup.  (Suffix-prefill executables still compile lazily
        on the first prefix hit per shape pair.)"""
        key_before = jnp.array(self.state["key"])   # copy: state is donated
        trash_rows = {g.key: np.full((g.ring_blocks,), g.trash_page,
                                     np.int32) for g in self.spec.groups}
        if self.chunked_prefill:
            # fused mode: no prefill buckets exist.  ONE inert admission
            # compiles the bookkeeping splice, ONE chunk compiles the
            # fused executable — total steady-state compile count 2.
            entry = {"slot": 0, "start": 0, "plen": 0, "rows": trash_rows,
                     "prompt": np.zeros((self.max_len,), np.int32),
                     "out_len0": 1, "max_new": 0, "eos": -1, "temp": 0.0}
            self._batched_admit([entry], [False])
            _, self.cache, self.state = self.executor.chunk(
                self.params, self.draft_params, self.cache, self.state)
            self.cache = self.executor.free_slot(self.cache, jnp.int32(0))
            self.state = dict(self.state, key=key_before)
            return
        for b in self.buckets:
            tokens = jnp.zeros((1, b), jnp.int32)
            length = jnp.zeros((1,), jnp.int32)
            key = jax.random.PRNGKey(0)
            temp = jnp.zeros((1,), jnp.float32)
            pad_to = self.buckets[-1]
            tok, one_cache = self.executor.prefill(
                self.params, tokens, length, key, temp, pad_to)
            draft = None
            if self.drafter is not None and self.drafter.kind == "model":
                draft = self.executor.draft_prefill(
                    self.draft_params, tokens, length, pad_to)
            entry = {"slot": 0, "start": 0, "plen": 0, "rows": trash_rows,
                     "tok": tok, "one_cache": one_cache, "draft": draft,
                     "out_len0": 1, "max_new": 0, "eos": -1, "temp": 0.0,
                     "hist": np.zeros((self._hist_cap,), np.int32)}
            self._batched_admit([entry], [False])
        _, self.cache, self.state = self.executor.chunk(
            self.params, self.draft_params, self.cache, self.state)
        # eviction splice: compiling it here keeps the first request
        # completion from paying a trace inside the serving loop (slot 0
        # is idle, so re-trashing its table rows is inert)
        self.cache = self.executor.free_slot(self.cache, jnp.int32(0))
        self.state = dict(self.state, key=key_before)

    def _req_temp(self, req: Request) -> float:
        if req.temperature is not None:
            return float(req.temperature)
        return self.default_temp

    @property
    def _chunked_ok(self) -> bool:
        """Prompts longer than the largest bucket can run as several
        suffix-prefill segments when the arch has the suffix machinery
        (single full-attention pool group) and no model drafter (whose
        dense draft prefill has no suffix path)."""
        return (self.spec.prefix_sharing_capable
                and (self.drafter is None or self.drafter.kind != "model"))

    def _chunked_prefill(self, adm: Admission, s: int) -> int:
        """Run all but the final ``<= Bmax`` prompt tokens of an overlong
        prompt as bucket-sized segments through the suffix-prefill path —
        each segment attends to the pages earlier segments spliced — and
        return the final segment's start offset.  Reuses the existing
        buckets and the existing suffix executables: no new compiles
        beyond the (segment bucket, ctx bucket) pairs sharing already
        pays for."""
        req, slot = adm.req, adm.slot
        prompt = req.effective_prompt
        plen = len(prompt)
        bmax = self.buckets[-1]
        rows = {k: jnp.asarray(v) for k, v in adm.rows.items()}
        cur = s
        while plen - cur > bmax:
            seg = list(prompt[cur:cur + bmax])
            self._key, sub = jax.random.split(self._key)
            temp = jnp.zeros((1,), jnp.float32)
            if cur == 0:
                _tok, oc = self.executor.prefill(
                    self.params, jnp.asarray([seg], jnp.int32),
                    jnp.asarray([bmax], jnp.int32), sub, temp, bmax)
            else:
                pools = [c if (c is not None and "pk" in c) else None
                         for c in self.cache["layers"]]
                _tok, oc = self.executor.prefill_suffix(
                    self.params, jnp.asarray([seg], jnp.int32),
                    jnp.asarray([bmax], jnp.int32), jnp.int32(cur),
                    jnp.asarray(self._ctx_row(adm, cur)), pools, sub,
                    temp, bmax)
            self.cache = self.executor.splice(
                self.cache, oc, jnp.int32(slot), jnp.int32(cur),
                jnp.int32(cur + bmax), rows)
            cur += bmax
        return cur

    def _admit(self) -> None:
        """Chunk-boundary admission with pool-pressure preemption: admit
        while the queue head fits; when it does not but a slot is free
        (pages, not slots, are the bottleneck), evict a victim — fewest
        tokens decoded first, most radix-recoverable on ties — and retry.
        Victims requeue at the back and resume through the radix/suffix
        path; each carries a ``max_preemptions`` cap, and at most
        ``slots`` evictions happen per boundary, so admission cannot
        livelock."""
        if self.chaos is not None and self._live() \
                and self.chaos.deny_admission():
            return   # injected admission-time exhaustion (delay, not loss)
        self._do_admissions()
        if not self.preemption:
            return
        guard = 0
        while self.scheduler.queue and guard < self.slots \
                and any(r is None for r in self._slot_req):
            victim = self._pick_victim()
            if victim is None:
                return
            guard += 1
            qlen = len(self.scheduler.queue)
            self._preempt_slot(victim, "pressure")
            self._do_admissions()
            if len(self.scheduler.queue) > qlen:
                return   # eviction did not unblock the head; stop churning

    def _pick_victim(self) -> Optional[int]:
        """Victim policy: lowest SLO-class priority first (batch yields
        to interactive under pool pressure — for legacy single-class
        workloads every request grades identically, so the historical
        order is unchanged), then fewest tokens decoded (least work
        lost), then most radix-recoverable pages (cheapest to resume),
        then lowest slot.  Slots at their preemption cap are never
        picked."""
        best, best_score = None, None
        P = self.spec.page_size
        for slot in range(self.slots):
            req = self._slot_req[slot]
            if req is None or req.preemptions >= req.max_preemptions:
                continue
            valid = len(req.effective_prompt) - (1 if req.out_tokens else 0)
            recoverable = valid // P if self.scheduler.radix is not None \
                else 0
            score = (-req.priority, len(req.out_tokens), -recoverable,
                     slot)
            if best_score is None or score < best_score:
                best, best_score = slot, score
        return best

    def _clear_slot(self, slot: int) -> None:
        """Device+host teardown shared by preemption and reaping: drop
        page references, re-trash the table rows, clear the active flag
        so the next chunk's dead-tail steps neither sample nor write."""
        self._slot_req[slot] = None
        self._slot_first_tok[slot] = None
        self._slot_first_pending[slot] = False
        self._slot_stale[slot] = 0
        self._slot_seen_len[slot] = 0
        self._slot_plen[slot] = 0
        if self.chaos is not None:
            self.chaos.clear_stall(slot)
        self.scheduler.release(slot)
        self.cache = self.executor.free_slot(self.cache, jnp.int32(slot))
        self.state = self.executor.deactivate(self.state, jnp.int32(slot))

    def _finish_terminal(self, req: Request, status: str) -> None:
        req.status = status
        req.done = True
        if req.finish_time is None:
            req.finish_time = self._clock()
        if status == RequestStatus.TIMED_OUT:
            self.fault_counters["timed_out"] += 1
        elif status == RequestStatus.CANCELLED:
            self.fault_counters["cancelled"] += 1
        self.finished.append(req)
        self._trace("finish", rid=req.rid, ts=req.finish_time,
                    status=req.status, tokens=len(req.out_tokens))

    def _evict_slot(self, slot: int, status: str) -> None:
        req = self._slot_req[slot]
        self._clear_slot(slot)
        self._finish_terminal(req, status)

    def _preempt_slot(self, slot: int, why: str) -> None:
        """Evict a running slot and requeue its request for resumption.
        The request's generated-so-far tokens replay as prompt tail on
        re-admission; full pages are preserved in the radix index first,
        so the resume's prefill recovers them as a prefix hit instead of
        recomputing."""
        req = self._slot_req[slot]
        if len(req.out_tokens) >= req.max_new_tokens or (
                req.eos_id is not None and req.out_tokens
                and req.out_tokens[-1] == int(req.eos_id)):
            # everything was already drained (a stalled slot can hide its
            # own finish): complete, don't resume an empty remainder
            self._evict_slot(slot, RequestStatus.FINISHED)
            return
        req.preemptions += 1
        self.fault_counters["preemptions"] += 1
        self.fault_counters[f"{why}_preemptions"] += 1
        self.preemption_log.append({
            "rid": req.rid, "slo_class": req.slo_class, "why": why,
            "candidate_classes": [
                r.slo_class for s2, r in enumerate(self._slot_req)
                if r is not None and s2 != slot
                and r.preemptions < r.max_preemptions]})
        self._trace("preempt", rid=req.rid, slot=slot, why=why,
                    preemptions=req.preemptions)
        upto = None
        if self.chunked_prefill \
                and self._slot_seen_len[slot] < self._slot_plen[slot]:
            # preempted mid-prefill: only the pages the host has SEEN
            # covered are certainly written (a chaos-stalled drain may
            # trail the device); preserve exactly that prefix
            upto = self._slot_seen_len[slot]
        self.scheduler.preserve(slot, req, upto=upto)
        self._clear_slot(slot)
        self.scheduler.requeue(req)

    def _reap(self) -> None:
        """Chunk-boundary reaping of cancelled and deadline-expired
        requests, queued or running: pages free immediately, the typed
        terminal status lands in ``finished``, and the very same
        boundary's admission pass can re-lease the freed slot."""
        now = self._clock()

        def dead(req: Request) -> bool:
            return req.cancel_requested or (
                req.deadline is not None and now > req.deadline)

        for req in [r for r in self.scheduler.queue if dead(r)]:
            self.scheduler.queue.remove(req)
            self._trace("reap", rid=req.rid, ts=now,
                        why="cancelled" if req.cancel_requested
                        else "timed_out")
            self._finish_terminal(
                req, RequestStatus.CANCELLED if req.cancel_requested
                else RequestStatus.TIMED_OUT)
        for slot in range(self.slots):
            req = self._slot_req[slot]
            if req is None or not dead(req):
                continue
            self._trace("reap", rid=req.rid, slot=slot, ts=now,
                        why="cancelled" if req.cancel_requested
                        else "timed_out")
            self._evict_slot(
                slot, RequestStatus.CANCELLED if req.cancel_requested
                else RequestStatus.TIMED_OUT)

    def _do_admissions(self) -> None:
        free = [i for i in range(self.slots) if self._slot_req[i] is None]
        pend: List[Dict] = []
        pvalid: List[bool] = []

        def flush():
            self._batched_admit(pend, pvalid)
            pend.clear()
            pvalid.clear()

        # stamp this boundary's admissions with the current chunk id so
        # the admission_log cross-references token_chunks / trace events
        self.scheduler.current_chunk = self.chunks
        now = self._clock()
        for adm in self.scheduler.admissions(free, now=now):
            req, slot = adm.req, adm.slot
            prompt = req.effective_prompt   # resume: replay emitted tail
            plen = len(prompt)
            if self.tracer is not None:
                resume = req.preemptions > 0
                if adm.suffix_start > 0:
                    self._trace("radix_hit", rid=req.rid, slot=slot,
                                ts=now, matched_tokens=adm.suffix_start,
                                resume=resume)
                if adm.cow is not None:
                    self._trace("cow", rid=req.rid, slot=slot, ts=now,
                                src_page=adm.cow[1], dst_page=adm.cow[2])
                if resume:
                    self._trace("resume", rid=req.rid, slot=slot, ts=now,
                                preemptions=req.preemptions)
                self._trace("admit", rid=req.rid, slot=slot, ts=now,
                            chunk=self.chunks,
                            suffix_start=adm.suffix_start, plen=plen,
                            resume=resume)
            if self.chunked_prefill:
                # fused chunked prefill: no prefill dispatch at all.  The
                # admission stages the prompt and rewinds the slot's len
                # to the cursor (shared-prefix / resume boundary); the
                # next chunks stream prefill_budget tokens per micro-step
                # through the fused executable.  No flush-before-CoW
                # dance: fused admissions write no KV, and the radix
                # index only ever names fully-written pages (deferred
                # insert), so a CoW source is always materialized.
                if adm.cow is not None:
                    _blk, src, dst = adm.cow
                    self.cache = self.executor.copy_page(
                        self.cache, jnp.int32(src), jnp.int32(dst),
                        self.scheduler.share_key)
                pbuf = np.zeros((self.max_len,), np.int32)
                pbuf[:plen] = prompt
                eos = -1 if req.eos_id is None else int(req.eos_id)
                pend.append({"slot": slot, "start": adm.suffix_start,
                             "plen": plen, "rows": adm.rows,
                             "prompt": pbuf,
                             "out_len0": len(req.out_tokens),
                             "max_new": req.max_new_tokens, "eos": eos,
                             "temp": self._req_temp(req)})
                pvalid.append(True)
                if req.preemptions > 0:
                    self.fault_counters["resumes"] += 1
                self._slot_req[slot] = req
                self._slot_seen_len[slot] = adm.suffix_start
                self._slot_plen[slot] = plen
                self._slot_stale[slot] = 0
                continue
            self._key, sub = jax.random.split(self._key)
            temp = jnp.asarray([self._req_temp(req)], jnp.float32)
            s = adm.suffix_start
            if adm.cow is not None or s > 0:
                # a pending admission in this same batch may own the CoW
                # source / ctx pages this one is about to read (radix
                # match against pages not yet spliced): flush first
                flush()
            if adm.cow is not None:
                # the slot will write into a shared page (partial-page
                # match, or last page of a fully-matched prompt): give it
                # a private copy BEFORE any prefill gather or splice
                _blk, src, dst = adm.cow
                self.cache = self.executor.copy_page(
                    self.cache, jnp.int32(src), jnp.int32(dst),
                    self.scheduler.share_key)
            if plen - s > self.buckets[-1] and self._chunked_ok:
                flush()    # segment splices interleave with self.cache
                s = self._chunked_prefill(adm, s)
            if s > 0:
                # prefix hit and/or chunked prefill: prefill only the
                # remaining tail, reading the earlier tokens from the
                # slot's (shared or just-spliced) pages
                suffix = list(prompt[s:])
                bucket = self.bucket_for(len(suffix))
                padded = suffix + [0] * (bucket - len(suffix))
                pools = [c if (c is not None and "pk" in c) else None
                         for c in self.cache["layers"]]
                tok, one_cache = self.executor.prefill_suffix(
                    self.params, jnp.asarray([padded], jnp.int32),
                    jnp.asarray([len(suffix)], jnp.int32), jnp.int32(s),
                    jnp.asarray(self._ctx_row(adm, s)), pools, sub, temp,
                    self.buckets[-1])
            else:
                bucket = self.bucket_for(plen)
                padded = list(prompt) + [0] * (bucket - plen)
                tok, one_cache = self.executor.prefill(
                    self.params, jnp.asarray([padded], jnp.int32),
                    jnp.asarray([plen], jnp.int32), sub, temp,
                    self.buckets[-1])
            draft = None
            if self.drafter is not None and self.drafter.kind == "model":
                dbucket = self.bucket_for(plen)
                dpadded = list(prompt) + [0] * (dbucket - plen)
                draft = self.executor.draft_prefill(
                    self.draft_params, jnp.asarray([dpadded], jnp.int32),
                    jnp.asarray([plen], jnp.int32), self.buckets[-1])
            hist = None
            if self._hist_cap:
                hist = np.zeros((self._hist_cap,), np.int32)
                head = prompt[:self._hist_cap]
                hist[:len(head)] = head
            eos = -1 if req.eos_id is None else int(req.eos_id)
            pend.append({"slot": slot, "start": s, "plen": plen,
                         "rows": adm.rows, "tok": tok,
                         "one_cache": one_cache, "draft": draft,
                         "out_len0": len(req.out_tokens) + 1,
                         "max_new": req.max_new_tokens, "eos": eos,
                         "temp": self._req_temp(req), "hist": hist})
            pvalid.append(True)
            if req.preemptions > 0:
                self.fault_counters["resumes"] += 1
            self._slot_req[slot] = req
            self._slot_first_tok[slot] = tok   # on device until drain
            self._slot_first_pending[slot] = True
            self._slot_stale[slot] = 0
        flush()
        self.peak_live_slots = max(
            self.peak_live_slots,
            sum(r is not None for r in self._slot_req))

    def _update_prefill_budgets(self) -> None:
        """``prefill_budget`` as a dynamic SLO knob, applied at the chunk
        boundary like every other policy: while any interactive request
        has blown its TTFT slack and is still waiting on a first token,
        NON-interactive slots' per-micro-step prompt slice shrinks to a
        quarter chunk (floor 1) so the urgent prefill and the decode
        rows get the arithmetic; full budgets restore once slack
        recovers.  A pure host->device value update — ``pbudget`` is
        data, not shape, so the one fused executable never retraces, and
        nothing here reads from the device."""
        if not self.chunked_prefill or self.policy != "slo":
            return
        S = self.executor.chunk_rows
        now = self._clock()

        def urgent(r: Request) -> bool:
            return (r.priority == 0 and r.first_token_time is None
                    and r.ttft_slack(now) < 0.0)

        pressure = any(urgent(r) for r in self.scheduler.queue) or any(
            r is not None and urgent(r) for r in self._slot_req)
        throttled = max(1, S // 4)
        vec = [throttled if (pressure and r is not None
                             and r.priority > 0) else S
               for r in self._slot_req]
        if vec != self._budget_vec:
            if pressure:
                self.budget_throttles += 1
            self._budget_vec = vec
            self.state = dict(self.state,
                              pbudget=jnp.asarray(vec, jnp.int32))

    def step_chunk(self) -> jax.Array:
        """Dispatch one fused decode chunk.  No host synchronization —
        safe to call under ``jax.transfer_guard_device_to_host``."""
        toks, self.cache, self.state = self.executor.chunk(
            self.params, self.draft_params, self.cache, self.state)
        self.steps += self.sync_interval
        return toks

    def _drain(self, toks: jax.Array) -> None:
        """One batched device->host transfer: token history + slot state.
        The history is [T, slots] with -1 where a slot was idle — and,
        under speculation, wherever a draft/verify round committed fewer
        than ``K+1`` tokens — so each slot's new tokens are the
        non-negative entries of its column, in order.  Finished slots are
        evicted: page refcounts drop in the scheduler (exclusive pages
        rejoin the free list; shared/radix-indexed pages survive for
        their other referents) and the slot's page-table rows are pointed
        at the trash pages, so its dead tail writes cannot touch
        re-leased pages."""
        fetch = (toks, self.state["out_len"], self.state["active"],
                 [self._slot_first_tok[i] for i in range(self.slots)])
        if self.chunked_prefill:
            # also drain the prefill cursor (cache["len"], capped by the
            # prompt length per slot below) in the SAME transfer
            toks_np, out_len, active, firsts, cache_len = jax.device_get(
                fetch + (self.cache["len"],))
        else:
            toks_np, out_len, active, firsts = jax.device_get(fetch)
            cache_len = None
        self.host_syncs += 1
        now = self._clock()   # one host clock read stamps every token
        self.chunks += 1      # chunk sequence number for this drain
        if self.tracer is not None:
            self._trace("chunk", ts=now, chunk=self.chunks,
                        queue_depth=len(self.scheduler.queue),
                        pages_in_use=self.scheduler.pages_in_use,
                        live_slots=sum(r is not None
                                       for r in self._slot_req))
        watchdog: List[int] = []
        for slot in range(self.slots):
            req = self._slot_req[slot]
            if req is None:
                continue
            if self.chaos is not None and self.chaos.stalled(slot):
                # injected straggler: the slot reported nothing this
                # boundary.  Progress stalls host-side until the watchdog
                # preempts it; tokens lost in between regenerate on
                # resume (token-identical at temperature 0).
                self._slot_stale[slot] += 1
                if self.stall_patience \
                        and self._slot_stale[slot] >= self.stall_patience:
                    watchdog.append(slot)
                continue
            progressed = False
            if self.chunked_prefill:
                # prefill cursor: past the prompt end, len counts decoded
                # tokens — those drain through out_len as usual
                plen0 = self._slot_plen[slot]
                seen = min(int(cache_len[slot]), plen0)
                if seen > self._slot_seen_len[slot]:
                    progressed = True   # mid-prefill progress ≠ a stall
                    prev = self._slot_seen_len[slot]
                    self._slot_seen_len[slot] = seen
                    if prev < plen0:
                        self._trace("prefill", rid=req.rid, slot=slot,
                                    ts=now, seen=seen, plen=plen0,
                                    chunk=self.chunks)
                    if prev < plen0 <= seen:
                        # prefill completed this chunk: NOW every prompt
                        # page is written, so the prompt becomes visible
                        # to the radix prefix index (deferred insert)
                        self.scheduler.index_slot(slot, req, plen0)
            if self._slot_first_pending[slot]:
                # prefill-sampled token (resumes arrive with a non-empty
                # out_tokens, so presence of output cannot gate this)
                req.out_tokens.append(int(firsts[slot][0]))
                req.token_times.append(now)
                req.token_chunks.append(self.chunks)
                if req.first_token_time is None:
                    req.first_token_time = now
                self._slot_first_pending[slot] = False
            k = int(out_len[slot]) - len(req.out_tokens)
            if k > 0:
                # the serving loop drains every chunk, so the whole gap is
                # in this history; a caller draining a partial history
                # (benchmarks) just gets what it carries
                vals = [int(t) for t in toks_np[:, slot] if t >= 0]
                assert len(vals) <= k, (slot, len(vals), k)
                req.out_tokens.extend(vals[-k:])
                req.token_times.extend([now] * len(vals[-k:]))
                req.token_chunks.extend([self.chunks] * len(vals[-k:]))
                if req.first_token_time is None and req.token_times:
                    # TTFT from the ORIGINAL submit_time — a request
                    # preempted mid-prefill and resumed later keeps its
                    # submit stamp, so the wait is charged to it
                    req.first_token_time = now
                self._slot_stale[slot] = 0
            elif self.stall_patience and not progressed:
                self._slot_stale[slot] += 1
                if self._slot_stale[slot] >= self.stall_patience:
                    watchdog.append(slot)
                    continue
            if not active[slot]:
                req.status = RequestStatus.FINISHED
                req.done = True
                req.finish_time = now
                self.finished.append(req)
                self._trace("finish", rid=req.rid, slot=slot, ts=now,
                            status=req.status,
                            tokens=len(req.out_tokens))
                self._slot_req[slot] = None
                self._slot_first_tok[slot] = None
                self._slot_first_pending[slot] = False
                self._slot_stale[slot] = 0
                self.scheduler.release(slot)
                self.cache = self.executor.free_slot(self.cache,
                                                     jnp.int32(slot))
        for slot in watchdog:
            # straggler recovery: treat the unresponsive slot as lost and
            # resume its request from the last drained token
            self._preempt_slot(slot, "watchdog")

    def _live(self) -> bool:
        return any(r is not None for r in self._slot_req)

    def step(self) -> None:
        """One reap + admit + fused-chunk + drain round
        (``sync_interval`` decode steps per call).  All policy — deadline
        reaping, cancellation, preemption, admission — runs on the host
        at this boundary; the chunk itself stays one sync-free
        executable."""
        self._reap()
        if self.chaos is not None:
            live = [i for i in range(self.slots)
                    if self._slot_req[i] is not None]
            self.chaos.tick(live)
            for slot in self.chaos.storm_victims(live):
                if self.policy == "slo":
                    # chaos decides THAT a preemption storm hits; under
                    # the SLO policy the class-aware victim rule decides
                    # WHO — interactive slots are evicted last, exactly
                    # as under genuine pool pressure
                    picked = self._pick_victim()
                    if picked is None:
                        continue
                    slot = picked
                if self._slot_req[slot] is not None:
                    self._preempt_slot(slot, "chaos")
        self._admit()
        self._update_prefill_budgets()
        if not self._live():
            if not self.scheduler.can_progress(0, now=self._clock()):
                head = self.queue[0]
                raise PagePoolExhausted(
                    f"wedged: rid={head.rid} cannot be admitted "
                    f"({self.scheduler.pool.free_pages} pages free) and no "
                    "slot is live to release more")
            return
        self._drain(self.step_chunk())

    def run(self, max_steps: int = 1000) -> List[Request]:
        while (self.queue or self._live()) and self.steps < max_steps:
            self.step()
        return self.finished
