"""Layered, shape-stable, sync-free serving runtime (paper §2.2.3, Fig. 14).

The paper's central measurement is that framework overhead — dispatch,
scheduling, synchronization, and memory management — dominates serving
once the math is tuned.  The runtime is split into three layers so each
overhead has exactly one owner:

* **Scheduler** (``serve/scheduler.Scheduler``) — host-side policy: FIFO
  queue, slot admission, page-budget reservation, eviction.  Continuous
  batching: slots free and re-admit at chunk boundaries without
  recompiling anything.
* **Executor** (``Executor`` below) — the compiled layer: bucketed
  prefill, the page-granular admission splice, and the fused decode chunk
  (``sync_interval`` decode steps + on-device sampling + slot bookkeeping
  in ONE ``lax.scan`` executable, zero host<->device syncs inside).
* **Driver** (``Engine``) — glues them: one batched device->host token
  drain per chunk, finish reporting, admission application.

The decode cache is the block-paged subsystem from ``serve/cache.py``:
attention KV lives in shared page pools behind per-slot page tables
(capacity bounded by the page budget, not ``slots x max_len``), while
mamba2/rwkv6 recurrent state stays dense.  ``CacheSpec`` carries logical
sharding axes for every buffer, so a ``parallel/sharding.Rules`` table
mapping ``BATCH``/``PAGES`` to the data mesh axis serves multi-device via
the existing ``launch/mesh.py`` machinery.

``ReferenceEngine`` in ``repro.serve.reference`` preserves the dense
per-token-sync loop as the measurement baseline and equivalence oracle for
``benchmarks/fig14_dispatch_overhead.py``.
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward_decode, forward_prefill
from repro.parallel import sharding as sh
from repro.serve import cache as cache_mod
from repro.serve import sampling
from repro.serve.cache import CacheSpec, empty_batch_cache  # noqa: F401
from repro.serve.scheduler import (PagePoolExhausted, Request,  # noqa: F401
                                   Scheduler)


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


class Executor:
    """Compiled serving layer: every function here is a jit with stable
    shapes (one executable per prefill bucket; exactly one decode chunk).
    The cache and slot state are donated through the chunk and the splice
    on backends that implement donation (not CPU)."""

    def __init__(self, cfg: ModelConfig, spec: CacheSpec, *, top_k: int,
                 sync_interval: int, donate: bool,
                 rules: Optional[sh.Rules] = None):
        self.cfg = cfg
        self.spec = spec
        self.top_k = int(top_k)
        self.sync_interval = int(sync_interval)
        self._rules = rules
        self._prefill_fn = jax.jit(self._prefill_impl)
        if donate:
            self._admit_fn = jax.jit(self._admit_impl, donate_argnums=(0, 1))
            self._chunk_fn = jax.jit(self._chunk_impl, donate_argnums=(1, 2))
            self._free_fn = jax.jit(self._free_impl, donate_argnums=(0,))
        else:
            self._admit_fn = jax.jit(self._admit_impl)
            self._chunk_fn = jax.jit(self._chunk_impl)
            self._free_fn = jax.jit(self._free_impl)

    def _ctx(self):
        """Sharding rules are a tracing-time thread-local; enter them for
        every compiled call so retraces see the same table."""
        if self._rules is None:
            return contextlib.nullcontext()
        return sh.axis_rules(self._rules)

    # ------------------------------------------------------ impls (traced)
    def _prefill_impl(self, params, tokens, length, key, temp):
        """Padded prefill + on-device first-token sampling.

        tokens [1, bucket], length [1].  One compile per bucket shape."""
        batch = {"tokens": tokens}
        if self.cfg.frontend:
            k = "frames" if self.cfg.family == "audio" else "frontend"
            batch[k] = jnp.zeros(
                (1, self.cfg.frontend_len, self.cfg.d_model), jnp.float32)
        logits, cache = forward_prefill(params, self.cfg, batch,
                                        length=length)
        tok = sampling.sample(logits, key, temperature=temp,
                              top_k=self.top_k)
        return tok, cache

    def _admit_impl(self, cache, state, one_cache, slot, plen,
                    pages_row, first_tok, max_new, eos, temp, active):
        """Jitted admission: page-granular splice of the prefill cache into
        ``slot`` (serve/cache.admit_cache) + device-side bookkeeping init.
        One compile per prefill bucket; everything else is traced."""
        new_cache = cache_mod.admit_cache(self.spec, cache, one_cache,
                                          slot, plen, pages_row)
        st = dict(state)
        st["tokens"] = state["tokens"].at[slot].set(first_tok)
        st["out_len"] = state["out_len"].at[slot].set(1)
        st["max_new"] = state["max_new"].at[slot].set(max_new)
        st["eos"] = state["eos"].at[slot].set(eos)
        st["temp"] = state["temp"].at[slot].set(temp)
        st["active"] = state["active"].at[slot].set(active)
        return new_cache, st

    def _chunk_impl(self, params, cache, state):
        """``sync_interval`` fused decode steps: forward (with paged KV
        lookup) + sample + slot bookkeeping, all on device.  Returns the
        [T, slots] token history (-1 where a slot was idle) — the only
        thing the host ever reads."""
        def body(carry, _):
            cache, state = carry
            logits, cache = forward_decode(
                params, self.cfg, state["tokens"][:, None], cache)
            cache.pop("enc_kv", None)   # decoder-only: keep carry structure
            key, sub = jax.random.split(state["key"])
            nxt = sampling.sample(logits, sub, temperature=state["temp"],
                                  top_k=self.top_k)
            state, emitted = sampling.decode_update(state, nxt, key)
            return (cache, state), emitted

        (cache, state), toks = jax.lax.scan(
            body, (cache, state), None, length=self.sync_interval)
        return toks, cache, state

    def _free_impl(self, cache, slot):
        return cache_mod.free_slot_cache(self.spec, cache, slot)

    # -------------------------------------------------------- public calls
    def prefill(self, params, tokens, length, key, temp):
        with self._ctx():
            return self._prefill_fn(params, tokens, length, key, temp)

    def admit(self, cache, state, *args):
        with self._ctx():
            return self._admit_fn(cache, state, *args)

    def chunk(self, params, cache, state):
        with self._ctx():
            return self._chunk_fn(params, cache, state)

    def free_slot(self, cache, slot):
        with self._ctx():
            return self._free_fn(cache, slot)

    # ----------------------------------------------------------- telemetry
    @property
    def prefill_compiles(self) -> int:
        return self._prefill_fn._cache_size()

    @property
    def decode_compiles(self) -> int:
        return self._chunk_fn._cache_size()


class Engine:
    """Host driver: composes Scheduler (policy) + Executor (compiled) over
    the paged cache.  ``max_len`` is the *logical* per-slot token cap (the
    page-table width x page_size); physical capacity is ``num_pages x
    page_size`` tokens shared by all slots (default: the old dense
    ``slots x max_len`` token capacity — equal KV bytes too for
    full-attention archs; windowed layers cost more under the default,
    see ``CacheSpec.from_config`` and ``memory_stats()``)."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 sync_interval: int = 8, min_bucket: int = 8,
                 buckets: Optional[List[int]] = None,
                 page_size: int = 8, num_pages: Optional[int] = None,
                 rules: Optional[sh.Rules] = None,
                 donate: Any = "auto"):
        if cfg.cross_attention:
            raise NotImplementedError(
                "Engine serves decoder-only archs; whisper uses "
                "examples/whisper_transcribe.py's direct loop")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        if temperature > 0.0:
            self.default_temp = float(temperature)
        else:
            self.default_temp = 0.0 if greedy else 1.0
        self.top_k = int(top_k)
        self.sync_interval = int(sync_interval)
        if buckets is None:
            b, buckets = min_bucket, []
            while b < _next_pow2(max_len):
                buckets.append(b)
                b *= 2
            buckets.append(b)
        self.buckets = sorted(set(int(b) for b in buckets))
        if donate == "auto":
            donate = jax.default_backend() != "cpu"
        self._donate = bool(donate)
        self._rules = rules

        self.spec = CacheSpec.from_config(cfg, slots, max_len,
                                          page_size=page_size,
                                          num_pages=num_pages)
        self.scheduler = Scheduler(self.spec)
        self.executor = Executor(cfg, self.spec, top_k=self.top_k,
                                 sync_interval=self.sync_interval,
                                 donate=self._donate, rules=rules)

        self._slot_req: List[Optional[Request]] = [None] * slots
        self._slot_first_tok: List[Optional[jax.Array]] = [None] * slots
        self.cache = self._empty_cache()
        self.state = sampling.make_slot_state(slots, seed)
        self._key = jax.random.PRNGKey(seed + 1)
        self.finished: List[Request] = []
        self.steps = 0
        self.host_syncs = 0

    # -------------------------------------------------------------- setup
    def _empty_cache(self):
        cache = self.spec.init_paged_cache()
        if self._rules is not None and self._rules.mesh is not None:
            shardings = self.spec.shardings(self._rules)
            cache = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                cache, shardings)
        return cache

    # ---------------------------------------------------------- telemetry
    @property
    def queue(self) -> List[Request]:
        return self.scheduler.queue

    @property
    def prefill_compiles(self) -> int:
        return self.executor.prefill_compiles

    @property
    def decode_compiles(self) -> int:
        return self.executor.decode_compiles

    def memory_stats(self) -> Dict[str, Any]:
        """Paged-cache memory telemetry (peak page occupancy + HBM bytes
        per live generated token at the current instant)."""
        live = sum(len(r.out_tokens) + len(r.prompt)
                   for r in self._slot_req if r is not None)
        stats = self.spec.memory_stats(self.scheduler.pages_in_use, live)
        stats["peak_pages_in_use"] = self.scheduler.peak_pages_in_use
        return stats

    # ------------------------------------------------------------ serving
    def submit(self, req: Request) -> None:
        # validate HERE, where the caller can handle it: raising mid-run()
        # would drop the request and strand in-flight slots
        if len(req.prompt) > self.max_len \
                and not self.cfg.supports_long_context:
            # full-attention page tables cap at max_len tokens; splicing a
            # longer prompt would silently mod-wrap it like a ring
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds "
                f"max_len={self.max_len} and {self.cfg.name} has "
                f"non-windowed attention; raise max_len")
        self.scheduler.submit(req)   # may raise PagePoolExhausted

    def bucket_for(self, plen: int) -> int:
        for b in self.buckets:
            if b >= plen:
                return b
        b = _next_pow2(max(plen, 1))
        self.buckets.append(b)   # keep the ≤ len(buckets) compile invariant
        self.buckets.sort()
        return b

    def warmup(self) -> None:
        """Pre-compile every prefill bucket, the splice, and the decode
        chunk so serving never pays a compile inside the hot loop.
        Semantically inert: admissions use the trash page table row and
        ``active=False``, and the PRNG key is restored afterwards, so
        seeded sampled runs are identical with or without warmup."""
        key_before = jnp.array(self.state["key"])   # copy: state is donated
        trash_row = jnp.full((self.spec.max_blocks,), self.spec.trash_page,
                             jnp.int32)
        for b in self.buckets:
            tokens = jnp.zeros((1, b), jnp.int32)
            length = jnp.zeros((1,), jnp.int32)
            key = jax.random.PRNGKey(0)
            temp = jnp.zeros((1,), jnp.float32)
            tok, one_cache = self.executor.prefill(
                self.params, tokens, length, key, temp)
            # active=False: compiles the splice without touching live slots
            self.cache, self.state = self.executor.admit(
                self.cache, self.state, one_cache, 0,
                jnp.int32(0), trash_row, tok[0], jnp.int32(0),
                jnp.int32(-1), jnp.float32(0.0), False)
        _, self.cache, self.state = self.executor.chunk(
            self.params, self.cache, self.state)
        # eviction splice: compiling it here keeps the first request
        # completion from paying a trace inside the serving loop (slot 0
        # is idle, so re-trashing its table row is inert)
        self.cache = self.executor.free_slot(self.cache, jnp.int32(0))
        self.state = dict(self.state, key=key_before)

    def _req_temp(self, req: Request) -> float:
        if req.temperature is not None:
            return float(req.temperature)
        return self.default_temp

    def _admit(self) -> None:
        free = [i for i in range(self.slots) if self._slot_req[i] is None]
        for slot, req, pages_row in self.scheduler.admissions(free):
            plen = len(req.prompt)
            bucket = self.bucket_for(plen)
            padded = list(req.prompt) + [0] * (bucket - plen)
            tokens = jnp.asarray([padded], jnp.int32)
            length = jnp.asarray([plen], jnp.int32)
            self._key, sub = jax.random.split(self._key)
            temp = jnp.asarray([self._req_temp(req)], jnp.float32)
            tok, one_cache = self.executor.prefill(
                self.params, tokens, length, sub, temp)
            eos = -1 if req.eos_id is None else int(req.eos_id)
            self.cache, self.state = self.executor.admit(
                self.cache, self.state, one_cache, slot,
                jnp.int32(plen), jnp.asarray(pages_row), tok[0],
                jnp.int32(req.max_new_tokens), jnp.int32(eos),
                jnp.float32(self._req_temp(req)), True)
            self._slot_req[slot] = req
            self._slot_first_tok[slot] = tok   # stays on device until drain

    def step_chunk(self) -> jax.Array:
        """Dispatch one fused decode chunk.  No host synchronization —
        safe to call under ``jax.transfer_guard_device_to_host``."""
        toks, self.cache, self.state = self.executor.chunk(
            self.params, self.cache, self.state)
        self.steps += self.sync_interval
        return toks

    def _drain(self, toks: jax.Array) -> None:
        """One batched device->host transfer: token history + slot state.
        Finished slots are evicted: pages return to the scheduler's free
        list and the slot's page-table row is pointed at the trash page,
        so its dead tail writes cannot touch re-leased pages."""
        toks_np, out_len, active, firsts = jax.device_get(
            (toks, self.state["out_len"], self.state["active"],
             [self._slot_first_tok[i] for i in range(self.slots)]))
        self.host_syncs += 1
        for slot in range(self.slots):
            req = self._slot_req[slot]
            if req is None:
                continue
            if not req.out_tokens:          # prefill-sampled first token
                req.out_tokens.append(int(firsts[slot][0]))
            k = int(out_len[slot]) - len(req.out_tokens)
            for i in range(k):
                req.out_tokens.append(int(toks_np[i, slot]))
            if not active[slot]:
                req.done = True
                self.finished.append(req)
                self._slot_req[slot] = None
                self._slot_first_tok[slot] = None
                self.scheduler.release(slot)
                self.cache = self.executor.free_slot(self.cache,
                                                     jnp.int32(slot))

    def _live(self) -> bool:
        return any(r is not None for r in self._slot_req)

    def step(self) -> None:
        """One admit + fused-chunk + drain round (``sync_interval`` decode
        steps per call)."""
        self._admit()
        if not self._live():
            if not self.scheduler.can_progress(0):
                head = self.queue[0]
                raise PagePoolExhausted(
                    f"wedged: rid={head.rid} cannot be admitted "
                    f"({self.scheduler.pool.free_pages} pages free) and no "
                    "slot is live to release more")
            return
        self._drain(self.step_chunk())

    def run(self, max_steps: int = 1000) -> List[Request]:
        while (self.queue or self._live()) and self.steps < max_steps:
            self.step()
        return self.finished
