"""Layered, shape-stable, sync-free serving runtime (paper §2.2.3, Fig. 14).

The paper's central measurement is that framework overhead — dispatch,
scheduling, synchronization, and memory management — dominates serving
once the math is tuned.  The runtime is split into three layers so each
overhead has exactly one owner:

* **Scheduler** (``serve/scheduler.Scheduler``) — host-side policy: FIFO
  queue, slot admission, per-group page-budget reservation, refcounted
  prefix sharing over a radix index, LRU prefix eviction.  Continuous
  batching: slots free and re-admit at chunk boundaries without
  recompiling anything.
* **Executor** (``Executor`` below) — the compiled layer: bucketed
  prefill (full and shared-prefix *suffix* variants), the page-granular
  admission splice, the copy-on-write page duplication, and the fused
  decode chunk (``sync_interval`` decode steps + on-device sampling +
  slot bookkeeping in ONE ``lax.scan`` executable, zero host<->device
  syncs inside).
* **Driver** (``Engine``) — glues them: one batched device->host token
  drain per chunk, finish reporting, admission application.

The decode cache is the refcounted block-paged subsystem from
``serve/cache.py``: attention KV lives in per-ring-width page pools with
independent budgets behind per-slot page tables (sliding-window layers
pay window-sized pools, capacity bounded by the page budgets, not
``slots x max_len``), while mamba2/rwkv6 recurrent state stays dense.
``CacheSpec`` carries logical sharding axes for every buffer, so a
``parallel/sharding.Rules`` table mapping ``BATCH``/``PAGES`` to the data
mesh axis serves multi-device via the existing ``launch/mesh.py``
machinery.

``ReferenceEngine`` in ``repro.serve.reference`` preserves the dense
per-token-sync loop as the measurement baseline and equivalence oracle for
``benchmarks/fig14_dispatch_overhead.py``.
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import forward_decode, forward_prefill
from repro.parallel import sharding as sh
from repro.serve import cache as cache_mod
from repro.serve import sampling
from repro.serve.cache import CacheSpec, empty_batch_cache  # noqa: F401
from repro.serve.scheduler import (Admission, PagePoolExhausted,  # noqa: F401
                                   Request, Scheduler)


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


class Executor:
    """Compiled serving layer: every function here is a jit with stable
    shapes (one executable per prefill bucket — plus one per (suffix
    bucket, ctx-block bucket) pair on the prefix-sharing path; exactly
    one decode chunk).  The cache and slot state are donated through the
    chunk and the splice on backends that implement donation (not CPU)."""

    def __init__(self, cfg: ModelConfig, spec: CacheSpec, *, top_k: int,
                 sync_interval: int, donate: bool,
                 rules: Optional[sh.Rules] = None,
                 paged_kernel: bool = False):
        self.cfg = cfg
        self.spec = spec
        self.top_k = int(top_k)
        self.sync_interval = int(sync_interval)
        self.paged_kernel = bool(paged_kernel)
        self._rules = rules
        self._prefill_fn = jax.jit(self._prefill_impl)
        # suffix prefill READS the live pools (shared-prefix gather), so
        # its cache argument is never donated
        self._suffix_fn = jax.jit(self._prefill_suffix_impl)
        if donate:
            self._admit_fn = jax.jit(self._admit_impl, donate_argnums=(0, 1))
            self._chunk_fn = jax.jit(self._chunk_impl, donate_argnums=(1, 2))
            self._free_fn = jax.jit(self._free_impl, donate_argnums=(0,))
            self._copy_fn = jax.jit(self._copy_impl, donate_argnums=(0,),
                                    static_argnums=(3,))
        else:
            self._admit_fn = jax.jit(self._admit_impl)
            self._chunk_fn = jax.jit(self._chunk_impl)
            self._free_fn = jax.jit(self._free_impl)
            self._copy_fn = jax.jit(self._copy_impl, static_argnums=(3,))

    def _ctx(self):
        """Sharding rules are a tracing-time thread-local; enter them for
        every compiled call so retraces see the same table."""
        if self._rules is None:
            return contextlib.nullcontext()
        return sh.axis_rules(self._rules)

    # ------------------------------------------------------ impls (traced)
    def _prefill_impl(self, params, tokens, length, key, temp):
        """Padded prefill + on-device first-token sampling.

        tokens [1, bucket], length [1].  One compile per bucket shape."""
        batch = {"tokens": tokens}
        if self.cfg.frontend:
            k = "frames" if self.cfg.family == "audio" else "frontend"
            batch[k] = jnp.zeros(
                (1, self.cfg.frontend_len, self.cfg.d_model), jnp.float32)
        logits, cache = forward_prefill(params, self.cfg, batch,
                                        length=length)
        tok = sampling.sample(logits, key, temperature=temp,
                              top_k=self.top_k)
        return tok, cache

    def _prefill_suffix_impl(self, params, tokens, length, off, ctx_row,
                             layer_pools, key, temp):
        """Shared-prefix suffix prefill: tokens [1, bucket] hold only the
        un-matched prompt tail at absolute positions ``off + i``; the
        matched prefix is attended through the pool pages named in
        ``ctx_row`` (the new slot's own table row — shared pages plus any
        copy-on-write duplicate) without being recomputed.  One compile
        per (suffix bucket, ctx-block bucket) shape pair."""
        ctx = {"off": off, "row": ctx_row, "layers": layer_pools}
        logits, cache = forward_prefill(params, self.cfg,
                                        {"tokens": tokens},
                                        length=length, ctx=ctx)
        tok = sampling.sample(logits, key, temperature=temp,
                              top_k=self.top_k)
        return tok, cache

    def _admit_impl(self, cache, state, one_cache, slot, start, plen,
                    rows, first_tok, max_new, eos, temp, active):
        """Jitted admission: page-granular splice of the (full or suffix)
        prefill cache into ``slot`` from token offset ``start``
        (serve/cache.admit_cache) + device-side bookkeeping init.  One
        compile per prefill bucket; everything else is traced."""
        new_cache = cache_mod.admit_cache(self.spec, cache, one_cache,
                                          slot, start, plen, rows)
        st = dict(state)
        st["tokens"] = state["tokens"].at[slot].set(first_tok)
        st["out_len"] = state["out_len"].at[slot].set(1)
        st["max_new"] = state["max_new"].at[slot].set(max_new)
        st["eos"] = state["eos"].at[slot].set(eos)
        st["temp"] = state["temp"].at[slot].set(temp)
        st["active"] = state["active"].at[slot].set(active)
        return new_cache, st

    def _chunk_impl(self, params, cache, state):
        """``sync_interval`` fused decode steps: forward (with paged KV
        lookup) + sample + slot bookkeeping, all on device.  Returns the
        [T, slots] token history (-1 where a slot was idle) — the only
        thing the host ever reads."""
        def body(carry, _):
            cache, state = carry
            # active as write mask: a finished slot's dead-tail steps must
            # not wrap KV writes into pages now shared with other slots
            # or the radix prefix index
            logits, cache = forward_decode(
                params, self.cfg, state["tokens"][:, None], cache,
                write_mask=state["active"],
                paged_kernel=self.paged_kernel)
            cache.pop("enc_kv", None)   # decoder-only: keep carry structure
            key, sub = jax.random.split(state["key"])
            nxt = sampling.sample(logits, sub, temperature=state["temp"],
                                  top_k=self.top_k)
            state, emitted = sampling.decode_update(state, nxt, key)
            return (cache, state), emitted

        (cache, state), toks = jax.lax.scan(
            body, (cache, state), None, length=self.sync_interval)
        return toks, cache, state

    def _free_impl(self, cache, slot):
        return cache_mod.free_slot_cache(self.spec, cache, slot)

    def _copy_impl(self, cache, src, dst, group_key):
        """Copy-on-write: duplicate page ``src`` into ``dst`` across the
        sharing group's layer pools before the owner slot writes."""
        return cache_mod.copy_shared_page(self.spec, cache, group_key,
                                          src, dst)

    # -------------------------------------------------------- public calls
    def prefill(self, params, tokens, length, key, temp):
        with self._ctx():
            return self._prefill_fn(params, tokens, length, key, temp)

    def prefill_suffix(self, params, tokens, length, off, ctx_row,
                       layer_pools, key, temp):
        with self._ctx():
            return self._suffix_fn(params, tokens, length, off, ctx_row,
                                   layer_pools, key, temp)

    def admit(self, cache, state, *args):
        with self._ctx():
            return self._admit_fn(cache, state, *args)

    def copy_page(self, cache, src, dst, group_key):
        with self._ctx():
            return self._copy_fn(cache, src, dst, group_key)

    def chunk(self, params, cache, state):
        with self._ctx():
            return self._chunk_fn(params, cache, state)

    def free_slot(self, cache, slot):
        with self._ctx():
            return self._free_fn(cache, slot)

    # ----------------------------------------------------------- telemetry
    @property
    def prefill_compiles(self) -> int:
        return self._prefill_fn._cache_size()

    @property
    def suffix_prefill_compiles(self) -> int:
        return self._suffix_fn._cache_size()

    @property
    def decode_compiles(self) -> int:
        return self._chunk_fn._cache_size()


class Engine:
    """Host driver: composes Scheduler (policy) + Executor (compiled) over
    the refcounted paged cache.  ``max_len`` is the *logical* per-slot
    token cap (the widest page-table width x page_size); physical
    capacity is per pool group — ``num_pages x page_size`` tokens for the
    widest (full-attention) group (default: the old dense ``slots x
    max_len`` token capacity), ``slots x window`` tokens for each
    sliding-window group (sized to the window, no flat-pool byte
    overhead).  ``prefix_sharing`` (on by default, auto-disabled for
    archs whose prefix state cannot live in pages) admits requests with a
    cached prompt prefix onto shared pages and prefillls only the
    suffix.  ``paged_kernel`` selects how decode attention reads the
    pools: ``True`` = pool-direct (``kernels/paged_attention``: Pallas
    page streaming on TPU, pool-wide masked attention elsewhere — the
    gather buffer never exists), ``False`` = gather-then-attend, and
    ``"auto"`` = kernel on a probe-passing TPU toolchain, gather
    elsewhere."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 sync_interval: int = 8, min_bucket: int = 8,
                 buckets: Optional[List[int]] = None,
                 page_size: int = 8, num_pages: Optional[int] = None,
                 prefix_sharing: bool = True,
                 paged_kernel: Any = "auto",
                 rules: Optional[sh.Rules] = None,
                 donate: Any = "auto"):
        if cfg.cross_attention:
            raise NotImplementedError(
                "Engine serves decoder-only archs; whisper uses "
                "examples/whisper_transcribe.py's direct loop")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        if temperature > 0.0:
            self.default_temp = float(temperature)
        else:
            self.default_temp = 0.0 if greedy else 1.0
        self.top_k = int(top_k)
        self.sync_interval = int(sync_interval)
        if buckets is None:
            b, buckets = min_bucket, []
            while b < _next_pow2(max_len):
                buckets.append(b)
                b *= 2
            buckets.append(b)
        self.buckets = sorted(set(int(b) for b in buckets))
        if donate == "auto":
            donate = jax.default_backend() != "cpu"
        self._donate = bool(donate)
        self._rules = rules

        self.spec = CacheSpec.from_config(cfg, slots, max_len,
                                          page_size=page_size,
                                          num_pages=num_pages)
        if paged_kernel == "auto":
            # pool-direct attention is the TPU hot path (compiled Pallas
            # kernel, gated on the runtime toolchain probe).  Off-TPU the
            # default stays gather-then-attend: at smoke scale XLA's
            # fused gather+softmax wins, and the pool-wide lowering only
            # pays off once the pool is oversubscribed — opt in with
            # paged_kernel=True (fig14 measures both).
            from repro.kernels import paged_attention as paged_ops
            paged_kernel = (self.spec.has_paged
                            and jax.default_backend() == "tpu"
                            and paged_ops.supported())
        self.paged_kernel = bool(paged_kernel) and self.spec.has_paged
        self.scheduler = Scheduler(self.spec, prefix_sharing=prefix_sharing)
        self.executor = Executor(cfg, self.spec, top_k=self.top_k,
                                 sync_interval=self.sync_interval,
                                 donate=self._donate, rules=rules,
                                 paged_kernel=self.paged_kernel)

        self._slot_req: List[Optional[Request]] = [None] * slots
        self._slot_first_tok: List[Optional[jax.Array]] = [None] * slots
        self.cache = self._empty_cache()
        self.state = sampling.make_slot_state(slots, seed)
        self._key = jax.random.PRNGKey(seed + 1)
        self.finished: List[Request] = []
        self.steps = 0
        self.host_syncs = 0

    # -------------------------------------------------------------- setup
    def _empty_cache(self):
        cache = self.spec.init_paged_cache()
        if self._rules is not None and self._rules.mesh is not None:
            shardings = self.spec.shardings(self._rules)
            cache = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                cache, shardings)
        return cache

    # ---------------------------------------------------------- telemetry
    @property
    def queue(self) -> List[Request]:
        return self.scheduler.queue

    @property
    def prefill_compiles(self) -> int:
        return self.executor.prefill_compiles

    @property
    def suffix_prefill_compiles(self) -> int:
        return self.executor.suffix_prefill_compiles

    @property
    def decode_compiles(self) -> int:
        return self.executor.decode_compiles

    def memory_stats(self) -> Dict[str, Any]:
        """Paged-cache memory telemetry (per-group page occupancy + HBM
        bytes per live generated token at the current instant)."""
        live = sum(len(r.out_tokens) + len(r.prompt)
                   for r in self._slot_req if r is not None)
        stats = self.spec.memory_stats(
            self.scheduler.pages_in_use_by_group, live)
        stats["peak_pages_in_use"] = self.scheduler.peak_pages_in_use
        return stats

    def prefix_stats(self) -> Dict[str, Any]:
        """Prefix-sharing telemetry (hit rate, skipped prefill tokens,
        shared-page attaches, CoW copies, radix evictions)."""
        return self.scheduler.prefix_stats()

    # ------------------------------------------------------------ serving
    def submit(self, req: Request) -> None:
        # validate HERE, where the caller can handle it: raising mid-run()
        # would drop the request and strand in-flight slots
        if len(req.prompt) + req.max_new_tokens > self.max_len \
                and not self.cfg.supports_long_context:
            # full-attention page tables cap at max_len tokens; a longer
            # prompt (or a generation budget running past the table)
            # would silently mod-wrap like a ring, overwriting the
            # oldest KV — including prefix pages other slots or the
            # radix index may reference
            raise ValueError(
                f"prompt length {len(req.prompt)} + max_new_tokens "
                f"{req.max_new_tokens} exceeds max_len={self.max_len} "
                f"and {self.cfg.name} has non-windowed attention; raise "
                "max_len or lower max_new_tokens")
        self.scheduler.submit(req)   # may raise PagePoolExhausted

    def bucket_for(self, plen: int) -> int:
        for b in self.buckets:
            if b >= plen:
                return b
        b = _next_pow2(max(plen, 1))
        self.buckets.append(b)   # keep the ≤ len(buckets) compile invariant
        self.buckets.sort()
        return b

    def _ctx_bucket(self, nblocks: int) -> int:
        """Pad the shared-prefix ctx gather to a power-of-two block count
        (capped at the sharing group's table width), so the suffix
        prefill compiles O(log^2) executables, not one per match."""
        ring = self.spec.group_of(self.scheduler.share_key).ring_blocks
        return min(_next_pow2(max(nblocks, 1)), ring)

    def warmup(self) -> None:
        """Pre-compile every prefill bucket, the splice, and the decode
        chunk so serving never pays a compile inside the hot loop.
        Semantically inert: admissions use trash page-table rows and
        ``active=False``, and the PRNG key is restored afterwards, so
        seeded sampled runs are identical with or without warmup.
        (Suffix-prefill executables still compile lazily on the first
        prefix hit per shape pair.)"""
        key_before = jnp.array(self.state["key"])   # copy: state is donated
        trash_rows = {g.key: jnp.full((g.ring_blocks,), g.trash_page,
                                      jnp.int32) for g in self.spec.groups}
        for b in self.buckets:
            tokens = jnp.zeros((1, b), jnp.int32)
            length = jnp.zeros((1,), jnp.int32)
            key = jax.random.PRNGKey(0)
            temp = jnp.zeros((1,), jnp.float32)
            tok, one_cache = self.executor.prefill(
                self.params, tokens, length, key, temp)
            # active=False: compiles the splice without touching live slots
            self.cache, self.state = self.executor.admit(
                self.cache, self.state, one_cache, 0,
                jnp.int32(0), jnp.int32(0), trash_rows, tok[0],
                jnp.int32(0), jnp.int32(-1), jnp.float32(0.0), False)
        _, self.cache, self.state = self.executor.chunk(
            self.params, self.cache, self.state)
        # eviction splice: compiling it here keeps the first request
        # completion from paying a trace inside the serving loop (slot 0
        # is idle, so re-trashing its table rows is inert)
        self.cache = self.executor.free_slot(self.cache, jnp.int32(0))
        self.state = dict(self.state, key=key_before)

    def _req_temp(self, req: Request) -> float:
        if req.temperature is not None:
            return float(req.temperature)
        return self.default_temp

    def _admit(self) -> None:
        free = [i for i in range(self.slots) if self._slot_req[i] is None]
        for adm in self.scheduler.admissions(free):
            req, slot = adm.req, adm.slot
            plen = len(req.prompt)
            self._key, sub = jax.random.split(self._key)
            temp = jnp.asarray([self._req_temp(req)], jnp.float32)
            if adm.cow is not None:
                # the slot will write into a shared page (partial-page
                # match, or last page of a fully-matched prompt): give it
                # a private copy BEFORE any prefill gather or splice
                _blk, src, dst = adm.cow
                self.cache = self.executor.copy_page(
                    self.cache, jnp.int32(src), jnp.int32(dst),
                    self.scheduler.share_key)
            s = adm.suffix_start
            if s > 0:
                # prefix hit: prefill only the un-matched suffix, reading
                # the matched prefix from the slot's (shared) pages
                gkey = self.scheduler.share_key
                suffix = list(req.prompt[s:])
                bucket = self.bucket_for(len(suffix))
                padded = suffix + [0] * (bucket - len(suffix))
                nctx = -(-s // self.spec.page_size)
                cb = self._ctx_bucket(nctx)
                trash = self.spec.group_of(gkey).trash_page
                ctx_row = np.full((cb,), trash, np.int32)
                ctx_row[:nctx] = adm.rows[gkey][:nctx]
                pools = [c if (c is not None and "pk" in c) else None
                         for c in self.cache["layers"]]
                tok, one_cache = self.executor.prefill_suffix(
                    self.params, jnp.asarray([padded], jnp.int32),
                    jnp.asarray([len(suffix)], jnp.int32), jnp.int32(s),
                    jnp.asarray(ctx_row), pools, sub, temp)
            else:
                bucket = self.bucket_for(plen)
                padded = list(req.prompt) + [0] * (bucket - plen)
                tok, one_cache = self.executor.prefill(
                    self.params, jnp.asarray([padded], jnp.int32),
                    jnp.asarray([plen], jnp.int32), sub, temp)
            eos = -1 if req.eos_id is None else int(req.eos_id)
            rows = {k: jnp.asarray(v) for k, v in adm.rows.items()}
            self.cache, self.state = self.executor.admit(
                self.cache, self.state, one_cache, slot,
                jnp.int32(s), jnp.int32(plen), rows, tok[0],
                jnp.int32(req.max_new_tokens), jnp.int32(eos),
                jnp.float32(self._req_temp(req)), True)
            self._slot_req[slot] = req
            self._slot_first_tok[slot] = tok   # stays on device until drain

    def step_chunk(self) -> jax.Array:
        """Dispatch one fused decode chunk.  No host synchronization —
        safe to call under ``jax.transfer_guard_device_to_host``."""
        toks, self.cache, self.state = self.executor.chunk(
            self.params, self.cache, self.state)
        self.steps += self.sync_interval
        return toks

    def _drain(self, toks: jax.Array) -> None:
        """One batched device->host transfer: token history + slot state.
        Finished slots are evicted: page refcounts drop in the scheduler
        (exclusive pages rejoin the free list; shared/radix-indexed pages
        survive for their other referents) and the slot's page-table rows
        are pointed at the trash pages, so its dead tail writes cannot
        touch re-leased pages."""
        toks_np, out_len, active, firsts = jax.device_get(
            (toks, self.state["out_len"], self.state["active"],
             [self._slot_first_tok[i] for i in range(self.slots)]))
        self.host_syncs += 1
        for slot in range(self.slots):
            req = self._slot_req[slot]
            if req is None:
                continue
            if not req.out_tokens:          # prefill-sampled first token
                req.out_tokens.append(int(firsts[slot][0]))
            k = int(out_len[slot]) - len(req.out_tokens)
            for i in range(k):
                req.out_tokens.append(int(toks_np[i, slot]))
            if not active[slot]:
                req.done = True
                self.finished.append(req)
                self._slot_req[slot] = None
                self._slot_first_tok[slot] = None
                self.scheduler.release(slot)
                self.cache = self.executor.free_slot(self.cache,
                                                     jnp.int32(slot))

    def _live(self) -> bool:
        return any(r is not None for r in self._slot_req)

    def step(self) -> None:
        """One admit + fused-chunk + drain round (``sync_interval`` decode
        steps per call)."""
        self._admit()
        if not self._live():
            if not self.scheduler.can_progress(0):
                head = self.queue[0]
                raise PagePoolExhausted(
                    f"wedged: rid={head.rid} cannot be admitted "
                    f"({self.scheduler.pool.free_pages} pages free) and no "
                    "slot is live to release more")
            return
        self._drain(self.step_chunk())

    def run(self, max_steps: int = 1000) -> List[Request]:
        while (self.queue or self._live()) and self.steps < max_steps:
            self.step()
        return self.finished
