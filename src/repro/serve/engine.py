"""Shape-stable, sync-free batched serving engine (paper §2.2.3, Fig. 14).

The paper's central measurement is that framework overhead — dispatch,
scheduling, synchronization — dominates serving once the math is tuned.
This engine removes all three from the steady-state decode loop:

* **Fused decode chunks.**  ``sync_interval`` decode steps (model forward +
  on-device sampling + per-slot EOS / max-token bookkeeping) are rolled
  into ONE compiled ``lax.scan`` computation: one dispatch per chunk, not
  per token, and zero host<->device synchronization inside it.  Tokens
  cross to the host as one batched ``[T, slots]`` transfer per chunk.
* **Shape stability.**  The decode state (token buffer, per-slot lengths,
  done flags, PRNG key) lives on device with fixed shapes, so exactly one
  decode executable is ever compiled (``decode_compiles == 1``).
* **Bucketed prefill.**  Prompts are right-padded to a power-of-two bucket
  and prefilled with a true-``length`` argument (see
  ``models/transformer.forward_prefill``), so mixed prompt lengths compile
  at most ``len(buckets)`` prefill executables instead of one per length.
* **Jitted splice.**  Admitting a request writes its prefill cache into a
  batch slot with a single compiled dynamic-update-slice (including the
  sliding-window ring-buffer gather), replacing the Python ``tree.map`` /
  ``.at[].set`` dispatch chain.
* **Donation.**  The batch cache and slot state are donated through the
  decode chunk and the splice (``donate_argnums``), so steady-state decode
  allocates no new cache buffers.  Donation is a no-op on CPU backends
  (JAX does not implement it there); ``donate="auto"`` enables it
  everywhere else.

``ReferenceEngine`` in ``repro.serve.reference`` preserves the old
per-token-sync loop as the measurement baseline for
``benchmarks/fig14_dispatch_overhead.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import cache_structure, forward_decode, forward_prefill
from repro.serve import sampling


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    temperature: Optional[float] = None   # None -> engine default
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def empty_batch_cache(cfg: ModelConfig, slots: int, max_len: int):
    """Zeroed slot-batched decode cache (shared with ReferenceEngine so
    the equivalence baseline can never diverge structurally)."""
    struct = cache_structure(cfg, slots, max_len)

    def is_leaf(x):
        return (isinstance(x, tuple) and len(x) == 2
                and isinstance(x[0], tuple))

    def mk(leaf):
        shp, _axes = leaf
        return jnp.zeros(shp, jnp.float32)

    cache = jax.tree.map(mk, struct, is_leaf=is_leaf)
    cache["len"] = jnp.zeros((slots,), jnp.int32)
    cache.pop("enc_kv", None)
    return cache


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 256, greedy: bool = True,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 sync_interval: int = 8, min_bucket: int = 8,
                 buckets: Optional[List[int]] = None,
                 donate: Any = "auto"):
        if cfg.cross_attention:
            raise NotImplementedError(
                "Engine serves decoder-only archs; whisper uses "
                "examples/whisper_transcribe.py's direct loop")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        if temperature > 0.0:
            self.default_temp = float(temperature)
        else:
            self.default_temp = 0.0 if greedy else 1.0
        self.top_k = int(top_k)
        self.sync_interval = int(sync_interval)
        if buckets is None:
            b, buckets = min_bucket, []
            while b < _next_pow2(max_len):
                buckets.append(b)
                b *= 2
            buckets.append(b)
        self.buckets = sorted(set(int(b) for b in buckets))
        if donate == "auto":
            donate = jax.default_backend() != "cpu"
        self._donate = bool(donate)

        self._prefill_fn = jax.jit(self._prefill_impl)
        # cache+state are donated through the decode chunk and the admit
        # splice; on CPU JAX has no donation so those stay plain jits.
        if self._donate:
            self._admit_fn = jax.jit(self._admit_impl, donate_argnums=(0, 1))
            self._chunk_fn = jax.jit(self._chunk_impl, donate_argnums=(1, 2))
        else:
            self._admit_fn = jax.jit(self._admit_impl)
            self._chunk_fn = jax.jit(self._chunk_impl)

        self._slot_req: List[Optional[Request]] = [None] * slots
        self._slot_first_tok: List[Optional[jax.Array]] = [None] * slots
        self.cache = self._empty_cache()
        self.state = sampling.make_slot_state(slots, seed)
        self._key = jax.random.PRNGKey(seed + 1)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.steps = 0
        self.host_syncs = 0

    # -------------------------------------------------------------- setup
    def _empty_cache(self):
        return empty_batch_cache(self.cfg, self.slots, self.max_len)

    # ------------------------------------------------------- compiled fns
    def _prefill_impl(self, params, tokens, length, key, temp):
        """Padded prefill + on-device first-token sampling.

        tokens [1, bucket], length [1].  One compile per bucket shape."""
        batch = {"tokens": tokens}
        if self.cfg.frontend:
            k = "frames" if self.cfg.family == "audio" else "frontend"
            batch[k] = jnp.zeros(
                (1, self.cfg.frontend_len, self.cfg.d_model), jnp.float32)
        logits, cache = forward_prefill(params, self.cfg, batch,
                                        length=length)
        tok = sampling.sample(logits, key, temperature=temp,
                              top_k=self.top_k)
        return tok, cache

    @staticmethod
    def _splice_leaf(big, small, slot, plen):
        """Write batch-1 prefill leaf ``small`` into row ``slot`` of the
        batch cache leaf ``big`` with one dynamic-update-slice.

        Attention KV leaves may disagree with the ring size R on the seq
        axis (-2).  ``small`` shorter than R is placed at its absolute
        positions (decode writes token t at slot t % R, and t < R here).
        ``small`` longer than R keeps, for each ring slot r, the *last
        valid* token t < plen with t ≡ r (mod R) — dtype-preserving and
        exact even when plen is 0, a multiple of R, or the window is
        exactly full (the old roll-based splice misplaced those)."""
        if big is None or small is None:
            return big
        if small.shape[1:] != big.shape[1:]:
            r_size, p_size = big.shape[-2], small.shape[-2]
            if p_size > r_size:
                r = jnp.arange(r_size)
                t = plen - 1 - ((plen - 1 - r) % r_size)
                t = jnp.clip(t, 0, p_size - 1)
                small = jnp.take(small, t, axis=-2)
            else:
                pad = [(0, 0)] * small.ndim
                pad[-2] = (0, r_size - p_size)
                small = jnp.pad(small, pad)
        return jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, axis=0)

    def _admit_impl(self, cache, state, one_cache, slot, plen, first_tok,
                    max_new, eos, temp, active):
        """Jitted admission: splice the prefill cache into ``slot`` and
        initialize its device-side bookkeeping.  One compile per prefill
        bucket (the one_cache seq dim); everything else is traced."""
        layers = jax.tree.map(
            lambda b, s: self._splice_leaf(b, s, slot, plen),
            cache["layers"], one_cache["layers"],
            is_leaf=lambda x: x is None)
        new_cache = dict(cache)
        new_cache["layers"] = layers
        new_cache["len"] = jax.lax.dynamic_update_slice_in_dim(
            cache["len"], plen[None].astype(jnp.int32), slot, axis=0)
        st = dict(state)
        st["tokens"] = state["tokens"].at[slot].set(first_tok)
        st["out_len"] = state["out_len"].at[slot].set(1)
        st["max_new"] = state["max_new"].at[slot].set(max_new)
        st["eos"] = state["eos"].at[slot].set(eos)
        st["temp"] = state["temp"].at[slot].set(temp)
        st["active"] = state["active"].at[slot].set(active)
        return new_cache, st

    def _chunk_impl(self, params, cache, state):
        """``sync_interval`` fused decode steps: forward + sample + slot
        bookkeeping, all on device.  Returns the [T, slots] token history
        (-1 where a slot was idle) — the only thing the host ever reads."""
        def body(carry, _):
            cache, state = carry
            logits, cache = forward_decode(
                params, self.cfg, state["tokens"][:, None], cache)
            cache.pop("enc_kv", None)   # decoder-only: keep carry structure
            key, sub = jax.random.split(state["key"])
            nxt = sampling.sample(logits, sub, temperature=state["temp"],
                                  top_k=self.top_k)
            state, emitted = sampling.decode_update(state, nxt, key)
            return (cache, state), emitted

        (cache, state), toks = jax.lax.scan(
            body, (cache, state), None, length=self.sync_interval)
        return toks, cache, state

    # ---------------------------------------------------------- telemetry
    @property
    def prefill_compiles(self) -> int:
        return self._prefill_fn._cache_size()

    @property
    def decode_compiles(self) -> int:
        return self._chunk_fn._cache_size()

    # ------------------------------------------------------------ serving
    def submit(self, req: Request) -> None:
        # validate HERE, where the caller can handle it: raising mid-run()
        # would drop the request and strand in-flight slots
        if len(req.prompt) > self.max_len \
                and not self.cfg.supports_long_context:
            # full-attention KV rows are capped at max_len; splicing a
            # longer prompt would silently mod-wrap it like a ring
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds "
                f"max_len={self.max_len} and {self.cfg.name} has "
                f"non-windowed attention; raise max_len")
        self.queue.append(req)

    def bucket_for(self, plen: int) -> int:
        for b in self.buckets:
            if b >= plen:
                return b
        b = _next_pow2(max(plen, 1))
        self.buckets.append(b)   # keep the ≤ len(buckets) compile invariant
        self.buckets.sort()
        return b

    def warmup(self) -> None:
        """Pre-compile every prefill bucket, the splice, and the decode
        chunk so serving never pays a compile inside the hot loop.
        Semantically inert: the PRNG key is restored afterwards, so seeded
        sampled runs are identical with or without warmup."""
        key_before = jnp.array(self.state["key"])   # copy: state is donated
        for b in self.buckets:
            tokens = jnp.zeros((1, b), jnp.int32)
            length = jnp.zeros((1,), jnp.int32)
            key = jax.random.PRNGKey(0)
            temp = jnp.zeros((1,), jnp.float32)
            tok, one_cache = self._prefill_fn(
                self.params, tokens, length, key, temp)
            # active=False: compiles the splice without touching live slots
            self.cache, self.state = self._admit_fn(
                self.cache, self.state, one_cache, 0, jnp.int32(0), tok[0],
                jnp.int32(0), jnp.int32(-1), jnp.float32(0.0), False)
        _, self.cache, self.state = self._chunk_fn(
            self.params, self.cache, self.state)
        self.state = dict(self.state, key=key_before)

    def _req_temp(self, req: Request) -> float:
        if req.temperature is not None:
            return float(req.temperature)
        return self.default_temp

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self._slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            plen = len(req.prompt)
            bucket = self.bucket_for(plen)
            padded = list(req.prompt) + [0] * (bucket - plen)
            tokens = jnp.asarray([padded], jnp.int32)
            length = jnp.asarray([plen], jnp.int32)
            self._key, sub = jax.random.split(self._key)
            temp = jnp.asarray([self._req_temp(req)], jnp.float32)
            tok, one_cache = self._prefill_fn(
                self.params, tokens, length, sub, temp)
            eos = -1 if req.eos_id is None else int(req.eos_id)
            self.cache, self.state = self._admit_fn(
                self.cache, self.state, one_cache, slot, jnp.int32(plen),
                tok[0], jnp.int32(req.max_new_tokens), jnp.int32(eos),
                jnp.float32(self._req_temp(req)), True)
            self._slot_req[slot] = req
            self._slot_first_tok[slot] = tok   # stays on device until drain

    def step_chunk(self) -> jax.Array:
        """Dispatch one fused decode chunk.  No host synchronization —
        safe to call under ``jax.transfer_guard_device_to_host``."""
        toks, self.cache, self.state = self._chunk_fn(
            self.params, self.cache, self.state)
        self.steps += self.sync_interval
        return toks

    def _drain(self, toks: jax.Array) -> None:
        """One batched device->host transfer: token history + slot state."""
        toks_np, out_len, active, firsts = jax.device_get(
            (toks, self.state["out_len"], self.state["active"],
             [self._slot_first_tok[i] for i in range(self.slots)]))
        self.host_syncs += 1
        for slot in range(self.slots):
            req = self._slot_req[slot]
            if req is None:
                continue
            if not req.out_tokens:          # prefill-sampled first token
                req.out_tokens.append(int(firsts[slot][0]))
            k = int(out_len[slot]) - len(req.out_tokens)
            for i in range(k):
                req.out_tokens.append(int(toks_np[i, slot]))
            if not active[slot]:
                req.done = True
                self.finished.append(req)
                self._slot_req[slot] = None
                self._slot_first_tok[slot] = None

    def _live(self) -> bool:
        return any(r is not None for r in self._slot_req)

    def step(self) -> None:
        """One admit + fused-chunk + drain round (``sync_interval`` decode
        steps per call)."""
        self._admit()
        if not self._live():
            return
        self._drain(self.step_chunk())

    def run(self, max_steps: int = 1000) -> List[Request]:
        while (self.queue or self._live()) and self.steps < max_steps:
            self.step()
        return self.finished
