"""Deterministic fault injection for the serving runtime (chaos layer).

Every recovery path the engine claims to have — admission backpressure,
preemption + radix re-admission, straggler detection, CoW/splice
degradation, speculative-drafter isolation — is only trustworthy if it
can be *driven* on demand.  ``ChaosMonkey`` is a seeded schedule of
injectable failure points the ``Engine`` consults at chunk boundaries
(never inside the compiled decode chunk, so the sync-free property is
untouched):

* **admission denial** (``p_deny_admission``) — a chunk boundary where
  every admission plan is treated as pool-exhausted, exercising queue
  backpressure.  Only applied while at least one slot is live, so denial
  can delay but never deadlock.
* **preemption storm** (``p_preempt``) — a live slot is forcibly
  preempted (pages released, prompt pages preserved in the radix index,
  request requeued) even without pool pressure.
* **slot stall** (``p_stall``) — the host drain *ignores* a slot, as if
  its worker stopped reporting.  The stall persists until the engine's
  watchdog notices the lack of progress and preempts the slot; tokens
  emitted while stalled are lost and regenerated after resume, so output
  stays token-identical at temperature 0.
* **sharing fault** (``p_sharing_fault``) — an admission plan is built
  without prefix sharing, the graceful-degradation path a real
  copy-on-write / splice failure takes (exclusive pages, full prefill,
  identical tokens).
* **garbage drafter** (``garbage_drafter=True``) — wraps the speculative
  drafter in ``GarbageDrafter``, which proposes constant nonsense
  tokens.  Rejection sampling (``serve/sampling.spec_accept``) keeps the
  committed output token-identical regardless; only the acceptance rate
  collapses — the fault stays isolated to throughput.

All draws come from one ``numpy`` generator seeded at construction, so a
given (seed, workload) pair replays the exact same fault schedule."""

from __future__ import annotations

from typing import Dict, List, Optional, Set

import numpy as np


class ChaosMonkey:
    """Seeded fault schedule the Engine consults at chunk boundaries."""

    def __init__(self, seed: int = 0, *, p_deny_admission: float = 0.0,
                 p_preempt: float = 0.0, p_stall: float = 0.0,
                 p_sharing_fault: float = 0.0,
                 garbage_drafter: bool = False,
                 max_faults: Optional[int] = None):
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self.p_deny_admission = float(p_deny_admission)
        self.p_preempt = float(p_preempt)
        self.p_stall = float(p_stall)
        self.p_sharing_fault = float(p_sharing_fault)
        self.garbage_drafter = bool(garbage_drafter)
        self.max_faults = max_faults
        self._stalled: Set[int] = set()
        # Optional observer hook: the Engine points this at its tracer
        # so every injected fault lands in the request-lifecycle trace
        # (repro.serve.trace).  Called host-side at chunk boundaries
        # only, with (fault_name, **attrs); None disables it.
        self.on_event = None
        self.counters: Dict[str, int] = {
            "admission_denials": 0,
            "forced_preemptions": 0,
            "stalls_started": 0,
            "stalled_drains": 0,
            "sharing_faults": 0,
        }

    @classmethod
    def smoke(cls, seed: int = 0) -> "ChaosMonkey":
        """The CI smoke preset: every failure point enabled at moderate
        rates — enough that a short serve run hits each path, not so
        much that nothing finishes."""
        return cls(seed, p_deny_admission=0.15, p_preempt=0.10,
                   p_stall=0.05, p_sharing_fault=0.25)

    # ------------------------------------------------------------- draws
    def _fire(self, p: float) -> bool:
        if p <= 0.0:
            return False
        if self.max_faults is not None \
                and sum(self.counters.values()) >= self.max_faults:
            return False
        return bool(self._rng.random() < p)

    def _emit(self, fault: str, **attrs) -> None:
        if self.on_event is not None:
            self.on_event(fault, **attrs)

    def deny_admission(self) -> bool:
        """One boundary's admissions are refused (simulated pool
        exhaustion at admission time)."""
        if self._fire(self.p_deny_admission):
            self.counters["admission_denials"] += 1
            self._emit("admission_denial")
            return True
        return False

    def storm_victims(self, live_slots: List[int]) -> List[int]:
        """Slots to forcibly preempt this boundary (at most one)."""
        if live_slots and self._fire(self.p_preempt):
            self.counters["forced_preemptions"] += 1
            victim = int(self._rng.choice(live_slots))
            self._emit("forced_preemption", slot=victim)
            return [victim]
        return []

    def tick(self, live_slots: List[int]) -> None:
        """Per-boundary bookkeeping: maybe pick a new stall victim.  A
        stall persists until the watchdog preempts the slot (the engine
        calls ``clear_stall``), so the only exit is the recovery path."""
        fresh = [s for s in live_slots if s not in self._stalled]
        if fresh and self._fire(self.p_stall):
            victim = int(self._rng.choice(fresh))
            self._stalled.add(victim)
            self.counters["stalls_started"] += 1
            self._emit("stall_started", slot=victim)

    def stalled(self, slot: int) -> bool:
        """True while the drain must pretend ``slot`` reported nothing."""
        if slot in self._stalled:
            self.counters["stalled_drains"] += 1
            return True
        return False

    def clear_stall(self, slot: int) -> None:
        self._stalled.discard(slot)

    def sharing_fault(self) -> bool:
        """Degrade this admission plan to exclusive pages (simulated
        CoW/splice failure)."""
        if self._fire(self.p_sharing_fault):
            self.counters["sharing_faults"] += 1
            self._emit("sharing_fault")
            return True
        return False

    # --------------------------------------------------------- telemetry
    def stats(self) -> Dict[str, int]:
        return dict(self.counters, seed=self.seed)


class GarbageDrafter:
    """Drafter wrapper proposing constant nonsense tokens.

    The speculative contract makes this safe by construction: the drafts
    are deterministic, so their proposal distribution is a point mass
    (``qprobs=None``) and rejection sampling accepts a garbage token
    only when the target model would have emitted it anyway.  Output is
    token-identical to the unwrapped engine; the acceptance rate is what
    collapses — which is exactly the isolation property the chaos test
    asserts."""

    def __init__(self, inner, token: int = 7):
        self._inner = inner
        self.token = int(token)

    # engine branches on these — forward them to the wrapped drafter
    @property
    def kind(self) -> str:
        return self._inner.kind

    @property
    def k(self) -> int:
        return self._inner.k

    @property
    def cfg(self):
        return self._inner.cfg

    def init_cache(self, slots: int):
        return self._inner.init_cache(slots)

    def propose(self, draft_params, cache, state, key, top_k):
        import jax.numpy as jnp
        slots = state["tokens"].shape[0]
        drafts = jnp.full((slots, self.k), self.token, jnp.int32)
        return drafts, None, cache
