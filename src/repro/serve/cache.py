"""Unified decode-cache subsystem: CacheSpec + refcounted block-paged pools.

Before this module, every serving slot preallocated a dense ``max_len`` KV
row per attention layer (``models/transformer.cache_structure``), so total
capacity was ``slots x max_len`` tokens no matter how long the actual
sequences were — the static-worst-case allocation the paper flags as a
naive-setting trap (§2.2.3).  ``CacheSpec`` replaces that plumbing with a
per-layer *kind* derived from ``ModelConfig``:

* ``PAGED_KV`` (attention / zamba2 shared-attention layers): keys and
  values live in block-paged pools.  Layers are grouped into **pool
  groups** by their logical ring width (``ceil(min(max_len, window) /
  page_size)`` pages); each group owns an independent pool
  ``[group.num_pages + 1, page_size, kv_heads, head_dim]``, an
  independent page budget, and an independent per-slot page table
  ``[slots, ring_blocks]``.  Sliding-window layers therefore size their
  pool to the *window* (``slots x ring_blocks`` pages) instead of the
  shared ``num_pages`` budget — the per-layer page-id remapping that
  removes the old flat-pool byte overhead for windowed archs.  Windowed
  groups ring over their table (token ``t`` lives at ring index ``t %
  ring``), so one mapping serves full attention, sliding windows, and
  wrap-around.  The last pool row of each group is a **trash page**:
  unreserved table entries point at it, so a slot whose budget ran out
  (or that finished mid-chunk) writes garbage there instead of into a
  neighbour's pages.
* ``STATE`` (mamba2 / rwkv6 layers): O(1) recurrent state stays dense
  ``[slots, ...]`` exactly as before — paging constant-size state buys
  nothing.

Pages are **refcounted** (``serve/scheduler.PagePool``): a physical page
may back the same logical block of several slots at once (prefix sharing
across requests with a common prompt, indexed by the scheduler's radix
tree) and stays allocated until every table reference *and* the radix
index drop it.  A slot that would write into a shared page gets a private
copy first (``copy_shared_page`` — the jitted copy-on-write path); the
compiled decode chunk itself never needs to know, because the host
guarantees at admission time that every page a slot will write is
exclusively owned or trash.

Total tokens per slot are bounded by the widest group's page budget, not
a per-slot preallocation, which lifts the ``max_len`` ceiling: one
request can run past the old dense per-slot limit as long as pages are
free.

Physical page ids are allocated host-side (``serve/scheduler``) at
admission, so the fused decode chunk stays a single shape-stable
executable with zero host synchronization: the compiled code only ever
*indexes* the tables, never grows them.

Sharding: the spec carries logical axes for every buffer (slot-batched
state on ``sh.BATCH``, every group's page pool on ``sh.PAGES``), so a
``parallel/sharding.Rules`` table mapping both to the data mesh axis
shards the serving state over the data axis of ``launch/mesh.py`` meshes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, MAMBA2, RWKV6, SHARED_ATTN, ModelConfig
from repro.models import attention, mamba2, rwkv6
from repro.models.attention import page_group_key
from repro.parallel import sharding as sh

PAGED_KV = "paged_kv"    # block-paged KV ring (attention mixers)
STATE = "state"          # constant-size recurrent state (mamba2 / rwkv6)

# ---- pool precision: K/V pages may be stored 8-bit with per-page,
# per-kv-head fp32 scales in a parallel scale pool ("ks"/"vs"); every
# producer re-quantizes whole pages (attention.rmw_quantized_pages) and
# every consumer dequantizes in the attention path, so fp32 K/V never
# materializes at pool width.
KV_DTYPES = ("fp32", "int8", "fp8_e4m3")


def kv_dtype_supported(kv_dtype: str) -> bool:
    """Capability gate: can this jax build store pools in ``kv_dtype``?
    fp8 needs a toolchain with ``jnp.float8_e4m3fn``; engines fall back
    to fp32 pools when this is False."""
    if kv_dtype in ("fp32", "int8"):
        return True
    return kv_dtype == "fp8_e4m3" and hasattr(jnp, "float8_e4m3fn")


def kv_pool_dtype(kv_dtype: str):
    """jnp dtype the K/V pools are stored in for ``kv_dtype``."""
    if kv_dtype == "int8":
        return jnp.int8
    if kv_dtype == "fp8_e4m3":
        return jnp.float8_e4m3fn
    return jnp.float32


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class PoolGroup:
    """One independently-budgeted page pool shared by every paged layer
    with the same logical ring width."""

    key: str            # "ring{R}" — stable pytree key for tables/pools
    ring_blocks: int    # page-table width (pages per slot)
    num_pages: int      # pool budget (physical pages, excl. trash)
    windowed: bool      # True when every member layer is sliding-window

    @property
    def trash_page(self) -> int:
        """Physical id of this group's write-discard page."""
        return self.num_pages


@dataclasses.dataclass(frozen=True)
class LayerCacheSpec:
    """Cache layout of one decoder layer."""

    kind: str
    # PAGED_KV: logical ring width in pages (ceil(min(max_len, window)/P))
    ring_blocks: int = 0
    window: Optional[int] = None
    group: int = -1     # index into CacheSpec.groups
    # STATE: {name: (shape, logical_axes)} at batch == slots
    state: Optional[Dict[str, Tuple]] = None


@dataclasses.dataclass
class CacheSpec:
    """Shapes + logical sharding axes + kinds for a slot-batched decode
    cache, derived per-layer from ``ModelConfig``."""

    cfg: ModelConfig
    slots: int
    max_len: int          # logical per-slot token cap (widest table * P)
    page_size: int
    num_pages: int        # widest (full-attention) group's page budget
    layers: List[Optional[LayerCacheSpec]]
    groups: List[PoolGroup]
    # speculative draft length K: windowed rings carry K tokens of slack
    # so an in-flight verify step can never wrap a draft write onto a
    # token still inside an earlier query's window (serve/spec)
    spec_tokens: int = 0
    # pool storage precision: "fp32" | "int8" | "fp8_e4m3" (KV_DTYPES)
    kv_dtype: str = "fp32"

    # ------------------------------------------------------------ factory
    @classmethod
    def from_config(cls, cfg: ModelConfig, slots: int, max_len: int, *,
                    page_size: int = 8,
                    num_pages: Optional[int] = None,
                    spec_tokens: int = 0,
                    kv_dtype: str = "fp32") -> "CacheSpec":
        if cfg.cross_attention:
            raise ValueError(
                f"{cfg.name}: cross-attention cache structures (enc_kv) are "
                "not representable as slot-batched decode caches; the "
                "serving cache subsystem is decoder-only.  Whisper decodes "
                "via examples/whisper_transcribe.py's direct loop.")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if kv_dtype not in KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {KV_DTYPES}, got {kv_dtype!r}")
        if not kv_dtype_supported(kv_dtype):
            raise ValueError(
                f"kv_dtype={kv_dtype!r} is unsupported by this jax build "
                "(no jnp.float8_e4m3fn); gate on kv_dtype_supported() and "
                "fall back to fp32 pools")
        if page_size & (page_size - 1):
            # fail HERE with an actionable message: a non-power-of-two
            # page used to survive until the paged-attention kernel's
            # block spec tried to tile it and died inside Pallas at
            # trace time (and the bucketed splice assumed pow2 rings)
            raise ValueError(
                f"page_size must be a power of two (kernel block specs "
                f"tile pages into the VMEM grid), got {page_size}")
        layers: List[Optional[LayerCacheSpec]] = []
        for block in cfg.blocks:
            if block.mixer in (ATTN, SHARED_ATTN):
                cap = min(max_len, block.window or max_len)
                if block.window is not None and spec_tokens:
                    # speculative slack: a verify step writes up to K
                    # drafted tokens past the newest committed one; the
                    # ring must keep window + K tokens so those writes
                    # never clobber in-window history (capped at max_len
                    # — a ring that large never wraps within budget and
                    # overlong draft writes are trash-redirected instead)
                    cap = min(max_len, block.window + spec_tokens)
                if page_size > cap:
                    raise ValueError(
                        f"page_size={page_size} exceeds a paged layer's "
                        f"ring width {cap} (min(max_len={max_len}, "
                        f"window={block.window})): one page would span "
                        "more tokens than the layer can ever hold and "
                        "the kernel block spec cannot tile it; lower "
                        "page_size or raise max_len")
                layers.append(LayerCacheSpec(
                    PAGED_KV, ring_blocks=_ceil_div(cap, page_size),
                    window=block.window))
            elif block.mixer == MAMBA2:
                layers.append(LayerCacheSpec(
                    STATE, state=mamba2.state_shapes(cfg, slots)))
            elif block.mixer == RWKV6:
                layers.append(LayerCacheSpec(
                    STATE, state=rwkv6.state_shapes(cfg, slots)))
            else:  # pragma: no cover - config validation forbids this
                raise ValueError(block.mixer)
        # ---- pool groups: one per distinct ring width.  The widest group
        # takes the shared ``num_pages`` budget knob (default: the old
        # dense layout's slots x max_len token capacity); every narrower
        # (windowed) group is sized to its window — slots x ring pages —
        # because a slot can never reference more than ``ring`` pages of
        # it.  This is what removes the old flat-pool byte overhead for
        # sliding-window layers.
        rings = sorted({ls.ring_blocks for ls in layers
                        if ls is not None and ls.kind == PAGED_KV})
        widest = rings[-1] if rings else 1
        if num_pages is None:
            num_pages = slots * widest
        groups: List[PoolGroup] = []
        for r in rings:
            windowed = all(ls.window is not None for ls in layers
                           if ls is not None and ls.kind == PAGED_KV
                           and ls.ring_blocks == r)
            budget = num_pages if r == widest else slots * r
            groups.append(PoolGroup(key=page_group_key(r), ring_blocks=r,
                                    num_pages=budget, windowed=windowed))
        gidx = {g.ring_blocks: i for i, g in enumerate(groups)}
        layers = [dataclasses.replace(ls, group=gidx[ls.ring_blocks])
                  if ls is not None and ls.kind == PAGED_KV else ls
                  for ls in layers]
        spec = cls(cfg=cfg, slots=slots, max_len=max_len,
                   page_size=page_size, num_pages=num_pages, layers=layers,
                   groups=groups, spec_tokens=spec_tokens,
                   kv_dtype=kv_dtype)
        # the compiled decode path re-derives each layer's ring width from
        # (window, widest table width, page size, spec slack) — attention.
        # paged_ring_blocks.  Verify the two formulas agree HERE so any
        # future layout change fails loudly at spec construction instead
        # of silently spliced and decoded with different ring widths
        # (wrong attention output).
        for block, ls in zip(cfg.blocks, spec.layers):
            if ls is not None and ls.kind == PAGED_KV:
                derived = attention.paged_ring_blocks(
                    block.window, spec.max_blocks, page_size, spec_tokens)
                assert derived == ls.ring_blocks, (
                    block.window, derived, ls.ring_blocks)
        return spec

    # --------------------------------------------------------- properties
    @property
    def has_paged(self) -> bool:
        return any(ls is not None and ls.kind == PAGED_KV
                   for ls in self.layers)

    @property
    def max_blocks(self) -> int:
        """Widest page-table: the widest logical ring of any paged layer."""
        widths = [ls.ring_blocks for ls in self.layers
                  if ls is not None and ls.kind == PAGED_KV]
        return max(widths) if widths else 1

    def group_of(self, key: str) -> PoolGroup:
        for g in self.groups:
            if g.key == key:
                return g
        raise KeyError(key)

    @property
    def widest_group(self) -> PoolGroup:
        return max(self.groups, key=lambda g: g.ring_blocks)

    @property
    def share_group_key(self) -> Optional[str]:
        """Pool group eligible for cross-request prefix sharing, or None.

        Sharing reuses prompt-prefix KV pages across slots, which is only
        sound when (a) every layer's prefix state lives in pages (no
        recurrent STATE layers — their prefix state is a dense per-slot
        tensor) and (b) no layer ever ring-wraps a decode write back into
        a prefix page (no sliding windows), and (c) token KV depends only
        on the token prefix (no modality frontend prefix, no zamba2
        shared-block h0 concat).  Under those conditions there is exactly
        one pool group and prompt pages are immutable once prefilled."""
        if not self.has_paged or self.cfg.frontend \
                or self.cfg.num_shared_groups:
            return None
        for ls in self.layers:
            if ls is None or ls.kind != PAGED_KV or ls.window is not None:
                return None
        assert len(self.groups) == 1
        return self.groups[0].key

    @property
    def prefix_sharing_capable(self) -> bool:
        return self.share_group_key is not None

    @property
    def trash_page(self) -> int:
        """Physical id of the widest group's write-discard page."""
        return self.widest_group.trash_page

    @property
    def quantized(self) -> bool:
        """True when K/V pages are stored 8-bit with a parallel scale pool."""
        return self.kv_dtype != "fp32"

    @property
    def pool_dtype(self):
        return kv_pool_dtype(self.kv_dtype)

    @property
    def kv_dtype_bytes(self) -> int:
        """Bytes per stored pool element (scales accounted separately)."""
        return 1 if self.quantized else 4

    def pool_shape_for(self, group: PoolGroup) -> Tuple[int, int, int, int]:
        return (group.num_pages + 1, self.page_size,
                self.cfg.num_kv_heads, self.cfg.resolved_head_dim)

    def scale_shape_for(self, group: PoolGroup) -> Tuple[int, int]:
        """Per-page, per-kv-head scale pool parallel to the page pool."""
        return (group.num_pages + 1, self.cfg.num_kv_heads)

    @property
    def pool_shape(self) -> Tuple[int, int, int, int]:
        return self.pool_shape_for(self.widest_group)

    POOL_AXES = (sh.PAGES, None, None, None)
    SCALE_AXES = (sh.PAGES, None)
    TABLE_AXES = (sh.BATCH, None)

    def blocks_needed(self, plen: int, max_new: int) -> Dict[str, int]:
        """Worst-case page-table entries a request ever touches, per pool
        group: tokens 0..plen+max_new-1, ring-wrapped at each group's
        table width.  Reserving this up-front at admission makes mid-run
        pool exhaustion impossible for admitted requests."""
        if not self.has_paged:
            return {}
        total = max(plen + max_new, 1)
        blocks = _ceil_div(total, self.page_size)
        return {g.key: min(blocks, g.ring_blocks) for g in self.groups}

    # -------------------------------------------------------------- inits
    def init_paged_cache(self, dtype=jnp.float32) -> Dict[str, Any]:
        """Zeroed paged decode cache.  Page-table entries start at each
        group's trash page, so an unadmitted slot's decode writes are
        discarded.  Quantized specs store the pools in ``pool_dtype`` and
        add per-page scale pools "ks"/"vs" (fp32; ``dtype`` still governs
        the dense STATE leaves)."""
        pool_dt = self.pool_dtype if self.quantized else dtype
        layer_caches: List[Optional[Dict]] = []
        for ls in self.layers:
            if ls is None:
                layer_caches.append(None)
            elif ls.kind == PAGED_KV:
                group = self.groups[ls.group]
                shape = self.pool_shape_for(group)
                entry = {
                    "pk": jnp.zeros(shape, pool_dt),
                    "pv": jnp.zeros(shape, pool_dt),
                }
                if self.quantized:
                    sshape = self.scale_shape_for(group)
                    # scale floor, not zero: an unwritten page dequantizes
                    # to exact zeros and never divides by zero on RMW
                    entry["ks"] = jnp.full(sshape, 1e-30, jnp.float32)
                    entry["vs"] = jnp.full(sshape, 1e-30, jnp.float32)
                layer_caches.append(entry)
            else:
                layer_caches.append({
                    k: jnp.zeros(shp, dtype)
                    for k, (shp, _axes) in ls.state.items()})
        return {
            "layers": layer_caches,
            "page_tables": {
                g.key: jnp.full((self.slots, g.ring_blocks),
                                g.trash_page, jnp.int32)
                for g in self.groups} if self.has_paged else {},
            "len": jnp.zeros((self.slots,), jnp.int32),
        }

    def init_dense_cache(self, dtype=jnp.float32) -> Dict[str, Any]:
        """Zeroed dense (pre-paging) cache: one ``max_len``-or-ring row per
        slot per attention layer.  Kept for ``ReferenceEngine`` so the
        equivalence oracle can never diverge structurally."""
        layer_caches: List[Optional[Dict]] = []
        for block, ls in zip(self.cfg.blocks, self.layers):
            if ls is None:
                layer_caches.append(None)
            elif ls.kind == PAGED_KV:
                shape, _axes = attention.init_cache_shape(
                    self.cfg, self.slots,
                    min(self.max_len, block.window or self.max_len))
                layer_caches.append({"k": jnp.zeros(shape, dtype),
                                     "v": jnp.zeros(shape, dtype)})
            else:
                layer_caches.append({
                    k: jnp.zeros(shp, dtype)
                    for k, (shp, _axes) in ls.state.items()})
        return {"layers": layer_caches,
                "len": jnp.zeros((self.slots,), jnp.int32)}

    # ---------------------------------------------------------- structure
    def structure(self) -> Dict[str, Any]:
        """Nested ``{name: (shape, logical_axes)}`` mirroring the paged
        runtime cache — the paged analogue of
        ``models/transformer.cache_structure``."""
        per_layer: List[Optional[Dict]] = []
        for ls in self.layers:
            if ls is None:
                per_layer.append(None)
            elif ls.kind == PAGED_KV:
                group = self.groups[ls.group]
                shape = self.pool_shape_for(group)
                entry = {"pk": (shape, self.POOL_AXES),
                         "pv": (shape, self.POOL_AXES)}
                if self.quantized:
                    sshape = self.scale_shape_for(group)
                    entry["ks"] = (sshape, self.SCALE_AXES)
                    entry["vs"] = (sshape, self.SCALE_AXES)
                per_layer.append(entry)
            else:
                per_layer.append(dict(ls.state))
        return {
            "layers": per_layer,
            "page_tables": {
                g.key: ((self.slots, g.ring_blocks), self.TABLE_AXES)
                for g in self.groups} if self.has_paged else {},
            "len": ((self.slots,), (sh.BATCH,)),
        }

    def shardings(self, rules: sh.Rules) -> Any:
        """NamedShardings (or None without a mesh) for the paged cache."""
        def is_leaf(x):
            return (isinstance(x, tuple) and len(x) == 2
                    and isinstance(x[0], tuple))

        return jax.tree.map(
            lambda leaf: rules.sharding_for(leaf[1], leaf[0]),
            self.structure(), is_leaf=is_leaf)

    # ------------------------------------------------------- memory stats
    def group_page_bytes(self, group: PoolGroup,
                         dtype_bytes: Optional[int] = None) -> int:
        """HBM bytes one physical page of ``group`` costs across every
        member layer (each page id backs a K and a V block per layer).
        Defaults to the spec's own pool precision; quantized pools also
        pay the per-page fp32 scale rows (one per kv head, K and V)."""
        if dtype_bytes is None:
            dtype_bytes = self.kv_dtype_bytes
        n = sum(1 for ls in self.layers
                if ls is not None and ls.kind == PAGED_KV
                and self.groups[ls.group] is group)
        per_layer = (2 * self.page_size * self.cfg.num_kv_heads
                     * self.cfg.resolved_head_dim * dtype_bytes)
        if self.quantized and dtype_bytes == self.kv_dtype_bytes:
            per_layer += 2 * self.cfg.num_kv_heads * 4   # ks/vs scale rows
        return n * per_layer

    def dense_kv_bytes(self, dtype_bytes: int = 4) -> int:
        """What the old dense layout preallocated for attention KV."""
        total = 0
        for block, ls in zip(self.cfg.blocks, self.layers):
            if ls is None or ls.kind != PAGED_KV:
                continue
            ring = min(self.max_len, block.window or self.max_len)
            total += (2 * self.slots * ring * self.cfg.num_kv_heads
                      * self.cfg.resolved_head_dim * dtype_bytes)
        return total

    def paged_kv_bytes(self, dtype_bytes: Optional[int] = None) -> int:
        return sum(g.num_pages * self.group_page_bytes(g, dtype_bytes)
                   for g in self.groups)

    def total_pages(self) -> int:
        return sum(g.num_pages for g in self.groups)

    def memory_stats(self, pages_in_use: Dict[str, int],
                     live_tokens: int) -> Dict[str, Any]:
        """Paged-cache memory telemetry for the BENCH_serve.json schema.

        ``pages_in_use`` maps group key -> leased pages (``{}`` for
        stateless archs)."""
        in_use_bytes = sum(pages_in_use.get(g.key, 0)
                           * self.group_page_bytes(g) for g in self.groups)
        dense = self.dense_kv_bytes()
        paged = self.paged_kv_bytes()
        return {
            "page_size": self.page_size,
            "num_pages": self.total_pages(),
            "pages_in_use": sum(pages_in_use.values()),
            "kv_dtype": self.kv_dtype,
            "hbm_bytes_per_live_token": (
                in_use_bytes / live_tokens if live_tokens else 0.0),
            # the trajectory metric the quantized-pool capacity claim is
            # tracked by: leased pool bytes (at stored precision, scales
            # included) per live token
            "pool_bytes_per_live_token": (
                in_use_bytes / live_tokens if live_tokens else 0.0),
            "dense_vs_paged_capacity_ratio": (
                dense / paged if paged else 1.0),
            "paged_kv_bytes": paged,
            "dense_kv_bytes": dense,
            "pool_groups": {
                g.key: {
                    "ring_blocks": g.ring_blocks,
                    "num_pages": g.num_pages,
                    "windowed": g.windowed,
                    "pages_in_use": pages_in_use.get(g.key, 0),
                } for g in self.groups},
        }


# ---------------------------------------------------------------------------
# Jit-traceable cache ops (called inside the Executor's compiled functions)
# ---------------------------------------------------------------------------

def splice_paged_layer(pool_k: jax.Array, pool_v: jax.Array,
                       pre_k: jax.Array, pre_v: jax.Array,
                       pages_row: jax.Array, start: jax.Array,
                       valid_len: jax.Array, ring_blocks: int,
                       page_size: int, trash_page: int,
                       scale_k: Optional[jax.Array] = None,
                       scale_v: Optional[jax.Array] = None) -> Tuple:
    """Write a batch-1 prefill KV ``[1, Hkv, bucket, dh]`` into the pool
    as one token-granular scatter.

    Local token ``i`` holds global position ``g = start + i``; it lands at
    page ``pages_row[(g // P) % ring_blocks]``, offset ``g % P`` — the
    same write rule decode uses.  ``start`` is 0 for a full prefill and
    the prefix-match length for a suffix prefill (prefix sharing), and
    need not be page-aligned: the scatter touches exactly the written
    offsets, so a copy-on-write page keeps its earlier tokens.  Masked
    tokens are redirected to the trash page instead of merged: pad
    positions (``i >= valid_len``, bucketed prefill) and — for windowed
    rings that wrap *within* one prefill — every token that is not the
    newest occupant of its ring slot, which keeps the scatter free of
    conflicting valid writes.

    With ``scale_k``/``scale_v`` (quantized pools, [num_pages+1, Hkv])
    the splice becomes page-granular: tokens are grouped into the logical
    pages they touch, each touched page is dequantized, overlaid, and
    re-quantized with a fresh amax scale (partial-page copy-on-write
    keeps its earlier tokens through the read-modify-write), and a
    4-tuple ``(pool_k, pool_v, scale_k, scale_v)`` is returned."""
    k = jnp.swapaxes(pre_k[0], 0, 1)   # [bucket, Hkv, dh]
    v = jnp.swapaxes(pre_v[0], 0, 1)
    bucket = k.shape[0]
    idx = jnp.arange(bucket)
    g = start + idx
    keep = idx < valid_len
    ring = ring_blocks * page_size
    if bucket > ring:   # static: only wrap-capable shapes pay the mask
        keep &= g >= start + valid_len - ring
    off = g % page_size
    if scale_k is not None:
        # page-granular quantizing RMW (see attention.rmw_quantized_pages):
        # the bucket spans at most ceil((bucket-1)/P)+1 consecutive
        # logical pages starting at start's page
        J = (bucket - 1) // page_size + 2
        base = start // page_size
        jtok = g // page_size - base                    # [bucket] in [0, J)
        lp = base + jnp.arange(J)
        page_live = jnp.zeros((J,), bool).at[jtok].max(keep)
        if J > ring_blocks:
            # ring narrower than the span: of logical pages congruent mod
            # ring_blocks only the newest occupant may be written
            page_live &= jnp.arange(J) + ring_blocks >= J
        phys = jnp.where(page_live, pages_row[lp % ring_blocks], trash_page)
        wrote = jnp.zeros((J, page_size), bool).at[jtok, off].max(keep)
        shape = (J, page_size) + k.shape[1:]
        nk = jnp.zeros(shape, jnp.float32).at[jtok, off].set(
            k.astype(jnp.float32))
        nv = jnp.zeros(shape, jnp.float32).at[jtok, off].set(
            v.astype(jnp.float32))
        pool_k, scale_k = attention.rmw_quantized_pages(
            pool_k, scale_k, phys, nk, wrote)
        pool_v, scale_v = attention.rmw_quantized_pages(
            pool_v, scale_v, phys, nv, wrote)
        return pool_k, pool_v, scale_k, scale_v
    phys = jnp.where(keep, pages_row[(g // page_size) % ring_blocks],
                     trash_page)
    pool_k = pool_k.at[phys, off].set(k.astype(pool_k.dtype))
    pool_v = pool_v.at[phys, off].set(v.astype(pool_v.dtype))
    return pool_k, pool_v


def _splice_state_leaf(big: Optional[jax.Array], small: Optional[jax.Array],
                       slot: jax.Array) -> Optional[jax.Array]:
    """Write a batch-1 recurrent-state leaf into row ``slot``."""
    if big is None or small is None:
        return big
    return jax.lax.dynamic_update_slice_in_dim(
        big, small.astype(big.dtype), slot, axis=0)


def admit_cache(spec: CacheSpec, cache: Dict, one_cache: Dict,
                slot: jax.Array, start: jax.Array, plen: jax.Array,
                rows: Dict[str, jax.Array],
                enabled: Optional[jax.Array] = None) -> Dict:
    """Jit-traceable admission: splice a batch-1 prefill cache into
    ``slot`` starting at global token position ``start`` (0 for a full
    prefill; the prefix-match length for a suffix prefill whose first
    ``start`` tokens ride on shared pages) and install its page-table
    rows (one per pool group; reserved pages padded with the trash id, so
    writes past the reservation are discarded, never aliased into a
    neighbour's pages).  ``plen`` is the request's full logical prompt
    length — the slot's ``len`` after admission regardless of how much
    prefill was skipped.

    ``enabled`` (scalar bool, optional) no-ops the whole admission when
    False: pool writes are redirected to the trash pages (``valid_len``
    forced to 0) and the table/len/state updates keep their prior values.
    The batched multi-slot admission path uses it to pad a chunk
    boundary's admissions to a fixed count, so ONE splice executable
    serves any number of simultaneous admissions.  Extra cache keys (the
    speculative draft cache) pass through untouched."""
    valid = plen - start
    if enabled is not None:
        valid = jnp.where(enabled, valid, 0)
    new_layers: List[Optional[Dict]] = []
    for ls, big, small in zip(spec.layers, cache["layers"],
                              one_cache["layers"]):
        if ls is None:
            new_layers.append(big)
        elif ls.kind == PAGED_KV:
            group = spec.groups[ls.group]
            if "ks" in big:     # quantized pool: re-quantizing splice
                pk, pv, sk, sv = splice_paged_layer(
                    big["pk"], big["pv"], small["k"], small["v"],
                    rows[group.key], start, valid, ls.ring_blocks,
                    spec.page_size, group.trash_page,
                    scale_k=big["ks"], scale_v=big["vs"])
                new_layers.append({"pk": pk, "pv": pv, "ks": sk, "vs": sv})
            else:
                pk, pv = splice_paged_layer(
                    big["pk"], big["pv"], small["k"], small["v"],
                    rows[group.key], start, valid, ls.ring_blocks,
                    spec.page_size, group.trash_page)
                new_layers.append({"pk": pk, "pv": pv})
        else:
            entry = {}
            for k in big:
                small_k = small[k]
                if enabled is not None and big[k] is not None \
                        and small_k is not None:
                    cur = jax.lax.dynamic_slice_in_dim(big[k], slot, 1, 0)
                    small_k = jnp.where(enabled,
                                        small_k.astype(big[k].dtype), cur)
                entry[k] = _splice_state_leaf(big[k], small_k, slot)
            new_layers.append(entry)
    page_tables = {}
    for k in cache["page_tables"]:
        row = rows[k][None].astype(jnp.int32)
        if enabled is not None:
            cur = jax.lax.dynamic_slice(
                cache["page_tables"][k], (slot, 0), (1, row.shape[1]))
            row = jnp.where(enabled, row, cur)
        page_tables[k] = jax.lax.dynamic_update_slice(
            cache["page_tables"][k], row, (slot, 0))
    new_len = plen[None].astype(jnp.int32)
    if enabled is not None:
        cur = jax.lax.dynamic_slice_in_dim(cache["len"], slot, 1, 0)
        new_len = jnp.where(enabled, new_len, cur)
    length = jax.lax.dynamic_update_slice_in_dim(
        cache["len"], new_len, slot, axis=0)
    return dict(cache, layers=new_layers, page_tables=page_tables,
                len=length)


def install_slot_rows(spec: CacheSpec, cache: Dict, slot: jax.Array,
                      start: jax.Array, rows: Dict[str, jax.Array],
                      enabled: Optional[jax.Array] = None) -> Dict:
    """Jit-traceable table-only admission for fused chunked prefill:
    install ``slot``'s page-table rows and rewind its ``len`` to the
    prefill cursor ``start`` (0 for a fresh prompt; the shared-prefix or
    resume boundary otherwise).  No KV is spliced — the fused chunk step
    writes prompt KV through these rows itself — so this stays a cheap
    bookkeeping dispatch.  ``enabled`` masks a padding entry exactly as
    in :func:`admit_cache`."""
    page_tables = {}
    for k in cache["page_tables"]:
        row = rows[k][None].astype(jnp.int32)
        if enabled is not None:
            cur = jax.lax.dynamic_slice(
                cache["page_tables"][k], (slot, 0), (1, row.shape[1]))
            row = jnp.where(enabled, row, cur)
        page_tables[k] = jax.lax.dynamic_update_slice(
            cache["page_tables"][k], row, (slot, 0))
    new_len = start[None].astype(jnp.int32)
    if enabled is not None:
        cur = jax.lax.dynamic_slice_in_dim(cache["len"], slot, 1, 0)
        new_len = jnp.where(enabled, new_len, cur)
    length = jax.lax.dynamic_update_slice_in_dim(
        cache["len"], new_len, slot, axis=0)
    return dict(cache, page_tables=page_tables, len=length)


def copy_shared_page(spec: CacheSpec, cache: Dict, group_key: str,
                     src: jax.Array, dst: jax.Array) -> Dict:
    """Jit-traceable copy-on-write: duplicate physical page ``src`` into
    ``dst`` in every layer pool of ``group_key``.  The scheduler invokes
    this at admission for a slot about to write into a shared page (e.g.
    a partially-matched prefix page, or the final page of a fully-matched
    prompt); the slot's table then points at the private copy, so the
    compiled decode path never observes sharing."""
    new_layers: List[Optional[Dict]] = []
    for ls, big in zip(spec.layers, cache["layers"]):
        if (ls is not None and ls.kind == PAGED_KV
                and spec.groups[ls.group].key == group_key):
            entry = {
                "pk": big["pk"].at[dst].set(big["pk"][src]),
                "pv": big["pv"].at[dst].set(big["pv"][src]),
            }
            if "ks" in big:     # quantized pool: the copy carries scales
                entry["ks"] = big["ks"].at[dst].set(big["ks"][src])
                entry["vs"] = big["vs"].at[dst].set(big["vs"][src])
            new_layers.append(entry)
        else:
            new_layers.append(big)
    return dict(cache, layers=new_layers)


def free_slot_cache(spec: CacheSpec, cache: Dict, slot: jax.Array) -> Dict:
    """Jit-traceable eviction: point the freed slot's page-table rows at
    each group's trash page and zero its length.  Its physical pages go
    back to the host-side refcounted pools (``serve/scheduler``); after
    this update the idle slot's dead decode writes land on trash pages,
    so exclusively-owned pages can be re-leased immediately without
    corruption — and shared pages stay valid for their other referents."""
    page_tables = {}
    for g in spec.groups:
        row = jnp.full((1, g.ring_blocks), g.trash_page, jnp.int32)
        page_tables[g.key] = jax.lax.dynamic_update_slice(
            cache["page_tables"][g.key], row, (slot, 0))
    length = jax.lax.dynamic_update_slice_in_dim(
        cache["len"], jnp.zeros((1,), jnp.int32), slot, axis=0)
    return dict(cache, page_tables=page_tables, len=length)


def empty_batch_cache(cfg: ModelConfig, slots: int, max_len: int):
    """Zeroed dense slot-batched decode cache (``ReferenceEngine``'s
    layout).  Cross-attention structures are rejected by ``CacheSpec``
    construction with a clear error — previously this silently
    ``pop``-ed the ``enc_kv`` entry and served garbage cross-attention."""
    return CacheSpec.from_config(cfg, slots, max_len).init_dense_cache()
