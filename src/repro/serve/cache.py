"""Unified decode-cache subsystem: CacheSpec + block-paged KV pools.

Before this module, every serving slot preallocated a dense ``max_len`` KV
row per attention layer (``models/transformer.cache_structure``), so total
capacity was ``slots x max_len`` tokens no matter how long the actual
sequences were — the static-worst-case allocation the paper flags as a
naive-setting trap (§2.2.3).  ``CacheSpec`` replaces that plumbing with a
per-layer *kind* derived from ``ModelConfig``:

* ``PAGED_KV`` (attention / zamba2 shared-attention layers): keys and
  values live in a block-paged pool ``[num_pages + 1, page_size, kv_heads,
  head_dim]`` shared by all slots.  A per-slot **page table**
  ``[slots, max_blocks]`` maps logical blocks to physical pages; windowed
  layers ring over their first ``ceil(window / page_size)`` table entries
  (token ``t`` lives at ring index ``t % ring``), so one mapping serves
  full attention, sliding windows, and wrap-around.  The last pool row is
  a **trash page**: unreserved table entries point at it, so a slot whose
  budget ran out (or that finished mid-chunk) writes garbage there instead
  of into a neighbour's pages.
* ``STATE`` (mamba2 / rwkv6 layers): O(1) recurrent state stays dense
  ``[slots, ...]`` exactly as before — paging constant-size state buys
  nothing.

Total tokens per slot are bounded by the shared page budget (``num_pages x
page_size``), not a per-slot preallocation, which lifts the ``max_len``
ceiling: one request can run past the old dense per-slot limit as long as
pages are free.

Physical page ids are allocated host-side (``serve/scheduler.PagePool``)
at admission, so the fused decode chunk stays a single shape-stable
executable with zero host synchronization: the compiled code only ever
*indexes* the table, never grows it.

Sharding: the spec carries logical axes for every buffer (slot-batched
state on ``sh.BATCH``, the page pool on ``sh.PAGES``), so a
``parallel/sharding.Rules`` table mapping both to the data mesh axis
shards the serving state over the data axis of ``launch/mesh.py`` meshes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, MAMBA2, RWKV6, SHARED_ATTN, ModelConfig
from repro.models import attention, mamba2, rwkv6
from repro.parallel import sharding as sh

PAGED_KV = "paged_kv"    # block-paged KV ring (attention mixers)
STATE = "state"          # constant-size recurrent state (mamba2 / rwkv6)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class LayerCacheSpec:
    """Cache layout of one decoder layer."""

    kind: str
    # PAGED_KV: logical ring width in pages (ceil(min(max_len, window)/P))
    ring_blocks: int = 0
    window: Optional[int] = None
    # STATE: {name: (shape, logical_axes)} at batch == slots
    state: Optional[Dict[str, Tuple]] = None


@dataclasses.dataclass
class CacheSpec:
    """Shapes + logical sharding axes + kinds for a slot-batched decode
    cache, derived per-layer from ``ModelConfig``."""

    cfg: ModelConfig
    slots: int
    max_len: int          # logical per-slot token cap (page-table width * P)
    page_size: int
    num_pages: int
    layers: List[Optional[LayerCacheSpec]]

    # ------------------------------------------------------------ factory
    @classmethod
    def from_config(cls, cfg: ModelConfig, slots: int, max_len: int, *,
                    page_size: int = 8,
                    num_pages: Optional[int] = None) -> "CacheSpec":
        if cfg.cross_attention:
            raise ValueError(
                f"{cfg.name}: cross-attention cache structures (enc_kv) are "
                "not representable as slot-batched decode caches; the "
                "serving cache subsystem is decoder-only.  Whisper decodes "
                "via examples/whisper_transcribe.py's direct loop.")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if num_pages is None:
            # equal-token-capacity default: slots x max_len tokens, like
            # the old dense preallocation.  NOTE: every paged layer's pool
            # is sized to the shared page budget, so windowed layers (old
            # dense rows: `window` tokens) allocate MORE bytes than dense
            # under this default — `memory_stats()['dense_vs_paged_
            # capacity_ratio']` reports the truth (< 1.0 for windowed
            # archs); pass num_pages explicitly to trade capacity for
            # bytes.  Per-layer page-id remapping to reclaim the windowed
            # overhead is a ROADMAP follow-up.
            num_pages = slots * _ceil_div(max_len, page_size)
        layers: List[Optional[LayerCacheSpec]] = []
        for block in cfg.blocks:
            if block.mixer in (ATTN, SHARED_ATTN):
                cap = min(max_len, block.window or max_len)
                layers.append(LayerCacheSpec(
                    PAGED_KV, ring_blocks=_ceil_div(cap, page_size),
                    window=block.window))
            elif block.mixer == MAMBA2:
                layers.append(LayerCacheSpec(
                    STATE, state=mamba2.state_shapes(cfg, slots)))
            elif block.mixer == RWKV6:
                layers.append(LayerCacheSpec(
                    STATE, state=rwkv6.state_shapes(cfg, slots)))
            else:  # pragma: no cover - config validation forbids this
                raise ValueError(block.mixer)
        spec = cls(cfg=cfg, slots=slots, max_len=max_len,
                   page_size=page_size, num_pages=num_pages, layers=layers)
        # the compiled decode path re-derives each layer's ring width from
        # (window, table width, page size) — attention.paged_ring_blocks.
        # Verify the two formulas agree HERE so any future layout change
        # fails loudly at spec construction instead of silently spliced
        # and decoded with different ring widths (wrong attention output).
        for block, ls in zip(cfg.blocks, spec.layers):
            if ls is not None and ls.kind == PAGED_KV:
                derived = attention.paged_ring_blocks(
                    block.window, spec.max_blocks, page_size)
                assert derived == ls.ring_blocks, (
                    block.window, derived, ls.ring_blocks)
        return spec

    # --------------------------------------------------------- properties
    @property
    def has_paged(self) -> bool:
        return any(ls is not None and ls.kind == PAGED_KV
                   for ls in self.layers)

    @property
    def max_blocks(self) -> int:
        """Page-table width: the widest logical ring of any paged layer."""
        widths = [ls.ring_blocks for ls in self.layers
                  if ls is not None and ls.kind == PAGED_KV]
        return max(widths) if widths else 1

    @property
    def trash_page(self) -> int:
        """Physical id of the write-discard page (last pool row)."""
        return self.num_pages

    @property
    def pool_shape(self) -> Tuple[int, int, int, int]:
        return (self.num_pages + 1, self.page_size,
                self.cfg.num_kv_heads, self.cfg.resolved_head_dim)

    POOL_AXES = (sh.PAGES, None, None, None)
    TABLE_AXES = (sh.BATCH, None)

    def blocks_needed(self, plen: int, max_new: int) -> int:
        """Worst-case page-table entries a request ever touches: tokens
        0..plen+max_new-1, ring-wrapped at the table width.  Reserving this
        up-front at admission makes mid-run pool exhaustion impossible for
        admitted requests."""
        if not self.has_paged:
            return 0
        total = max(plen + max_new, 1)
        return min(_ceil_div(total, self.page_size), self.max_blocks)

    # -------------------------------------------------------------- inits
    def init_paged_cache(self, dtype=jnp.float32) -> Dict[str, Any]:
        """Zeroed paged decode cache.  Page-table entries start at the
        trash page, so an unadmitted slot's decode writes are discarded."""
        layer_caches: List[Optional[Dict]] = []
        for ls in self.layers:
            if ls is None:
                layer_caches.append(None)
            elif ls.kind == PAGED_KV:
                layer_caches.append({
                    "pk": jnp.zeros(self.pool_shape, dtype),
                    "pv": jnp.zeros(self.pool_shape, dtype),
                })
            else:
                layer_caches.append({
                    k: jnp.zeros(shp, dtype)
                    for k, (shp, _axes) in ls.state.items()})
        return {
            "layers": layer_caches,
            "page_table": jnp.full((self.slots, self.max_blocks),
                                   self.trash_page, jnp.int32),
            "len": jnp.zeros((self.slots,), jnp.int32),
        }

    def init_dense_cache(self, dtype=jnp.float32) -> Dict[str, Any]:
        """Zeroed dense (pre-paging) cache: one ``max_len``-or-ring row per
        slot per attention layer.  Kept for ``ReferenceEngine`` so the
        equivalence oracle can never diverge structurally."""
        layer_caches: List[Optional[Dict]] = []
        for block, ls in zip(self.cfg.blocks, self.layers):
            if ls is None:
                layer_caches.append(None)
            elif ls.kind == PAGED_KV:
                shape, _axes = attention.init_cache_shape(
                    self.cfg, self.slots,
                    min(self.max_len, block.window or self.max_len))
                layer_caches.append({"k": jnp.zeros(shape, dtype),
                                     "v": jnp.zeros(shape, dtype)})
            else:
                layer_caches.append({
                    k: jnp.zeros(shp, dtype)
                    for k, (shp, _axes) in ls.state.items()})
        return {"layers": layer_caches,
                "len": jnp.zeros((self.slots,), jnp.int32)}

    # ---------------------------------------------------------- structure
    def structure(self) -> Dict[str, Any]:
        """Nested ``{name: (shape, logical_axes)}`` mirroring the paged
        runtime cache — the paged analogue of
        ``models/transformer.cache_structure``."""
        per_layer: List[Optional[Dict]] = []
        for ls in self.layers:
            if ls is None:
                per_layer.append(None)
            elif ls.kind == PAGED_KV:
                per_layer.append({"pk": (self.pool_shape, self.POOL_AXES),
                                  "pv": (self.pool_shape, self.POOL_AXES)})
            else:
                per_layer.append(dict(ls.state))
        return {
            "layers": per_layer,
            "page_table": ((self.slots, self.max_blocks), self.TABLE_AXES),
            "len": ((self.slots,), (sh.BATCH,)),
        }

    def shardings(self, rules: sh.Rules) -> Any:
        """NamedShardings (or None without a mesh) for the paged cache."""
        def is_leaf(x):
            return (isinstance(x, tuple) and len(x) == 2
                    and isinstance(x[0], tuple))

        return jax.tree.map(
            lambda leaf: rules.sharding_for(leaf[1], leaf[0]),
            self.structure(), is_leaf=is_leaf)

    # ------------------------------------------------------- memory stats
    def page_bytes(self, dtype_bytes: int = 4) -> int:
        """HBM bytes one physical page costs across every paged layer
        (each page id backs a K and a V block in each paged layer)."""
        n_paged = sum(1 for ls in self.layers
                      if ls is not None and ls.kind == PAGED_KV)
        per_layer = (2 * self.page_size * self.cfg.num_kv_heads
                     * self.cfg.resolved_head_dim * dtype_bytes)
        return n_paged * per_layer

    def dense_kv_bytes(self, dtype_bytes: int = 4) -> int:
        """What the old dense layout preallocated for attention KV."""
        total = 0
        for block, ls in zip(self.cfg.blocks, self.layers):
            if ls is None or ls.kind != PAGED_KV:
                continue
            ring = min(self.max_len, block.window or self.max_len)
            total += (2 * self.slots * ring * self.cfg.num_kv_heads
                      * self.cfg.resolved_head_dim * dtype_bytes)
        return total

    def paged_kv_bytes(self, dtype_bytes: int = 4) -> int:
        return self.num_pages * self.page_bytes(dtype_bytes)

    def memory_stats(self, pages_in_use: int,
                     live_tokens: int) -> Dict[str, Any]:
        """Paged-cache memory telemetry for the BENCH_serve.json schema."""
        in_use_bytes = pages_in_use * self.page_bytes()
        dense = self.dense_kv_bytes()
        paged = self.paged_kv_bytes()
        return {
            "page_size": self.page_size,
            "num_pages": self.num_pages,
            "pages_in_use": pages_in_use,
            "hbm_bytes_per_live_token": (
                in_use_bytes / live_tokens if live_tokens else 0.0),
            "dense_vs_paged_capacity_ratio": (
                dense / paged if paged else 1.0),
            "paged_kv_bytes": paged,
            "dense_kv_bytes": dense,
        }


# ---------------------------------------------------------------------------
# Jit-traceable cache ops (called inside the Executor's compiled functions)
# ---------------------------------------------------------------------------

def splice_paged_layer(pool_k: jax.Array, pool_v: jax.Array,
                       pre_k: jax.Array, pre_v: jax.Array,
                       pages_row: jax.Array, plen: jax.Array,
                       ring_blocks: int, page_size: int
                       ) -> Tuple[jax.Array, jax.Array]:
    """Write a batch-1 prefill KV ``[1, Hkv, bucket, dh]`` into the pool,
    one page-granular read-modify-write per logical block.

    Token ``t`` lands at page ``pages_row[(t // P) % ring_blocks]``, offset
    ``t % P`` — i.e. ring index ``t % (ring_blocks * P)``, the same write
    rule decode uses.  Pad positions (``t >= plen``, bucketed prefill) are
    masked out of the merge, so they can neither clobber wrapped-around
    valid tokens nor leak garbage into pages another slot may later attend
    to.  The block loop is static (one compile per prefill bucket)."""
    k = jnp.swapaxes(pre_k[0], 0, 1)   # [bucket, Hkv, dh]
    v = jnp.swapaxes(pre_v[0], 0, 1)
    bucket = k.shape[0]
    nblocks = _ceil_div(bucket, page_size)
    pad = nblocks * page_size - bucket
    if pad:
        k = jnp.pad(k, ((0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0), (0, 0)))
    kb = k.reshape(nblocks, page_size, *k.shape[1:]).astype(pool_k.dtype)
    vb = v.reshape(nblocks, page_size, *v.shape[1:]).astype(pool_v.dtype)
    for j in range(nblocks):           # static: exact HLO, no dynamic loop
        dest = pages_row[j % ring_blocks]
        colmask = (j * page_size + jnp.arange(page_size)) < plen
        cm = colmask[:, None, None]
        pool_k = pool_k.at[dest].set(jnp.where(cm, kb[j], pool_k[dest]))
        pool_v = pool_v.at[dest].set(jnp.where(cm, vb[j], pool_v[dest]))
    return pool_k, pool_v


def _splice_state_leaf(big: Optional[jax.Array], small: Optional[jax.Array],
                       slot: jax.Array) -> Optional[jax.Array]:
    """Write a batch-1 recurrent-state leaf into row ``slot``."""
    if big is None or small is None:
        return big
    return jax.lax.dynamic_update_slice_in_dim(
        big, small.astype(big.dtype), slot, axis=0)


def admit_cache(spec: CacheSpec, cache: Dict, one_cache: Dict,
                slot: jax.Array, plen: jax.Array,
                pages_row: jax.Array) -> Dict:
    """Jit-traceable admission: splice a batch-1 prefill cache into
    ``slot`` and install its page-table row (reserved pages padded with
    the trash id, so writes past the reservation are discarded, never
    aliased into a neighbour's pages)."""
    new_layers: List[Optional[Dict]] = []
    for ls, big, small in zip(spec.layers, cache["layers"],
                              one_cache["layers"]):
        if ls is None:
            new_layers.append(big)
        elif ls.kind == PAGED_KV:
            pk, pv = splice_paged_layer(
                big["pk"], big["pv"], small["k"], small["v"],
                pages_row, plen, ls.ring_blocks, spec.page_size)
            new_layers.append({"pk": pk, "pv": pv})
        else:
            new_layers.append({
                k: _splice_state_leaf(big[k], small[k], slot)
                for k in big})
    page_table = jax.lax.dynamic_update_slice(
        cache["page_table"], pages_row[None].astype(jnp.int32), (slot, 0))
    length = jax.lax.dynamic_update_slice_in_dim(
        cache["len"], plen[None].astype(jnp.int32), slot, axis=0)
    return {"layers": new_layers, "page_table": page_table, "len": length}


def free_slot_cache(spec: CacheSpec, cache: Dict, slot: jax.Array) -> Dict:
    """Jit-traceable eviction: point the freed slot's page-table row at the
    trash page and zero its length.  Its physical pages go back to the
    host-side free list (``scheduler.PagePool``); after this update the
    idle slot's dead decode writes land on the trash page, so those pages
    can be re-leased immediately without corruption."""
    row = jnp.full((1, spec.max_blocks), spec.trash_page, jnp.int32)
    page_table = jax.lax.dynamic_update_slice(
        cache["page_table"], row, (slot, 0))
    length = jax.lax.dynamic_update_slice_in_dim(
        cache["len"], jnp.zeros((1,), jnp.int32), slot, axis=0)
    return dict(cache, page_table=page_table, len=length)


def empty_batch_cache(cfg: ModelConfig, slots: int, max_len: int):
    """Zeroed dense slot-batched decode cache (``ReferenceEngine``'s
    layout).  Cross-attention structures are rejected by ``CacheSpec``
    construction with a clear error — previously this silently
    ``pop``-ed the ``enc_kv`` entry and served garbage cross-attention."""
    return CacheSpec.from_config(cfg, slots, max_len).init_dense_cache()
