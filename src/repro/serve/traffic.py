"""Deterministic multi-tenant traffic harness: seeded arrival processes,
mixed length distributions, SLO-class mixes, and engine replay.

The paper's serving claim (and the fig04 scheduling study) is only
measurable under controlled load: latency percentiles from an
uncontrolled arrival process compare machines, not schedulers.  This
module generates the "millions of users" side of that experiment as a
**fully deterministic, replayable trace**:

* ``TrafficGenerator`` — seeded ``numpy`` RNG over two arrival
  processes: ``"poisson"`` (exponential interarrivals at ``rate``) and
  ``"bursty"`` (a 2-state Markov-modulated Poisson process: a calm
  state at ``rate`` and a burst state at ``rate * burst_ratio``, with
  seeded state transitions after every arrival).  Each arrival draws an
  SLO class from ``class_mix`` and its prompt/output lengths from that
  class's profile (interactive traffic is short-prompt/short-output,
  batch long/long by default) — same seed, same trace, byte for byte.
* ``VirtualClock`` — a counter the Engine uses as its injectable
  ``clock``; ``replay`` advances it by ``dt`` per chunk boundary, so
  TTFT/TPOT and every deadline decision are functions of the schedule
  alone.  Two replays of one trace produce identical
  ``fault_stats()`` / ``latency_stats()`` counters on any machine.
* ``replay`` — drives an ``Engine`` through a trace: submit every
  request whose arrival time has passed, step, tick.

``benchmarks/fig04_scheduling.py --slo-mix`` builds the gated
SLO-vs-FIFO comparison on top; ``repro.launch.serve --traffic
poisson:SEED`` is the CLI entry.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.scheduler import Request, SLO_CLASSES


@dataclasses.dataclass(frozen=True)
class ClassProfile:
    """Per-class length distributions (inclusive integer ranges) and
    optional explicit latency targets in clock units (None defers to the
    ``SLO_CLASSES`` defaults)."""

    prompt_len: Tuple[int, int]
    max_new: Tuple[int, int]
    ttft_target: Optional[float] = None
    tpot_target: Optional[float] = None


#: Default per-class shapes: interactive = chat turns (short prompt,
#: short completion), batch = document jobs (long/long), best_effort =
#: background filler.
DEFAULT_PROFILES: Dict[str, ClassProfile] = {
    "interactive": ClassProfile(prompt_len=(2, 10), max_new=(4, 10)),
    "batch": ClassProfile(prompt_len=(8, 24), max_new=(10, 24)),
    "best_effort": ClassProfile(prompt_len=(2, 16), max_new=(4, 16)),
}

DEFAULT_MIX: Dict[str, float] = {
    "interactive": 0.5, "batch": 0.3, "best_effort": 0.2,
}


@dataclasses.dataclass(frozen=True)
class TrafficRequest:
    """One trace entry — everything needed to rebuild the ``Request``."""

    rid: int
    arrival: float
    prompt: Tuple[int, ...]
    max_new_tokens: int
    slo_class: str
    ttft_target: Optional[float] = None
    tpot_target: Optional[float] = None

    def to_request(self) -> Request:
        return Request(rid=self.rid, prompt=list(self.prompt),
                       max_new_tokens=self.max_new_tokens,
                       slo_class=self.slo_class,
                       ttft_target=self.ttft_target,
                       tpot_target=self.tpot_target)


class TrafficGenerator:
    """Seeded, deterministic arrival-process generator.

    ``process`` is ``"poisson"`` or ``"bursty"``; ``rate`` is arrivals
    per clock unit in the calm state.  The bursty process multiplies the
    rate by ``burst_ratio`` while in the burst state and moves between
    states after every arrival with probabilities ``p_burst`` (enter)
    and ``p_calm`` (leave) — a discrete Markov-modulated Poisson
    process.  All randomness flows from ONE ``numpy`` generator seeded
    with ``seed``, so two instances with equal arguments emit
    byte-identical traces."""

    def __init__(self, seed: int, *, rate: float = 1.0,
                 process: str = "poisson", burst_ratio: float = 8.0,
                 p_burst: float = 0.08, p_calm: float = 0.25,
                 class_mix: Optional[Dict[str, float]] = None,
                 profiles: Optional[Dict[str, ClassProfile]] = None,
                 vocab: int = 250):
        if process not in ("poisson", "bursty"):
            raise ValueError(
                f"process must be 'poisson' or 'bursty', got {process!r}")
        if rate <= 0.0:
            raise ValueError(f"rate must be > 0, got {rate}")
        self.seed = int(seed)
        self.rate = float(rate)
        self.process = process
        self.burst_ratio = float(burst_ratio)
        self.p_burst = float(p_burst)
        self.p_calm = float(p_calm)
        self.class_mix = dict(class_mix or DEFAULT_MIX)
        for cls in self.class_mix:
            if cls not in SLO_CLASSES:
                raise ValueError(f"unknown SLO class {cls!r} in mix "
                                 f"(known: {sorted(SLO_CLASSES)})")
        self.profiles = dict(DEFAULT_PROFILES)
        self.profiles.update(profiles or {})
        self.vocab = int(vocab)

    def generate(self, n: int) -> List[TrafficRequest]:
        """The first ``n`` arrivals of the seeded process (a fresh RNG
        per call: ``generate`` is a pure function of ``(seed, args)``,
        never of generator history)."""
        rng = np.random.default_rng(self.seed)
        names = sorted(self.class_mix)
        weights = np.asarray([self.class_mix[c] for c in names])
        weights = weights / weights.sum()
        out: List[TrafficRequest] = []
        t = 0.0
        bursting = False
        for rid in range(n):
            lam = self.rate * (self.burst_ratio if bursting else 1.0)
            t += float(rng.exponential(1.0 / lam))
            cls = names[int(rng.choice(len(names), p=weights))]
            prof = self.profiles.get(cls, DEFAULT_PROFILES["best_effort"])
            plen = int(rng.integers(prof.prompt_len[0],
                                    prof.prompt_len[1] + 1))
            max_new = int(rng.integers(prof.max_new[0],
                                       prof.max_new[1] + 1))
            prompt = tuple(int(v) for v in
                           rng.integers(1, self.vocab, size=plen))
            out.append(TrafficRequest(
                rid=rid, arrival=t, prompt=prompt,
                max_new_tokens=max_new, slo_class=cls,
                ttft_target=prof.ttft_target,
                tpot_target=prof.tpot_target))
            if self.process == "bursty":
                flip = float(rng.random())
                bursting = (flip >= self.p_calm if bursting
                            else flip < self.p_burst)
        return out


def trace_fingerprint(trace: List[TrafficRequest]) -> str:
    """Canonical string form of a trace — equal strings == byte-identical
    traces (the determinism gate compares these)."""
    parts = []
    for tr in trace:
        parts.append(f"{tr.rid}|{tr.arrival!r}|{tr.slo_class}|"
                     f"{tr.max_new_tokens}|{tr.ttft_target!r}|"
                     f"{tr.tpot_target!r}|{','.join(map(str, tr.prompt))}")
    return "\n".join(parts)


class VirtualClock:
    """Deterministic engine clock: time moves only when ``tick`` is
    called (one chunk boundary == ``dt`` units), so every latency stamp
    and deadline decision replays identically on any machine."""

    def __init__(self, dt: float = 1.0, start: float = 0.0):
        self.dt = float(dt)
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def tick(self) -> None:
        self.now += self.dt


def replay(eng, trace: List[TrafficRequest],
           clock: Optional[VirtualClock] = None,
           max_steps: int = 100_000) -> Dict[str, Any]:
    """Drive ``eng`` through ``trace``: at each boundary submit every
    arrival whose time has come, step, tick.  When the engine goes idle
    before the next arrival the clock jumps straight to it (no busy
    spinning).  ``clock`` should be the SAME object passed as the
    engine's ``clock=`` for deterministic replay.  Returns submit
    results keyed by rid (None == accepted)."""
    clock = clock if clock is not None else VirtualClock()
    pending = sorted(trace, key=lambda tr: (tr.arrival, tr.rid))
    results: Dict[int, Any] = {}
    i = 0
    steps = 0
    while i < len(pending) or eng.queue or eng._live():
        if steps >= max_steps:
            raise RuntimeError(f"replay exceeded {max_steps} steps")
        while i < len(pending) and pending[i].arrival <= clock():
            tr = pending[i]
            results[tr.rid] = eng.submit(tr.to_request())
            i += 1
        if eng.queue or eng._live():
            eng.step()
            steps += 1
            clock.tick()
        elif i < len(pending):
            clock.now = max(clock.now, pending[i].arrival)
    return results
