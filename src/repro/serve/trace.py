"""Bounded ring-buffer request-lifecycle tracing for the serving engine.

Every host-side lifecycle transition -- submit, admit, prefill-cursor
advance, chunk boundary, preempt/resume, copy-on-write, radix hit,
reap, chaos fault, terminal -- is recorded as one :class:`TraceEvent`
``(ts, kind, rid, slot, attrs)``.  Events are recorded **only at chunk
boundaries** by the host driver, timestamped from the engine's
injectable clock (the single per-drain ``_clock()`` read; a
``VirtualClock`` under replay), so tracing adds zero device syncs and
the fused decode chunk stays one compiled executable.  The buffer is a
bounded ring: at capacity the oldest non-terminal event is evicted
(``Tracer.dropped`` counts them) while terminal events (``finish`` /
``reject``) are never dropped.

Exporters: :func:`to_chrome_trace` renders the Chrome trace-event /
Perfetto JSON timeline (per-slot tracks, async queue spans,
per-request flow arrows across preempt/resume, counter tracks for pool
occupancy and queue depth) behind ``Engine.export_trace`` and
validated by ``benchmarks/check_trace.py``; :func:`explain` renders a
per-request causal chain with per-phase durations behind
``Engine.explain``.  ``Tracer.fingerprint()`` is a canonical string
over the buffered events -- two replays of the same seeded traffic on
a ``VirtualClock`` produce byte-identical fingerprints
(``tests/test_trace.py``).
"""

import collections
import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

# The event taxonomy (docs/observability.md documents each kind):
EVENT_KINDS = (
    "submit",      # request entered the engine (ts = submit_time)
    "admit",       # leased a slot; attrs: chunk, suffix_start, resume
    "resume",      # re-admission of a previously preempted request
    "radix_hit",   # prefix pages attached from the radix index
    "cow",         # copy-on-write page duplication at admission
    "prefill",     # chunked-prefill cursor advance observed by a drain
    "chunk",       # chunk boundary; attrs carry counter samples
    "preempt",     # slot evicted (pressure / chaos / watchdog)
    "reap",        # deadline/cancel enforcement at a boundary
    "chaos",       # injected fault fired (serve/chaos.py)
    "finish",      # terminal: FINISHED / TIMED_OUT / CANCELLED
    "reject",      # terminal: shed at submit (infeasible / queue_full)
)

#: Terminal kinds are never evicted from the ring.
TERMINAL_KINDS = frozenset({"finish", "reject"})

# Chrome-trace thread ids: one engine-wide track for chunk boundaries
# and counters, one queue track for wait phases, one track per slot.
ENGINE_TID = 0
QUEUE_TID = 1
SLOT_TID_BASE = 10


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One lifecycle transition: ``(ts, kind, rid, slot, attrs)``.

    ``seq`` is a per-tracer monotonic sequence number that gives a
    total order even when many events share one chunk-boundary
    timestamp.
    """

    ts: float
    kind: str
    rid: Optional[int] = None
    slot: Optional[int] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    seq: int = 0


class Tracer:
    """Bounded structured event ring with terminal-event retention."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._ring: collections.deque = collections.deque()
        self._pinned: List[TraceEvent] = []   # evicted-but-terminal
        self._seq = 0
        self.dropped = 0                      # non-terminal evictions

    def record(self, kind: str, ts: float, rid: Optional[int] = None,
               slot: Optional[int] = None, **attrs: Any) -> TraceEvent:
        ev = TraceEvent(ts=float(ts), kind=kind, rid=rid, slot=slot,
                        attrs=attrs, seq=self._seq)
        self._seq += 1
        self._ring.append(ev)
        # Evict oldest-first, but terminal events survive eviction by
        # moving to the pinned list (which may push us past capacity:
        # terminal events are never dropped, by contract).
        while (len(self._ring) + len(self._pinned) > self.capacity
               and self._ring):
            old = self._ring.popleft()
            if old.kind in TERMINAL_KINDS:
                self._pinned.append(old)
            else:
                self.dropped += 1
        return ev

    def events(self) -> List[TraceEvent]:
        return sorted(self._pinned + list(self._ring),
                      key=lambda e: e.seq)

    def __len__(self) -> int:
        return len(self._ring) + len(self._pinned)

    def fingerprint(self) -> str:
        """Canonical string over all buffered events.

        Timestamps are ``repr``-ed so replayed ``VirtualClock``
        experiments compare byte-exact.
        """
        lines = []
        for e in self.events():
            a = ",".join(f"{k}={e.attrs[k]!r}" for k in sorted(e.attrs))
            lines.append(f"{e.seq}|{e.ts!r}|{e.kind}|{e.rid}|{e.slot}|{a}")
        return "\n".join(lines)


def _lifecycle_phases(evs: List[TraceEvent]) -> List[
        Tuple[str, float, Optional[float], Optional[int]]]:
    """Contiguous ``(phase, t0, t1, slot)`` segments for one rid.

    Phases are ``queued`` (submit->admit), ``running`` (admit->
    preempt/terminal) and ``requeued`` (preempt->re-admit).  The last
    segment has ``t1 is None`` when the request never reached a
    terminal event in the buffer.
    """
    phases: List[Tuple[str, float, Optional[float], Optional[int]]] = []
    cur: Optional[Tuple[str, float, Optional[int]]] = None
    for e in evs:
        if e.kind == "submit":
            cur = ("queued", e.ts, None)
        elif e.kind == "admit":
            if cur is None:           # submit evicted from the ring
                cur = ("queued", e.ts, None)
            phases.append((cur[0], cur[1], e.ts, cur[2]))
            cur = ("running", e.ts, e.slot)
        elif e.kind == "preempt":
            if cur is not None:
                phases.append((cur[0], cur[1], e.ts, cur[2]))
            cur = ("requeued", e.ts, None)
        elif e.kind in TERMINAL_KINDS:
            if cur is not None:
                phases.append((cur[0], cur[1], e.ts, cur[2]))
                cur = None
    if cur is not None:
        phases.append((cur[0], cur[1], None, cur[2]))
    return phases


# Counter tracks sampled from each chunk event's attrs.
_COUNTER_TRACKS = (
    ("pool.pages_in_use", "pages_in_use"),
    ("sched.queue_depth", "queue_depth"),
    ("pool.live_slots", "live_slots"),
)


def to_chrome_trace(events: Iterable[TraceEvent], *,
                    pid: int = 1) -> Dict[str, Any]:
    """Render events as a Chrome trace-event / Perfetto JSON object.

    Layout: running phases are ``X`` complete events on per-slot
    tracks (``tid = SLOT_TID_BASE + slot``); queued/requeued waits are
    async ``b``/``e`` pairs keyed by rid; one ``s``/``t``/``f`` flow
    chain per request links submit through every admit/preempt hop to
    its terminal event; chunk-boundary counter samples become ``C``
    counter tracks.  ``benchmarks/check_trace.py`` validates the
    result against the trace-event schema.
    """
    evs = sorted(events, key=lambda e: e.seq)
    t0 = min((e.ts for e in evs), default=0.0)

    def us(t: float) -> float:
        return (t - t0) * 1e6

    out: List[Dict[str, Any]] = [
        {"ph": "M", "pid": pid, "tid": ENGINE_TID, "name": "process_name",
         "args": {"name": "repro.serve"}},
        {"ph": "M", "pid": pid, "tid": ENGINE_TID, "name": "thread_name",
         "args": {"name": "engine"}},
        {"ph": "M", "pid": pid, "tid": QUEUE_TID, "name": "thread_name",
         "args": {"name": "queue"}},
    ]
    for s in sorted({e.slot for e in evs if e.slot is not None}):
        out.append({"ph": "M", "pid": pid, "tid": SLOT_TID_BASE + s,
                    "name": "thread_name", "args": {"name": f"slot {s}"}})

    by_rid: Dict[int, List[TraceEvent]] = {}
    for e in evs:
        if e.rid is not None:
            by_rid.setdefault(e.rid, []).append(e)

    for rid, revs in sorted(by_rid.items()):
        # Split the rid's event stream into lifecycle generations at
        # terminal events: benchmark harnesses reuse rids across runs
        # within one tracer, and each reuse must get its own wait spans
        # and flow chain (distinct ``id``), not fuse into one.
        gens: List[List[TraceEvent]] = []
        cur_gen: List[TraceEvent] = []
        for e in revs:
            cur_gen.append(e)
            if e.kind in TERMINAL_KINDS:
                gens.append(cur_gen)
                cur_gen = []
        if cur_gen:
            gens.append(cur_gen)
        multi = len(gens) > 1
        for gi, gen in enumerate(gens):
            fid = f"{rid}#{gi}" if multi else str(rid)
            last_ts = gen[-1].ts
            for name, a, b, slot in _lifecycle_phases(gen):
                end = last_ts if b is None else b
                if name == "running" and slot is not None:
                    out.append({"ph": "X", "pid": pid,
                                "tid": SLOT_TID_BASE + slot,
                                "ts": us(a),
                                "dur": max(0.0, us(end) - us(a)),
                                "name": f"run rid={rid}",
                                "cat": "running", "args": {"rid": rid}})
                else:
                    pair = {"pid": pid, "tid": QUEUE_TID,
                            "cat": "request", "id": fid,
                            "name": f"wait rid={rid}"}
                    out.append(dict(pair, ph="b", ts=us(a),
                                    args={"rid": rid, "phase": name}))
                    out.append(dict(pair, ph="e", ts=us(end), args={}))
            # One flow chain per generation: submit -> admits/preempts
            # -> terminal.  The arrows survive preempt/resume slot hops.
            terminal = next((e for e in gen if e.kind in TERMINAL_KINDS),
                            None)
            points: List[Tuple[float, int]] = []
            sub = next((e for e in gen if e.kind == "submit"), None)
            if sub is not None:
                points.append((sub.ts, QUEUE_TID))
            for e in gen:
                if e.kind in ("admit", "preempt") and e.slot is not None:
                    points.append((e.ts, SLOT_TID_BASE + e.slot))
            if terminal is not None:
                ttid = (SLOT_TID_BASE + terminal.slot
                        if terminal.slot is not None else QUEUE_TID)
                points.append((terminal.ts, ttid))
            if len(points) >= 2:
                base = {"pid": pid, "cat": "lifecycle", "id": fid,
                        "name": f"req {rid}"}
                first, mids, last = points[0], points[1:-1], points[-1]
                out.append(dict(base, ph="s", ts=us(first[0]),
                                tid=first[1]))
                for t, tid in mids:
                    out.append(dict(base, ph="t", ts=us(t), tid=tid))
                out.append(dict(base, ph="f", bp="e", ts=us(last[0]),
                                tid=last[1]))

    for e in evs:
        if e.kind == "chunk":
            for cname, akey in _COUNTER_TRACKS:
                if akey in e.attrs:
                    out.append({"ph": "C", "pid": pid, "tid": ENGINE_TID,
                                "ts": us(e.ts), "name": cname,
                                "args": {"value": e.attrs[akey]}})
            out.append({"ph": "i", "s": "g", "pid": pid, "tid": ENGINE_TID,
                        "ts": us(e.ts), "name": "chunk", "cat": "event",
                        "args": dict(e.attrs)})
            continue
        tid = (SLOT_TID_BASE + e.slot if e.slot is not None
               else QUEUE_TID)
        args = dict(e.attrs)
        if e.rid is not None:
            args["rid"] = e.rid
        out.append({"ph": "i", "s": "t", "pid": pid, "tid": tid,
                    "ts": us(e.ts), "name": e.kind, "cat": "event",
                    "args": args})

    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": {"source": "repro.serve.trace"}}


def explain(events: Iterable[TraceEvent], rid: int) -> str:
    """Per-request text explain: the causal chain from submit to
    terminal, with per-phase durations."""
    evs = sorted((e for e in events if e.rid == rid),
                 key=lambda e: e.seq)
    if not evs:
        return f"rid {rid}: no trace events recorded"
    t_base = evs[0].ts
    lines = [f"request {rid}: causal chain ({len(evs)} events)"]
    for e in evs:
        loc = f" slot={e.slot}" if e.slot is not None else ""
        attrs = " ".join(f"{k}={e.attrs[k]}" for k in sorted(e.attrs))
        lines.append(f"  +{e.ts - t_base:.6f}s {e.kind}{loc}"
                     + (f" {attrs}" if attrs else ""))
    phases = _lifecycle_phases(evs)
    if phases:
        agg: Dict[str, Tuple[float, int]] = {}
        for name, a, b, _slot in phases:
            end = evs[-1].ts if b is None else b
            d, n = agg.get(name, (0.0, 0))
            agg[name] = (d + (end - a), n + 1)
        lines.append("phase durations:")
        for name in ("queued", "running", "requeued"):
            if name in agg:
                d, n = agg[name]
                lines.append(f"  {name}: {d:.6f}s over {n} span(s)")
        lines.append(f"  total: {evs[-1].ts - t_base:.6f}s")
    term = next((e for e in evs if e.kind in TERMINAL_KINDS), None)
    if term is not None:
        lines.append(f"terminal: {term.attrs.get('status', term.kind)}")
    else:
        lines.append("terminal: (still in flight)")
    return "\n".join(lines)
