"""Pallas TPU kernel: matmul with *fused data preparation*.

Paper §5.2 (``MatMul2``): the winning CPU operator design parallelizes the
data preparation (layout/dtype massaging) and overlaps it with the FMA-bound
library kernel via hyperthreading.  The TPU-native translation: do the prep
*per tile in VMEM on the VPU* — dtype cast + per-row dequant scaling — inside
the same kernel whose MXU matmul consumes the tile.  The Pallas pipeline
double-buffers HBM->VMEM copies, so prep of tile k+1 overlaps the MXU work
of tile k: the hyperthreading win, re-created with the TPU memory hierarchy.

The reference implementation (``ref.py``) is the ``MatMul1`` shape: prep as
a separate materialized op (one extra HBM round-trip), then a plain dot.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams



def _kernel(x_ref, w_ref, xscale_ref, o_ref, acc_ref, *, nsteps: int,
            out_dtype):
    """One (bm x bn) output tile; k-loop is the last grid dim.

    x tile [bm, bk] (possibly low precision + per-row scale), w tile
    [bk, bn].  Data prep = upcast + scale, done in VMEM right before the
    MXU dot.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- fused data preparation (VPU work, overlapped by the pipeline)
    x = x_ref[...].astype(jnp.float32)
    if xscale_ref is not None:
        x = x * xscale_ref[...].astype(jnp.float32)  # [bm, 1] row scales
    w = w_ref[...].astype(jnp.float32)

    # ---- MXU contraction
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k == nsteps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def fused_matmul(x: jax.Array, w: jax.Array,
                 x_scale: Optional[jax.Array] = None, *,
                 block_m: int = 256, block_n: int = 256, block_k: int = 512,
                 out_dtype=None, interpret: bool = False) -> jax.Array:
    """x [M, K] (any dtype, e.g. int8/bf16) x w [K, N] -> [M, N].

    ``x_scale`` [M, 1] applies per-row dequantization as the fused prep.
    Block sizes are MXU-aligned (multiples of 128 on the minor dims).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    out_dtype = out_dtype or w.dtype
    bm = min(block_m, m)
    bn = min(block_n, n)
    bk = min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, w.shape,
                                                         (bm, bn, bk))
    grid = (m // bm, n // bn, k // bk)

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = [x, w]
    if x_scale is not None:
        assert x_scale.shape == (m, 1), x_scale.shape
        in_specs.append(pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)))
        args.append(x_scale)
        kern = functools.partial(_kernel, nsteps=grid[2], out_dtype=out_dtype)
    else:
        def kern(x_ref, w_ref, o_ref, acc_ref):
            _kernel(x_ref, w_ref, None, o_ref, acc_ref, nsteps=grid[2],
                    out_dtype=out_dtype)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
