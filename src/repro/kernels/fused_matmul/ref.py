"""Pure-jnp oracle for fused_matmul — and the paper's ``MatMul1`` baseline.

``matmul1`` materializes the prepared (upcast + scaled) x before the dot —
the separate data-preparation step whose overhead §5.1 measures.  The
numerics are identical to the kernel; only the fusion structure differs.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def prep(x: jax.Array, x_scale: Optional[jax.Array] = None) -> jax.Array:
    """The 'data preparation': upcast + per-row dequant scale."""
    xf = x.astype(jnp.float32)
    if x_scale is not None:
        xf = xf * x_scale.astype(jnp.float32)
    return xf


def matmul1(x: jax.Array, w: jax.Array,
            x_scale: Optional[jax.Array] = None,
            out_dtype=None) -> jax.Array:
    """Separate prep (one HBM round-trip), then the library dot."""
    out_dtype = out_dtype or w.dtype
    xf = prep(x, x_scale)
    return jax.lax.dot_general(
        xf, w.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_dtype)


fused_matmul_ref = matmul1  # the oracle: same math, unfused structure
