from repro.kernels.fused_matmul.kernel import fused_matmul
from repro.kernels.fused_matmul.ops import matmul
from repro.kernels.fused_matmul.ref import fused_matmul_ref, matmul1, prep

__all__ = ["fused_matmul", "matmul", "fused_matmul_ref", "matmul1", "prep"]
