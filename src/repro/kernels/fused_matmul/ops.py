"""jit'd public op for fused_matmul with autodiff + CPU interpret fallback."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fused_matmul import ref as _ref
from repro.kernels.fused_matmul.kernel import fused_matmul as _kernel_call


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def matmul(x, w, x_scale=None, block_m=256, block_n=256, block_k=512):
    """Fused-prep matmul; Pallas on TPU, interpret-mode kernel elsewhere."""
    return _kernel_call(x, w, x_scale, block_m=block_m, block_n=block_n,
                        block_k=block_k, interpret=not _on_tpu())


def _fwd(x, w, x_scale, block_m, block_n, block_k):
    out = matmul(x, w, x_scale, block_m, block_n, block_k)
    return out, (x, w, x_scale)


def _bwd(block_m, block_n, block_k, res, g):
    x, w, x_scale = res
    xf = _ref.prep(x, x_scale)
    gf = g.astype(jnp.float32)
    dx_f = gf @ w.astype(jnp.float32).T            # [M, K] in prepared space
    dw = (xf.T @ gf).astype(w.dtype)
    if x_scale is not None:
        dx = (dx_f * x_scale.astype(jnp.float32)).astype(x.dtype)
        dscale = jnp.sum(dx_f * x.astype(jnp.float32), axis=1,
                         keepdims=True).astype(x_scale.dtype)
    else:
        dx = dx_f.astype(x.dtype)
        dscale = None
    return dx, dw, dscale


matmul.defvjp(_fwd, _bwd)
