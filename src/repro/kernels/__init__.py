"""Pallas TPU kernels for the paper's compute hot-spots.

Each subpackage ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd wrapper; interpret mode off-TPU), ref.py (pure-jnp oracle).
"""

from repro.kernels import (flash_attention, fused_matmul, mamba2_scan,
                           moe_gmm, paged_attention, rwkv6_wkv)

__all__ = ["flash_attention", "fused_matmul", "mamba2_scan", "moe_gmm",
           "paged_attention", "rwkv6_wkv"]
