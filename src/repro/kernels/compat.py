"""Pallas API version compatibility, shared by every kernel.

Two renames are shimmed here so the same kernel source runs on every
toolchain the repo supports:

* ``TPUCompilerParams`` -> ``CompilerParams`` (jax>=0.5);
* ``pltpu.PrefetchScalarGridSpec`` -> ``pl.GridSpec(...,
  num_scalar_prefetch=...)`` (newer pallas folds scalar prefetch into the
  generic grid spec).  The paged_attention kernel needs scalar prefetch —
  its BlockSpec index maps read the page table to pick which physical
  page to stream next.

Kernels import these names instead of touching ``pltpu`` directly; tests
self-gate on a runtime capability probe (see tests/test_kernels.py), so
an API drift that this module misses shows up as a clean skip, not a
wall of red.
"""

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

if not hasattr(pltpu, "CompilerParams"):  # pragma: no cover - version compat
    CompilerParams = pltpu.TPUCompilerParams
else:
    CompilerParams = pltpu.CompilerParams

if hasattr(pltpu, "PrefetchScalarGridSpec"):
    PrefetchScalarGridSpec = pltpu.PrefetchScalarGridSpec
else:  # pragma: no cover - version compat
    def PrefetchScalarGridSpec(*, num_scalar_prefetch, grid, in_specs,
                               out_specs, scratch_shapes=()):
        return pl.GridSpec(grid=grid, in_specs=in_specs,
                           out_specs=out_specs,
                           num_scalar_prefetch=num_scalar_prefetch,
                           scratch_shapes=scratch_shapes)
