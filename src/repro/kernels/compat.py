"""Pallas API version compatibility, shared by every kernel.

pallas renamed ``TPUCompilerParams`` -> ``CompilerParams`` (jax>=0.5);
alias once here so the same kernel source runs on both toolchains.
"""

from jax.experimental.pallas import tpu as pltpu

if not hasattr(pltpu, "CompilerParams"):  # pragma: no cover - version compat
    CompilerParams = pltpu.TPUCompilerParams
else:
    CompilerParams = pltpu.CompilerParams
