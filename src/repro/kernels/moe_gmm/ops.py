"""jit'd grouped-matmul op."""

from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.moe_gmm.kernel import moe_gmm as _kernel
from repro.kernels.moe_gmm.ref import moe_gmm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gmm(x, w, row_counts: Optional[jax.Array] = None, **blocks):
    return _kernel(x, w, row_counts, interpret=not _on_tpu(), **blocks)
