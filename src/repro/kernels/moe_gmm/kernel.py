"""Pallas TPU kernel: grouped (per-expert) matmul for MoE FFNs.

x [E, C, D] (capacity-dispatched tokens) x w [E, D, F] -> [E, C, F].
Grid (E, C/bm, F/bn, D/bk); k-dim sequential with an f32 VMEM accumulator.
``row_counts`` [E] (actual tokens per expert) lets the kernel skip output
tiles that contain only padding — the dominant saving under imbalanced
routing (paper §4's workload-imbalance story at the kernel level).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams



def _kernel(counts_ref, x_ref, w_ref, o_ref, acc_ref, *, bm: int, nsteps: int,
            use_counts: bool):
    e = pl.program_id(0)
    im = pl.program_id(1)
    kk = pl.program_id(3)

    needed = jnp.bool_(True)
    if use_counts:
        needed = im * bm < counts_ref[e]

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(needed)
    def _step():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kk == nsteps - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm(x: jax.Array, w: jax.Array,
            row_counts: Optional[jax.Array] = None, *,
            block_m: int = 128, block_n: int = 256, block_k: int = 256,
            interpret: bool = False) -> jax.Array:
    """x [E, C, D] x w [E, D, F] -> [E, C, F]."""
    e, c, d = x.shape
    _, _, f = w.shape
    bm = min(block_m, c)
    bn = min(block_n, f)
    bk = min(block_k, d)
    while c % bm:
        bm //= 2
    while f % bn:
        bn //= 2
    while d % bk:
        bk //= 2
    grid = (e, c // bm, f // bn, d // bk)
    use_counts = row_counts is not None
    if row_counts is None:
        row_counts = jnp.full((e,), c, jnp.int32)

    kern = functools.partial(_kernel, bm=bm, nsteps=grid[3],
                             use_counts=use_counts)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # row_counts, whole array
            pl.BlockSpec((1, bm, bk), lambda e_, im, jn, kk: (e_, im, kk)),
            pl.BlockSpec((1, bk, bn), lambda e_, im, jn, kk: (e_, kk, jn)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e_, im, jn, kk: (e_, im, jn)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(row_counts.astype(jnp.int32), x, w)
