from repro.kernels.moe_gmm.kernel import moe_gmm
from repro.kernels.moe_gmm.ops import gmm
from repro.kernels.moe_gmm.ref import moe_gmm_ref

__all__ = ["moe_gmm", "gmm", "moe_gmm_ref"]
