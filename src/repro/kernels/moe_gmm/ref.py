"""Oracle: batched per-expert einsum (rows beyond row_counts are zeroed)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def moe_gmm_ref(x: jax.Array, w: jax.Array,
                row_counts: Optional[jax.Array] = None) -> jax.Array:
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32)).astype(x.dtype)
    if row_counts is not None:
        c = x.shape[1]
        valid = jnp.arange(c)[None, :] < row_counts[:, None]
        out = out * valid[..., None].astype(out.dtype)
    return out
