"""jit'd rwkv6 wkv op with model-layout adapter."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.kernel import rwkv6_wkv as _kernel
from repro.kernels.rwkv6_wkv.ref import rwkv6_wkv_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def wkv(r, k, v, lw, u, *, chunk: int = 16):
    return _kernel(r, k, v, lw, u, chunk=chunk, interpret=not _on_tpu())


def wkv_model_layout(rh, kh, vh, lwh, uh, *, chunk: int = 16):
    """Adapter for the model's [B,S,H,K] layout; uh [H,K].

    Returns (y [B,S,H,K], S_final [B,H,K,K])."""
    b, s, h, kk = rh.shape
    def flat(z):
        return jnp.swapaxes(z, 1, 2).reshape(b * h, s, kk)
    u2 = jnp.broadcast_to(uh[None], (b, h, kk)).reshape(b * h, kk)
    y, hf = wkv(flat(rh), flat(kh), flat(vh), flat(lwh), u2, chunk=chunk)
    y = jnp.swapaxes(y.reshape(b, h, s, kk), 1, 2)
    return y, hf.reshape(b, h, kk, kk)
