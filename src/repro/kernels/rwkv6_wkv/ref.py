"""Oracle: naive per-step wkv6 recurrence.

    y_t = r_t . (S_{t-1} + (u * k_t) (x) v_t)
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_wkv_ref(r, k, v, lw, u, h0=None):
    """r,k,v,lw [BH,S,K]; u [BH,K] -> (y [BH,S,K], S_final [BH,K,K])."""
    bh, s, kk = r.shape
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((bh, kk, kk), f32)

    def step(hst, inp):
        rt, kt, vt, lwt = (z.astype(f32) for z in inp)     # each [BH,K]
        kv = jnp.einsum("bk,bv->bkv", kt, vt)
        y = jnp.einsum("bk,bkv->bv", rt,
                       hst + u.astype(f32)[:, :, None] * kv)
        hst = jnp.exp(lwt)[:, :, None] * hst + kv
        return hst, y

    xs = tuple(jnp.swapaxes(z, 0, 1) for z in (r, k, v, lw))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.swapaxes(ys, 0, 1).astype(r.dtype), h_final
