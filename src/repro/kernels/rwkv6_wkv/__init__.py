from repro.kernels.rwkv6_wkv.kernel import rwkv6_wkv
from repro.kernels.rwkv6_wkv.ops import wkv, wkv_model_layout
from repro.kernels.rwkv6_wkv.ref import rwkv6_wkv_ref

__all__ = ["rwkv6_wkv", "wkv", "wkv_model_layout", "rwkv6_wkv_ref"]
