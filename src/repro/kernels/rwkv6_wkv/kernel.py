"""Pallas TPU kernel: chunked RWKV6 wkv with data-dependent decay.

One (batch x head) stream per grid row, chunk dim sequential, state [K, V]
in VMEM scratch.  The chunk math matches ``repro.models.rwkv6.wkv_chunked``:
the factored decay products are normalized so every exponent is bounded by
|LOG_W_MIN| * chunk (log decays are pre-clamped by the caller).

Shapes (prepared by ops.py):
    r,k,v [BH, S, K]   lw [BH, S, K] (log decays, <= 0)   u [BH, K]
Returns y [BH, S, K] and final state [BH, K, V].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams



def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, hout_ref, state_ref,
            *, q: int, nc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)              # [Q, K]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)              # [K]

    cw = jnp.cumsum(lw, axis=0)                   # [Q, K] inclusive
    cwx = cw - lw                                 # exclusive
    cw_end = cw[q - 1]                            # [K]

    r_tilde = r * jnp.exp(cwx)
    k_tilde = k * jnp.exp(-cw)
    amat = jax.lax.dot_general(r_tilde, k_tilde, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    amat = jnp.where(cols < rows, amat, 0.0)      # strictly lower
    y = jax.lax.dot_general(amat, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # diagonal bonus: (r . (u * k)) v
    diag = jnp.sum(r * k * u[None, :], axis=1, keepdims=True)
    y += diag * v
    # inter-chunk: r_tilde . state
    y += jax.lax.dot_general(r_tilde, state_ref[...], (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    kdec = k * jnp.exp(cw_end[None, :] - cw)
    state_ref[...] = state_ref[...] * jnp.exp(cw_end)[:, None] + \
        jax.lax.dot_general(kdec, v, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _flush():
        hout_ref[0] = state_ref[...].astype(hout_ref.dtype)


def rwkv6_wkv(r, k, v, lw, u, *, chunk: int = 16, interpret: bool = False):
    """See module docstring."""
    bh, s, kk = r.shape
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    grid = (bh, nc)
    kern = functools.partial(_kernel, q=q, nc=nc)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, kk), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, q, kk), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, q, kk), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, q, kk), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, kk), lambda i, ic: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, kk), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, kk, kk), lambda i, ic: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, kk), r.dtype),
            jax.ShapeDtypeStruct((bh, kk, kk), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kk, kk), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, lw, u)
