"""Public paged decode-attention op: pool-direct reads on every backend.

``paged_attention`` is what ``models/attention.paged_decode_step`` calls
on the serving fast path when the engine's ``paged_kernel`` flag is on.
Dispatch:

* **TPU** — the compiled Pallas kernel (``kernel.paged_decode_attention``):
  scalar-prefetch page tables, one K/V page DMA'd per grid step, online
  softmax in VMEM scratch.  No gathered ring buffer exists at any point.
* **other backends** — ``pool_attention_xla`` below: score the query
  against the *entire* pool and mask by a scattered table-membership
  mask.  Still gather-free (the only scatter is a tiny ``[B, num_pages+1,
  P]`` boolean mask; KV bytes are read in place), and on CPU it lowers to
  two large einsums, which XLA runs faster than the per-page interpret
  emulation of the kernel.  Its cost scales with the *physical pool*, not
  the worst-case table width — cheaper than gather-then-attend whenever
  the pool is oversubscribed (``num_pages < slots * ring_blocks``), which
  is the configuration paging exists for.
* ``interpret=True`` — force the Pallas kernel through interpret mode on
  any backend: the parity-debugging path the kernel tests use on CPU.

Correctness requires the scheduler invariant that already holds for the
gather path: within one slot's table row every non-trash entry is a
distinct physical page (pages shared *across* slots are fine — that is
prefix sharing).  ``supported()`` is the capability probe engines and
tests gate on: it runs the real kernel through the Pallas toolchain
(interpret mode off-TPU) instead of sniffing versions.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_decode_attention

NEG_INF = -1e30


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pool_attention_xla(q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
                       page_table: jax.Array, cache_len: jax.Array, *,
                       window: Optional[int] = None,
                       softcap: Optional[float] = None,
                       k_scale: Optional[jax.Array] = None,
                       v_scale: Optional[jax.Array] = None) -> jax.Array:
    """Gather-free XLA lowering: attend to the whole pool under a
    scattered per-slot validity mask.

    Ring validity (``u = t - ((t - r) mod R)``, window mask) is computed
    in table space ``[B, nb, P]`` and scattered to pool space ``[B,
    num_pages+1, P]`` through the page table; the trash row is then
    force-masked, so duplicate trash entries cannot resurrect it.  Rows
    with no valid position (unadmitted slots) return exactly 0, matching
    the kernel's clamped denominator.  ``q`` may carry ``S`` query rows
    per slot ([B,S,H,dh], the speculative verify step); query row ``i``
    sits at absolute position ``cache_len - S + i`` and the mask is
    evaluated per row, so a drafted query never attends past itself.

    ``k_scale``/``v_scale`` [num_pages+1, Hkv]: 8-bit quantized pools.
    Dequantization is *folded*, pool-wide — the K scale multiplies the
    scores (before the softcap), the V scale multiplies the softmax
    weights — so no fp32 copy of the pool is ever stored (the 8-bit→f32
    cast is a transient XLA fuses into the einsum)."""
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    b, sq, h, dh = q.shape
    npg, page_size, hkv, _ = pool_k.shape
    nb = page_table.shape[1]
    ring = nb * page_size
    g = h // hkv
    scale = dh ** -0.5
    quant = k_scale is not None
    if quant:
        pool_k = pool_k.astype(jnp.float32)
        pool_v = pool_v.astype(jnp.float32)
    t = (cache_len - 1)[:, None, None]                         # [B,1,1]
    r = (jnp.arange(nb)[:, None] * page_size
         + jnp.arange(page_size)[None, :])[None]               # [1,nb,P]
    u = t - ((t - r) % ring)                                   # [B,nb,P]
    if sq == 1:              # plain decode: keep the PR-4 lowering exactly
        valid = u >= 0
        if window is not None:
            valid &= u > t - window
        mask = jnp.zeros((b, npg, page_size), bool)
        mask = mask.at[jnp.arange(b)[:, None], page_table].set(valid)
        mask = mask.at[:, npg - 1].set(False)                  # trash row
        q2 = q[:, 0].reshape(b, hkv, g, dh)
        s = jnp.einsum("bkgd,npkd->bkgnp", q2, pool_k)
        s = s.astype(jnp.float32) * scale
        if quant:        # dequant K: fold per-page scales into the scores
            s = s * jnp.transpose(k_scale)[None, :, None, :, None]
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        w = jnp.exp(s - jnp.max(s, axis=(-2, -1), keepdims=True))
        w = jnp.where(mask[:, None, None], w, 0.0)
        l = jnp.maximum(jnp.sum(w, axis=(-2, -1), keepdims=True), 1e-30)
        w = w / l
        if quant:        # dequant V: fold into the softmax weights
            w = w * jnp.transpose(v_scale)[None, :, None, :, None]
        else:
            w = w.astype(pool_v.dtype)
        out = jnp.einsum("bkgnp,npkd->bkgd", w, pool_v)
        out = out.reshape(b, 1, h, dh)
        return out[:, 0] if squeeze else out
    qpos = (cache_len - sq)[:, None] + jnp.arange(sq)[None, :]  # [B,S]
    valid = (u >= 0)[:, None] & (u[:, None] <= qpos[:, :, None, None])
    if window is not None:
        valid &= u[:, None] > qpos[:, :, None, None] - window
    mask = jnp.zeros((b, npg, page_size, sq), bool)
    mask = mask.at[jnp.arange(b)[:, None], page_table].set(
        jnp.moveaxis(valid, 1, -1))
    mask = mask.at[:, npg - 1].set(False)                      # trash row
    mask = jnp.moveaxis(mask, 3, 1)[:, None, None]             # [B,1,1,S,n,P]
    q2 = q.reshape(b, sq, hkv, g, dh)
    s = jnp.einsum("bqkgd,npkd->bkgqnp", q2, pool_k)
    s = s.astype(jnp.float32) * scale
    if quant:                # [1,k,1,1,n,1] — scale per (page, kv head)
        s = s * jnp.transpose(k_scale)[None, :, None, None, :, None]
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask, s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=(-2, -1), keepdims=True))
    w = jnp.where(mask, w, 0.0)
    l = jnp.maximum(jnp.sum(w, axis=(-2, -1), keepdims=True), 1e-30)
    w = w / l
    if quant:
        w = w * jnp.transpose(v_scale)[None, :, None, None, :, None]
    else:
        w = w.astype(pool_v.dtype)
    out = jnp.einsum("bkgqnp,npkd->bqkgd", w, pool_v)
    return out.reshape(b, sq, h, dh)


def paged_attention(q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
                    page_table: jax.Array, cache_len: jax.Array, *,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    k_scale: Optional[jax.Array] = None,
                    v_scale: Optional[jax.Array] = None,
                    interpret: bool = False) -> jax.Array:
    """Pool-direct decode attention for 1..K+1 query rows per slot
    (``q`` [B,H,dh] or [B,S,H,dh]); ``k_scale``/``v_scale``
    [num_pages+1, Hkv] when the pools are 8-bit quantized (dequant
    happens inside whichever lowering runs); see module docstring for
    dispatch."""
    if interpret or _on_tpu():
        return paged_decode_attention(
            q, pool_k, pool_v, page_table, cache_len, window=window,
            softcap=softcap, k_scale=k_scale, v_scale=v_scale,
            interpret=interpret or not _on_tpu())
    return pool_attention_xla(q, pool_k, pool_v, page_table, cache_len,
                              window=window, softcap=softcap,
                              k_scale=k_scale, v_scale=v_scale)


_POOL_DTYPES = {"fp32": jnp.float32, "int8": jnp.int8}


@functools.lru_cache(maxsize=None)
def supported(kv_dtype: str = "fp32") -> bool:
    """Probe, don't version-sniff: run the smallest real paged-attention
    kernel through the Pallas toolchain (interpret mode off-TPU), in the
    pool storage dtype the engine wants (scale operands + in-kernel
    dequant included for 8-bit dtypes).  API drift (grid-spec /
    scalar-prefetch / DMA renames beyond what compat.py shims) surfaces
    here as a clean False instead of a trace-time crash."""
    try:
        if kv_dtype == "fp8_e4m3":
            if not hasattr(jnp, "float8_e4m3fn"):
                return False
            pool_dt = jnp.float8_e4m3fn
        else:
            pool_dt = _POOL_DTYPES[kv_dtype]
        q = jnp.zeros((1, 2, 8), jnp.float32)
        pool = jnp.zeros((3, 4, 1, 8), pool_dt)
        pt = jnp.asarray([[0, 1]], jnp.int32)
        cl = jnp.asarray([5], jnp.int32)
        sc = (jnp.ones((3, 1), jnp.float32)
              if kv_dtype != "fp32" else None)
        out = paged_decode_attention(q, pool, pool, pt, cl,
                                     k_scale=sc, v_scale=sc,
                                     interpret=not _on_tpu())
        return out.shape == (1, 2, 8)
    except Exception:
        return False
