from repro.kernels.paged_attention.kernel import paged_decode_attention
from repro.kernels.paged_attention.ops import (paged_attention,
                                               pool_attention_xla,
                                               supported)
from repro.kernels.paged_attention.ref import paged_attention_ref

__all__ = ["paged_decode_attention", "paged_attention",
           "pool_attention_xla", "paged_attention_ref", "supported"]
