"""Pure-jnp oracle: gather-then-attend over the paged pool.

This is the semantics the kernel must reproduce — and exactly the data
flow the kernel exists to kill: gather every table entry's page into a
contiguous ``[B, ring, Hkv, dh]`` buffer, then run masked attention over
it.  Validity is the ring formula from ``models/attention.ring_valid``
(``u = t - ((t - r) mod R)``) plus the trash-page convention (a table
entry equal to the trash id — the last pool row — masks its whole page).

The softmax is the *masked-accumulate* form (weights zeroed where
invalid, denominator clamped) rather than ``jax.nn.softmax`` over
-inf-filled scores: the two agree wherever at least one position is
valid, but a fully-dead row (unadmitted slot, all-trash table) comes out
exactly 0 here — matching the kernel's clamped-denominator flush — where
a plain softmax would average garbage uniformly.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q: jax.Array, pool_k: jax.Array, pool_v: jax.Array,
                        page_table: jax.Array, cache_len: jax.Array, *,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        k_scale: Optional[jax.Array] = None,
                        v_scale: Optional[jax.Array] = None) -> jax.Array:
    """q [B,H,dh] or [B,S,H,dh] (S query rows, newest last); pools
    [num_pages+1,P,Hkv,dh]; page_table [B,nb]; cache_len [B] (incl. the
    newest query token); ``k_scale``/``v_scale`` [num_pages+1, Hkv] when
    the pools are 8-bit quantized (gathered pages are dequantized before
    attending) -> output shaped like ``q``."""
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    b, sq, h, dh = q.shape
    npg, page_size, hkv, _ = pool_k.shape
    nb = page_table.shape[1]
    ring = nb * page_size
    g = h // hkv
    gk = pool_k[page_table]                       # [B, nb, P, Hkv, dh]
    gv = pool_v[page_table]
    if k_scale is not None:    # dequant: scale per (page, kv head)
        gk = gk.astype(jnp.float32) * k_scale[page_table][:, :, None, :, None]
        gv = gv.astype(jnp.float32) * v_scale[page_table][:, :, None, :, None]
    ck = jnp.moveaxis(gk.reshape(b, ring, hkv, dh), 1, 2)
    cv = jnp.moveaxis(gv.reshape(b, ring, hkv, dh), 1, 2)
    t = (cache_len - 1)[:, None]
    r = jnp.arange(ring)[None, :]
    u = t - ((t - r) % ring)                                    # [B, ring]
    qpos = (cache_len - sq)[:, None] + jnp.arange(sq)[None, :]  # [B, S]
    valid = (u >= 0)[:, None, :] & (u[:, None, :] <= qpos[:, :, None])
    if window is not None:
        valid &= u[:, None, :] > qpos[:, :, None] - window
    not_trash = jnp.repeat(page_table != npg - 1, page_size, axis=1)
    valid &= not_trash[:, None, :]
    q2 = q.reshape(b, sq, hkv, g, dh)
    scale = dh ** -0.5
    s = jnp.einsum("bqkgd,bksd->bkgqs", q2, ck).astype(jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    mask = valid[:, None, None]                   # [B,1,1,S,ring]
    s = jnp.where(mask, s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = jnp.where(mask, w, 0.0)
    l = jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgqs,bksd->bqkgd", (w / l).astype(cv.dtype), cv)
    out = out.reshape(b, sq, h, dh)
    return out[:, 0] if squeeze else out
