"""Pallas TPU paged decode attention: gather-free, double-buffered pool
reads with in-kernel dequantization.

Up to ``K+1`` decode tokens per slot (one for plain decode, several for a
speculative verify step) attend to the slot's block-paged KV ring
(``serve/cache.py`` pool layout ``[num_pages+1, page_size, kv_heads,
dh]`` behind a per-slot page table) *without* ever materializing the
gathered ``[slots, ring, kv_heads, dh]`` buffer the XLA path builds.
The page table and cache lengths ride in as **scalar prefetch** operands
(``compat.PrefetchScalarGridSpec``); the pools stay in HBM/ANY memory
and the kernel issues its own page DMAs (``pltpu.make_async_copy``) into
a 2-deep VMEM ring: while page ``j`` is being scored, page ``j+1``'s
copy is already in flight (double buffering), so the DMA latency hides
behind the flash-style online-softmax compute.

**Quantized pools** (``k_scale``/``v_scale`` given): K/V pages are
stored 8-bit (int8 / fp8_e4m3) with per-page, per-kv-head fp32 scales in
a parallel scale pool.  Each page's scale rows are DMA'd alongside the
page and folded in-kernel — the K scale into the scores before the
softcap, the V scale into the PV accumulation — so the *dequantized*
page never exists anywhere: HBM holds 8-bit, VMEM holds one 8-bit page
block, and dequantization is two scalar multiplies per kv head.

Per page the kernel recomputes the ring-validity mask from the same
formula the XLA path uses (``models/attention.ring_token_positions``):
ring offset ``r`` holds absolute token ``u = t - ((t - r) mod R)``,
valid iff ``u >= 0`` (ever written) and, for sliding windows, ``u > t -
window``.  With ``q_len > 1`` query rows (speculative verify), query
row ``i`` sits at absolute position ``t - (q_len-1) + i`` and the mask
is evaluated *per row*, so a drafted query can attend to the drafted
tokens before it but never to the ones after it.  The **trash page**
(last pool row, where unreserved table entries point) contributes -inf
scores: a table entry equal to the trash id masks its whole page, so a
slot whose reservation ran out can never attend to the write-discard
garbage.  A slot with *no* valid page (unadmitted / warmup rows)
produces exactly 0 output — the denominator is clamped, matching
``ref.paged_attention_ref``.

Grouped-query attention needs no KV repeat: queries arrive grouped
``[slots, kv_heads, q_len * group, dh]`` and each kv head's page block
is shared by its ``q_len * group`` query rows inside the kernel.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams
from repro.kernels.compat import PrefetchScalarGridSpec as _PrefetchGrid

NEG_INF = -1e30


def _kernel(pt_ref, cl_ref, q_ref, kp_ref, vp_ref, *rest, page_size: int,
            nb: int, hkv: int, g: int, q_len: int, trash: int,
            window: Optional[int], softcap: Optional[float], scale: float,
            quantized: bool):
    if quantized:
        (ks_ref, vs_ref, o_ref, kbuf, vbuf, sbuf,
         sem_k, sem_v, sem_s, m_ref, l_ref, acc_ref) = rest
    else:
        ks_ref = vs_ref = sbuf = sem_s = None
        o_ref, kbuf, vbuf, sem_k, sem_v, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    t = cl_ref[b] - 1                    # newest query's absolute position
    ring = nb * page_size
    rows = q_len * g                     # query rows per kv head

    def page_copies(j, slot):
        """The async copies that stream page ``pt[b, j]`` into VMEM ring
        slot ``slot``.  Reconstructed identically at start and wait time
        (the descriptors are pure functions of their arguments)."""
        p = pt_ref[b, j]
        cps = [pltpu.make_async_copy(kp_ref.at[p], kbuf.at[slot],
                                     sem_k.at[slot]),
               pltpu.make_async_copy(vp_ref.at[p], vbuf.at[slot],
                                     sem_v.at[slot])]
        if quantized:
            cps.append(pltpu.make_async_copy(ks_ref.at[p], sbuf.at[slot, 0],
                                             sem_s.at[slot, 0]))
            cps.append(pltpu.make_async_copy(vs_ref.at[p], sbuf.at[slot, 1],
                                             sem_s.at[slot, 1]))
        return cps

    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)
    for c in page_copies(0, 0):          # warm the pipeline: page 0
        c.start()

    def body(j, _):
        slot = jax.lax.rem(j, 2)

        @pl.when(j + 1 < nb)
        def _prefetch():                 # overlap: start page j+1 now
            for c in page_copies(j + 1, jax.lax.rem(j + 1, 2)):
                c.start()

        for c in page_copies(j, slot):   # land page j
            c.wait()

        phys = pt_ref[b, j]
        r = (j * page_size
             + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1))
        u = t - ((t - r) % ring)         # latest token at each ring offset
        valid = u >= 0
        if window is not None:
            valid = jnp.logical_and(valid, u > t - window)
        if q_len > 1:
            # per-row causal mask: row i (of any kv head) is query q = i//g
            # at absolute position t - (q_len - 1) + (i // g)
            qpos = (t - (q_len - 1)
                    + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // g)
            valid = jnp.logical_and(u >= 0, u <= qpos)       # [rows, P]
            if window is not None:
                valid = jnp.logical_and(valid, u > qpos - window)
        # page-skip predicate AFTER the per-row recompute: a page whose
        # tokens are stale for the newest row can still be in-window for an
        # earlier draft row (its window starts q_len-1 positions earlier)
        live = jnp.logical_and(phys != trash, jnp.any(valid))

        @pl.when(live)
        def _step():
            q = q_ref[0].astype(jnp.float32)            # [Hkv*q_len*G, dh]
            kb = kbuf[slot]                             # [P, Hkv, dh]
            vb = vbuf[slot]
            for kh in range(hkv):   # static loop: one dot per kv head
                k = kb[:, kh].astype(jnp.float32)       # [P, dh]
                v = vb[:, kh].astype(jnp.float32)
                sl = slice(kh * rows, (kh + 1) * rows)
                s = jax.lax.dot_general(
                    q[sl], k, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32) * scale  # [rows, P]
                if quantized:       # dequant K: fold the page's scale in
                    s = s * sbuf[slot, 0, kh]
                if softcap is not None:
                    s = jnp.tanh(s / softcap) * softcap
                s = jnp.where(valid, s, NEG_INF)
                m_prev = m_ref[sl]
                m_new = jnp.maximum(m_prev,
                                    jnp.max(s, axis=1, keepdims=True))
                # masked-accumulate: a row with NO valid position anywhere
                # (cl < q_len pad/draft rows) must flush to exactly 0, not
                # exp(NEG_INF - NEG_INF) == 1 garbage weights
                p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
                corr = jnp.exp(m_prev - m_new)
                l_ref[sl] = (l_ref[sl] * corr
                             + jnp.sum(p, axis=1, keepdims=True))
                pv = jax.lax.dot_general(
                    p, v, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                if quantized:       # dequant V: fold into the PV update
                    pv = pv * sbuf[slot, 1, kh]
                acc_ref[sl] = acc_ref[sl] * corr + pv
                m_ref[sl] = m_new

        return _

    jax.lax.fori_loop(0, nb, body, None)
    l = jnp.maximum(l_ref[...], 1e-30)
    o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, pool_k: jax.Array,
                           pool_v: jax.Array, page_table: jax.Array,
                           cache_len: jax.Array, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           interpret: bool = False) -> jax.Array:
    """q [B,H,dh] (single decode token) or [B,S,H,dh] (S <= K+1 verify
    rows, newest last); pools [num_pages+1,P,Hkv,dh]; page_table [B,nb]
    int32; cache_len [B] int32 (valid tokens *including* the newest query
    token, whose KV must already be written through the table);
    ``k_scale``/``v_scale`` [num_pages+1,Hkv] fp32 per-page scales when
    the pools are 8-bit quantized -> output shaped like ``q``."""
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    b, s, h, dh = q.shape
    npg, page_size, hkv, _ = pool_k.shape
    nb = page_table.shape[1]
    g = h // hkv
    quantized = k_scale is not None
    # rows grouped by kv head: [B, Hkv, S, G, dh] -> [B, Hkv*S*G, dh]
    qr = q.reshape(b, s, hkv, g, dh).transpose(0, 2, 1, 3, 4)
    qr = qr.reshape(b, hkv * s * g, dh)
    kern = functools.partial(
        _kernel, page_size=page_size, nb=nb, hkv=hkv, g=g, q_len=s,
        trash=npg - 1, window=window, softcap=softcap, scale=dh ** -0.5,
        quantized=quantized)
    rows = h * s
    any_spec = pl.BlockSpec(memory_space=pltpu.ANY)
    in_specs = [
        pl.BlockSpec((1, rows, dh), lambda i, pt, cl: (i, 0, 0)),
        any_spec,                  # pool_k stays in HBM; kernel DMAs pages
        any_spec,                  # pool_v
    ]
    operands = [qr, pool_k, pool_v]
    scratch = [
        pltpu.VMEM((2, page_size, hkv, dh), pool_k.dtype),  # K page ring
        pltpu.VMEM((2, page_size, hkv, dh), pool_v.dtype),  # V page ring
    ]
    sems = [
        pltpu.SemaphoreType.DMA((2,)),                      # K page DMA
        pltpu.SemaphoreType.DMA((2,)),                      # V page DMA
    ]
    if quantized:
        in_specs += [any_spec, any_spec]
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]
        scratch.append(pltpu.VMEM((2, 2, hkv), jnp.float32))  # ks/vs rows
        sems.append(pltpu.SemaphoreType.DMA((2, 2)))
    grid_spec = _PrefetchGrid(
        num_scalar_prefetch=2,   # page_table + cache_len feed the DMAs
        grid=(b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, rows, dh), lambda i, pt, cl: (i, 0, 0)),
        scratch_shapes=scratch + sems + [
            pltpu.VMEM((rows, 1), jnp.float32),    # running max
            pltpu.VMEM((rows, 1), jnp.float32),    # running denominator
            pltpu.VMEM((rows, dh), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, rows, dh), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(page_table.astype(jnp.int32), cache_len.astype(jnp.int32), *operands)
    out = out.reshape(b, hkv, s, g, dh).transpose(0, 2, 1, 3, 4)
    out = out.reshape(b, s, h, dh)
    return out[:, 0] if squeeze else out
