from repro.kernels.mamba2_scan.kernel import mamba2_scan
from repro.kernels.mamba2_scan.ops import scan, scan_model_layout
from repro.kernels.mamba2_scan.ref import mamba2_scan_ref

__all__ = ["mamba2_scan", "scan", "scan_model_layout", "mamba2_scan_ref"]
