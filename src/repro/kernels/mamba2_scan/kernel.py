"""Pallas TPU kernel: chunked Mamba2 (SSD) scan.

One (batch x head) stream per grid row; the chunk dim is sequential
("arbitrary") so the [N, P] SSM state lives in VMEM scratch across chunks.
Within a chunk the SSD form turns the recurrence into two MXU matmuls
(intra-chunk "attention" + state readout), which is exactly how the XLA
reference in ``repro.models.mamba2`` is structured — the kernel removes the
HBM round-trips between those steps.

Shapes (prepared by ops.py):
    x  [BH, S, P]   dt [BH, S]    (softplus'd, >0)
    b  [BH, S, N]   c  [BH, S, N]
    a  [BH]         (negative per-head decay, -exp(A_log))
Returns y [BH, S, P] and final state [BH, N, P].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams



def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hout_ref, state_ref,
            *, q: int, nc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)               # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)             # [Q]
    b = b_ref[0].astype(jnp.float32)               # [Q, N]
    c = c_ref[0].astype(jnp.float32)               # [Q, N]
    a = a_ref[0].astype(jnp.float32)               # scalar (negative)

    da = dt * a                                    # [Q] log decays
    cum = jnp.cumsum(da)                           # [Q] inclusive
    cum_end = cum[q - 1]

    # intra-chunk: y[i] += sum_{j<=i} exp(cum_i - cum_j) (c_i.b_j) dt_j x_j
    lmat = cum[:, None] - cum[None, :]             # [Q, Q]
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lower = cols <= rows
    decay = jnp.where(lower, jnp.exp(jnp.where(lower, lmat, -60.0)), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    mt = scores * decay * dt[None, :]              # [Q, Q]
    y = jax.lax.dot_general(mt, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y[i] += exp(cum_i) * c_i . state
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, state_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: h = exp(cum_end) h + sum_j exp(cum_end - cum_j) dt_j b_j x_j^T
    kdec = b * (jnp.exp(cum_end - cum) * dt)[:, None]   # [Q, N]
    state_ref[...] = state_ref[...] * jnp.exp(cum_end) + jax.lax.dot_general(
        kdec, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _flush():
        hout_ref[0] = state_ref[...].astype(hout_ref.dtype)


def mamba2_scan(x: jax.Array, dt: jax.Array, b: jax.Array, c: jax.Array,
                a: jax.Array, *, chunk: int = 256,
                interpret: bool = False):
    """See module docstring.  Returns (y [BH,S,P], h_final [BH,N,P])."""
    bh, s, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q
    grid = (bh, nc)

    kern = functools.partial(_kernel, q=q, nc=nc)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, p), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, q), lambda i, ic: (i, ic)),
            pl.BlockSpec((1, q, n), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, q, n), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1,), lambda i, ic: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, p), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, n, p), lambda i, ic: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, b, c, a)
