"""jit'd mamba2 scan op with model-layout adapters."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_scan.kernel import mamba2_scan as _kernel
from repro.kernels.mamba2_scan.ref import mamba2_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def scan(x, dt, b, c, a, *, chunk: int = 256):
    """Kernel on TPU, interpret-mode kernel elsewhere."""
    return _kernel(x, dt, b, c, a, chunk=chunk, interpret=not _on_tpu())


def scan_model_layout(xh, dt, b_in, c_in, a_log, *, chunk: int = 256):
    """Adapter for the model's [B,S,H,P] layout (b/c shared across heads).

    Returns (y [B,S,H,P], h_final [B,H,N,P])."""
    bsz, s, h, p = xh.shape
    n = b_in.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))                   # [H]
    x2 = jnp.swapaxes(xh, 1, 2).reshape(bsz * h, s, p)
    dt2 = jnp.swapaxes(dt, 1, 2).reshape(bsz * h, s)
    bb = jnp.broadcast_to(b_in[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)
    cc = jnp.broadcast_to(c_in[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)
    aa = jnp.broadcast_to(a[None], (bsz, h)).reshape(bsz * h)
    y, hf = scan(x2, dt2, bb, cc, aa, chunk=chunk)
    y = jnp.swapaxes(y.reshape(bsz, h, s, p), 1, 2)
    return y, hf.reshape(bsz, h, n, p)
