"""Oracle: naive per-step SSD recurrence (lax.scan over time).

    h_t = exp(dt_t * a) h_{t-1} + dt_t * b_t (x) x_t
    y_t = c_t . h_t

This is the ground truth for BOTH the Pallas kernel and the chunked XLA
implementation in ``repro.models.mamba2``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba2_scan_ref(x, dt, b, c, a, h0=None):
    """x [BH,S,P], dt [BH,S], b/c [BH,S,N], a [BH] ->
    (y [BH,S,P], h_final [BH,N,P])."""
    bh, s, p = x.shape
    n = b.shape[-1]
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((bh, n, p), f32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                    # [BH,P],[BH],[BH,N],[BH,N]
        decay = jnp.exp(dtt * a)                 # [BH]
        upd = jnp.einsum("bn,b,bp->bnp", bt, dtt, xt)
        h = h * decay[:, None, None] + upd
        y = jnp.einsum("bn,bnp->bp", ct, h)
        return h, y

    xs = (jnp.swapaxes(x.astype(f32), 0, 1), jnp.swapaxes(dt.astype(f32), 0, 1),
          jnp.swapaxes(b.astype(f32), 0, 1), jnp.swapaxes(c.astype(f32), 0, 1))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.swapaxes(ys, 0, 1).astype(x.dtype), h_final
