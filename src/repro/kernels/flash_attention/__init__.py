from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import flash_attention_ref

__all__ = ["flash_attention", "attention", "flash_attention_ref"]
