"""jit'd flash attention op: Pallas on TPU, interpret elsewhere; ref-based
backward via custom_vjp (standard for serving; training uses the XLA path)."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention import ref as _ref
from repro.kernels.flash_attention.kernel import flash_attention as _kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def attention(q, k, v, causal=True, window=None, softcap=None):
    return _kernel(q, k, v, causal=causal, window=window, softcap=softcap,
                   interpret=not _on_tpu())


def _fwd(q, k, v, causal, window, softcap):
    return attention(q, k, v, causal, window, softcap), (q, k, v)


def _bwd(causal, window, softcap, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window, softcap=softcap),
        q, k, v)
    return vjp(g)


attention.defvjp(_fwd, _bwd)
