"""Pure-jnp oracle: direct full-softmax attention."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jax.Array:
    """q [B,H,Sq,dh]; k,v [B,Hkv,Skv,dh] -> [B,H,Sq,dh]."""
    b, h, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    g = h // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
