"""Pallas TPU flash attention (forward): blockwise online softmax.

Layout [B, H, S, dh]; grid (B*H, Sq/bq, Skv/bk) with the KV dim sequential
("arbitrary") so running max / denominator / accumulator live in VMEM
scratch across KV steps.  Supports causal masking, sliding windows, logit
softcap (gemma2/grok) and GQA (kv-head index derived from the q-head grid
index).  Fully-masked KV blocks are skipped with ``pl.when`` — the Pallas
analogue of flash attention's block-sparsity on the causal structure.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, nkv: int, causal: bool,
            window: Optional[int], softcap: Optional[float], scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = iq * bq
    k_lo = ik * bk
    needed = jnp.bool_(True)
    if causal:
        needed = k_lo <= q_lo + bq - 1
    if window is not None:
        needed = jnp.logical_and(needed, k_lo + bk - 1 > q_lo - window)

    @pl.when(needed)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)         # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)         # [bk, dh]
        v = v_ref[0, 0].astype(jnp.float32)         # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = cols <= rows
        if window is not None:
            mask = jnp.logical_and(mask, cols > rows - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nkv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q [B,H,Sq,dh]; k,v [B,Hkv,Skv,dh] -> [B,H,Sq,dh]."""
    b, h, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    g = h // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    while sq % bq:
        bq //= 2
    while skv % bk:
        bk //= 2
    grid = (b * h, sq // bq, skv // bk)
    scale = dh ** -0.5

    kern = functools.partial(
        _kernel, bq=bq, bk=bk, nkv=grid[2], causal=causal, window=window,
        softcap=softcap, scale=scale)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh),
                         lambda i, iq, ik: (i // h, i % h, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda i, iq, ik: (i // h, (i % h) // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda i, iq, ik: (i // h, (i % h) // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda i, iq, ik: (i // h, i % h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running denominator
            pltpu.VMEM((bq, dh), jnp.float32),   # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
