"""Preemption-safe sharded checkpointing (no orbax on this box — built from
scratch per the assignment).

Layout:
    <dir>/step_000123.tmp/            (written)
        manifest.json                 (treedef, shapes, dtypes, step)
        shard_000.npz ...             (leaves, chunked ~512 MB per file)
    <dir>/step_000123/                (atomic rename commit)

Fault-tolerance properties:
  * atomic commit via rename — a killed writer never corrupts the latest
    complete checkpoint;
  * ``restore`` takes an *abstract* state (shapes + shardings) and re-shards
    on load, so a checkpoint written on one mesh restores onto another
    (elastic scaling / failed-node recovery);
  * async mode writes on a background thread with a bounded queue; the
    training loop never blocks more than one pending write.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.optim.adamw import QTensor

SHARD_BYTES = 512 << 20


def _flatten(state) -> Tuple[List[Any], Any]:
    return jax.tree.flatten(state)


def save(ckpt_dir: str, state, step: int) -> Path:
    d = Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    tmp = d / f"step_{step:08d}.tmp"
    final = d / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(state)
    arrays = [np.asarray(x) for x in leaves]
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(arrays),
        "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                   for a in arrays],
        "shards": [],
    }
    shard, shard_bytes, shard_id = {}, 0, 0

    def flush():
        nonlocal shard, shard_bytes, shard_id
        if not shard:
            return
        np.savez(tmp / f"shard_{shard_id:03d}.npz", **shard)
        manifest["shards"].append(
            {"file": f"shard_{shard_id:03d}.npz", "keys": list(shard)})
        shard, shard_bytes = {}, 0
        shard_id += 1

    for i, a in enumerate(arrays):
        shard[f"leaf_{i}"] = a
        shard_bytes += a.nbytes
        if shard_bytes >= SHARD_BYTES:
            flush()
    flush()
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, abstract_state, step: Optional[int] = None):
    """Load a checkpoint onto the shardings of ``abstract_state`` (a pytree
    of ShapeDtypeStruct or arrays).  Mesh-independent: re-shards on load."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    with open(d / "manifest.json") as f:
        manifest = json.load(f)
    flat: Dict[str, np.ndarray] = {}
    for sh in manifest["shards"]:
        with np.load(d / sh["file"]) as z:
            for k in sh["keys"]:
                flat[k] = z[k]
    arrays = [flat[f"leaf_{i}"] for i in range(manifest["num_leaves"])]
    ab_leaves, treedef = _flatten(abstract_state)
    assert len(ab_leaves) == len(arrays), (len(ab_leaves), len(arrays))
    out = []
    for ab, a in zip(ab_leaves, arrays):
        sharding = getattr(ab, "sharding", None)
        dtype = getattr(ab, "dtype", a.dtype)
        arr = a.astype(dtype) if str(a.dtype) != str(dtype) else a
        out.append(jax.device_put(arr, sharding) if sharding is not None
                   else jax.device_put(arr))
    return jax.tree.unflatten(treedef, out), step


class AsyncCheckpointer:
    """One background writer; at most one pending save (back-pressure)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._pending: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, state, step: int) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                save(self.ckpt_dir, host_state, step)
            except BaseException as e:  # noqa: BLE001
                self.last_error = e

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
