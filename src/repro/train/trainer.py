"""Training loop with the fault-tolerance features the 1000-node story
needs, exercised at smoke scale on CPU:

  * checkpoint/restart: async sharded checkpoints + auto-resume;
  * elastic restore: a checkpoint written under one mesh restores onto
    another (re-sharded on load);
  * straggler mitigation: a step watchdog flags steps slower than
    ``watchdog_factor`` x the running median (on real multi-host this is
    where the controller would evict/replace the slow host — here we log
    and count, and the deterministic data pipeline guarantees the replay);
  * deterministic replay: batch(step) is pure, so recovery replays the
    exact stream.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM
from repro.models import forward_train, model_defs
from repro.models import module as m
from repro.optim import adamw, schedule


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 64
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    watchdog_factor: float = 3.0
    peak_lr: float = 3e-4
    seed: int = 0
    remat: bool = False
    param_dtype: Any = None  # default f32 on CPU smoke


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainerConfig):
        import jax.numpy as jnp
        self.cfg = cfg
        self.tc = tc
        self.ocfg = adamw.AdamWConfig(lr=tc.peak_lr)
        dtype = tc.param_dtype or jnp.float32
        defs = model_defs(cfg)
        key = jax.random.PRNGKey(tc.seed)
        params = m.init_params(defs, key, dtype)
        self.state = {"params": params,
                      "opt": adamw.init(params, self.ocfg),
                      "step": jnp.zeros((), jnp.int32)}
        self.data = SyntheticLM(cfg, tc.batch, tc.seq_len, seed=tc.seed)
        self.step_times: List[float] = []
        self.straggler_events: List[Dict] = []
        self.metrics_history: List[Dict] = []
        self._ckpt = None
        if tc.ckpt_dir:
            from repro.train.checkpoint import AsyncCheckpointer
            self._ckpt = AsyncCheckpointer(tc.ckpt_dir)

        ocfg = self.ocfg
        model_cfg = cfg
        remat = tc.remat

        def train_step(state, batch):
            def loss_fn(p):
                loss, metrics = forward_train(p, model_cfg, batch,
                                              remat=remat)
                return loss, metrics
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"])
            lr = schedule.linear_warmup_cosine(
                state["step"], peak_lr=ocfg.lr, warmup=10, total=tc.steps)
            new_p, new_opt, om = adamw.update(grads, state["opt"],
                                              state["params"], ocfg, lr)
            return ({"params": new_p, "opt": new_opt,
                     "step": state["step"] + 1},
                    {**metrics, **om, "lr": lr})

        self._step_fn = jax.jit(train_step, donate_argnums=(0,))

    # ------------------------------------------------------------------
    def maybe_restore(self) -> int:
        if not self.tc.ckpt_dir:
            return 0
        from repro.train import checkpoint as ck
        step = ck.latest_step(self.tc.ckpt_dir)
        if step is None:
            return 0
        self.state, step = ck.restore(self.tc.ckpt_dir, self.state)
        return step

    def run(self, steps: Optional[int] = None) -> Dict:
        steps = steps or self.tc.steps
        start = int(self.state["step"])
        for step in range(start, steps):
            batch = {k: jax.device_put(v)
                     for k, v in self.data.batch_at(step).items()}
            t0 = time.perf_counter()
            self.state, metrics = self._step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self._watchdog(step, dt)
            if step % self.tc.log_every == 0 or step == steps - 1:
                row = {k: float(v) for k, v in metrics.items()}
                row["step"] = step
                row["step_time_s"] = dt
                self.metrics_history.append(row)
            if (self._ckpt and self.tc.ckpt_every
                    and (step + 1) % self.tc.ckpt_every == 0):
                self._ckpt.save(self.state, step + 1)
        if self._ckpt:
            self._ckpt.save(self.state, steps)
            self._ckpt.wait()
        return {"final_loss": self.metrics_history[-1]["loss"],
                "history": self.metrics_history,
                "stragglers": self.straggler_events}

    def _watchdog(self, step: int, dt: float) -> None:
        if len(self.step_times) >= 5:
            med = statistics.median(self.step_times[-20:])
            if dt > self.tc.watchdog_factor * med:
                self.straggler_events.append(
                    {"step": step, "step_time_s": dt, "median_s": med})
        self.step_times.append(dt)
