"""repro: "Exploiting Parallelism Opportunities with Deep Learning
Frameworks" (Wang et al., 2019) as a production-grade TPU/JAX framework.

Entry points:
    repro.core          the paper's technique (graph width -> mesh plan)
    repro.configs       the 10 assigned architectures + shapes
    repro.launch.dryrun multi-pod lower+compile proof
    repro.launch.train / repro.launch.serve   drivers
"""

__version__ = "1.0.0"
