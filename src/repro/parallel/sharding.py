"""Logical-axis sharding: the bridge between model code and meshes.

Model code annotates activations with *logical* axes (``shard(h, BATCH,
SEQ, EMBED)``) and parameters carry logical axes in their ``ParamDef``.
A ``Rules`` table — produced by the paper-technique tuner
(``repro.core.tuner``) — maps logical axes to physical mesh axes.  Outside
an ``axis_rules`` context every annotation is a no-op, so the same model
code runs unsharded on one CPU device (smoke tests) and fully sharded on
the 512-chip production mesh (dry-run).

Divisibility fallback: a rule that does not divide the dimension is dropped
(recorded in ``Rules.fallbacks``) instead of crashing — e.g. gemma2's 8 query
heads on a 16-way model axis.  The roofline then *shows* the waste, which is
exactly the paper's "naive setting" story, and the tuned/factored mesh
removes it.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# logical activation axes
BATCH = "batch"
SEQ = "seq"          # sequence (activations)
KV_SEQ = "kv_seq"    # kv-cache sequence dim (decode: sharded on model)
# paged-KV pool page dim (serving: sharded on data).  One logical axis
# covers every pool group (serve/cache.PoolGroup): each group's pool and
# page-id space shard independently along their own dim-0, and the
# divisibility fallback below drops the rule per-pool where a group's
# (num_pages + trash) row count does not divide the mesh axis.
PAGES = "pages"
EMBED = "act_embed"  # activation d_model dim
HEADS = "act_heads"
MLP = "act_mlp"
EXPERT = "act_expert"
GROUPS = "act_groups"  # MoE dispatch groups
VOCAB = "act_vocab"

MeshAxis = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass
class Rules:
    """logical axis -> mesh axis (or tuple of mesh axes, or None)."""

    table: Dict[str, MeshAxis]
    mesh: Optional[Mesh] = None
    fallbacks: List[str] = dataclasses.field(default_factory=list)
    # context parallelism: activations stay seq-sharded through the blocks
    # (no SP gather); attention gathers KV instead of sharding heads
    context_parallel: bool = False

    def mesh_size(self, axis: MeshAxis) -> int:
        if axis is None or self.mesh is None:
            return 1
        if isinstance(axis, tuple):
            return int(np.prod([self.mesh.shape[a] for a in axis]))
        return int(self.mesh.shape[axis])

    def spec_for(self, logical_axes: Sequence[Optional[str]],
                 dims: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for a tensor with the given logical axes.

        ``dims`` (if given) enables the divisibility fallback.
        """
        entries: List[MeshAxis] = []
        used: set = set()
        for i, ax in enumerate(logical_axes):
            phys = self.table.get(ax) if ax is not None else None
            if phys is not None:
                # a mesh axis may appear only once per spec: keep the unused
                # subtuple (e.g. expert dim takes "pool", ff dim keeps "intra")
                flat = phys if isinstance(phys, tuple) else (phys,)
                flat = tuple(f for f in flat if f not in used)
                phys = None if not flat else (flat if len(flat) > 1 else flat[0])
                if phys is not None and dims is not None and \
                        dims[i] % self.mesh_size(phys) != 0:
                    # try progressively smaller prefixes before giving up
                    while flat and dims[i] % self.mesh_size(
                            flat if len(flat) > 1 else flat[0]) != 0:
                        flat = flat[:-1]
                    if flat:
                        phys = flat if len(flat) > 1 else flat[0]
                    else:
                        self.fallbacks.append(
                            f"{ax}: dim {dims[i]} not divisible")
                        phys = None
            if phys is not None:
                used.update(phys if isinstance(phys, tuple) else (phys,))
            entries.append(phys)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def sharding_for(self, logical_axes, dims=None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec_for(logical_axes, dims))


_ctx = threading.local()


def current_rules() -> Optional[Rules]:
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[Rules]):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op outside a context)."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{logical_axes} vs shape {x.shape}")
    s = rules.sharding_for(logical_axes, x.shape)
    if s is None or all(e is None for e in s.spec):
        return x
    return jax.lax.with_sharding_constraint(x, s)


def sp_boundary(x: jax.Array) -> jax.Array:
    """Megatron-SP boundary: forward all-gathers the sequence dim (forces
    the gather on the *bf16* residual stream, before any f32 norm internals);
    backward constrains the cotangent to seq-sharded, so XLA emits a
    reduce-scatter instead of an all-reduce for the accumulated dx.

    x is [B, S, D].  No-op outside an axis_rules context.
    """
    rules = current_rules()
    if rules is None or rules.mesh is None or \
            rules.table.get(SEQ) is None or rules.context_parallel:
        return x

    @jax.custom_vjp
    def f(y):
        return shard(y, BATCH, None, None)

    def fwd(y):
        return f(y), None

    def bwd(_, ct):
        return (shard(ct, BATCH, SEQ, None),)

    f.defvjp(fwd, bwd)
    return f(x)


def param_shardings(axes_pytree: Any, shapes_pytree: Any,
                    rules: Rules) -> Any:
    """NamedShardings for a parameter tree (axes from ParamDef tables)."""
    return jax.tree.map(
        lambda ax, shp: rules.sharding_for(ax, shp),
        axes_pytree, shapes_pytree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x),
    )


def unsharded_like(tree: Any) -> Any:
    return jax.tree.map(lambda _: None, tree)
