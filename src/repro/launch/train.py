"""End-to-end training driver.

Smoke/real mode (runs on this box):
    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
        --steps 50 --batch 8 --seq 64 --ckpt /tmp/ck

Production mode emits the exact jit/sharding configuration for the 256- or
512-chip mesh and verifies it compiles (the dry-run path), since this box
has no TPU to execute it:
    PYTHONPATH=src python -m repro.launch.train --arch dbrx-132b \
        --shape train_4k --production --multi-pod
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--setting", default="guideline")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.production:
        import os
        os.environ.setdefault(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=512")
        from repro.launch.dryrun import run_cell
        run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                 setting=args.setting)
        return

    from repro.configs import get_config, reduced
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    tc = TrainerConfig(steps=args.steps, batch=args.batch, seq_len=args.seq,
                       ckpt_dir=args.ckpt)
    tr = Trainer(cfg, tc)
    if args.resume and args.ckpt:
        start = tr.maybe_restore()
        print(f"resumed from step {start}")
    result = tr.run()
    for row in result["history"]:
        print(f"step {row['step']:5d} loss {row['loss']:.4f} "
              f"({row['step_time_s']*1e3:.0f} ms)")
    print(f"final loss: {result['final_loss']:.4f}  "
          f"stragglers flagged: {len(result['stragglers'])}")


if __name__ == "__main__":
    main()
