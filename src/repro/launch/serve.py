"""Batched serving driver (smoke scale on CPU; production = dry-run lower).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
        --requests 6 --max-new 12

``--warmup`` pre-compiles every prefill bucket, the jitted cache splice,
and the fused decode chunk before the first request arrives, so the
serving loop never pays a compile (the steady-state loop then runs one
dispatch per ``--sync-interval`` decode steps with zero per-token host
syncs — see docs/serving.md).
"""

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--sync-interval", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--warmup", action="store_true",
                    help="pre-compile prefill buckets + decode chunk")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.engine import Engine, Request

    cfg = reduced(get_config(args.arch))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    eng = Engine(cfg, params, slots=args.slots, max_len=64,
                 temperature=args.temperature, top_k=args.top_k,
                 sync_interval=args.sync_interval)
    if args.warmup:
        t0 = time.perf_counter()
        eng.warmup()
        print(f"warmup: {len(eng.buckets)} prefill buckets "
              f"{eng.buckets} + decode chunk compiled in "
              f"{time.perf_counter() - t0:.2f}s")
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(rid=i, prompt=[1 + i, 2, 3, 4 + i % 3],
                           max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: {r.out_tokens}")
    print(f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {eng.steps} engine steps, "
          f"{eng.host_syncs} host syncs, "
          f"{eng.prefill_compiles} prefill compiles / "
          f"{eng.decode_compiles} decode compiles)")


if __name__ == "__main__":
    main()
