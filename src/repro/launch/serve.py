"""Batched serving driver (smoke scale on CPU; production = dry-run lower).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
        --requests 6 --max-new 12

``--warmup`` pre-compiles every full-prefill bucket, the jitted cache
splice, and the fused decode chunk before the first request arrives, so
the steady-state loop runs one dispatch per ``--sync-interval`` decode
steps with zero per-token host syncs (see docs/serving.md).  Suffix-
prefill executables (prefix hits) still compile lazily on the first hit
per (suffix bucket, ctx bucket) shape — so with the default shared-
prefix workload the first timed run includes one such compile; re-run or
lengthen the workload for steady-state tok/s.

Prefix sharing is on by default for sharing-capable archs (pure
full-attention stacks): requests whose prompts share a cached prefix ride
on refcounted shared pages and prefill only their suffix.  The default
workload sends every request the same prompt head, so the effect shows up
directly in the printed hit rate / pages summary; ``--no-prefix-sharing``
restores exclusive page ownership for comparison.

``--spec-draft ngram --spec-k 4`` turns on speculative decoding
(serve/spec): each fused chunk step drafts K tokens per slot (prompt-
lookup n-gram drafter, or a reduced draft model when an arch name is
given), verifies all K+1 positions in one multi-query paged dispatch,
and commits a variable number via on-device rejection sampling — output
tokens are identical to the non-speculative engine at temperature 0.
The summary prints the measured acceptance rate and tokens per verify
step.

``--policy slo`` switches the scheduler to least-slack-first SLO
ordering (serve/scheduler.py classes: interactive > batch >
best_effort) with class-aware preemption victims and dynamic prefill-
budget throttling; ``--slo-class`` tags the synthetic workload, and
``--traffic poisson:SEED`` / ``--traffic bursty:SEED`` replays a
seeded deterministic mixed-class arrival trace (serve/traffic.py) on a
virtual clock instead, printing per-class TTFT/TPOT percentiles and
goodput from ``Engine.latency_stats()``.

``--trace out.json`` turns on the request-lifecycle tracer
(serve/trace.py): every submit/admit/preempt/resume/finish transition
is recorded host-side at chunk boundaries (zero added device syncs)
and exported as a Chrome-trace/Perfetto timeline — per-slot tracks,
flow arrows following each request across preemption, and counter
tracks for pool occupancy and queue depth.  See docs/observability.md.
"""

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64,
                    help="logical per-slot token cap (widest page-table "
                         "width x page size)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (serve/cache.py paged pools)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page budget of the widest (full-attention) pool "
                         "group; default slots*max_len/page_size (the old "
                         "dense cache's token capacity).  Sliding-window "
                         "groups are always window-sized: slots x "
                         "ceil(window/page_size) pages each")
    ap.add_argument("--kv-dtype", choices=["auto", "fp32", "int8",
                                           "fp8_e4m3"], default="auto",
                    help="paged KV pool storage precision: 8-bit pools "
                         "('int8'/'fp8_e4m3') store per-page fp32 scales "
                         "alongside and dequantize inside the attention "
                         "read (in-kernel on TPU).  'auto'/'fp32' keep "
                         "full-precision pools; an unsupported 8-bit "
                         "dtype falls back to fp32 with a notice")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable radix prefix sharing / copy-on-write "
                         "page reuse (exclusive page ownership)")
    ap.add_argument("--paged-kernel", choices=["auto", "on", "off"],
                    default="auto",
                    help="decode-attention pool reads: 'on' forces the "
                         "pool-direct path (Pallas kernel on TPU, "
                         "pool-wide masked attention elsewhere), 'off' "
                         "forces gather-then-attend (parity debugging), "
                         "'auto' picks the kernel on a probe-passing "
                         "TPU toolchain")
    ap.add_argument("--spec-draft", default="off",
                    help="speculative decoding drafter: 'off' (default), "
                         "'ngram' (prompt-lookup, no second model), or a "
                         "configs/ arch name served reduced as the draft "
                         "model (attention-only archs only)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per verify step (the fused chunk "
                         "verifies K+1 positions per slot per dispatch)")
    ap.add_argument("--shared-prefix", type=int, default=12,
                    help="length of the prompt head shared by every "
                         "request in the synthetic workload (0 = fully "
                         "distinct prompts)")
    ap.add_argument("--chunked-prefill", choices=["auto", "on", "off"],
                    default="auto",
                    help="fused mixed prefill+decode chunks (Sarathi-"
                         "style): prompts stream through the decode "
                         "executable prefill_budget tokens per micro-"
                         "step — no prefill executables at all. 'auto' "
                         "enables it whenever the arch is paged-KV "
                         "capable with no model drafter")
    ap.add_argument("--prefill-budget", type=int, default=32,
                    help="prompt tokens each fused chunk micro-step "
                         "spends per admitting slot (the TTFT-vs-decode-"
                         "jitter knob; only with chunked prefill)")
    ap.add_argument("--sync-interval", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--warmup", action="store_true",
                    help="pre-compile prefill buckets + decode chunk")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="run under a seeded fault-injection schedule "
                         "(serve/chaos.py smoke preset: admission "
                         "denials, preemption storms, slot stalls, "
                         "CoW degradation); asserts every request "
                         "reaches a clean terminal status and zero "
                         "pages leak at drain")
    ap.add_argument("--queue-limit", type=int, default=None,
                    help="bound the admission queue; overflow is "
                         "handled by --shed-policy")
    ap.add_argument("--shed-policy", default="reject",
                    choices=["reject", "block", "evict-lru-prefix"],
                    help="load-shedding at a full admission queue")
    ap.add_argument("--ttl", type=float, default=None,
                    help="per-request deadline in seconds from submit; "
                         "expired requests are reaped as TIMED_OUT at "
                         "the next chunk boundary")
    ap.add_argument("--policy", default="fifo", choices=["fifo", "slo"],
                    help="admission/victim policy: 'slo' orders the "
                         "queue least-slack-first by SLO class "
                         "(interactive > batch > best_effort), picks "
                         "lowest-class preemption victims, and throttles "
                         "non-interactive prefill budgets when an "
                         "interactive TTFT slack goes negative")
    ap.add_argument("--slo-class", default=None,
                    choices=["interactive", "batch", "best_effort"],
                    help="SLO class for every synthetic request "
                         "(default: best_effort; carries per-class "
                         "TTFT/TPOT targets from serve/scheduler.py)")
    ap.add_argument("--traffic", default=None, metavar="PROC:SEED",
                    help="replace the synthetic workload with a seeded "
                         "deterministic arrival trace from "
                         "serve/traffic.py: 'poisson:SEED' or "
                         "'bursty:SEED' (mixed SLO classes and lengths, "
                         "virtual-clock replay, prints per-class "
                         "TTFT/TPOT percentiles + goodput)")
    ap.add_argument("--traffic-rate", type=float, default=8.0,
                    help="arrivals per virtual clock unit for --traffic")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record request-lifecycle trace events "
                         "(serve/trace.py ring buffer; zero added device "
                         "syncs) and export a Chrome-trace/Perfetto "
                         "timeline JSON here at exit — open it in "
                         "ui.perfetto.dev or chrome://tracing; also "
                         "prints the unified Engine.observe() metric "
                         "snapshot and an Engine.explain() causal chain "
                         "for one request.  benchmarks/check_trace.py "
                         "validates the exported schema in CI")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.chaos import ChaosMonkey
    from repro.serve.engine import Engine, Request
    from repro.serve.scheduler import RequestStatus

    from repro.serve.spec import SpecConfig

    cfg = reduced(get_config(args.arch))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    spec = None
    if args.spec_draft != "off":
        spec = SpecConfig(draft=args.spec_draft, k=args.spec_k)
    chaos = None
    if args.chaos is not None:
        chaos = ChaosMonkey.smoke(args.chaos)
    clock = None
    traffic_proc = traffic_seed = None
    if args.traffic is not None:
        from repro.serve.traffic import VirtualClock
        traffic_proc, _, s = args.traffic.partition(":")
        traffic_seed = int(s or 0)
        # virtual clock: arrival times, TTFT/TPOT, and deadlines all move
        # in trace units, one tick per chunk boundary — deterministic on
        # any machine
        clock = VirtualClock(dt=0.05)
    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len,
                 policy=args.policy, clock=clock,
                 page_size=args.page_size, num_pages=args.num_pages,
                 prefix_sharing=not args.no_prefix_sharing,
                 paged_kernel={"auto": "auto", "on": True,
                               "off": False}[args.paged_kernel],
                 spec=spec,
                 temperature=args.temperature, top_k=args.top_k,
                 sync_interval=args.sync_interval,
                 queue_limit=args.queue_limit,
                 shed_policy=args.shed_policy,
                 chaos=chaos,
                 chunked_prefill={"auto": "auto", "on": True,
                                  "off": False}[args.chunked_prefill],
                 prefill_budget=args.prefill_budget,
                 kv_dtype=args.kv_dtype,
                 trace=args.trace is not None)
    if eng.kv_dtype != eng.requested_kv_dtype:
        print(f"kv-dtype: '{eng.requested_kv_dtype}' unsupported on this "
              f"toolchain -> fp32 pools")
    if args.warmup:
        t0 = time.perf_counter()
        eng.warmup()
        if eng.chunked_prefill:
            print(f"warmup: fused prefill+decode chunk "
                  f"(prefill_budget={eng.prefill_budget}) + admission "
                  f"splice compiled in {time.perf_counter() - t0:.2f}s")
        else:
            print(f"warmup: {len(eng.buckets)} prefill buckets "
                  f"{eng.buckets} + decode chunk compiled in "
                  f"{time.perf_counter() - t0:.2f}s")
    t0 = time.perf_counter()
    if args.traffic is not None:
        from repro.serve.traffic import TrafficGenerator, replay
        gen = TrafficGenerator(traffic_seed, rate=args.traffic_rate,
                               process=traffic_proc)
        trace = gen.generate(args.requests)
        replay(eng, trace, clock=clock)
        done = list(eng.finished)
        submitted = done + list(eng.rejected)
        print(f"traffic[{traffic_proc}:{traffic_seed}]: "
              f"{len(trace)} arrivals over "
              f"{trace[-1].arrival:.2f} virtual units, classes="
              f"{sorted(set(tr.slo_class for tr in trace))}")
    else:
        head = [1 + (3 * j) % 97
                for j in range(max(args.shared_prefix, 0))]
        submitted = []
        for i in range(args.requests):
            req = Request(rid=i, prompt=head + [1 + i, 2, 3, 4 + i % 3],
                          max_new_tokens=args.max_new, ttl=args.ttl,
                          slo_class=args.slo_class or "best_effort")
            submitted.append(req)
            eng.submit(req)
        done = eng.run(max_steps=100_000 if chaos is not None else 1000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: {r.out_tokens}")
    print(f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {eng.steps} engine steps, "
          f"{eng.host_syncs} host syncs, "
          f"{eng.prefill_compiles} prefill compiles / "
          f"{eng.suffix_prefill_compiles} suffix compiles / "
          f"{eng.decode_compiles} decode compiles)")
    ms = eng.memory_stats()
    groups = ", ".join(
        f"{k}:{v['num_pages']}p{'w' if v['windowed'] else ''}"
        for k, v in ms["pool_groups"].items())
    print(f"paged KV: page_size={ms['page_size']} pools=[{groups}] "
          f"kv_dtype={ms.get('kv_dtype', 'fp32')} "
          f"peak_pages_in_use={ms['peak_pages_in_use']} "
          f"dense/paged capacity ratio="
          f"{ms['dense_vs_paged_capacity_ratio']:.2f} "
          f"decode_attention="
          f"{'pool-direct' if eng.paged_kernel else 'gather'} "
          f"prefill="
          f"{'fused-chunked' if eng.chunked_prefill else 'bucketed'}")
    ss = eng.spec_stats()
    if ss["spec"]:
        print(f"speculative [{ss['drafter']}, k={ss['spec_k']}]: "
              f"acceptance={ss['acceptance_rate']:.2f} "
              f"({ss['accepted_tokens']}/{ss['drafted_tokens']} drafts), "
              f"{ss['tokens_per_step']:.2f} tokens/verify-step over "
              f"{ss['spec_steps']} steps")
    fs = eng.fault_stats()
    print(f"faults: {fs['preemptions']} preemptions "
          f"({fs['pressure_preemptions']} pressure / "
          f"{fs['chaos_preemptions']} chaos / "
          f"{fs['watchdog_preemptions']} watchdog), "
          f"{fs['resumes']} resumes "
          f"(recovered_prefill={fs['recovered_prefill_fraction']:.2f}), "
          f"{fs['timed_out']} timed out, {fs['cancelled']} cancelled, "
          f"{fs['rejected']} rejected, "
          f"{eng.leaked_pages()} leaked pages")
    if chaos is not None:
        cs = fs["chaos"]
        print(f"chaos[seed={cs['seed']}]: "
              f"{cs['admission_denials']} admission denials, "
              f"{cs['forced_preemptions']} forced preemptions, "
              f"{cs['stalls_started']} stalls, "
              f"{cs['sharing_faults']} sharing faults")
        bad = [r for r in submitted
               if r.status not in RequestStatus.TERMINAL]
        assert not bad, f"non-terminal requests after drain: " \
            f"{[(r.rid, r.status) for r in bad]}"
        assert eng.leaked_pages() == 0, \
            f"leaked {eng.leaked_pages()} pages at drain"
        print("chaos: clean drain (all terminal statuses, zero leaked "
              "pages)")
    if args.traffic is not None or args.slo_class is not None \
            or args.policy != "fifo":
        ls = eng.latency_stats()
        unit = "vu" if clock is not None else "s"
        for name, c in sorted(ls["classes"].items()):
            print(f"slo[{name}]: n={c['count']} "
                  f"goodput={c['goodput'] if c['goodput'] is not None else '-'} "
                  f"ttft_p50/p99={c['ttft_p50']}/{c['ttft_p99']}{unit} "
                  f"tpot_p50/p99={c['tpot_p50']}/{c['tpot_p99']}{unit}")
        print(f"slo overall: goodput={ls['goodput']} "
              f"budget_throttles={ls['budget_throttles']} "
              f"policy={args.policy}")
    ps = eng.prefix_stats()
    if ps["prefix_sharing"]:
        print(f"prefix sharing: hit_rate={ps['prefix_hit_rate']:.2f} "
              f"({ps['prefix_hits']}/{ps['admissions']} admissions), "
              f"{ps['prefill_tokens_skipped']} prefill tokens skipped, "
              f"{ps['shared_page_attaches']} shared attaches, "
              f"{ps['cow_copies']} CoW copies, "
              f"{ps['radix_evictions']} evictions, "
              f"{ps['radix_pages']} pages indexed")
    if args.trace is not None:
        eng.export_trace(args.trace)
        obs = eng.observe(spec=False)
        print(f"trace: {obs['trace.events']} lifecycle events "
              f"({obs['trace.dropped']} dropped) over "
              f"{obs['engine.chunks']} chunks -> {args.trace} "
              f"(open in ui.perfetto.dev)")
        if done:
            print(eng.explain(min(r.rid for r in done)))


if __name__ == "__main__":
    main()
