"""Batched serving driver (smoke scale on CPU; production = dry-run lower).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
        --requests 6 --max-new 12
"""

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.engine import Engine, Request

    cfg = reduced(get_config(args.arch))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    eng = Engine(cfg, params, slots=args.slots, max_len=64)
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(rid=i, prompt=[1 + i, 2, 3, 4 + i % 3],
                           max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: {r.out_tokens}")
    print(f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {eng.steps} engine steps)")


if __name__ == "__main__":
    main()
