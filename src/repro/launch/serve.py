"""Batched serving driver (smoke scale on CPU; production = dry-run lower).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --smoke \
        --requests 6 --max-new 12

``--warmup`` pre-compiles every prefill bucket, the jitted cache splice,
and the fused decode chunk before the first request arrives, so the
serving loop never pays a compile (the steady-state loop then runs one
dispatch per ``--sync-interval`` decode steps with zero per-token host
syncs — see docs/serving.md).
"""

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64,
                    help="logical per-slot token cap (page-table width "
                         "x page size)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (serve/cache.py paged pools)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="shared KV page budget; default slots*max_len/"
                         "page_size (the old dense cache's token capacity;"
                         " windowed archs pay more bytes — see "
                         "dense/paged ratio in the output)")
    ap.add_argument("--sync-interval", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--warmup", action="store_true",
                    help="pre-compile prefill buckets + decode chunk")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.engine import Engine, Request

    cfg = reduced(get_config(args.arch))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len,
                 page_size=args.page_size, num_pages=args.num_pages,
                 temperature=args.temperature, top_k=args.top_k,
                 sync_interval=args.sync_interval)
    if args.warmup:
        t0 = time.perf_counter()
        eng.warmup()
        print(f"warmup: {len(eng.buckets)} prefill buckets "
              f"{eng.buckets} + decode chunk compiled in "
              f"{time.perf_counter() - t0:.2f}s")
    t0 = time.perf_counter()
    for i in range(args.requests):
        eng.submit(Request(rid=i, prompt=[1 + i, 2, 3, 4 + i % 3],
                           max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: {r.out_tokens}")
    print(f"{len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {eng.steps} engine steps, "
          f"{eng.host_syncs} host syncs, "
          f"{eng.prefill_compiles} prefill compiles / "
          f"{eng.decode_compiles} decode compiles)")
    ms = eng.memory_stats()
    print(f"paged KV: page_size={ms['page_size']} num_pages={ms['num_pages']} "
          f"peak_pages_in_use={ms['peak_pages_in_use']} "
          f"dense/paged capacity ratio={ms['dense_vs_paged_capacity_ratio']:.2f}")


if __name__ == "__main__":
    main()
