import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every live
(architecture x input-shape) cell on the 16x16 single-pod mesh and the
2x16x16 multi-pod mesh, print memory/cost analysis, and dump the roofline
inputs to results/dryrun/.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \
        --shape train_4k --multi-pod --setting guideline
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.analysis import hlo, roofline
from repro.configs import get_config, get_shape, live_cells
from repro.launch import build as buildlib
from repro.launch import mesh as meshlib

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             setting: str = "guideline", factored: bool = False,
             plan=None, tag: str = "", save: bool = True,
             verbose: bool = True) -> dict:
    t0 = time.time()
    built = buildlib.build(arch, shape_name, setting=setting,
                           multi_pod=multi_pod, factored=factored, plan=plan)
    mesh_name = ("multi" if multi_pod else "single") + \
        ("-factored" if factored and built.plan.pools > 1 else "")
    chips = 512 if multi_pod else 256

    lowered = built.lower()
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    text = compiled.as_text()
    roof = roofline.analyze(
        built.cfg, built.shape, arch=arch, mesh_name=mesh_name,
        setting=setting if plan is None else plan.name, chips=chips,
        cost={k: cost.get(k, 0.0) for k in ("flops", "bytes accessed",
                                            "transcendentals")},
        hlo_text=text, memory_stats=roofline.memory_stats_dict(ma),
        note=built.notes)
    row = roof.row()
    row.update({
        "plan": {"pools": built.plan.pools, "intra": built.plan.intra,
                 "data": built.plan.data, "fsdp": built.plan.fsdp,
                 "seq_shard": built.plan.seq_shard,
                 "pod_mode": built.plan.pod_mode},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": roofline.memory_stats_dict(ma),
        "sharding_fallbacks": sorted(set(built.rules.fallbacks)),
        "ok": True,
    })
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name} x {row['setting']}] "
              f"compile={t_compile:.1f}s "
              f"mem/dev={row['memory_per_device_bytes']/2**30:.2f}GiB "
              f"flops/dev={row['flops_per_device']:.3e} "
              f"wire/dev={row['wire_bytes_per_device']/2**20:.1f}MiB "
              f"dominant={row['dominant']} frac={row['roofline_frac']:.3f}")
        print(f"  memory_analysis: {ma}")
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        name = f"{arch}__{shape_name}__{mesh_name}__{row['setting']}{tag}.json"
        with open(RESULTS / name, "w") as f:
            json.dump(row, f, indent=1)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--setting", default="guideline",
                    choices=("guideline", "tf", "intel"))
    ap.add_argument("--factored", action="store_true",
                    help="use the tuner's factored (data,pool,intra) mesh")
    ap.add_argument("--all", action="store_true",
                    help="every live cell on both meshes")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--continue-on-error", action="store_true", default=True)
    args = ap.parse_args()

    print(f"devices: {len(jax.devices())} ({jax.devices()[0].platform})")
    cells = live_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]

    # cheap cells first so partial runs cover the most ground
    order = {"decode_32k": 0, "long_500k": 1, "prefill_32k": 2, "train_4k": 3}
    cells = sorted(cells, key=lambda c: order.get(c[1], 9))
    failures = []
    for arch, shape_name in cells:
        for mp in meshes:
            mesh_name = "multi" if mp else "single"
            out = RESULTS / (f"{arch}__{shape_name}__{mesh_name}__"
                             f"{args.setting}.json")
            if args.skip_existing and out.exists():
                continue
            try:
                run_cell(arch, shape_name, multi_pod=mp,
                         setting=args.setting, factored=args.factored)
            except Exception as e:  # noqa: BLE001 - report all cell failures
                failures.append((arch, shape_name, mp, repr(e)))
                print(f"FAIL [{arch} x {shape_name} x "
                      f"{'multi' if mp else 'single'}]: {e}")
                traceback.print_exc(limit=4)
                if not args.continue_on_error:
                    raise
    print(f"\n{len(cells) * len(meshes) - len(failures)} ok, "
          f"{len(failures)} failed")
    for f in failures:
        print("  FAILED:", f)


if __name__ == "__main__":
    main()
