"""Production meshes.

``make_production_mesh`` is the spec-mandated entry point: 16x16 = 256 chips
per pod, and 2x16x16 = 512 chips for the multi-pod dry-run.  It is a
function (never a module-level constant) so importing this module touches no
jax device state.

``make_tuned_mesh`` reshapes the *same* device order into the paper-tuner's
factored ("data", "pool", "intra") axes when a plan wants ``pools > 1`` that
the flat model axis cannot express (e.g. grok's 8 experts on a 16-wide
axis).  Device order is preserved, so ICI adjacency assumptions carry over.
"""

from __future__ import annotations

from typing import Optional

import jax

try:  # AxisType / axis_types= landed after jax 0.4.37; run without it there
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _auto(n: int):
    return (AxisType.Auto,) * n if AxisType is not None else None


def _make_mesh(shape, axes):
    at = _auto(len(axes))
    if at is None:
        return jax.make_mesh(shape, axes)
    try:
        return jax.make_mesh(shape, axes, axis_types=at)
    except TypeError:  # pragma: no cover - older make_mesh signature
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_tuned_mesh(pools: int, *, multi_pod: bool = False,
                    model_axis: int = 16, data_axis: int = 16):
    if pools <= 1:
        return make_production_mesh(multi_pod=multi_pod)
    assert model_axis % pools == 0, (model_axis, pools)
    intra = model_axis // pools
    if multi_pod:
        return _make_mesh((2, data_axis, pools, intra),
                          ("pod", "data", "pool", "intra"))
    return _make_mesh((data_axis, pools, intra), ("data", "pool", "intra"))


def mesh_for_plan(plan, *, multi_pod: bool = False, factored: bool = False):
    """The mesh a plan runs on.  ``factored=False`` keeps the spec-mandated
    axes (pool degree expressed through divisible dims only)."""
    if factored and plan.pools > 1:
        return make_tuned_mesh(plan.pools, multi_pod=multi_pod,
                               model_axis=plan.pools * plan.intra,
                               data_axis=plan.data)
    return make_production_mesh(multi_pod=multi_pod)


def describe(mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())
