"""Launcher glue: (arch, shape, setting, mesh) -> jit-able step functions +
abstract (zero-allocation) inputs for the dry-run, or real initialized state
for the examples/trainer.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_shape
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import tuner
from repro.launch import mesh as meshlib
from repro.models import (cache_structure, forward_decode, forward_prefill,
                          forward_train, model_defs)
from repro.models import module as m
from repro.optim import adamw, schedule
from repro.parallel import sharding as sh

PARAM_DTYPE = jnp.bfloat16
SETTINGS = {
    "guideline": tuner.guideline_plan,
    "tf": tuner.tf_setting,
    "intel": tuner.intel_setting,
}


@dataclasses.dataclass
class Built:
    cfg: ModelConfig
    shape: ShapeConfig
    plan: tuner.Plan
    mesh: Any
    rules: sh.Rules
    step_fn: Callable              # jit-able (state/batch signatures below)
    abstract_args: Tuple           # ShapeDtypeStructs for .lower()
    opt_cfg: Optional[adamw.AdamWConfig] = None
    notes: str = ""

    def lower(self):
        with self.mesh:
            with sh.axis_rules(self.rules):
                return jax.jit(self.step_fn, donate_argnums=(0,)).lower(
                    *self.abstract_args)


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def _tok_spec(rules: sh.Rules, b: int, s: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(
        (b, s), jnp.int32,
        sharding=rules.sharding_for((sh.BATCH, None), (b, s)))


def _embed_spec(rules: sh.Rules, b: int, f: int, d: int):
    return jax.ShapeDtypeStruct(
        (b, f, d), PARAM_DTYPE,
        sharding=rules.sharding_for((sh.BATCH, None, None), (b, f, d)))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, rules: sh.Rules) -> Dict:
    b = shape.global_batch
    s = shape.seq_len
    out: Dict[str, Any] = {"tokens": _tok_spec(rules, b, s)}
    if shape.kind == "train":
        out["labels"] = _tok_spec(rules, b, s)
    if cfg.family == "audio":
        out["frames"] = _embed_spec(rules, b, cfg.frontend_len, cfg.d_model)
    elif cfg.frontend:
        out["frontend"] = _embed_spec(rules, b, cfg.frontend_len, cfg.d_model)
    return out


def abstract_tree(struct: Any, rules: sh.Rules, dtype=PARAM_DTYPE):
    """cache_structure-style nested {name: (shape, axes)} -> SDS tree."""
    def is_leaf(x):
        return (isinstance(x, tuple) and len(x) == 2
                and isinstance(x[0], tuple))

    def mk(leaf):
        shp, axes = leaf
        dt = jnp.int32 if axes and axes == (sh.BATCH,) and len(shp) == 1 else dtype
        return jax.ShapeDtypeStruct(
            shp, dt, sharding=rules.sharding_for(axes, shp))

    return jax.tree.map(mk, struct, is_leaf=is_leaf)


def abstract_model_params(cfg: ModelConfig, rules: sh.Rules):
    defs = model_defs(cfg)
    axes = m.axes_tree(defs)
    shapes = m.shapes_tree(defs)
    shardings = sh.param_shardings(axes, shapes, rules)
    return m.abstract_params(defs, PARAM_DTYPE, shardings), defs


def zero1_sharding_fn(cfg: ModelConfig, rules: sh.Rules, defs):
    """Optimizer-state shardings: param rules with the d_model/param axes
    forced onto the data axis (ZeRO-1)."""
    table = dict(rules.table)
    dp = table.get(sh.BATCH)
    table[m.EMBED] = dp  # always shard states over data
    zrules = sh.Rules(table=table, mesh=rules.mesh)
    axes = m.axes_tree(defs)
    shapes = m.shapes_tree(defs)
    flat_axes = jax.tree.leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    flat_shapes = jax.tree.leaves(
        shapes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, int) for e in x))
    by_shape = {}
    for ax, shp in zip(flat_axes, flat_shapes):
        by_shape.setdefault(shp, ax)

    def fn(p):
        ax = by_shape.get(tuple(p.shape))
        if ax is None:
            return None
        return zrules.sharding_for(ax, p.shape)

    return fn


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def build(arch: str, shape_name: str, *, setting: str = "guideline",
          multi_pod: bool = False, factored: bool = False,
          remat: bool = False, quantize_v: Optional[bool] = None,
          q_chunk: Optional[int] = None, plan: Optional[tuner.Plan] = None,
          ) -> Built:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    pods = 2 if multi_pod else 1
    if plan is None:
        plan = SETTINGS[setting](cfg, shape, pods=pods)
    mesh = meshlib.mesh_for_plan(plan, multi_pod=multi_pod, factored=factored)
    rules = tuner.make_rules(plan, mesh)
    if q_chunk is None:
        q_chunk = 2048 if shape.kind == "train" else 4096

    if shape.kind == "train":
        return _build_train(cfg, shape, plan, mesh, rules, remat=remat,
                            quantize_v=quantize_v, q_chunk=q_chunk)
    if shape.kind == "prefill":
        return _build_prefill(cfg, shape, plan, mesh, rules, q_chunk=q_chunk)
    return _build_decode(cfg, shape, plan, mesh, rules)


def _build_train(cfg, shape, plan, mesh, rules, *, remat, quantize_v,
                 q_chunk) -> Built:
    params, defs = abstract_model_params(cfg, rules)
    nparams = m.param_count(defs)
    if quantize_v is None:
        quantize_v = nparams > 50e9  # big models need the int8 second moment
    ocfg = adamw.AdamWConfig(quantize_v=quantize_v)
    zfn = zero1_sharding_fn(cfg, rules, defs)
    opt = adamw.abstract_state(params, ocfg, m_sharding_fn=zfn)
    state = {"params": params, "opt": opt,
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    batch = batch_specs(cfg, shape, rules)

    def train_step(st, bt):
        def loss_fn(p):
            loss, metrics = forward_train(p, cfg, bt, q_chunk=q_chunk,
                                          remat=remat)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(st["params"])
        lr = schedule.linear_warmup_cosine(st["step"], peak_lr=ocfg.lr)
        new_p, new_opt, om = adamw.update(grads, st["opt"], st["params"],
                                          ocfg, lr)
        new_state = {"params": new_p, "opt": new_opt, "step": st["step"] + 1}
        return new_state, {**metrics, **om, "lr": lr}

    return Built(cfg, shape, plan, mesh, rules, train_step, (state, batch),
                 opt_cfg=ocfg, notes=plan.notes)


def _build_prefill(cfg, shape, plan, mesh, rules, *, q_chunk) -> Built:
    params, _ = abstract_model_params(cfg, rules)
    batch = batch_specs(cfg, shape, rules)

    def prefill_step(params_, bt):
        return forward_prefill(params_, cfg, bt, q_chunk=q_chunk)

    return Built(cfg, shape, plan, mesh, rules, prefill_step, (params, batch),
                 notes=plan.notes)


def _build_decode(cfg, shape, plan, mesh, rules) -> Built:
    params, _ = abstract_model_params(cfg, rules)
    b = shape.global_batch
    struct = cache_structure(cfg, b, shape.seq_len)
    cache = abstract_tree(struct, rules)
    cache["len"] = jax.ShapeDtypeStruct(
        (b,), jnp.int32, sharding=rules.sharding_for((sh.BATCH,), (b,)))
    tokens = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32,
        sharding=rules.sharding_for((sh.BATCH, None), (b, 1)))

    def decode_step(cache_, params_, tokens_):
        logits, new_cache = forward_decode(params_, cfg, tokens_, cache_)
        return new_cache, logits

    return Built(cfg, shape, plan, mesh, rules, decode_step,
                 (cache, params, tokens), notes=plan.notes)
