"""Batched serving on the sync-free fast path: 8 ragged requests through
4 cache slots of a reduced rwkv6 (O(1)-state decode), plus a
prefill/decode consistency check and a temperature/top-k sampling demo.

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import forward_prefill, model_defs
from repro.models import module as m
from repro.serve.engine import Engine, Request


def main() -> None:
    cfg = reduced(get_config("rwkv6-7b"), layers=2, d_model=64)
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    eng = Engine(cfg, params, slots=4, max_len=64)
    eng.warmup()   # pre-compile prefill buckets + fused decode chunk
    t0 = time.perf_counter()
    for i in range(8):
        # ragged prompt lengths exercise the power-of-two prefill buckets
        eng.submit(Request(rid=i, prompt=[(7 * i + j) % cfg.vocab_size
                                          for j in range(3 + i)],
                           max_new_tokens=10))
    done = eng.run()
    dt = time.perf_counter() - t0
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: {r.out_tokens}")
    toks = sum(len(r.out_tokens) for r in done)
    print(f"{len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"({eng.steps} batched decode steps, {eng.host_syncs} host "
          f"syncs, {eng.prefill_compiles} prefill compiles for "
          f"{len(set(len(r.prompt) for r in done))} prompt lengths)")
    assert len(done) == 8 and all(len(r.out_tokens) == 10 for r in done)

    # consistency: greedy continuation from the engine matches teacher-forced
    # logits from a fresh unpadded prefill of prompt+generated tokens
    r0 = done[0]
    full = r0.prompt + r0.out_tokens[:-1]
    logits, _ = jax.jit(lambda p, b: forward_prefill(p, cfg, b))(
        params, {"tokens": jnp.asarray([full], jnp.int32)})
    nxt = int(jnp.argmax(logits[0]))
    assert nxt == r0.out_tokens[-1], (nxt, r0.out_tokens[-1])
    print("prefill/decode consistency check passed")

    # non-greedy: on-device temperature + top-k sampling, seeded PRNG
    eng2 = Engine(cfg, params, slots=2, max_len=64, greedy=False,
                  temperature=1.0, top_k=8, seed=7)
    for i in range(4):
        eng2.submit(Request(rid=i, prompt=[5, 6, 7], max_new_tokens=8))
    sampled = eng2.run()
    assert len(sampled) == 4 and all(len(r.out_tokens) == 8 for r in sampled)
    outs = {tuple(r.out_tokens) for r in sampled}
    print(f"sampled {len(outs)} distinct continuations from 4 identical "
          f"prompts (temperature=1.0, top_k=8)")


if __name__ == "__main__":
    main()
