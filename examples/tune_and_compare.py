"""The paper's contribution in action: analyze each architecture's graph
width, derive the guideline plan, and compare its cost-model step time with
the TensorFlow / Intel recommended-setting analogues and the exhaustive
sweep optimum (Fig. 18 at mesh-plan granularity).

    PYTHONPATH=src python examples/tune_and_compare.py
"""

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core import autotune, build_graph, guideline_plan


def main() -> None:
    shape = SHAPES["train_4k"]
    print(f"{'arch':22s} {'avg_w':>5s} {'max_w':>5s} {'plan':>14s} "
          f"{'guideline':>10s} {'tf':>10s} {'intel':>10s} {'optimum':>10s} "
          f"{'gap':>6s}")
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        g = build_graph(cfg, training=True, global_batch=shape.global_batch)
        rows = autotune.compare_settings(cfg, shape)
        opt = rows["global_optimum"].step_s
        gap = rows["guideline"].step_s / opt if opt else float("nan")
        plan = rows["guideline"].plan
        print(f"{arch:22s} {g.avg_width:5d} {g.max_width:5d} "
              f"{'p%d·i%d%s' % (plan.pools, plan.intra, '·fsdp' if plan.fsdp else ''):>14s} "
              f"{rows['guideline'].step_s*1e3:9.1f}ms "
              f"{rows['tf_setting'].step_s*1e3:9.1f}ms "
              f"{rows['intel_setting'].step_s*1e3:9.1f}ms "
              f"{opt*1e3:9.1f}ms {gap:6.2f}")
    print("\ngap = guideline / swept-optimum (1.00 = guideline matches the "
          "exhaustive search, the paper's Fig. 18 claim)")


if __name__ == "__main__":
    main()
