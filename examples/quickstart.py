"""Quickstart: train a reduced gemma2 for 100 steps on CPU, watch the loss
fall, checkpoint, and resume.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.configs import get_config, reduced
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    cfg = reduced(get_config("gemma2-2b"), layers=2, d_model=64)
    with tempfile.TemporaryDirectory() as ckpt:
        tc = TrainerConfig(steps=100, batch=8, seq_len=32, ckpt_dir=ckpt,
                           ckpt_every=50, log_every=20)
        trainer = Trainer(cfg, tc)
        result = trainer.run()
        print("--- loss curve ---")
        for row in result["history"]:
            print(f"step {row['step']:4d}  loss {row['loss']:.4f}")
        first, last = result["history"][0], result["history"][-1]
        assert last["loss"] < first["loss"], "loss did not decrease!"
        print(f"\nloss fell {first['loss']:.3f} -> {last['loss']:.3f}; "
              f"checkpoints written to {ckpt}")

        # resume from the checkpoint and take a few more steps
        trainer2 = Trainer(cfg, TrainerConfig(steps=110, batch=8, seq_len=32,
                                              ckpt_dir=ckpt))
        start = trainer2.maybe_restore()
        print(f"restored at step {start}; continuing to 110")
        trainer2.run()
        print("resume OK")


if __name__ == "__main__":
    main()
