"""Speculative decoding subsystem (serve/spec): drafter units, the
rejection sampler's greedy/stochastic semantics, engine-level token
parity (full-attention and ring-wrapped windowed archs), rollback of
rejected drafts, EOS/budget clamping, self-speculation acceptance, the
capability gate, and the sync-free/single-executable properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import forward_dense_logits, model_defs
from repro.models import module as m
from repro.serve import sampling
from repro.serve.engine import Engine, Request
from repro.serve.spec import SpecConfig, ngram_propose


def _model(arch, **kw):
    cfg = reduced(get_config(arch), **kw)
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    return cfg, params


def _run(cfg, params, spec, reqs, **kw):
    eng = Engine(cfg, params, spec=spec, **kw)
    for i, (prompt, mx) in enumerate(reqs):
        eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=mx))
    done = eng.run(max_steps=100_000)
    assert len(done) == len(reqs)
    return {r.rid: r.out_tokens for r in done}, eng


# ---------------------------------------------------------------------------
# Drafter units
# ---------------------------------------------------------------------------

def test_ngram_propose_lookup_and_fallbacks():
    cap = 24
    # 1 2 3 4 1 2 3 -> trailing (2,3) seen at pos 1 -> continue 4 1 2
    h = np.zeros((1, cap + 1), np.int32)
    h[0, :7] = [1, 2, 3, 4, 1, 2, 3]
    d = ngram_propose(jnp.asarray(h), jnp.asarray([7]), k=3, n=2)
    assert d.tolist() == [[4, 1, 2]]
    # constant run: periodic extension keeps drafting the constant
    h2 = np.zeros((1, cap + 1), np.int32)
    h2[0, :10] = 7
    d2 = ngram_propose(jnp.asarray(h2), jnp.asarray([10]), k=4, n=3)
    assert d2.tolist() == [[7, 7, 7, 7]]
    # period-2 cycle wraps through the period
    h3 = np.zeros((1, cap + 1), np.int32)
    h3[0, :10] = [3, 9] * 5
    d3 = ngram_propose(jnp.asarray(h3), jnp.asarray([10]), k=5, n=3)
    assert d3.tolist() == [[3, 9, 3, 9, 3]]
    # no earlier match: repeat the last token (cheap fallback)
    h4 = np.zeros((1, cap + 1), np.int32)
    h4[0, :4] = [5, 6, 7, 8]
    d4 = ngram_propose(jnp.asarray(h4), jnp.asarray([4]), k=2, n=2)
    assert d4.tolist() == [[8, 8]]


# ---------------------------------------------------------------------------
# Accept/reject sampler semantics
# ---------------------------------------------------------------------------

def _onehot_logits(seq, v=11):
    return jnp.asarray([[np.where(np.arange(v) == t, 5.0, -5.0)
                         for t in seq]], jnp.float32)


def test_spec_accept_greedy_is_exact():
    """At temperature 0 the rule must reproduce sequential greedy: accept
    while the draft matches the target argmax, then emit the argmax."""
    logits = _onehot_logits([2, 3, 4, 5])          # [1, K+1=4, V]
    t0 = jnp.zeros((1,), jnp.float32)
    key = jax.random.PRNGKey(0)
    cand, n_acc = sampling.spec_accept(
        logits, jnp.asarray([[2, 3, 4]]), None, t0, 0, key)
    assert int(n_acc[0]) == 3 and cand[0, :4].tolist() == [2, 3, 4, 5]
    cand, n_acc = sampling.spec_accept(
        logits, jnp.asarray([[9, 3, 4]]), None, t0, 0, key)
    assert int(n_acc[0]) == 0 and int(cand[0, 0]) == 2
    cand, n_acc = sampling.spec_accept(
        logits, jnp.asarray([[2, 9, 4]]), None, t0, 0, key)
    assert int(n_acc[0]) == 1 and cand[0, :2].tolist() == [2, 3]


def test_spec_accept_matches_target_distribution():
    """Speculative sampling guarantee: whatever the proposal, the first
    emitted token's marginal equals the target distribution."""
    v, k = 5, 2
    key = jax.random.PRNGKey(3)
    plog = jax.random.normal(key, (1, k + 1, v)) * 1.5
    qlog = jax.random.normal(jax.random.fold_in(key, 1), (1, k, v))
    temp = jnp.ones((1,), jnp.float32)
    qprobs = sampling.spec_probs(qlog, temp, 0)

    def one(sample_key):
        dk, ak = jax.random.split(sample_key)
        drafts = jax.random.categorical(dk, qlog[0], axis=-1)[None]
        cand, _ = sampling.spec_accept(plog, drafts.astype(jnp.int32),
                                       qprobs, temp, 0, ak)
        return cand[0, 0]

    n = 6000
    toks = jax.vmap(one)(jax.random.split(jax.random.PRNGKey(7), n))
    emp = np.bincount(np.asarray(toks), minlength=v) / n
    want = np.asarray(sampling.spec_probs(plog, temp, 0))[0, 0]
    np.testing.assert_allclose(emp, want, atol=0.03)


def test_spec_update_budget_and_eos():
    state = sampling.make_slot_state(2, 0, hist_cap=16)
    state["active"] = jnp.asarray([True, True])
    state["max_new"] = jnp.asarray([10, 2], jnp.int32)
    state["eos"] = jnp.asarray([4, -1], jnp.int32)
    state["hist_len"] = jnp.asarray([3, 3], jnp.int32)
    cand = jnp.asarray([[2, 3, 4, 5], [7, 8, 9, 6]], jnp.int32)
    st, emitted, n_emit = sampling.spec_update(
        state, cand, jnp.asarray([3, 3], jnp.int32), jax.random.PRNGKey(1))
    # slot 0 stops at its EOS (emits it); slot 1 is budget-clamped to 2
    assert n_emit.tolist() == [3, 2]
    assert emitted.tolist() == [[2, 3, 4, -1], [7, 8, -1, -1]]
    assert st["active"].tolist() == [False, False]
    assert st["hist"][0, 3:6].tolist() == [2, 3, 4]
    assert st["tokens"].tolist() == [4, 8]


# ---------------------------------------------------------------------------
# Engine-level parity: drafted/verified decode must be invisible at T=0
# ---------------------------------------------------------------------------

def _ragged_reqs(cfg, n=5, max_new=9):
    out = []
    for i in range(n):
        plen = 2 + (4 * i) % 7
        out.append(([(5 * i + j) % cfg.vocab_size for j in range(plen)],
                    max_new - i % 3))
    return out


@pytest.mark.parametrize("k", [1, 4])
def test_spec_ngram_matches_plain_engine(k):
    cfg, params = _model("internlm2-1.8b")
    reqs = _ragged_reqs(cfg)
    base, _ = _run(cfg, params, None, reqs, slots=2, max_len=64)
    spec, eng = _run(cfg, params, SpecConfig(draft="ngram", k=k), reqs,
                     slots=2, max_len=64)
    assert spec == base
    assert eng.decode_compiles == 1 and eng.admit_compiles == 1


def test_spec_windowed_ring_wrap_matches_teacher_forcing():
    """gemma2's sliding-window rings wrap during a drafted run; the
    spec-slack ring sizing must keep multi-token writes from clobbering
    in-window history.  Teacher forcing is the oracle."""
    cfg, params = _model("gemma2-2b")
    window = next(b.window for b in cfg.blocks if b.window)
    n_new = window + 10
    _, eng = _run(cfg, params, SpecConfig(draft="ngram", k=4),
                  [([3, 1, 4, 1, 5], n_new)], slots=1, max_len=96)
    (r,) = eng.finished
    full = r.prompt + r.out_tokens
    dense = jax.jit(lambda p, b: forward_dense_logits(p, cfg, b))(
        params, {"tokens": jnp.asarray([full], jnp.int32)})
    for i, tok in enumerate(r.out_tokens):
        pos = len(r.prompt) - 1 + i
        assert int(jnp.argmax(dense[0, pos])) == tok, f"diverged at {i}"


def test_model_drafter_self_speculation_accepts_everything():
    """Draft model == target model: every draft must be accepted (the
    strongest check that the draft cache stays position-exact through
    commits and rollbacks) and output must stay token-identical."""
    cfg, params = _model("internlm2-1.8b")
    reqs = _ragged_reqs(cfg)
    base, _ = _run(cfg, params, None, reqs, slots=2, max_len=64)
    spec = SpecConfig(draft="self", k=3, draft_cfg=cfg, draft_params=params)
    out, eng = _run(cfg, params, spec, reqs, slots=2, max_len=64)
    st = eng.spec_stats()
    assert out == base
    assert st["acceptance_rate"] > 0.99, st
    # k+1 = 4 tokens/step except where the generation budget clamps the
    # final step of each request
    assert st["tokens_per_step"] > 2.5, st


def test_model_drafter_disagreeing_draft_still_exact():
    """A random-weights draft model proposes near-garbage; rejection
    sampling must still deliver the target's exact greedy output."""
    cfg, params = _model("internlm2-1.8b")
    dcfg, dparams = _model("internlm2-1.8b", layers=1, d_model=32, heads=2,
                           d_ff=64)
    reqs = _ragged_reqs(cfg, n=3)
    base, _ = _run(cfg, params, None, reqs, slots=2, max_len=64)
    spec = SpecConfig(draft="tiny", k=3, draft_cfg=dcfg,
                      draft_params=dparams)
    out, eng = _run(cfg, params, spec, reqs, slots=2, max_len=64)
    assert out == base


def test_spec_eos_and_budget():
    cfg, params = _model("internlm2-1.8b")
    probe, _ = _run(cfg, params, None, [([2, 3], 8)], slots=1, max_len=64)
    eos = probe[0][3]
    eng = Engine(cfg, params, slots=1, max_len=64, spec=SpecConfig(k=4))
    eng.submit(Request(rid=0, prompt=[2, 3], max_new_tokens=8, eos_id=eos))
    (r,) = eng.run()
    assert r.out_tokens == probe[0][:4]          # truncated AT the eos
    # budgets are exact even when a verify step could overshoot
    out, _ = _run(cfg, params, SpecConfig(k=4),
                  [([4, 5], 7), ([6], 3)], slots=2, max_len=64)
    assert len(out[0]) == 7 and len(out[1]) == 3


def test_spec_sampled_run_completes_and_mixes_temperatures():
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, spec=SpecConfig(k=3),
                 seed=11)
    eng.submit(Request(rid=0, prompt=[2, 3], max_new_tokens=6))
    eng.submit(Request(rid=1, prompt=[2, 3], max_new_tokens=6,
                       temperature=1.5))
    done = {r.rid: r for r in eng.run()}
    # the greedy slot must match a solo greedy run exactly
    solo, _ = _run(cfg, params, SpecConfig(k=3), [([2, 3], 6)], slots=2,
                   max_len=64)
    assert done[0].out_tokens == solo[0]
    assert len(done[1].out_tokens) == 6
    assert all(0 <= t < cfg.vocab_size for t in done[1].out_tokens)


def test_spec_chunk_is_sync_free():
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, spec=SpecConfig(k=4))
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=40))
    eng.submit(Request(rid=1, prompt=[4, 5], max_new_tokens=40))
    eng._admit()
    with jax.transfer_guard_device_to_host("disallow"):
        toks = eng.step_chunk()
    eng._drain(toks)
    assert eng.host_syncs == 1
    assert eng.decode_compiles == 1


def test_spec_pool_direct_reads_match_gather():
    """Speculative verify over the pool-direct decode-attention path
    (kernels/paged_attention multi-query lowering) must match the
    gather-then-attend path token for token."""
    cfg, params = _model("internlm2-1.8b")
    reqs = _ragged_reqs(cfg, n=4)
    gather, _ = _run(cfg, params, SpecConfig(k=4), reqs, slots=2,
                     max_len=64, paged_kernel=False)
    pooled, _ = _run(cfg, params, SpecConfig(k=4), reqs, slots=2,
                     max_len=64, paged_kernel=True)
    assert pooled == gather


def test_spec_capability_gate():
    for arch in ("rwkv6-7b", "zamba2-7b"):
        cfg, params = _model(arch)
        with pytest.raises(ValueError, match="speculative"):
            Engine(cfg, params, slots=1, max_len=32, spec=SpecConfig(k=2))


def test_spec_warmup_inert_and_compile_counts():
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, spec=SpecConfig(k=4))
    eng.warmup()
    n_pre, n_dec, n_adm = (eng.prefill_compiles, eng.decode_compiles,
                           eng.admit_compiles)
    assert n_dec == 1 and n_adm == 1
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[1 + i] * (2 + 7 * i),
                           max_new_tokens=5))
    done = eng.run()
    assert len(done) == 3
    assert (eng.prefill_compiles, eng.decode_compiles,
            eng.admit_compiles) == (n_pre, n_dec, n_adm)
    # warmup contributed nothing to the telemetry counters
    st = eng.spec_stats()
    assert st["emitted_tokens"] == sum(len(r.out_tokens) for r in done) - 3


def test_spec_with_prefix_sharing_matches_exclusive():
    """Speculation on top of radix prefix sharing: shared pages are CoW'd
    at admission, drafted writes never touch them, and outputs stay
    token-identical to the exclusive-ownership speculative engine."""
    cfg, params = _model("internlm2-1.8b")
    prefix = [(3 * j) % 200 + 1 for j in range(16)]
    tail = [50, 51, 52, 53, 54, 55, 56, 57]
    seed = [(prefix + tail, 8)]                  # indexes 3 full pages
    reqs = [(prefix + tail[:3] + [99], 8),       # partial page-2 match: CoW
            (prefix + tail, 8),                  # full re-hit
            (prefix + tail[:2] + [7, 8], 8)]     # second partial match
    # two waves: fused chunked prefill indexes the seed prompt's pages at
    # prefill completion, so the sharers must arrive after it finishes
    excl_eng = Engine(cfg, params, spec=SpecConfig(k=4), slots=2,
                      max_len=64, prefix_sharing=False)
    eng = Engine(cfg, params, spec=SpecConfig(k=4), slots=2, max_len=64)
    excl, shared = {}, {}
    for wave in (seed, reqs):
        for e, out in ((excl_eng, excl), (eng, shared)):
            rs = [Request(rid=len(out) + i, prompt=list(p),
                          max_new_tokens=mx)
                  for i, (p, mx) in enumerate(wave)]
            for r in rs:
                e.submit(r)
            done = e.run(max_steps=100_000)
            out.update({r.rid: r.out_tokens for r in done})
    assert shared == excl
    ps = eng.prefix_stats()
    assert ps["prefix_hits"] >= 3 and ps["cow_copies"] >= 2
    assert eng.scheduler.pages_in_use == eng.scheduler.radix.node_count
