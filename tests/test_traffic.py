"""Determinism and distribution sanity for the serve/traffic harness:
same seed -> byte-identical traces, virtual-clock replays through the
Engine yield identical fault/latency counters, and the Poisson process
empirically hits its configured rate."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import model_defs
from repro.models import module as m
from repro.serve.engine import Engine
from repro.serve.traffic import (ClassProfile, TrafficGenerator,
                                 VirtualClock, replay, trace_fingerprint)


def test_same_seed_identical_trace():
    for proc in ("poisson", "bursty"):
        a = TrafficGenerator(42, rate=2.0, process=proc).generate(200)
        b = TrafficGenerator(42, rate=2.0, process=proc).generate(200)
        assert trace_fingerprint(a) == trace_fingerprint(b)
        # generate() is pure: a second call on the SAME instance too
        c = TrafficGenerator(42, rate=2.0, process=proc)
        assert trace_fingerprint(c.generate(200)) \
            == trace_fingerprint(c.generate(200))
        # a different seed genuinely changes the trace
        d = TrafficGenerator(43, rate=2.0, process=proc).generate(200)
        assert trace_fingerprint(a) != trace_fingerprint(d)


def test_trace_shape_and_validation():
    gen = TrafficGenerator(
        7, rate=1.0,
        class_mix={"interactive": 0.5, "batch": 0.5},
        profiles={"interactive": ClassProfile(prompt_len=(3, 5),
                                              max_new=(2, 4),
                                              ttft_target=0.25)})
    trace = gen.generate(100)
    assert [t.rid for t in trace] == list(range(100))
    assert all(trace[i].arrival < trace[i + 1].arrival
               for i in range(99))          # strictly increasing
    for t in trace:
        assert t.slo_class in ("interactive", "batch")
        if t.slo_class == "interactive":
            assert 3 <= len(t.prompt) <= 5
            assert 2 <= t.max_new_tokens <= 4
            assert t.ttft_target == 0.25    # profile override carried
        req = t.to_request()
        assert req.rid == t.rid and list(req.prompt) == list(t.prompt)
        assert req.slo_class == t.slo_class
    with pytest.raises(ValueError):
        TrafficGenerator(0, process="uniform")
    with pytest.raises(ValueError):
        TrafficGenerator(0, rate=0.0)
    with pytest.raises(ValueError):
        TrafficGenerator(0, class_mix={"gold": 1.0})


def test_poisson_empirical_rate_within_tolerance():
    rate = 4.0
    n = 4000
    trace = TrafficGenerator(3, rate=rate, process="poisson").generate(n)
    measured = n / trace[-1].arrival
    # mean interarrival estimator is ~N(1/rate, 1/(rate^2 n)): 5 sigma
    assert measured == pytest.approx(rate, rel=5.0 / n ** 0.5)
    # bursty at burst_ratio=1 degenerates to the same Poisson rate
    flat = TrafficGenerator(3, rate=rate, process="bursty",
                            burst_ratio=1.0).generate(n)
    assert n / flat[-1].arrival == pytest.approx(rate, rel=5.0 / n ** 0.5)
    # a real burst ratio raises the aggregate rate
    bursty = TrafficGenerator(3, rate=rate, process="bursty",
                              burst_ratio=8.0, p_burst=0.2).generate(n)
    assert n / bursty[-1].arrival > measured


def test_virtual_clock_ticks():
    clk = VirtualClock(dt=0.25, start=1.0)
    assert clk() == 1.0
    clk.tick()
    clk.tick()
    assert clk() == 1.5


def test_replay_identical_counters_across_runs():
    """Two virtual-clock replays of one trace produce identical
    fault_stats / latency_stats / output tokens — the property the
    fig04 gate and any bisection of a serving regression rely on."""
    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    trace = TrafficGenerator(5, rate=3.0, process="bursty").generate(10)

    def once(policy="slo"):
        clk = VirtualClock(dt=0.05)
        eng = Engine(cfg, params, slots=2, max_len=64, page_size=8,
                     num_pages=10, sync_interval=4, policy=policy,
                     prefix_sharing=False, clock=clk)
        results = replay(eng, trace, clock=clk)
        fs = eng.fault_stats()
        fs.pop("chaos", None)
        return (results, fs, eng.latency_stats(),
                {r.rid: list(r.out_tokens) for r in eng.finished},
                eng.leaked_pages())

    r1, fs1, ls1, toks1, leak1 = once()
    r2, fs2, ls2, toks2, leak2 = once()
    assert r1 == r2
    assert fs1 == fs2
    assert ls1 == ls2
    assert toks1 == toks2
    assert leak1 == leak2 == 0
    assert set(toks1) == {t.rid for t in trace}      # everything finished
