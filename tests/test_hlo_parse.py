"""HLO collective-parser unit tests on synthetic HLO text."""

import pytest

from repro.analysis import hlo

SAMPLE = """
HloModule jit_train_step
%x = f32[16,4096,2048]{2,1,0} all-reduce(%y), channel_id=3, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%add
%g = bf16[2048,92544]{0,1} all-gather(%w), channel_id=4, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
%rs = bf16[16,256,2048]{2,1,0} reduce-scatter(%z), channel_id=5, replica_groups=[16,16]<=[256], dimensions={1}, to_apply=%add
%a2a = (f32[16,256,128]{2,1,0}, f32[16,256,128]{2,1,0}) all-to-all(%p, %q), channel_id=6, replica_groups={{0,1,2,3},{4,5,6,7}}
%cp = bf16[8,128]{1,0} collective-permute(%r), channel_id=7, source_target_pairs={{0,1},{1,0}}
%ard = f32[4]{0} all-reduce-done(%ars)
"""


def test_parse_counts_and_groups():
    colls = hlo.parse_collectives(SAMPLE)
    ops = sorted(c.op for c in colls)
    assert ops == ["all-gather", "all-reduce", "all-to-all",
                   "collective-permute", "reduce-scatter"]
    by = {c.op: c for c in colls}
    assert by["all-reduce"].group_size == 16
    assert by["all-gather"].group_size == 16
    assert by["all-to-all"].group_size == 4


def test_wire_byte_formulas():
    colls = {c.op: c for c in hlo.parse_collectives(SAMPLE)}
    ar = colls["all-reduce"]
    assert ar.bytes_payload == 16 * 4096 * 2048 * 4
    assert ar.wire_bytes == pytest.approx(2 * ar.bytes_payload * 15 / 16)
    ag = colls["all-gather"]
    assert ag.wire_bytes == pytest.approx(ag.bytes_payload * 15 / 16)
    rs = colls["reduce-scatter"]
    assert rs.wire_bytes == pytest.approx(rs.bytes_payload * 15)
    a2a = colls["all-to-all"]
    assert a2a.bytes_payload == 2 * 16 * 256 * 128 * 4
    assert a2a.wire_bytes == pytest.approx(a2a.bytes_payload * 3 / 4)
    cp = colls["collective-permute"]
    assert cp.wire_bytes == cp.bytes_payload == 8 * 128 * 2


def test_summary_totals():
    s = hlo.summarize(hlo.parse_collectives(SAMPLE))
    assert s["num_collectives"] == 5
    assert s["total_wire_bytes"] == pytest.approx(
        sum(c.wire_bytes for c in hlo.parse_collectives(SAMPLE)))


def test_done_ops_not_double_counted():
    txt = ("%s = f32[8]{0} all-reduce-start(%x), replica_groups=[2,4]<=[8]\n"
           "%d = f32[8]{0} all-reduce-done(%s)\n")
    colls = hlo.parse_collectives(txt)
    assert len(colls) == 1
