"""Per-arch reduced-config smoke tests (deliverable f): one forward/train
step on CPU, asserting output shapes and finiteness — plus the strongest
correctness check in the suite: prefill + token-by-token decode must
reproduce the teacher-forced dense logits for every architecture family
(exercising KV caches, ring buffers, SSM/RWKV recurrent states).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import (forward_decode, forward_dense_logits,
                          forward_prefill, forward_train, model_defs,
                          prepare_decode_cache)
from repro.models import module as m

B, T = 2, 24


def _batch(cfg, key, seq=T):
    tokens = jax.random.randint(key, (B, seq), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model)) * 0.1
    elif cfg.frontend:
        batch["frontend"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, rng):
    cfg = reduced(get_config(arch))
    params = m.init_params(model_defs(cfg), rng, jnp.float32)
    batch = _batch(cfg, rng)

    def loss_fn(p):
        loss, metrics = forward_train(p, cfg, batch)
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_dense(arch, rng):
    cfg = reduced(get_config(arch))
    params = m.init_params(model_defs(cfg), rng, jnp.float32)
    batch = _batch(cfg, rng)
    tokens = batch["tokens"]
    t0 = 10

    dense = jax.jit(lambda p, b: forward_dense_logits(p, cfg, b))(
        params, batch)                                   # [B, T, V]

    pre_batch = dict(batch)
    pre_batch.pop("labels")
    pre_batch["tokens"] = tokens[:, :t0]
    logits_p, cache = jax.jit(lambda p, b: forward_prefill(p, cfg, b))(
        params, pre_batch)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(dense[:, t0 - 1]),
                               rtol=2e-3, atol=2e-3)

    cache = prepare_decode_cache(cfg, cache, T)
    decode = jax.jit(lambda p, t, c: forward_decode(p, cfg, t, c))
    for t in range(t0, T):
        logits_d, cache = decode(params, tokens[:, t:t + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(dense[:, t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch} decode mismatch at position {t}")


def test_long_context_flags():
    assert get_config("rwkv6-7b").supports_long_context
    assert get_config("zamba2-7b").supports_long_context
    for arch in ("mistral-large-123b", "gemma2-2b", "gemma3-12b",
                 "dbrx-132b", "whisper-medium"):
        assert not get_config(arch).supports_long_context, arch


def test_param_counts_close_to_nameplates():
    from repro.core.cost_model import model_param_count
    expect = {"dbrx-132b": 132e9, "grok-1-314b": 314e9,
              "mistral-large-123b": 123e9, "gemma2-2b": 2.6e9,
              "gemma3-12b": 12e9, "internlm2-1.8b": 1.8e9,
              "pixtral-12b": 12e9, "rwkv6-7b": 7.6e9}
    for arch, n in expect.items():
        got = model_param_count(get_config(arch))
        assert abs(got - n) / n < 0.25, (arch, got, n)
