"""Hand-computed oracle tests for the latency/goodput math behind
``Engine.latency_stats()`` (nearest-rank percentiles, TTFT from the
ORIGINAL submit time across preemption, per-class goodput), plus the
deterministic SLO-policy tests that need no optional deps: admission
ordering by (priority, slack, arrival) and the engine-level
interactive-first admission + dynamic prefill-budget throttle."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import model_defs
from repro.models import module as m
from repro.serve.cache import CacheSpec
from repro.serve.engine import (Engine, compute_latency_stats, percentile,
                                request_slo_met, request_tpot,
                                request_ttft)
from repro.serve.scheduler import (Request, RequestStatus, SLO_CLASSES,
                                   Scheduler)


# ---------------------------------------------------------------------------
# percentile: nearest-rank, hand-computed oracle
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank_oracle():
    assert percentile([], 50) is None
    assert percentile([], 99) is None
    assert percentile([7.0], 1) == 7.0          # single sample: any q
    assert percentile([7.0], 50) == 7.0
    assert percentile([7.0], 99) == 7.0
    vals = [float(v) for v in range(1, 11)]      # 1..10
    assert percentile(vals, 50) == 5.0           # ceil(0.5*10)=5th
    assert percentile(vals, 90) == 9.0
    assert percentile(vals, 99) == 10.0          # ceil(.99*10)=10th
    assert percentile(vals, 100) == 10.0
    assert percentile([4.0, 1.0, 3.0, 2.0], 50) == 2.0   # unsorted input
    assert percentile([1.0, 2.0, 3.0, 4.0], 25) == 1.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 26) == 2.0   # rank boundary


def _req(cls="interactive", status=RequestStatus.FINISHED, submit=0.0,
         first=None, times=(), rid=0, ttft_target=None, tpot_target=None):
    r = Request(rid=rid, prompt=[1, 2, 3], max_new_tokens=8,
                slo_class=cls, ttft_target=ttft_target,
                tpot_target=tpot_target)
    r.status = status
    r.submit_time = submit
    r.first_token_time = first
    r.token_times = list(times)
    return r


def test_request_ttft_and_tpot_oracle():
    r = _req(submit=2.0, first=5.0, times=[5.0, 6.0, 8.0])
    assert request_ttft(r) == 3.0
    assert request_tpot(r) == pytest.approx((8.0 - 5.0) / 2)
    assert request_ttft(_req(first=None)) is None        # no token yet
    assert request_tpot(_req(times=[4.0])) is None       # < 2 tokens
    r2 = _req(submit=None, first=4.0)
    assert request_ttft(r2) is None


def test_slo_met_target_resolution():
    # class default targets apply when the request carries none
    ok = _req(submit=0.0, first=0.5, times=[0.5, 0.55, 0.6])
    assert ok.resolved_ttft_target == SLO_CLASSES["interactive"].ttft_target
    assert request_slo_met(ok)
    # per-request override beats the class default
    tight = _req(submit=0.0, first=0.5, times=[0.5, 0.55, 0.6],
                 ttft_target=0.1)
    assert not request_slo_met(tight)
    # absent target (best_effort) always passes once FINISHED
    be = _req(cls="best_effort", first=None)
    assert request_slo_met(be)
    # a target with NO measurement is a miss, not a pass
    silent = _req(cls="interactive", first=None)
    assert not request_slo_met(silent)
    # non-FINISHED terminal states never meet their SLO
    dead = _req(status=RequestStatus.TIMED_OUT, first=0.1,
                times=[0.1, 0.15])
    assert not request_slo_met(dead)


def test_stats_zero_finished_and_empty():
    stats = compute_latency_stats([])
    assert stats["classes"] == {}
    assert stats["goodput"] == 0.0
    assert stats["overall"]["ttft_p50"] is None
    # queued-only: nothing terminal, nothing measured — all None/0
    queued = _req(status=RequestStatus.QUEUED, first=None)
    stats = compute_latency_stats([queued])
    c = stats["classes"]["interactive"]
    assert c["count"] == 1 and c["terminal"] == 0 and c["finished"] == 0
    assert c["goodput"] == 0.0
    assert c["ttft_p50"] is None and c["tpot_p99"] is None


def test_stats_single_request_oracle():
    r = _req(submit=1.0, first=1.25, times=[1.25, 1.31, 1.35])
    stats = compute_latency_stats([r])
    c = stats["classes"]["interactive"]
    assert c["ttft_p50"] == c["ttft_p99"] == 0.25
    assert c["tpot_p50"] == pytest.approx(0.05)
    # both interactive targets met (ttft 0.25 <= 1.0, tpot 0.05 <= 0.1)
    assert c["goodput"] == 1.0 and stats["goodput"] == 1.0
    # the same tokens spread 0.25s apart blow the 0.1 tpot target
    slow = _req(submit=1.0, first=1.25, times=[1.25, 1.5, 1.75])
    assert compute_latency_stats([slow])["goodput"] == 0.0


def test_stats_all_timed_out_class_and_mixed_goodput():
    # an all-timed-out class: percentiles may exist (first tokens were
    # drained) but goodput is 0 — terminal non-FINISHED is a miss
    dead = [_req(cls="batch", status=RequestStatus.TIMED_OUT, submit=0.0,
                 first=1.0 + i, times=[1.0 + i, 2.0 + i], rid=i)
            for i in range(3)]
    ok = _req(cls="interactive", submit=0.0, first=0.2,
              times=[0.2, 0.25, 0.3], rid=10)
    stats = compute_latency_stats(dead + [ok])
    assert stats["classes"]["batch"]["goodput"] == 0.0
    assert stats["classes"]["batch"]["ttft_p99"] == 3.0
    assert stats["classes"]["interactive"]["goodput"] == 1.0
    # overall: 1 of 4 terminal requests met its SLO
    assert stats["goodput"] == 0.25


def test_ttft_measured_from_original_submit_across_preemption():
    """A mid-flight preempted-then-resumed request keeps its ORIGINAL
    submit_time: TTFT covers the whole queue+preemption wait, not the
    time since resume."""
    r = _req(submit=10.0, first=None, times=[])
    r.preemptions = 1                    # preempted before first token
    r.status = RequestStatus.PREEMPTED
    assert request_ttft(r) is None       # not measured yet
    # resume: first token finally drains at t=50
    r.status = RequestStatus.FINISHED
    r.first_token_time = 50.0
    r.token_times = [50.0, 51.0]
    assert request_ttft(r) == 40.0       # from t=10, NOT from resume
    stats = compute_latency_stats([r])
    assert stats["classes"]["interactive"]["ttft_p50"] == 40.0


# ---------------------------------------------------------------------------
# deterministic SLO-policy ordering (no hypothesis needed)
# ---------------------------------------------------------------------------

def test_slo_orders_by_priority_then_slack_then_arrival():
    cfg = reduced(get_config("internlm2-1.8b"))
    spec = CacheSpec.from_config(cfg, slots=4, max_len=64, page_size=8)
    s = Scheduler(spec, prefix_sharing=False, policy="slo")
    batch = Request(rid=0, prompt=[1, 2], max_new_tokens=4,
                    slo_class="batch", submit_time=0.0)
    inter_late = Request(rid=1, prompt=[1, 2], max_new_tokens=4,
                         slo_class="interactive", submit_time=5.0)
    inter_early = Request(rid=2, prompt=[1, 2], max_new_tokens=4,
                          slo_class="interactive", submit_time=1.0)
    best = Request(rid=3, prompt=[1, 2], max_new_tokens=4,
                   slo_class="best_effort", submit_time=0.0)
    for r in (batch, inter_late, inter_early, best):
        s.submit(r)
    order = [r.rid for r in s.admission_order(now=6.0)]
    # interactive first, least slack (earlier submit) first among them,
    # then batch, then best_effort
    assert order == [2, 1, 0, 3]
    # unknown classes degrade to best_effort instead of crashing
    weird = Request(rid=4, prompt=[1], max_new_tokens=2,
                    slo_class="no-such-class")
    assert weird.priority == SLO_CLASSES["best_effort"].priority
    assert weird.ttft_slack(100.0) == float("inf")
    # invalid policy is rejected at construction
    with pytest.raises(ValueError):
        Scheduler(spec, policy="priority")


def test_engine_slo_policy_admits_interactive_first_and_throttles():
    """Engine-level integration: with a full pool of batch work and a
    deep queue, a late interactive arrival (a) jumps the admission
    queue under policy='slo' and (b) while its TTFT slack is negative
    the non-interactive slots' prefill budgets are throttled on
    device — visible in ``budget_throttles`` and the pbudget vector."""
    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    clk = {"t": 0.0}
    eng = Engine(cfg, params, slots=2, max_len=64, page_size=8,
                 sync_interval=4, policy="slo", prefix_sharing=False,
                 clock=lambda: clk["t"], chunked_prefill=True)
    assert eng.chunked_prefill
    for i in range(4):
        eng.submit(Request(rid=i, prompt=[1 + i, 2, 3],
                           max_new_tokens=24, slo_class="batch"))
    eng.step()                      # two batch slots live, two queued
    assert all(r is not None for r in eng._slot_req)
    # interactive arrives, then waits in queue past its TTFT target
    clk["t"] = 50.0
    urgent = Request(rid=10, prompt=[9, 9, 9], max_new_tokens=4,
                     slo_class="interactive")
    eng.submit(urgent)
    clk["t"] = 52.0                 # queued past the 1.0 TTFT target
    assert urgent.ttft_slack(clk["t"]) < 0.0
    eng.step()
    # budget throttle engaged while the urgent request waits/streams
    assert eng.budget_throttles >= 1
    S = eng.executor.chunk_rows
    vec = [int(v) for v in jax.device_get(eng.state["pbudget"])]
    assert any(v == max(1, S // 4) for v in vec), vec
    done = eng.run(max_steps=10_000)
    assert {r.rid for r in done} == {0, 1, 2, 3, 10}
    # pressure gone: budgets restored to the full chunk width
    vec = [int(v) for v in jax.device_get(eng.state["pbudget"])]
    assert vec == [S] * eng.spec.slots
    # the interactive rid was admitted before the two still-queued
    # batch rids despite arriving after them
    admits = [rid for _, rid, _, _, _ in eng.scheduler.admission_log]
    assert admits.index(10) < admits.index(2)
    assert admits.index(10) < admits.index(3)
    assert eng.leaked_pages() == 0
    ls = eng.latency_stats()
    assert set(ls["classes"]) == {"batch", "interactive"}
    assert ls["classes"]["interactive"]["finished"] == 1
    assert ls["budget_throttles"] == eng.budget_throttles


def test_shed_lowest_class_evicts_queued_lower_priority():
    """shed-lowest-class at a full queue: an incoming interactive
    request sheds the worst queued lower-class request instead of being
    rejected itself; an incoming best_effort finds no lower class and
    is rejected as usual."""
    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    eng = Engine(cfg, params, slots=1, max_len=64, page_size=8,
                 prefix_sharing=False, queue_limit=2,
                 shed_policy="shed-lowest-class")
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=30))
    eng.step()                                # rid 0 occupies the slot
    assert eng.submit(Request(rid=1, prompt=[1, 2], max_new_tokens=4,
                              slo_class="batch")) is None
    assert eng.submit(Request(rid=2, prompt=[1, 2], max_new_tokens=4,
                              slo_class="best_effort")) is None
    # queue full (limit 2): interactive sheds the best_effort entry
    urgent = Request(rid=3, prompt=[1, 2], max_new_tokens=4,
                     slo_class="interactive")
    assert eng.submit(urgent) is None
    assert [r.rid for r in eng.queue] == [1, 3]
    shed = [r for r in eng.rejected if r.rid == 2]
    assert len(shed) == 1
    assert shed[0].status == RequestStatus.REJECTED
    assert eng.fault_counters["rejected_shed_lower_class"] == 1
    # best_effort incoming with no lower class queued: rejected itself
    rej = eng.submit(Request(rid=4, prompt=[1, 2], max_new_tokens=4,
                             slo_class="best_effort"))
    assert rej is not None
    done = eng.run(max_steps=10_000)
    assert {r.rid for r in done} == {0, 1, 3}
    assert eng.leaked_pages() == 0
