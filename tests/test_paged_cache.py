"""Paged decode-cache subsystem: CacheSpec layout, page-table splice,
slot lifecycle (eviction / re-admission / FIFO fairness), page-pool
backpressure, long-output capacity beyond the dense max_len ceiling, and
data-axis sharding specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.models import forward_dense_logits, model_defs
from repro.models import module as m
from repro.models.attention import ring_token_positions, ring_valid
from repro.parallel import sharding as sh
from repro.serve.cache import PAGED_KV, STATE, CacheSpec
from repro.serve.engine import Engine, Request
from repro.serve.scheduler import (PagePool, PagePoolExhausted,  # noqa: F401
                                   RequestStatus, Scheduler)


def _model(arch, **kw):
    cfg = reduced(get_config(arch), **kw)
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    return cfg, params


# ---------------------------------------------------------------------------
# CacheSpec construction
# ---------------------------------------------------------------------------

def test_cachespec_kinds_per_layer():
    cfg, _ = _model("zamba2-7b")    # mamba2 backbone + shared attention
    spec = CacheSpec.from_config(cfg, slots=2, max_len=64, page_size=8)
    kinds = [ls.kind for ls in spec.layers]
    assert PAGED_KV in kinds and STATE in kinds
    assert spec.has_paged
    # the widest group's default budget covers slots x its ring tokens
    # (zamba2's shared attention is windowed, so its only paged group is
    # window-sized — no longer inflated to slots x max_len)
    widest = spec.widest_group
    assert spec.num_pages == 2 * widest.ring_blocks
    assert spec.trash_page == spec.num_pages
    assert spec.pool_shape[0] == spec.num_pages + 1


def test_cachespec_windowed_ring_blocks():
    cfg, _ = _model("gemma2-2b")    # alternating window=16 / global layers
    spec = CacheSpec.from_config(cfg, slots=1, max_len=64, page_size=8)
    rings = {ls.window: ls.ring_blocks for ls in spec.layers
             if ls is not None and ls.kind == PAGED_KV}
    assert rings[16] == 2           # ceil(16/8): windowed layers ring early
    assert rings[None] == 8         # ceil(64/8): full layers span max_len
    assert spec.max_blocks == 8


def test_cachespec_per_layer_pool_budgets():
    """Windowed layers get their own window-sized pool (per-layer page-id
    remapping) instead of paying the full-attention group's budget; the
    dense-vs-paged byte ratio is therefore 1.0 for windowed archs."""
    cfg, _ = _model("gemma2-2b")
    spec = CacheSpec.from_config(cfg, slots=2, max_len=64, page_size=8)
    by_key = {g.key: g for g in spec.groups}
    assert set(by_key) == {"ring2", "ring8"}
    assert by_key["ring2"].windowed and not by_key["ring8"].windowed
    assert by_key["ring2"].num_pages == 2 * 2       # slots x ring (window)
    assert by_key["ring8"].num_pages == 2 * 8       # slots x max_len / P
    # every paged layer points at the group matching its ring
    for ls in spec.layers:
        if ls is not None and ls.kind == PAGED_KV:
            assert spec.groups[ls.group].ring_blocks == ls.ring_blocks
    stats = spec.memory_stats({k: 0 for k in by_key}, 0)
    assert stats["dense_vs_paged_capacity_ratio"] == 1.0
    assert stats["num_pages"] == 4 + 16
    # tables are per group, trash ids are per group
    cache = spec.init_paged_cache()
    assert cache["page_tables"]["ring2"].shape == (2, 2)
    assert cache["page_tables"]["ring8"].shape == (2, 8)
    assert int(cache["page_tables"]["ring2"][0, 0]) == by_key["ring2"].num_pages
    assert int(cache["page_tables"]["ring8"][0, 0]) == by_key["ring8"].num_pages


def test_cachespec_rejects_cross_attention():
    """The old empty_batch_cache silently pop()-ed enc_kv; now the spec
    refuses the structure outright with an actionable error."""
    cfg, _ = _model("whisper-medium")
    with pytest.raises(ValueError, match="cross-attention"):
        CacheSpec.from_config(cfg, slots=2, max_len=32)


def test_cachespec_blocks_needed_caps_at_table_width():
    cfg, _ = _model("rwkv6-7b")
    spec = CacheSpec.from_config(cfg, 2, 64)
    assert not spec.has_paged and spec.blocks_needed(100, 100) == {}
    cfg2, _ = _model("internlm2-1.8b")
    spec2 = CacheSpec.from_config(cfg2, 2, 64, page_size=8)
    key = spec2.widest_group.key
    assert spec2.blocks_needed(3, 4) == {key: 1}
    assert spec2.blocks_needed(0, 1) == {key: 1}   # empty prompt still pages
    assert spec2.blocks_needed(60, 1000) == {key: spec2.max_blocks}
    # per-group caps: windowed groups reserve at most their ring
    cfg3, _ = _model("gemma2-2b")
    spec3 = CacheSpec.from_config(cfg3, 2, 64, page_size=8)
    assert spec3.blocks_needed(60, 1000) == {"ring2": 2, "ring8": 8}


# ---------------------------------------------------------------------------
# Ring position math (shared by splice and paged decode attention)
# ---------------------------------------------------------------------------

def test_ring_token_positions_and_validity():
    # ring of 8, current token t=10 (cache_len=11): slots hold tokens 3..10
    u = np.asarray(ring_token_positions(jnp.asarray([11]), 8))[0]
    assert sorted(u.tolist()) == list(range(3, 11))
    assert u[10 % 8] == 10
    # before wrap (t=2): slots 3.. were never written -> negative
    u2 = np.asarray(ring_token_positions(jnp.asarray([3]), 8))[0]
    assert (u2[:3] == [0, 1, 2]).all() and (u2[3:] < 0).all()
    # window mask hides ring-retained tokens older than the window
    v = np.asarray(ring_valid(jnp.asarray([11]), 8, window=4))[0]
    assert v.sum() == 4
    u = np.asarray(ring_token_positions(jnp.asarray([11]), 8))[0]
    assert (u[v] > 10 - 4).all()


# ---------------------------------------------------------------------------
# Slot lifecycle
# ---------------------------------------------------------------------------

def test_eviction_returns_pages_to_pool():
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, page_size=8)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[1 + i, 2, 3], max_new_tokens=4))
    assert eng.scheduler.pages_in_use == 0
    done = eng.run()
    assert len(done) == 3
    # every lease was released on finish; peak shows the pool was used
    assert eng.scheduler.pages_in_use == 0
    assert eng.scheduler.peak_pages_in_use >= 2
    stats = eng.memory_stats()
    assert stats["pages_in_use"] == 0 and stats["num_pages"] == 16


def test_readmission_into_freed_slot_mid_run():
    """A short request finishes, its slot and pages are re-leased to a
    queued request mid-run, and the long-running neighbour is unaffected
    (its tokens match a solo run) — freed pages were not corrupted."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, page_size=8)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=20))  # long
    eng.submit(Request(rid=1, prompt=[4, 5], max_new_tokens=3))      # short
    eng.submit(Request(rid=2, prompt=[6, 7], max_new_tokens=3))      # reuses
    done = {r.rid: r for r in eng.run()}
    assert len(done) == 3
    solo = Engine(cfg, params, slots=2, max_len=64, page_size=8)
    solo.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=20))
    (s,) = solo.run()
    assert done[0].out_tokens == s.out_tokens


def test_fifo_queue_fairness_no_jumping():
    """Head-of-line backpressure: when the queue head's page reservation
    does not fit, a smaller later request must NOT be admitted around it."""
    cfg, _ = _model("internlm2-1.8b")
    spec = CacheSpec.from_config(cfg, slots=2, max_len=64, page_size=8,
                                 num_pages=8)
    sched = Scheduler(spec)
    r0 = Request(rid=0, prompt=[1] * 8, max_new_tokens=24)    # 4 pages
    r1 = Request(rid=1, prompt=[1] * 8, max_new_tokens=40)    # 6 pages
    r2 = Request(rid=2, prompt=[1], max_new_tokens=2)         # 1 page
    for r in (r0, r1, r2):
        sched.submit(r)
    admitted = list(sched.admissions([0, 1]))
    # r0 fits (4 <= 8); r1 needs 6 > 4 free -> head-of-line blocks r2 too
    assert [a.req.rid for a in admitted] == [0]
    assert [r.rid for r in sched.queue] == [1, 2]
    sched.release(admitted[0].slot)
    admitted2 = list(sched.admissions([0, 1]))
    assert [a.req.rid for a in admitted2] == [1, 2]


def test_fifo_completion_order_end_to_end():
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=1, max_len=64, page_size=8,
                 num_pages=8)
    eng.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=40))  # 6 pages
    eng.submit(Request(rid=1, prompt=[3], max_new_tokens=2))
    eng.submit(Request(rid=2, prompt=[4], max_new_tokens=2))
    done = eng.run()
    assert [r.rid for r in done] == [0, 1, 2]


def test_page_pool_exhaustion_is_clean_backpressure():
    """A request that can never fit is shed with a typed "infeasible"
    RequestRejected at submit() (no exception leaks to the caller);
    nothing is admitted and in-flight neighbours are unharmed."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, page_size=8,
                 num_pages=4)   # 32-token pool
    assert eng.submit(
        Request(rid=0, prompt=[1, 2, 3], max_new_tokens=8)) is None
    doomed = Request(rid=1, prompt=[1] * 30, max_new_tokens=16)
    rej = eng.submit(doomed)
    assert rej is not None and rej.kind == "infeasible"
    assert "pages" in rej.reason
    assert doomed.status == RequestStatus.REJECTED and doomed.done
    assert eng.fault_stats()["rejected_infeasible"] == 1
    assert len(eng.queue) == 1
    (r,) = eng.run()
    assert r.rid == 0 and len(r.out_tokens) == 8
    solo = Engine(cfg, params, slots=2, max_len=64, page_size=8)
    solo.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=8))
    (s,) = solo.run()
    assert r.out_tokens == s.out_tokens


def test_page_pool_allocator_invariants():
    pool = PagePool(4)
    a = pool.alloc(3)
    assert sorted(a) == [0, 1, 2] and pool.in_use == 3
    assert pool.alloc(2) is None           # backpressure, not partial
    assert pool.in_use == 3                # failed alloc leaks nothing
    pool.free(a)
    assert pool.free_pages == 4 and pool.peak_in_use == 3


# ---------------------------------------------------------------------------
# Multi-token cache appends (speculative verify writes K+1 tokens/step)
# ---------------------------------------------------------------------------

def _multi_vs_sequential(page_size, nb, num_pages, cache_len0, s, window):
    """Oracle: an S-token paged_decode_step must equal S sequential
    single-token steps — same pool contents, same per-position attention
    outputs."""
    from repro.models.attention import paged_decode_step

    b, h, hkv, dh = 2, 4, 2, 16
    key = jax.random.PRNGKey(nb + s + (window or 0))
    q = jax.random.normal(key, (b, s, h, dh)) * 0.5
    kk = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, dh)) * 0.5
    vv = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, dh))
    pool = jax.random.normal(jax.random.fold_in(key, 3),
                             (num_pages + 1, page_size, hkv, dh)) * 0.3
    rs = np.random.RandomState(3)
    pt = jnp.asarray(np.stack([rs.permutation(num_pages)[:nb]
                               for _ in range(b)]), jnp.int32)
    cl0 = jnp.asarray(cache_len0, jnp.int32)

    multi, mnew = paged_decode_step(
        q, kk, vv, {"pk": pool, "pv": pool, "pt": pt}, cl0 + s,
        window=window, softcap=None)
    seq_out = []
    cur = {"pk": pool, "pv": pool}
    for i in range(s):
        o, cur = paged_decode_step(
            q[:, i:i + 1], kk[:, i:i + 1], vv[:, i:i + 1],
            {"pk": cur["pk"], "pv": cur["pv"], "pt": pt}, cl0 + i + 1,
            window=window, softcap=None)
        seq_out.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(mnew["pk"]),
                               np.asarray(cur["pk"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(mnew["pv"]),
                               np.asarray(cur["pv"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(multi),
                               np.asarray(jnp.stack(seq_out, 1)),
                               rtol=2e-4, atol=2e-4)


def test_multi_token_scatter_across_page_boundary():
    """K-token append straddling a page boundary: positions 6..10 with
    page_size 8 span pages 0 and 1."""
    _multi_vs_sequential(page_size=8, nb=4, num_pages=12,
                         cache_len0=[6, 13], s=5, window=None)


def test_multi_token_scatter_into_ring_wrapped_window():
    """K-token append into a windowed ring that wraps mid-append.  The
    ring carries spec slack (ring tokens >= window + S - 1, the
    CacheSpec sizing), so wrapped writes only land on ring slots whose
    tokens are already outside every query's window.  Oracles: a numpy
    emulation of the token-position write rule for the pool contents,
    and the gather-then-attend ``paged_attention_ref`` for the output."""
    from repro.kernels.paged_attention import paged_attention_ref
    from repro.models.attention import paged_decode_step

    b, h, hkv, dh, P, nb, window, s = 2, 4, 2, 16, 4, 4, 12, 5
    ring = P * nb                                  # 16 >= window + s - 1
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (b, s, h, dh)) * 0.5
    kk = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, dh))
    vv = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, dh))
    pool = jax.random.normal(jax.random.fold_in(key, 3),
                             (11, P, hkv, dh)) * 0.3
    rs = np.random.RandomState(5)
    pt = jnp.asarray(np.stack([rs.permutation(10)[:nb]
                               for _ in range(b)]), jnp.int32)
    cl = jnp.asarray([14 + s, 29 + s], jnp.int32)  # second slot wraps
    out, new = paged_decode_step(
        q, kk, vv, {"pk": pool, "pv": pool, "pt": pt}, cl,
        window=window, softcap=None)
    want_k = np.asarray(pool).copy()
    for bi in range(b):
        for i in range(s):
            g = int(cl[bi]) - s + i                # absolute position
            want_k[int(pt[bi, (g // P) % nb]), g % P] = np.asarray(kk)[bi, i]
    np.testing.assert_allclose(np.asarray(new["pk"]), want_k, atol=1e-6)
    want = paged_attention_ref(q, new["pk"], new["pv"], pt, cl,
                               window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_multi_token_write_clamped_outside_nonwrapping_ring():
    """A verify step whose draft positions run past a NON-wrapping ring
    (full attention) must discard those writes instead of mod-wrapping
    them onto page 0 — under prefix sharing that page may belong to
    other slots."""
    from repro.models.attention import paged_decode_step

    b, h, hkv, dh, P, nb = 1, 2, 1, 8, 4, 2       # ring = 8 tokens
    q = jnp.zeros((b, 3, h, dh))
    kk = jnp.ones((b, 3, hkv, dh))
    vv = jnp.ones((b, 3, hkv, dh))
    pool = jnp.zeros((4, P, hkv, dh))
    pt = jnp.asarray([[0, 1]], jnp.int32)
    # positions 6, 7, 8: the last is beyond the 8-token ring
    _, new = paged_decode_step(
        q, kk, vv, {"pk": pool, "pv": pool, "pt": pt},
        jnp.asarray([9], jnp.int32), window=None, softcap=None)
    pk = np.asarray(new["pk"])
    assert pk[1, 2:].sum() == 2 * hkv * dh        # positions 6,7 written
    assert pk[0].sum() == 0                       # page 0 NOT wrapped into
    assert pk[3].sum() > 0                        # overflow went to trash


def test_multi_token_append_into_cow_shared_page_rolls_back():
    """Engine-level: a slot admitted onto a partially-matched shared page
    copies it exactly once (CoW), drafted writes then land in the
    private copy, and rejected-draft rollback leaves refcounts intact —
    after the run every page reference is the radix tree's own."""
    from repro.serve.spec import SpecConfig

    cfg, params = _model("internlm2-1.8b")
    prefix = [(3 * j) % 200 + 1 for j in range(16)]
    tail = [50, 51, 52, 53, 54, 55, 56, 57]
    # legacy prefill path: same-wave sharing (rid=1 attaches rid=0's
    # pages while both admit together) needs eager radix indexing, which
    # fused chunked prefill defers to prefill completion
    eng = Engine(cfg, params, slots=2, max_len=64, page_size=8,
                 spec=SpecConfig(draft="ngram", k=4),
                 chunked_prefill=False)
    eng.submit(Request(rid=0, prompt=prefix + tail, max_new_tokens=6))
    eng.submit(Request(rid=1, prompt=prefix + tail[:3] + [99],
                       max_new_tokens=6))
    done = {r.rid: r for r in eng.run()}
    assert len(done) == 2
    ps = eng.prefix_stats()
    assert ps["cow_copies"] == 1                  # the copy fired ONCE
    # solo oracle: the CoW'd slot's output is unaffected by sharing +
    # speculative rollback
    solo = Engine(cfg, params, slots=2, max_len=64, page_size=8,
                  spec=SpecConfig(draft="ngram", k=4),
                  chunked_prefill=False)
    solo.submit(Request(rid=1, prompt=prefix + tail[:3] + [99],
                        max_new_tokens=6))
    (s,) = solo.run()
    assert done[1].out_tokens == s.out_tokens
    # every slot lease was released; only the radix tree holds pages, at
    # refcount 1 each
    sched = eng.scheduler
    assert sched.pages_in_use == sched.radix.node_count
    pool = sched.pools[sched.share_key]
    for leaf in sched.radix._leaves():
        assert pool.refcount(leaf.page) == 1


# ---------------------------------------------------------------------------
# Capacity: paged lifts the per-slot dense ceiling at equal memory
# ---------------------------------------------------------------------------

def test_output_exceeds_dense_max_len_at_equal_memory():
    """Old dense layout: 2 slots x 32 tokens.  Same total budget as pages
    (8 pages x 8 tokens) serves ONE request of 56 tokens — longer than any
    single dense slot could ever hold — and it still matches teacher
    forcing.  The queued second request (3-page reservation vs 1 free)
    back-pressures mid-run, then completes after the long one evicts."""
    dense_max_len = 32
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, page_size=8,
                 num_pages=2 * dense_max_len // 8)   # equal slots x max_len
    n_new = 51                                        # 5 + 51 = 56 tokens
    eng.submit(Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new_tokens=n_new))
    eng.submit(Request(rid=1, prompt=[2, 7], max_new_tokens=20))
    eng.step()
    # r0 reserved 7 of 8 pages; r1 needs 3 -> engine-level backpressure
    assert [r.rid for r in eng.queue] == [1]
    assert eng.scheduler.pages_in_use == 7
    done = {r.rid: r for r in eng.run(max_steps=10_000)}
    assert len(done) == 2
    r = done[0]
    assert len(r.prompt) + len(r.out_tokens) == 56 > dense_max_len
    full = r.prompt + r.out_tokens
    dense = jax.jit(lambda p, b: forward_dense_logits(p, cfg, b))(
        params, {"tokens": jnp.asarray([full], jnp.int32)})
    for i, tok in enumerate(r.out_tokens):
        pos = len(r.prompt) - 1 + i
        assert int(jnp.argmax(dense[0, pos])) == tok, f"diverged at {i}"
    assert len(done[1].out_tokens) == 20


# ---------------------------------------------------------------------------
# Sharding: CacheSpec threads Rules onto the data mesh axis
# ---------------------------------------------------------------------------

def test_cachespec_data_axis_sharding_specs():
    cfg, _ = _model("internlm2-1.8b")
    spec = CacheSpec.from_config(cfg, slots=4, max_len=64, page_size=8)
    rules = sh.Rules(table={sh.BATCH: "data", sh.PAGES: "data"})
    # slot batch and every group's page pool shard over the data mesh axis
    assert rules.spec_for(spec.TABLE_AXES) == P("data")
    assert rules.spec_for(spec.POOL_AXES) == P("data")
    struct = spec.structure()
    key = spec.widest_group.key
    assert struct["page_tables"][key][0] == (4, spec.max_blocks)
    assert struct["len"][1] == (sh.BATCH,)
    # shardings() is a full-tree map; without a mesh it yields None leaves
    shardings = spec.shardings(rules)
    leaves = jax.tree.leaves(shardings)
    assert leaves == []         # mesh-less Rules -> no NamedShardings
    # multi-group spec: each group's table/pool carries its own shapes
    cfg2, _ = _model("gemma2-2b")
    spec2 = CacheSpec.from_config(cfg2, slots=4, max_len=64, page_size=8)
    struct2 = spec2.structure()
    assert struct2["page_tables"]["ring2"][0] == (4, 2)
    assert struct2["page_tables"]["ring8"][0] == (4, 8)


def test_engine_accepts_rules_single_device():
    """rules wiring is a no-op on one device but must not change tokens."""
    mesh = jax.make_mesh((1,), ("data",))
    rules = sh.Rules(table={sh.BATCH: "data", sh.PAGES: "data"}, mesh=mesh)
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, rules=rules)
    eng.submit(Request(rid=0, prompt=[5, 6, 7], max_new_tokens=6))
    (r,) = eng.run()
    plain = Engine(cfg, params, slots=2, max_len=64)
    plain.submit(Request(rid=0, prompt=[5, 6, 7], max_new_tokens=6))
    (p,) = plain.run()
    assert r.out_tokens == p.out_tokens


# ------------------------------------------------------ quantized KV pools
def _supported_qdtypes():
    from repro.serve.cache import KV_DTYPES, kv_dtype_supported

    return [d for d in KV_DTYPES if d != "fp32" and kv_dtype_supported(d)]


@pytest.mark.parametrize("page_size", [4, 8, 16])
@pytest.mark.parametrize("hkv", [1, 2, 4])
def test_quantize_roundtrip_bounded_error(page_size, hkv):
    """Property sweep: per-(page, kv-head) symmetric quantization across
    page sizes and head counts must reconstruct within one quantization
    step of that page's amax — including tiny (1e-20) and huge (1e8)
    amax pages and exactly-zero pages (which must come back exactly 0,
    never NaN from a zero scale)."""
    from repro.models.attention import dequantize_pages, quantize_pages
    from repro.serve.cache import kv_pool_dtype

    npg, dh = 6, 8
    rng = np.random.RandomState(page_size * 10 + hkv)
    x = jnp.asarray(rng.randn(npg, page_size, hkv, dh).astype(np.float32))
    x = x.at[1].multiply(1e-20)          # tiny amax
    x = x.at[2].multiply(1e8)            # huge amax
    x = x.at[3].set(0.0)                 # zero page -> zero scale floor
    for kv_dtype in _supported_qdtypes():
        qmax = {"int8": 127.0, "fp8_e4m3": 448.0}[kv_dtype]
        q, scale = quantize_pages(x, kv_pool_dtype(kv_dtype))
        y = dequantize_pages(q, scale)
        assert not np.any(np.isnan(np.asarray(y))), kv_dtype
        np.testing.assert_array_equal(np.asarray(y[3]), 0.0)
        amax = np.max(np.abs(np.asarray(x)), axis=(1, 3))   # [npg, hkv]
        # int8: uniform grid, error <= amax/qmax per (page, head).
        # fp8_e4m3: 3 mantissa bits -> relative error <= 1/16 of amax
        step = amax / qmax if kv_dtype == "int8" else amax / 16.0
        err = np.max(np.abs(np.asarray(y - x)), axis=(1, 3))
        assert np.all(err <= step + 1e-30), (kv_dtype, err, step)


def test_quantize_scale_shape_and_trash_invariance():
    """Scales are per (page, kv head); quantizing must not mix pages —
    overwriting one page (the trash row) leaves every other page's
    quantized block and scale bit-identical."""
    from repro.models.attention import quantize_pages

    npg, P, hkv, dh = 5, 4, 2, 8
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(npg, P, hkv, dh).astype(np.float32))
    q1, s1 = quantize_pages(x, jnp.int8)
    assert s1.shape == (npg, hkv)
    q2, s2 = quantize_pages(x.at[npg - 1].set(1e6), jnp.int8)
    np.testing.assert_array_equal(np.asarray(q1[:-1]), np.asarray(q2[:-1]))
    np.testing.assert_array_equal(np.asarray(s1[:-1]), np.asarray(s2[:-1]))


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
def test_quantized_splice_parity_with_fp32(kv_dtype):
    """admit_cache on a quantized spec must land the same KV (within
    quantization error) as the fp32 spec — full admission and the
    partial-page read-modify-write suffix admission (start mid-page
    re-quantizes the boundary page without losing its earlier tokens).
    The trash page is excluded: fp32 scatters masked tokens there, the
    quantized RMW zeros it; both are write-discard garbage."""
    from repro.models.attention import dequantize_pages
    from repro.serve import cache as cm

    if kv_dtype not in _supported_qdtypes():
        pytest.skip(f"{kv_dtype} pools unsupported on this toolchain")
    cfg, _ = _model("internlm2-1.8b")
    spec32 = CacheSpec.from_config(cfg, 2, 64, page_size=8)
    spec8 = CacheSpec.from_config(cfg, 2, 64, page_size=8,
                                  kv_dtype=kv_dtype)
    rows = {g.key: jnp.arange(1, g.ring_blocks + 1, dtype=jnp.int32)
            for g in spec32.groups}

    def one_cache(seed):
        r = np.random.RandomState(seed)
        layers = []
        for entry in spec32.init_paged_cache()["layers"]:
            if entry is not None and "pk" in entry:
                hkv, dh = entry["pk"].shape[2], entry["pk"].shape[3]
                layers.append({
                    "k": jnp.asarray(r.randn(1, hkv, 16, dh)
                                     .astype(np.float32)),
                    "v": jnp.asarray(r.randn(1, hkv, 16, dh)
                                     .astype(np.float32))})
            else:
                layers.append(entry)
        return {"layers": layers}

    def worst_err(Ca, Cb):
        worst = 0.0
        for l32, l8 in zip(Ca["layers"], Cb["layers"]):
            if l32 is None or "pk" not in l32:
                continue
            trash = l32["pk"].shape[0] - 1
            for pool, sc, ref in (("pk", "ks", "pk"), ("pv", "vs", "pv")):
                deq = dequantize_pages(l8[pool], l8[sc])[:trash]
                worst = max(worst, float(jnp.max(jnp.abs(
                    deq - l32[ref][:trash]))))
        return worst

    C32, C8 = spec32.init_paged_cache(), spec8.init_paged_cache()
    args = (jnp.int32(0), jnp.int32(0), jnp.int32(13), rows)
    C32 = cm.admit_cache(spec32, C32, one_cache(0), *args)
    C8 = cm.admit_cache(spec8, C8, one_cache(0), *args)
    tol = 0.05 if kv_dtype == "int8" else 0.3
    assert worst_err(C32, C8) < tol

    # suffix admission starting mid-page: the boundary page is RMW
    # re-quantized (earlier tokens dequantized, overlaid, re-scaled)
    args2 = (jnp.int32(0), jnp.int32(13), jnp.int32(24), rows)
    C32 = cm.admit_cache(spec32, C32, one_cache(7), *args2)
    C8 = cm.admit_cache(spec8, C8, one_cache(7), *args2)
    assert worst_err(C32, C8) < tol


def test_quantized_copy_shared_page_copies_scales():
    """CoW page copies on a quantized spec must carry the scale rows:
    a copied page dequantizes identically to its source."""
    from repro.models.attention import dequantize_pages
    from repro.serve import cache as cm

    if not _supported_qdtypes():
        pytest.skip("no quantized pool dtypes on this toolchain")
    cfg, _ = _model("internlm2-1.8b")
    spec = CacheSpec.from_config(cfg, 2, 64, page_size=8, kv_dtype="int8")
    C = spec.init_paged_cache()
    rng = np.random.RandomState(3)
    for entry in C["layers"]:
        if entry is None or "pk" not in entry:
            continue
        shape = entry["pk"].shape
        entry["pk"] = jnp.asarray(
            rng.randint(-127, 128, size=shape).astype(np.int8))
        entry["ks"] = jnp.asarray(
            rng.rand(*entry["ks"].shape).astype(np.float32) + 0.01)
    key = max(spec.groups, key=lambda g: g.ring_blocks).key
    C2 = cm.copy_shared_page(spec, C, key, jnp.int32(1), jnp.int32(4))
    for entry in C2["layers"]:
        if entry is None or "pk" not in entry:
            continue
        src = dequantize_pages(entry["pk"][1][None], entry["ks"][1][None])
        dst = dequantize_pages(entry["pk"][4][None], entry["ks"][4][None])
        np.testing.assert_array_equal(np.asarray(src), np.asarray(dst))


def test_quantized_spec_memory_accounting():
    """8-bit pools cost ~1/4 the fp32 pool bytes (+ scale rows), and the
    capacity ratio vs the dense fp32 layout reflects it — the >=1.8x
    concurrent-slots claim rests on this accounting."""
    cfg, _ = _model("internlm2-1.8b")
    s32 = CacheSpec.from_config(cfg, 4, 64, page_size=8)
    s8 = CacheSpec.from_config(cfg, 4, 64, page_size=8, kv_dtype="int8")
    assert s8.paged_kv_bytes() < s32.paged_kv_bytes() / 2
    m32 = s32.memory_stats({}, 0)
    m8 = s8.memory_stats({}, 0)
    assert m8["kv_dtype"] == "int8" and m32["kv_dtype"] == "fp32"
    assert (m8["dense_vs_paged_capacity_ratio"]
            >= 1.8 * m32["dense_vs_paged_capacity_ratio"])
    # fp32-width accounting of the same spec matches the fp32 spec's
    # pools exactly (scale rows only exist at stored precision)
    assert s8.paged_kv_bytes(4) == s32.paged_kv_bytes()


def test_engine_kv_dtype_validation_and_fallback():
    """Engine(kv_dtype=...): unknown names raise; 'auto' is fp32; an
    unsupported 8-bit dtype falls back to fp32 (capability gate, not a
    crash) while recording what was requested."""
    from repro.serve import cache as cm

    cfg, params = _model("internlm2-1.8b")
    with pytest.raises(ValueError, match="kv_dtype"):
        Engine(cfg, params, slots=2, max_len=64, kv_dtype="int4")
    eng = Engine(cfg, params, slots=2, max_len=64, kv_dtype="auto")
    assert eng.kv_dtype == "fp32" and eng.spec.kv_dtype == "fp32"
    if "int8" in _supported_qdtypes():
        eng8 = Engine(cfg, params, slots=2, max_len=64, kv_dtype="int8")
        assert eng8.kv_dtype == "int8" and eng8.spec.quantized
        stats = eng8.memory_stats()
        assert stats["kv_dtype"] == "int8"
        assert "pool_bytes_per_live_token" in stats
        assert "peak_live_slots" in stats
