"""Pure (device-free) tests of the logical-axis sharding rules."""

import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, do not error

import hypothesis.strategies as st
from hypothesis import given, settings
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as sh


class FakeMesh:
    """Just enough mesh for Rules (axis sizes + names)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def rules(table, mesh_shape):
    r = sh.Rules(table=dict(table), mesh=None)
    r.mesh = FakeMesh(mesh_shape)
    return r


BASE = {"batch": "data", "heads": "model", "mlp": "model",
        "seq": "model", "expert": ("pool",), "wide": ("pool", "intra")}
MESH = {"data": 16, "model": 16, "pool": 4, "intra": 4}


def test_basic_spec():
    r = rules(BASE, MESH)
    assert r.spec_for(("batch", None, "mlp"), (256, 128, 4096)) == \
        P("data", None, "model")


def test_axis_used_once_first_dim_wins():
    r = rules(BASE, MESH)
    # seq and heads both want "model": first dim keeps it
    spec = r.spec_for(("batch", "seq", "heads", None), (256, 4096, 32, 128))
    assert spec == P("data", "model")


def test_partial_tuple_reduction():
    r = rules(BASE, MESH)
    # expert takes "pool"; the wide axis keeps the leftover "intra"
    spec = r.spec_for(("expert", None, "wide"), (8, 64, 4096))
    assert spec == P("pool", None, "intra")


def test_divisibility_fallback():
    r = rules(BASE, MESH)
    spec = r.spec_for(("batch", "heads"), (256, 8))  # 8 % 16 != 0
    assert spec == P("data")
    assert any("heads" in f for f in r.fallbacks)


def test_tuple_prefix_shrinks_on_divisibility():
    r = rules({"wide": ("pool", "intra")}, MESH)
    # 8 % 16 != 0 but 8 % 4 == 0 -> keep the ("pool",) prefix
    spec = r.spec_for(("wide",), (8,))
    assert spec == P("pool")


@given(st.lists(st.sampled_from([None, "batch", "heads", "mlp", "seq",
                                 "expert", "wide"]),
                min_size=1, max_size=5),
       st.lists(st.integers(1, 512), min_size=1, max_size=5))
@settings(max_examples=200, deadline=None)
def test_spec_never_reuses_axis(axes, dims):
    dims = (dims * 5)[: len(axes)]
    r = rules(BASE, MESH)
    spec = r.spec_for(tuple(axes), tuple(dims))
    used = []
    for e in spec:
        if e is None:
            continue
        used.extend(e if isinstance(e, tuple) else (e,))
    assert len(used) == len(set(used)), (axes, dims, spec)
    # every sharded dim must be divisible by its axis product
    for dim, e in zip(dims, tuple(spec) + (None,) * len(dims)):
        if e is not None:
            assert dim % r.mesh_size(e) == 0
