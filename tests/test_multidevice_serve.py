"""Multi-device serving smoke: the ``Engine(rules=...)`` sharded path,
end-to-end in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` so the rest of the
suite keeps seeing ONE device (dry-run isolation rule, same convention as
tests/test_distribution.py)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run_sub(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def _two_device_capable() -> bool:
    """Probe (not version-sniff): can this jax fan the host platform out
    to 2 devices and build the plain data mesh the serving path uses?"""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax
        assert jax.device_count() == 2
        jax.make_mesh((2,), ("data",))
        print("OK")
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    try:
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=300)
    except (OSError, subprocess.TimeoutExpired):
        return False
    return r.returncode == 0 and "OK" in r.stdout


pytestmark = pytest.mark.skipif(
    not _two_device_capable(),
    reason="cannot force 2 host devices (probe failed); the sharded "
           "serving path is covered on multi-chip CI")


def test_sharded_engine_matches_unsharded_tokens():
    """Slot state + per-group page pools sharded over a 2-way data mesh
    must serve token-identical outputs to the unsharded engine — through
    continuous batching, paged splice, prefix sharing (shared-prefix
    prompts included), and the fused decode chunk."""
    out = _run_sub("""
        from repro.configs import get_config, reduced
        from repro.models import model_defs
        from repro.models import module as m
        from repro.parallel import sharding as sh
        from repro.serve.engine import Engine, Request

        cfg = reduced(get_config("internlm2-1.8b"))
        params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                               jnp.float32)
        prefix = [(3 * j) % 200 + 1 for j in range(10)]

        def load(eng):
            for i in range(6):
                tail = [(7 * i + j) % 150 + 1 for j in range(1 + i % 3)]
                eng.submit(Request(rid=i, prompt=prefix + tail,
                                   max_new_tokens=6))
            return {r.rid: r.out_tokens for r in eng.run()}

        mesh = jax.make_mesh((2,), ("data",))
        rules = sh.Rules(table={sh.BATCH: "data", sh.PAGES: "data"},
                         mesh=mesh)
        sharded = Engine(cfg, params, slots=2, max_len=64, rules=rules)
        got = load(sharded)
        plain = Engine(cfg, params, slots=2, max_len=64)
        want = load(plain)
        assert got == want, (got, want)
        assert len(got) == 6
        # the sharded engine exercised the prefix-sharing admission path
        ps = sharded.prefix_stats()
        assert ps["prefix_hits"] > 0 and ps["prefill_tokens_skipped"] > 0
        # slot batch really lands on the data axis
        table = sharded.cache["page_tables"][
            sharded.spec.widest_group.key]
        assert "data" in str(table.sharding), table.sharding
        print("OK", ps["prefix_hit_rate"])
    """)
    assert "OK" in out
