"""Multi-device behaviour, each case in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the rest of the suite
keeps seeing ONE device (per the assignment's dry-run isolation rule)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _multidevice_capable() -> bool:
    """Probe, don't version-sniff (same pattern as test_kernels): spawn
    one subprocess that forces 8 host devices and builds the explicit-
    axis-type mesh every case here relies on.  Any API drift (e.g. a jax
    build without ``jax.sharding.AxisType``) or a backend that cannot
    fan out host devices surfaces as a module-level skip instead of a
    wall of red; the cases are covered on multi-chip CI."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        assert jax.device_count() == 8
        mesh = jax.make_mesh((4, 2), ("pool", "x"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        assert dict(mesh.shape) == {"pool": 4, "x": 2}
        print("OK")
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    try:
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=300)
    except (OSError, subprocess.TimeoutExpired):
        return False
    return r.returncode == 0 and "OK" in r.stdout


pytestmark = pytest.mark.skipif(
    not _multidevice_capable(),
    reason="no multi-device-capable jax (8-forced-host-device mesh probe "
           "failed); distribution behaviour is covered on multi-chip CI")


def run_sub(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_scheduler_sync_async_equivalence():
    out = run_sub("""
        from repro.core import scheduler
        mesh = jax.make_mesh((4, 2), ("pool", "x"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        k = jax.random.PRNGKey(0)
        stacked = {"w": jax.random.normal(k, (4, 16, 16))}
        x = jax.random.normal(jax.random.fold_in(k, 1), (8, 16))
        fn = lambda p, v: jnp.tanh(v @ p["w"])
        y_sync = scheduler.run_sync(fn, stacked, x)
        y_async = scheduler.run_async(fn, stacked, x, mesh=mesh)
        y_hybrid = scheduler.hybrid_pools(fn, stacked, x, mesh=mesh)
        np.testing.assert_allclose(np.asarray(y_sync), np.asarray(y_async),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(y_sync), np.asarray(y_hybrid),
                                   rtol=1e-5, atol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """The same train step, unsharded vs (data=2, model=4)-sharded, must
    produce the same loss/metrics (SPMD is numerics-preserving modulo
    reduction order)."""
    out = run_sub("""
        import dataclasses
        from repro.configs import get_config, reduced
        from repro.core import tuner
        from repro.launch import build as B
        from repro.models import forward_train, model_defs
        from repro.models import module as m
        from repro.parallel import sharding as sh

        cfg = reduced(get_config("dbrx-132b"), layers=2, d_model=64,
                      experts=4)
        defs = model_defs(cfg)
        params = m.init_params(defs, jax.random.PRNGKey(0), jnp.float32)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                    cfg.vocab_size)
        batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
        loss0, _ = jax.jit(lambda p, b: forward_train(p, cfg, b))(params,
                                                                  batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        plan = tuner.Plan(name="t", data=2, pools=4, intra=1, fsdp=True,
                          seq_shard=False)
        rules = tuner.make_rules(plan, mesh)
        with mesh, sh.axis_rules(rules):
            loss1, _ = jax.jit(lambda p, b: forward_train(p, cfg, b))(
                params, batch)
        np.testing.assert_allclose(float(loss0), float(loss1), rtol=5e-4)
        print("OK", float(loss0), float(loss1))
    """)
    assert "OK" in out


def test_make_production_mesh_shapes():
    out = run_sub("""
        from repro.launch.mesh import make_production_mesh, make_tuned_mesh
        # 8 host devices: use tuned mesh factors that fit
        m = make_tuned_mesh(2, model_axis=4, data_axis=2)
        assert dict(m.shape) == {"data": 2, "pool": 2, "intra": 2}
        print("OK")
    """)
    assert "OK" in out


def test_checkpoint_elastic_restore_across_meshes():
    out = run_sub("""
        import tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ck
        mesh1 = jax.make_mesh((8,), ("model",),
                              axis_types=(jax.sharding.AxisType.Auto,))
        mesh2 = jax.make_mesh((2, 4), ("data", "model"),
                              axis_types=(jax.sharding.AxisType.Auto,)*2)
        w = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh1, P("model", None)))
        with tempfile.TemporaryDirectory() as d:
            ck.save(d, {"w": w}, 1)
            target = {"w": jax.ShapeDtypeStruct(
                (8, 8), jnp.float32,
                sharding=NamedSharding(mesh2, P("data", "model")))}
            restored, _ = ck.restore(d, target)
        assert restored["w"].sharding.spec == P("data", "model")
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(w))
        print("OK")
    """)
    assert "OK" in out


def test_sp_boundary_grad_correctness():
    out = run_sub("""
        from repro.core import tuner
        from repro.parallel import sharding as sh
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        plan = tuner.Plan(name="t", data=2, pools=1, intra=4,
                          seq_shard=True)
        rules = tuner.make_rules(plan, mesh)
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))

        def f(x):
            return jnp.sum(jnp.sin(sh.sp_boundary(x)) ** 2)

        g_plain = jax.grad(lambda x: jnp.sum(jnp.sin(x) ** 2))(x)
        with mesh, sh.axis_rules(rules):
            g = jax.jit(jax.grad(f))(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_plain),
                                   rtol=1e-5)
        print("OK")
    """)
    assert "OK" in out
