"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see ONE
device; multi-device behaviour is tested via subprocesses
(test_distribution.py)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import model_defs
from repro.models import module as m


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny(arch: str, **kw):
    return reduced(get_config(arch), **kw)


def init_model(cfg, seed=0, dtype=jnp.float32):
    defs = model_defs(cfg)
    return m.init_params(defs, jax.random.PRNGKey(seed), dtype)
