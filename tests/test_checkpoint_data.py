"""Checkpoint roundtrip/atomicity/resume + deterministic data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import SyntheticLM
from repro.optim import adamw
from repro.train import checkpoint as ck
from repro.train.trainer import Trainer, TrainerConfig


def _state():
    k = jax.random.PRNGKey(3)
    params = {"a": jax.random.normal(k, (16, 130)),
              "nested": {"b": jnp.arange(12).reshape(3, 4)}}
    cfg = adamw.AdamWConfig(quantize_v=True)
    return {"params": params, "opt": adamw.init(params, cfg),
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip_with_qtensor(tmp_path):
    state = _state()
    ck.save(str(tmp_path), state, 7)
    restored, step = ck.restore(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_atomicity(tmp_path):
    state = _state()
    ck.save(str(tmp_path), state, 5)
    ck.save(str(tmp_path), state, 9)
    # a stale .tmp dir (simulated crash) must be ignored
    os.makedirs(tmp_path / "step_00000011.tmp")
    assert ck.latest_step(str(tmp_path)) == 9


def test_restore_respects_target_dtype(tmp_path):
    state = {"w": jnp.ones((4, 4), jnp.float32)}
    ck.save(str(tmp_path), state, 1)
    target = {"w": jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)}
    restored, _ = ck.restore(str(tmp_path), target)
    assert restored["w"].dtype == jnp.bfloat16


def test_trainer_resume_replays_deterministically(tmp_path):
    cfg = reduced(get_config("internlm2-1.8b"), layers=1, d_model=32,
                  d_ff=64, vocab=64)
    tc = TrainerConfig(steps=6, batch=2, seq_len=16,
                       ckpt_dir=str(tmp_path), ckpt_every=3, log_every=1)
    t1 = Trainer(cfg, tc)
    t1.run()
    loss_full = t1.metrics_history[-1]["loss"]

    # restart from step 3 and replay 3..5: identical final loss
    t2 = Trainer(cfg, tc)
    start = t2.maybe_restore()
    assert start == 6  # final checkpoint; restore the mid one instead
    t3 = Trainer(cfg, tc)
    t3.state, _ = ck.restore(str(tmp_path), t3.state, step=3)
    t3.run()
    np.testing.assert_allclose(t3.metrics_history[-1]["loss"], loss_full,
                               rtol=1e-5)


def test_data_determinism_and_structure():
    cfg = reduced(get_config("internlm2-1.8b"))
    src = SyntheticLM(cfg, batch=4, seq_len=32, seed=11)
    b1 = src.batch_at(5)
    b2 = src.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], src.batch_at(6)["tokens"])
    # labels are next-token shifted stream
    assert b1["tokens"].shape == (4, 32) and b1["labels"].shape == (4, 32)
    assert (b1["tokens"] < cfg.vocab_size).all()
    # learnable: majority of transitions follow next = (31x+17) % v
    det = (b1["tokens"] * 31 + 17) % cfg.vocab_size
    frac = (det == b1["labels"]).mean()
    assert frac > 0.5, frac


def test_prefetcher(tmp_path):
    from repro.data.pipeline import DevicePrefetcher
    cfg = reduced(get_config("internlm2-1.8b"))
    src = SyntheticLM(cfg, batch=2, seq_len=16, seed=0)
    pf = DevicePrefetcher(src, depth=2)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [0, 1, 2, 3]
