"""Property-based SLO-scheduler invariants (hypothesis) + engine-level
SLO policy behavior: random interleavings of submit/cancel/preempt/
release across classes never starve an admitted request, never leak
pages, and always admit in slack order among feasible requests; the
Engine's slo policy favors interactive admissions and throttles
non-interactive prefill budgets when interactive TTFT slack goes
negative."""

import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, do not error

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs import get_config, reduced
from repro.serve.cache import CacheSpec
from repro.serve.scheduler import (PagePoolExhausted, Request,
                                   RequestStatus, SLO_CLASSES, Scheduler)

CLASSES = sorted(SLO_CLASSES)


def _scheduler(policy, slots=4):
    cfg = reduced(get_config("internlm2-1.8b"))
    spec = CacheSpec.from_config(cfg, slots=slots, max_len=64, page_size=8)
    return Scheduler(spec, prefix_sharing=False, policy=policy)


class _Harness:
    """Pure-host Scheduler driver: mirrors the Engine's slot/lease
    bookkeeping (admit into free slots, release on finish, requeue on
    preempt) without any device work, so hypothesis can hammer it."""

    def __init__(self, policy, slots=4):
        self.sched = _scheduler(policy, slots)
        self.slots = slots
        self.live = {}
        self.now = 0.0
        self.next_rid = 0
        self.submitted = []

    def free_slots(self):
        return [s for s in range(self.slots) if s not in self.live]

    def submit(self, cls, plen, max_new):
        req = Request(rid=self.next_rid, prompt=list(range(1, plen + 1)),
                      max_new_tokens=max_new, slo_class=cls,
                      submit_time=self.now)
        self.next_rid += 1
        try:
            self.sched.submit(req)
        except PagePoolExhausted:
            return
        self.submitted.append(req)

    def admit(self):
        for adm in self.sched.admissions(self.free_slots(), now=self.now):
            self.live[adm.slot] = adm.req

    def release(self, pick):
        if not self.live:
            return
        slot = sorted(self.live)[pick % len(self.live)]
        req = self.live.pop(slot)
        req.status = RequestStatus.FINISHED
        self.sched.release(slot)

    def preempt(self, pick):
        if not self.live:
            return
        slot = sorted(self.live)[pick % len(self.live)]
        req = self.live.pop(slot)
        self.sched.release(slot)
        req.preemptions += 1
        if req.preemptions <= 2:
            self.sched.requeue(req)
        else:
            req.status = RequestStatus.FINISHED

    def cancel(self, pick):
        if not self.sched.queue:
            return
        req = self.sched.queue[pick % len(self.sched.queue)]
        self.sched.queue.remove(req)
        req.status = RequestStatus.CANCELLED

    def drain(self):
        """Admit/release to completion — must terminate (no starvation)
        because every blocked head fits the empty pool by ``validate``."""
        for _ in range(20 * (len(self.submitted) + 1)):
            if not self.sched.queue and not self.live:
                return True
            self.now += 1.0
            self.admit()
            self.release(0)
        return False


_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("submit"), st.sampled_from(CLASSES),
                  st.integers(2, 12), st.integers(1, 16)),
        st.tuples(st.just("admit")),
        st.tuples(st.just("release"), st.integers(0, 7)),
        st.tuples(st.just("preempt"), st.integers(0, 7)),
        st.tuples(st.just("cancel"), st.integers(0, 7)),
        st.tuples(st.just("tick"), st.integers(1, 5)),
    ), min_size=1, max_size=40)


@settings(max_examples=60, deadline=None)
@given(ops=_OPS, policy=st.sampled_from(["fifo", "slo"]))
def test_random_interleavings_never_starve_or_leak(ops, policy):
    h = _Harness(policy)
    for op in ops:
        if op[0] == "submit":
            h.submit(*op[1:])
        elif op[0] == "admit":
            h.admit()
        elif op[0] == "release":
            h.release(op[1])
        elif op[0] == "preempt":
            h.preempt(op[1])
        elif op[0] == "cancel":
            h.cancel(op[1])
        else:
            h.now += op[1]
    assert h.drain(), (
        f"{policy}: queue failed to drain — an admitted request starved")
    assert h.sched.pages_in_use == 0, (
        f"{policy}: {h.sched.pages_in_use} pages leaked after clean drain")
    for req in h.submitted:
        assert req.status in (RequestStatus.FINISHED,
                              RequestStatus.CANCELLED)


@settings(max_examples=60, deadline=None)
@given(ops=_OPS)
def test_slo_admissions_in_slack_order_within_boundary(ops):
    """Within every chunk boundary the slo policy admits in
    (priority, slack) order — the admission_log replay is monotone."""
    h = _Harness("slo")
    for op in ops:
        if op[0] == "submit":
            h.submit(*op[1:])
        elif op[0] == "admit":
            h.admit()
        elif op[0] == "release":
            h.release(op[1])
        elif op[0] == "preempt":
            h.preempt(op[1])
        elif op[0] == "cancel":
            h.cancel(op[1])
        else:
            h.now += op[1]
    h.drain()
    by_boundary = {}
    for boundary, rid, prio, slack, _chunk in h.sched.admission_log:
        by_boundary.setdefault(boundary, []).append((prio, slack, rid))
    for boundary, entries in by_boundary.items():
        keys = [(p, s) for p, s, _ in entries]
        assert keys == sorted(keys), (
            f"boundary {boundary} admitted out of slack order: {entries}")


@settings(max_examples=60, deadline=None)
@given(ops=_OPS)
def test_fifo_admissions_in_arrival_order(ops):
    """The default policy must stay byte-for-byte FIFO: the admission
    log's rids are a subsequence-respecting arrival order within each
    drain of the queue (requeued preemption victims re-enter at the
    back with their original _seq, so we assert per-boundary order by
    queue position instead of globally)."""
    h = _Harness("fifo")
    for op in ops:
        if op[0] == "submit":
            h.submit(*op[1:])
        elif op[0] == "admit":
            h.admit()
        elif op[0] == "release":
            h.release(op[1])
        elif op[0] == "preempt":
            h.preempt(op[1])
        elif op[0] == "cancel":
            h.cancel(op[1])
        else:
            h.now += op[1]
    # under FIFO, admissions within one boundary follow queue order,
    # and the scheduler never reorders the queue itself
    assert h.sched.admission_order(h.now) == h.sched.queue
    h.drain()
    assert h.sched.pages_in_use == 0


# deterministic (non-hypothesis) SLO policy tests live in
# tests/test_latency_stats.py so they run even without the optional
# hypothesis dependency this module is gated on
