"""Graph/width/tuner/cost-model tests, incl. hypothesis property tests on
the system's invariants."""

import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, do not error

import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core import autotune, build_graph, cost_model, graph, tuner


# ------------------------------------------------------------- graph widths
def test_paper_width_definition_example():
    """Paper §8: the Fig. 5b module (7 heavy ops, 3 levels) has avg width 2."""
    b = graph._Builder("fig5b")
    root = b.add("matmul", "in", 1.0)
    # 4 branches with 1,1,2,3 convs (7 heavy ops total after the root trim)
    b1 = b.add("matmul", "b1.c1", 1.0, (root,))
    b2 = b.add("matmul", "b2.c1", 1.0, (root,))
    b3a = b.add("matmul", "b3.c1", 1.0, (root,))
    b3b = b.add("matmul", "b3.c2", 1.0, (b3a,))
    b4a = b.add("matmul", "b4.c1", 1.0, (root,))
    b4b = b.add("matmul", "b4.c2", 1.0, (b4a,))
    b4c = b.add("matmul", "b4.c3", 1.0, (b4b,))
    g = b.graph()
    # 8 nodes, depth 4 -> floor(8/4) = 2, matching the paper's worked example
    assert g.depth == 4
    assert g.avg_width == 2
    assert g.max_width == 4


def test_widths_across_archs():
    dense = build_graph(get_config("mistral-large-123b"))
    assert dense.avg_width == 1
    moe = build_graph(get_config("dbrx-132b"))
    assert moe.avg_width >= 4
    assert moe.max_width >= 16
    whisper = build_graph(get_config("whisper-medium"))
    assert whisper.avg_width == 2  # encoder chain runs beside decoder chain


def test_training_widens_graph():
    cfg = get_config("internlm2-1.8b")
    g_inf = build_graph(cfg)
    g_tr = build_graph(cfg, training=True, global_batch=8)
    assert g_tr.num_heavy_ops == 2 * g_inf.num_heavy_ops
    assert g_tr.max_width == 2 * g_inf.max_width
    # paper §4.1: large batches make grad/weight ops imbalanced -> no widening
    g_tr_big = build_graph(cfg, training=True, global_batch=256)
    assert g_tr_big.num_heavy_ops == g_inf.num_heavy_ops


@given(st.integers(1, 6), st.integers(1, 8), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_width_invariants_random_graphs(levels, width, fan):
    """avg_width <= max_width; depth == #levels for layered graphs."""
    b = graph._Builder("rand")
    prev_level = [b.add("matmul", "root", 1.0)]
    for li in range(levels):
        cur = []
        for wi in range(width):
            deps = tuple(prev_level[: max(1, min(fan, len(prev_level)))])
            cur.append(b.add("matmul", f"l{li}w{wi}", 1.0, deps))
        prev_level = cur
    g = b.graph()
    assert 1 <= g.avg_width <= g.max_width
    assert g.depth == levels + 1
    assert g.max_width == max(g.level_sizes())


# ------------------------------------------------------------------- tuner
@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_guideline_plan_invariants(arch, shape):
    cfg = get_config(arch)
    plan = tuner.guideline_plan(cfg, SHAPES[shape])
    assert plan.pools * plan.intra == 16
    assert plan.pools >= 1
    if cfg.moe is None:
        assert plan.pools == 1  # width >1 only realizable via experts
    else:
        assert plan.pools <= cfg.moe.num_experts
    avg_w = int(plan.notes.split("avg_width=")[1].split()[0])
    assert plan.pools <= max(avg_w, 1)


@given(st.sampled_from(ARCH_IDS), st.sampled_from(list(SHAPES)))
@settings(max_examples=20, deadline=None)
def test_enumerated_plans_are_valid(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    for plan in tuner.enumerate_plans(cfg, shape):
        assert plan.pools * plan.intra == 16
        if plan.pools > 1:
            assert cfg.moe and plan.pools <= cfg.moe.num_experts
    ranked = autotune.sweep(cfg, shape)
    costs = [r.step_s for r in ranked if r.fits]
    assert costs == sorted(costs)


def test_guideline_close_to_sweep_optimum():
    """Fig. 18 claim at cost-model level: guideline within 1.5x of the swept
    optimum for every arch (the paper reports >=95%; our cost model is
    coarser, the compiled-HLO check lives in the benchmarks)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        rows = autotune.compare_settings(cfg, SHAPES["train_4k"])
        opt = rows["global_optimum"]
        gl = rows["guideline"]
        assert gl.step_s <= 1.5 * opt.step_s, (arch, gl.step_s, opt.step_s)


# -------------------------------------------------------------- cost model
def test_model_flops_scaling():
    cfg = get_config("internlm2-1.8b")
    f_train = cost_model.model_flops(cfg, SHAPES["train_4k"])
    f_pref = cost_model.model_flops(cfg, SHAPES["prefill_32k"])
    assert f_train == pytest.approx(3 * f_pref, rel=1e-6)  # 6ND vs 2ND
    f_dec = cost_model.model_flops(cfg, SHAPES["decode_32k"])
    assert f_dec < f_pref / 1000


def test_moe_active_params():
    cfg = get_config("dbrx-132b")
    total = cost_model.model_param_count(cfg)
    active = cost_model.model_active_param_count(cfg)
    assert active < 0.4 * total  # top-4 of 16 experts


@given(st.integers(1, 16).filter(lambda p: 16 % p == 0),
       st.booleans())
@settings(max_examples=20, deadline=None)
def test_cost_terms_positive(pools, fsdp):
    cfg = get_config("dbrx-132b")
    c = cost_model.estimate(cfg, SHAPES["train_4k"], data=16, pools=pools,
                            intra=16 // pools, fsdp=fsdp)
    assert c.compute_s > 0 and c.memory_s > 0 and c.collective_s >= 0
    assert c.dominant in ("compute", "memory", "collective")
