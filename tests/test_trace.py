"""Observability invariants: tracing on vs off is token-identical with
identical compile counts and a sync-free decode chunk (tracing records
host-side at chunk boundaries only); the bounded ring evicts oldest
non-terminal events while terminal events survive by contract; seeded
``TrafficGenerator`` replays under a ``VirtualClock`` produce
byte-identical trace fingerprints; ``Engine.observe()`` emits only
registry-known dotted names; ``export_trace`` / ``explain`` render
complete submit->terminal chains."""

import json

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import model_defs
from repro.models import module as m
from repro.serve import metrics
from repro.serve.engine import Engine, Request
from repro.serve.trace import TERMINAL_KINDS, Tracer, to_chrome_trace
from repro.serve.traffic import TrafficGenerator, VirtualClock, replay


def _model(arch, **kw):
    cfg = reduced(get_config(arch), **kw)
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    return cfg, params


def _workload(eng, n=6):
    for i in range(n):
        plen = 2 + (3 * i) % 7
        eng.submit(Request(rid=i, prompt=[(i + j) % 150 + 1
                                          for j in range(plen)],
                           max_new_tokens=4 + i % 3))
    return eng.run(max_steps=50_000)


# ---------------------------------------------------------------------------
# Tracing on vs off: token parity, compile parity, sync freedom
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma2-2b"])
def test_tracing_is_invisible_to_outputs_and_compiles(arch):
    """Tracing must be a pure host-side observer: identical tokens and
    identical executable counts with and without it."""
    cfg, params = _model(arch)
    runs = {}
    for traced in (False, True):
        eng = Engine(cfg, params, slots=2, max_len=64,
                     prefix_sharing=False, trace=traced)
        done = _workload(eng)
        runs[traced] = ({r.rid: list(r.out_tokens) for r in done},
                        (eng.prefill_compiles, eng.suffix_prefill_compiles,
                         eng.decode_compiles, eng.admit_compiles))
        if traced:
            evs = eng.tracer.events()
            assert {e.kind for e in evs} >= {"submit", "admit", "chunk",
                                             "finish"}
            assert eng.tracer.dropped == 0
    assert runs[False][0] == runs[True][0]
    assert runs[False][1] == runs[True][1]


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma2-2b"])
def test_traced_decode_chunk_stays_sync_free(arch):
    """With tracing on, the fused decode chunk still performs zero
    device->host transfers (events are recorded by the host drain at
    chunk boundaries, from the drain's one clock read)."""
    cfg, params = _model(arch)
    eng = Engine(cfg, params, slots=2, max_len=64,
                 prefix_sharing=False, trace=True)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=32))
    eng.submit(Request(rid=1, prompt=[4, 5], max_new_tokens=32))
    eng._admit()
    with jax.transfer_guard_device_to_host("disallow"):
        toks = eng.step_chunk()
        toks2 = eng.step_chunk()
    eng._drain(jnp.concatenate([toks, toks2]))
    assert eng.host_syncs == 1 and eng.steps == 2 * eng.sync_interval
    assert eng.decode_compiles == 1
    assert len(eng.tracer.events()) > 0


def test_token_chunks_parallel_to_token_times():
    """Satellite bugfix: every emitted token carries the chunk sequence
    number it was drained in, parallel to token_times, and admission_log
    entries carry the chunk id for cross-referencing."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, sync_interval=4,
                 prefix_sharing=False)
    done = _workload(eng, n=4)
    for r in done:
        assert len(r.token_chunks) == len(r.token_times) \
            == len(r.out_tokens)
        assert r.token_chunks == sorted(r.token_chunks)  # monotone
        # tokens drained in the same chunk share the same timestamp;
        # distinct chunk ids disambiguate them for TPOT attribution
        for i in range(1, len(r.token_chunks)):
            if r.token_chunks[i] == r.token_chunks[i - 1]:
                assert r.token_times[i] == r.token_times[i - 1]
    assert len({c for r in done for c in r.token_chunks}) > 1
    for entry in eng.scheduler.admission_log:
        assert len(entry) == 5
        assert entry[4] >= 0                             # the chunk id


# ---------------------------------------------------------------------------
# Ring buffer: bounded, oldest-first eviction, terminal retention
# ---------------------------------------------------------------------------

def test_ring_evicts_oldest_but_never_terminal_events():
    tr = Tracer(capacity=8)
    for i in range(6):
        tr.record("chunk", float(i), chunk=i)
    tr.record("finish", 6.0, rid=0, status="FINISHED")
    tr.record("reject", 7.0, rid=1, why="queue_full")
    assert len(tr) == 8 and tr.dropped == 0
    for i in range(20):
        tr.record("prefill", 8.0 + i, rid=2, slot=0)
    # ring stayed bounded; evicted chunk/prefill events were counted
    # (2 of the 8 retained are the pinned terminals, so 6 of the 26
    # non-terminal events survive and 20 were dropped)
    assert len(tr) == tr.capacity
    assert tr.dropped == 20
    kinds = [e.kind for e in tr.events()]
    assert kinds.count("finish") == 1 and kinds.count("reject") == 1
    # events() stays seq-ordered even with pinned terminals interleaved
    seqs = [e.seq for e in tr.events()]
    assert seqs == sorted(seqs)


def test_ring_terminal_events_may_exceed_capacity():
    """Terminal events are never dropped, even if that means holding
    more than ``capacity`` events."""
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.record("finish", float(i), rid=i, status="FINISHED")
    assert len(tr) == 10 and tr.dropped == 0
    assert all(e.kind in TERMINAL_KINDS for e in tr.events())
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_engine_trace_capacity_and_eviction_end_to_end():
    """A tiny engine-side ring still retains every terminal event after
    a workload that overflows it many times over."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, sync_interval=2,
                 prefix_sharing=False, trace=8)
    done = _workload(eng, n=6)
    assert len(done) == 6
    assert eng.tracer.dropped > 0
    terms = [e for e in eng.tracer.events() if e.kind in TERMINAL_KINDS]
    assert sorted(e.rid for e in terms) == list(range(6))


# ---------------------------------------------------------------------------
# Deterministic fingerprints under VirtualClock replay
# ---------------------------------------------------------------------------

def test_replayed_traffic_yields_identical_fingerprints():
    """Two virtual-clock replays of one seeded trace produce
    byte-identical trace fingerprints (the property fig04
    --trace-report gates); a different traffic seed changes them."""
    cfg, params = _model("internlm2-1.8b")

    def once(seed):
        trace = TrafficGenerator(seed, rate=3.0,
                                 process="bursty").generate(8)
        clk = VirtualClock(dt=0.05)
        eng = Engine(cfg, params, slots=2, max_len=64, page_size=8,
                     num_pages=10, sync_interval=4, policy="slo",
                     prefix_sharing=False, clock=clk, trace=True)
        replay(eng, trace, clock=clk)
        assert eng.leaked_pages() == 0
        return eng.tracer.fingerprint()

    fp1, fp2 = once(5), once(5)
    assert fp1 == fp2
    assert fp1 != once(6)


# ---------------------------------------------------------------------------
# observe() registry discipline + exporters
# ---------------------------------------------------------------------------

def test_observe_emits_only_registered_names():
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, trace=True)
    _workload(eng, n=3)
    obs = eng.observe()
    assert obs, "observe() returned nothing"
    for name, value in obs.items():
        assert metrics.kind_of(name) is not None, name
        assert isinstance(value, (int, float, bool)), (name, value)
    # the registry's headline names are present
    for name in ("engine.chunks", "engine.host_syncs",
                 "pool.pages_in_use", "pool.peak_pages_in_use",
                 "sched.admissions", "sched.preemptions.total",
                 "latency.goodput", "trace.events", "trace.dropped"):
        assert name in obs, name
    # metric names are stable API: renaming one must raise loudly
    with pytest.raises(KeyError):
        metrics._put({}, "engine.not_a_metric", 1)


def test_export_trace_and_explain_complete_chains(tmp_path):
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, trace=True)
    done = _workload(eng, n=4)
    path = tmp_path / "trace.json"
    obj = eng.export_trace(str(path))
    assert json.loads(path.read_text()) == obj
    evs = obj["traceEvents"]
    # every finished rid has a submit instant, a terminal instant, and
    # a flow chain (s ... f) linking them
    for r in done:
        inst = [e for e in evs if e["ph"] == "i"
                and e.get("args", {}).get("rid") == r.rid]
        assert any(e["name"] == "submit" for e in inst), r.rid
        assert any(e["name"] == "finish" for e in inst), r.rid
        flows = [e for e in evs if e["ph"] in ("s", "t", "f")
                 and e.get("id") == str(r.rid)]
        assert [e["ph"] for e in flows][:1] == ["s"]
        assert [e["ph"] for e in flows][-1:] == ["f"]
        txt = eng.explain(r.rid)
        assert "submit" in txt and "terminal: FINISHED" in txt
        assert "queued:" in txt and "running:" in txt
    assert eng.explain(9999) == "rid 9999: no trace events recorded"
    # untraced engines refuse rather than silently returning nothing
    bare = Engine(cfg, params, slots=1, max_len=64)
    with pytest.raises(ValueError):
        bare.export_trace()
    with pytest.raises(ValueError):
        bare.explain(0)


def test_chrome_trace_preempt_flow_spans_slot_hop():
    """A preempted-and-resumed request's flow chain hops across slot
    tracks and its wait phases include a requeued span."""
    tr = Tracer()
    tr.record("submit", 0.0, rid=7)
    tr.record("admit", 1.0, rid=7, slot=0, chunk=1)
    tr.record("preempt", 2.0, rid=7, slot=0, why="pressure")
    tr.record("admit", 3.0, rid=7, slot=1, chunk=3, resume=True)
    tr.record("finish", 4.0, rid=7, slot=1, status="FINISHED")
    obj = to_chrome_trace(tr.events())
    evs = obj["traceEvents"]
    flows = [e for e in evs if e["ph"] in ("s", "t", "f")]
    assert [e["ph"] for e in flows] == ["s", "t", "t", "t", "f"]
    assert flows[-1]["bp"] == "e"
    assert len({e["tid"] for e in flows}) == 3     # queue + both slots
    waits = [e for e in evs if e["ph"] == "b"]
    assert [w["args"]["phase"] for w in waits] == ["queued", "requeued"]
    runs = [e for e in evs if e["ph"] == "X"]
    assert len(runs) == 2 and all(e["dur"] > 0 for e in runs)
