"""Robustness: preemption/resume token parity, deadline + cancellation
reaping, typed admission-control rejections, watchdog stall recovery,
and chaos clean-drain (zero leaked pages, all-terminal statuses)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import model_defs
from repro.models import module as m
from repro.serve.chaos import ChaosMonkey
from repro.serve.engine import Engine, Request
from repro.serve.scheduler import RequestStatus


def _model(arch, **kw):
    cfg = reduced(get_config(arch), **kw)
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    return cfg, params


def _prompts(n):
    return [[(3 * i + j) % 250 + 1 for j in range(2 + (5 * i) % 11)]
            for i in range(n)]


# ---------------------------------------------------------------------------
# deadline reaping + mid-stream cancellation
# ---------------------------------------------------------------------------

def test_deadline_reaped_mid_stream_and_slot_reused_same_boundary():
    """A running request whose deadline expires is reaped TIMED_OUT at
    the next chunk boundary — pages free immediately and a queued
    request admits into the freed slot at that very boundary."""
    cfg, params = _model("internlm2-1.8b")
    clk = {"t": 0.0}
    eng = Engine(cfg, params, slots=1, max_len=64, prefix_sharing=False,
                 clock=lambda: clk["t"])
    doomed = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=40, ttl=5.0)
    waiting = Request(rid=1, prompt=[4, 5, 6], max_new_tokens=12)
    assert eng.submit(doomed) is None
    assert eng.submit(waiting) is None
    eng.step()                                  # doomed admitted, decoding
    assert doomed.status == RequestStatus.RUNNING
    assert eng.scheduler.pool.in_use > 0
    clk["t"] = 10.0                             # past the 5s deadline
    eng.step()                                  # reap + re-admit boundary
    assert doomed.status == RequestStatus.TIMED_OUT
    assert doomed.done and 0 < len(doomed.out_tokens) < 40
    assert eng._slot_req[0] is waiting          # freed slot reused at once
    done = eng.run(max_steps=1000)
    assert waiting in done
    assert waiting.status == RequestStatus.FINISHED
    assert len(waiting.out_tokens) == 12
    fs = eng.fault_stats()
    assert fs["timed_out"] == 1
    assert eng.scheduler.pool.in_use == 0       # everything released
    assert eng.leaked_pages() == 0


def test_queued_request_times_out_without_ever_running():
    """Deadlines also apply while QUEUED: an expired queued request is
    reaped without occupying a slot, and never emits a token."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=1, max_len=64, prefix_sharing=False)
    live = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=6)
    dead = Request(rid=1, prompt=[4, 5, 6], max_new_tokens=6,
                   deadline=0.0)   # monotonic clock starts past 0
    eng.submit(live)
    eng.submit(dead)
    done = eng.run(max_steps=1000)
    assert len(done) == 2
    assert live in done and dead in done
    assert dead.status == RequestStatus.TIMED_OUT
    assert dead.out_tokens == []
    assert live.status == RequestStatus.FINISHED
    assert eng.leaked_pages() == 0


def test_cancel_mid_stream_frees_slot_same_boundary():
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=1, max_len=64, prefix_sharing=False)
    victim = Request(rid=0, prompt=[7, 8, 9], max_new_tokens=40)
    waiting = Request(rid=1, prompt=[4, 5, 6], max_new_tokens=12)
    eng.submit(victim)
    eng.submit(waiting)
    eng.step()
    assert victim.status == RequestStatus.RUNNING
    victim.cancel()
    eng.step()                                  # reap + re-admit boundary
    assert victim.status == RequestStatus.CANCELLED
    assert victim.done and 0 < len(victim.out_tokens) < 40
    assert eng._slot_req[0] is waiting
    eng.run(max_steps=1000)
    assert waiting.status == RequestStatus.FINISHED
    fs = eng.fault_stats()
    assert fs["cancelled"] == 1
    assert eng.scheduler.pool.in_use == 0
    assert eng.leaked_pages() == 0


# ---------------------------------------------------------------------------
# preemption / resume token parity at temperature 0
# ---------------------------------------------------------------------------

def test_pressure_preemption_token_parity_full_attention():
    """Oversubscribed pool (full slot occupancy impossible): the engine
    must preempt under pressure, and every preempted-then-resumed
    request's greedy output must be identical to an uncontended run."""
    cfg, params = _model("internlm2-1.8b")
    prompts = _prompts(6)

    def load(eng):
        reqs = [Request(rid=i, prompt=list(p), max_new_tokens=12)
                for i, p in enumerate(prompts)]
        for r in reqs:
            assert eng.submit(r) is None
        done = eng.run(max_steps=100_000)
        assert len(done) == len(reqs)
        return {r.rid: list(r.out_tokens) for r in done}, reqs

    calm = Engine(cfg, params, slots=4, max_len=64, page_size=8)
    out_calm, _ = load(calm)

    # 4 slots x 3-page worst case = 12 > 9 physical pages
    eng = Engine(cfg, params, slots=4, max_len=64, page_size=8,
                 num_pages=9)
    out_ft, reqs = load(eng)
    fs = eng.fault_stats()
    assert fs["pressure_preemptions"] >= 1
    assert any(r.preemptions > 0 for r in reqs)
    assert out_ft == out_calm
    assert all(r.status == RequestStatus.FINISHED for r in reqs)
    assert eng.leaked_pages() == 0


def test_preempt_resume_token_parity_windowed_ring_wrap():
    """gemma2 sliding windows: preempt mid-generation, resume with the
    generated tokens replayed as prompt tail (no radix on windowed
    archs — full re-prefill, ring-wrapping in the splice), and decode
    past the window after resume.  Output must match the uninterrupted
    run exactly."""
    cfg, params = _model("gemma2-2b")
    window = next(b.window for b in cfg.blocks if b.window)
    prompt, max_new = [3, 1, 4, 1, 5], window + 8   # wraps post-resume

    solo = Engine(cfg, params, slots=1, max_len=96)
    ref = Request(rid=0, prompt=list(prompt), max_new_tokens=max_new)
    solo.submit(ref)
    solo.run(max_steps=1000)
    assert len(ref.out_tokens) == max_new

    eng = Engine(cfg, params, slots=1, max_len=96)
    req = Request(rid=0, prompt=list(prompt), max_new_tokens=max_new)
    eng.submit(req)
    eng.step()
    eng.step()
    assert 0 < len(req.out_tokens) < max_new
    eng._preempt_slot(0, "pressure")
    assert req.status == RequestStatus.PREEMPTED
    assert req.preemptions == 1
    assert eng.queue and eng.queue[0] is req
    eng.run(max_steps=1000)
    assert req.status == RequestStatus.FINISHED
    assert req.out_tokens == ref.out_tokens
    assert eng.fault_stats()["resumes"] == 1
    assert eng.leaked_pages() == 0


def test_resume_recovers_prefill_from_radix_when_pool_has_slack():
    """When preemption is NOT page-bound, the preserved prompt pages
    survive in the radix index and the resume admits as a prefix hit:
    most of the replayed effective prompt is recovered, not recomputed."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, page_size=8)
    req = Request(rid=0, prompt=[(5 * j) % 200 + 1 for j in range(12)],
                  max_new_tokens=20)
    eng.submit(req)
    eng.step()                       # prefill + first chunk
    assert len(req.out_tokens) > 0
    eng._preempt_slot(0, "watchdog")
    eng.run(max_steps=1000)
    assert req.status == RequestStatus.FINISHED
    fs = eng.fault_stats()
    assert fs["watchdog_preemptions"] == 1
    assert fs["resumes"] == 1
    assert fs["resume_recovered_tokens"] > 0
    assert fs["recovered_prefill_fraction"] > 0.5
    assert eng.leaked_pages() == 0


# ---------------------------------------------------------------------------
# admission control: typed rejection and shed policies
# ---------------------------------------------------------------------------

def test_queue_full_sheds_typed_rejection():
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=1, max_len=64, prefix_sharing=False,
                 queue_limit=1, shed_policy="reject")
    first = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4)
    shed = Request(rid=1, prompt=[4, 5, 6], max_new_tokens=4)
    assert eng.submit(first) is None
    rej = eng.submit(shed)
    assert rej is not None and rej.kind == "queue_full"
    assert rej.req is shed
    assert shed.status == RequestStatus.REJECTED
    assert shed in eng.rejected
    fs = eng.fault_stats()
    assert fs["rejected"] == 1 and fs["rejected_queue_full"] == 1
    eng.run(max_steps=1000)
    assert first.status == RequestStatus.FINISHED


def test_block_shed_policy_applies_backpressure():
    """shed_policy='block' drives the engine until the queue drains
    instead of shedding — the submission succeeds, just later."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=1, max_len=64, prefix_sharing=False,
                 queue_limit=1, shed_policy="block")
    first = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4)
    second = Request(rid=1, prompt=[4, 5, 6], max_new_tokens=4)
    assert eng.submit(first) is None
    assert eng.submit(second) is None     # blocked until first admitted
    done = eng.run(max_steps=1000)
    assert first in done and second in done
    assert second.status == RequestStatus.FINISHED
    assert eng.fault_counters["rejected"] == 0


# ---------------------------------------------------------------------------
# chaos: seeded fault schedule must always drain clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_chaos_smoke_drains_clean(seed):
    """Under the smoke fault schedule (admission denials, preemption
    storms, persistent slot stalls + watchdog, sharing faults) every
    request still reaches a typed terminal status and no page leaks."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=3, max_len=64, page_size=8,
                 num_pages=12, chaos=ChaosMonkey.smoke(seed))
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=8, ttl=600.0)
            for i, p in enumerate(_prompts(6))]
    for r in reqs:
        assert eng.submit(r) is None
    eng.run(max_steps=100_000)
    assert all(r.status in RequestStatus.TERMINAL for r in reqs)
    assert all(r.status == RequestStatus.FINISHED and
               len(r.out_tokens) == 8 for r in reqs)
    assert eng.leaked_pages() == 0
    assert eng.decode_compiles == 1


@pytest.mark.parametrize("seed", [0, 1])
def test_chaos_slo_mix_preempts_interactive_last(seed):
    """Chaos storm + mixed SLO classes under the slo policy: every
    forced preemption must pick the lowest-class victim available —
    an interactive slot is evicted only when NO lower-priority slot
    was preemptable at that boundary — and the storm still drains
    clean (all terminal, zero leaked pages, one decode executable)."""
    from repro.serve.scheduler import SLO_CLASSES

    cfg, params = _model("internlm2-1.8b")
    # storm-heavy schedule: the smoke preset's 10% per-boundary storm
    # rate can fire zero times in a short drain; this case exists to
    # exercise victim selection, so make storms near-certain and give
    # each request enough chunks (max_new=16 @ sync_interval=4) that
    # chaos sees live slots at many boundaries
    eng = Engine(cfg, params, slots=3, max_len=64, page_size=8,
                 num_pages=12, sync_interval=4, policy="slo",
                 chaos=ChaosMonkey(seed, p_preempt=0.6,
                                   p_deny_admission=0.1,
                                   p_sharing_fault=0.25))
    classes = ["interactive", "batch", "best_effort"]
    reqs = [Request(rid=i, prompt=list(p), max_new_tokens=16, ttl=600.0,
                    slo_class=classes[i % 3])
            for i, p in enumerate(_prompts(9))]
    for r in reqs:
        assert eng.submit(r) is None
    eng.run(max_steps=100_000)
    assert all(r.status in RequestStatus.TERMINAL for r in reqs)
    assert all(r.status == RequestStatus.FINISHED and
               len(r.out_tokens) == 16 for r in reqs)
    assert eng.leaked_pages() == 0
    assert eng.decode_compiles == 1
    # class-ordering invariant, replayed from the preemption log: when
    # an interactive slot was evicted, every other preemptable live
    # slot was interactive too (batch/best_effort always yield first)
    assert eng.preemption_log, "chaos storm produced no preemptions"
    inter_prio = SLO_CLASSES["interactive"].priority
    for ev in eng.preemption_log:
        victim_prio = SLO_CLASSES[ev["slo_class"]].priority
        others = [SLO_CLASSES[c].priority
                  for c in ev["candidate_classes"]]
        assert all(victim_prio >= p for p in others), (
            f"preempted {ev['slo_class']} ({ev['why']}) while a "
            f"lower-priority slot was live: {ev['candidate_classes']}")
        if victim_prio == inter_prio:
            assert all(p == inter_prio for p in others)
