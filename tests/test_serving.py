"""Serving engine: continuous batching, ring buffers, request lifecycle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import (forward_dense_logits, model_defs)
from repro.models import module as m
from repro.serve.engine import Engine, Request


def _engine(arch, slots=3, max_len=64, **kw):
    cfg = reduced(get_config(arch), **kw)
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    return cfg, params, Engine(cfg, params, slots=slots, max_len=max_len)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "rwkv6-7b", "gemma2-2b"])
def test_engine_completes_more_requests_than_slots(arch):
    cfg, params, eng = _engine(arch)
    for i in range(7):
        eng.submit(Request(rid=i, prompt=[1 + i % 5, 2, 3],
                           max_new_tokens=6))
    done = eng.run()
    assert len(done) == 7
    assert all(len(r.out_tokens) == 6 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out_tokens)


def test_engine_greedy_matches_teacher_forcing():
    cfg, params, eng = _engine("internlm2-1.8b", slots=2)
    eng.submit(Request(rid=0, prompt=[5, 6, 7, 8], max_new_tokens=5))
    (r,) = eng.run()
    full = r.prompt + r.out_tokens
    dense = jax.jit(lambda p, b: forward_dense_logits(p, cfg, b))(
        params, {"tokens": jnp.asarray([full], jnp.int32)})
    for i, tok in enumerate(r.out_tokens):
        pos = len(r.prompt) - 1 + i
        assert int(jnp.argmax(dense[0, pos])) == tok, (i, pos)


def test_engine_windowed_arch_long_generation():
    """gemma-style sliding windows: generate beyond the window so the ring
    buffer wraps, then check against teacher forcing."""
    cfg, params, eng = _engine("gemma2-2b", slots=1, max_len=96)
    window = next(b.window for b in cfg.blocks if b.window)
    n_new = window + 8  # force wraparound
    eng.submit(Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new_tokens=n_new))
    (r,) = eng.run(max_steps=n_new + 4)
    full = r.prompt + r.out_tokens
    dense = jax.jit(lambda p, b: forward_dense_logits(p, cfg, b))(
        params, {"tokens": jnp.asarray([full], jnp.int32)})
    for i, tok in enumerate(r.out_tokens):
        pos = len(r.prompt) - 1 + i
        assert int(jnp.argmax(dense[0, pos])) == tok, f"diverged at {i}"


def test_eos_terminates():
    cfg, params, eng = _engine("internlm2-1.8b", slots=1)
    # discover greedy continuation, then set its 3rd token as eos
    eng.submit(Request(rid=0, prompt=[2, 3], max_new_tokens=8))
    (probe,) = eng.run()
    eos = probe.out_tokens[2]
    cfg2, params2, eng2 = _engine("internlm2-1.8b", slots=1)
    eng2.submit(Request(rid=1, prompt=[2, 3], max_new_tokens=8, eos_id=eos))
    (r,) = eng2.run()
    assert r.out_tokens[-1] == eos and len(r.out_tokens) == 3
