"""Serving engine: continuous batching, ring buffers, request lifecycle,
bucketed prefill, retrace/sync regression guards."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import (forward_dense_logits, forward_prefill, model_defs)
from repro.models import module as m
from repro.serve.engine import Engine, Request
from repro.serve.reference import ReferenceEngine


def _model(arch, **kw):
    cfg = reduced(get_config(arch), **kw)
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    return cfg, params


def _engine(arch, slots=3, max_len=64, **kw):
    cfg, params = _model(arch, **kw)
    return cfg, params, Engine(cfg, params, slots=slots, max_len=max_len)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "rwkv6-7b", "gemma2-2b"])
def test_engine_completes_more_requests_than_slots(arch):
    cfg, params, eng = _engine(arch)
    for i in range(7):
        eng.submit(Request(rid=i, prompt=[1 + i % 5, 2, 3],
                           max_new_tokens=6))
    done = eng.run()
    assert len(done) == 7
    assert all(len(r.out_tokens) == 6 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out_tokens)


def test_engine_greedy_matches_teacher_forcing():
    cfg, params, eng = _engine("internlm2-1.8b", slots=2)
    eng.submit(Request(rid=0, prompt=[5, 6, 7, 8], max_new_tokens=5))
    (r,) = eng.run()
    full = r.prompt + r.out_tokens
    dense = jax.jit(lambda p, b: forward_dense_logits(p, cfg, b))(
        params, {"tokens": jnp.asarray([full], jnp.int32)})
    for i, tok in enumerate(r.out_tokens):
        pos = len(r.prompt) - 1 + i
        assert int(jnp.argmax(dense[0, pos])) == tok, (i, pos)


def test_engine_windowed_arch_long_generation():
    """gemma-style sliding windows: generate beyond the window so the ring
    buffer wraps, then check against teacher forcing."""
    cfg, params, eng = _engine("gemma2-2b", slots=1, max_len=96)
    window = next(b.window for b in cfg.blocks if b.window)
    n_new = window + 8  # force wraparound
    eng.submit(Request(rid=0, prompt=[3, 1, 4, 1, 5], max_new_tokens=n_new))
    (r,) = eng.run(max_steps=n_new + 4)
    full = r.prompt + r.out_tokens
    dense = jax.jit(lambda p, b: forward_dense_logits(p, cfg, b))(
        params, {"tokens": jnp.asarray([full], jnp.int32)})
    for i, tok in enumerate(r.out_tokens):
        pos = len(r.prompt) - 1 + i
        assert int(jnp.argmax(dense[0, pos])) == tok, f"diverged at {i}"


def test_windowed_prompt_longer_than_window_wraps_in_splice():
    """A prompt LONGER than the sliding window ring-wraps *within one
    prefill splice*: only the newest occupant of each ring slot may land
    (token-scatter mask), older wrapped tokens go to the trash page.
    Teacher forcing is the oracle."""
    cfg, params, eng = _engine("gemma2-2b", slots=1, max_len=96)
    window = next(b.window for b in cfg.blocks if b.window)
    prompt = [(5 * j) % 200 + 1 for j in range(window + 6)]
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=10))
    (r,) = eng.run(max_steps=200)
    full = r.prompt + r.out_tokens
    dense = jax.jit(lambda p, b: forward_dense_logits(p, cfg, b))(
        params, {"tokens": jnp.asarray([full], jnp.int32)})
    for i, tok in enumerate(r.out_tokens):
        pos = len(r.prompt) - 1 + i
        assert int(jnp.argmax(dense[0, pos])) == tok, f"diverged at {i}"


def test_eos_terminates():
    cfg, params, eng = _engine("internlm2-1.8b", slots=1)
    # discover greedy continuation, then set its 3rd token as eos
    eng.submit(Request(rid=0, prompt=[2, 3], max_new_tokens=8))
    (probe,) = eng.run()
    eos = probe.out_tokens[2]
    cfg2, params2, eng2 = _engine("internlm2-1.8b", slots=1)
    eng2.submit(Request(rid=1, prompt=[2, 3], max_new_tokens=8, eos_id=eos))
    (r,) = eng2.run()
    assert r.out_tokens[-1] == eos and len(r.out_tokens) == 3


# ---------------------------------------------------------------------------
# Fast-path regression suite: ragged batching, bucketed prefill, retraces
# ---------------------------------------------------------------------------

def test_ragged_continuous_batching_staggered():
    """Mixed prompt lengths AND generation budgets: slots free and refill
    at different chunk boundaries; every request still completes with
    exactly its budget (no EOS set)."""
    cfg, params, eng = _engine("internlm2-1.8b", slots=2)
    budgets = [3, 9, 5, 14, 7, 4, 11]
    for i, mn in enumerate(budgets):
        plen = 1 + (3 * i) % 9
        eng.submit(Request(rid=i, prompt=[(2 * i + j) % cfg.vocab_size
                                          for j in range(plen)],
                           max_new_tokens=mn))
    done = eng.run()
    assert len(done) == len(budgets)
    by_rid = {r.rid: r for r in done}
    for i, mn in enumerate(budgets):
        assert len(by_rid[i].out_tokens) == mn, (i, by_rid[i].out_tokens)
        assert all(0 <= t < cfg.vocab_size for t in by_rid[i].out_tokens)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma2-2b", "rwkv6-7b",
                                  "zamba2-7b"])
def test_engine_matches_reference_engine(arch):
    """Token-for-token parity with the pre-fast-path dense engine (greedy)
    across all mixer families: full attention (internlm2), windowed rings
    (gemma2), rwkv6 state, and the zamba2 mamba2+shared-attention hybrid."""
    cfg, params = _model(arch)
    eng = Engine(cfg, params, slots=2, max_len=64)
    ref = ReferenceEngine(cfg, params, slots=2, max_len=64)
    for i in range(5):
        plen = 2 + (4 * i) % 7
        prompt = [(5 * i + j) % cfg.vocab_size for j in range(plen)]
        eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=5 + i % 3))
        ref.submit(Request(rid=i, prompt=prompt, max_new_tokens=5 + i % 3))
    em = {r.rid: r.out_tokens for r in eng.run()}
    rm = {r.rid: r.out_tokens for r in ref.run()}
    assert em == rm


def test_empty_prompt_no_stale_slot():
    """plen == 0 admits cleanly (fresh state, len 0) and generates."""
    cfg, params, eng = _engine("rwkv6-7b", slots=2)
    eng.submit(Request(rid=0, prompt=[], max_new_tokens=4))
    eng.submit(Request(rid=1, prompt=[4, 5, 6], max_new_tokens=4))
    done = eng.run()
    assert len(done) == 2
    assert all(len(r.out_tokens) == 4 for r in done)
    # the non-empty request must be unaffected by its neighbour: compare
    # against a solo run
    cfg2, params2, solo = _engine("rwkv6-7b", slots=2)
    solo.submit(Request(rid=1, prompt=[4, 5, 6], max_new_tokens=4))
    (s,) = solo.run()
    assert s.out_tokens == next(r for r in done if r.rid == 1).out_tokens


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "rwkv6-7b", "gemma2-2b",
                                  "zamba2-7b"])
def test_bucketed_prefill_matches_unpadded(arch):
    """Right-padding a prompt to a bucket with a true ``length`` argument
    must reproduce the unpadded prefill: last-token logits and every
    carried state (KV rows, SSM/wkv states, token shifts) within fp32
    tolerance.  Pad token is deliberately != 0 to prove masking."""
    cfg, params = _model(arch)
    prompt = [3, 1, 4, 1, 5]
    plen, bucket = len(prompt), 16

    @jax.jit
    def fn(toks, length):
        return forward_prefill(params, cfg, {"tokens": toks}, length=length)

    logits_u, cache_u = fn(jnp.asarray([prompt], jnp.int32), None)
    padded = prompt + [9] * (bucket - plen)
    logits_p, cache_p = fn(jnp.asarray([padded], jnp.int32),
                           jnp.asarray([plen], jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_u), np.asarray(logits_p),
                               atol=1e-4, rtol=1e-4)
    assert int(cache_p["len"][0]) == plen
    for lu, lp in zip(cache_u["layers"], cache_p["layers"]):
        if lu is None:
            continue
        for k in lu:
            u, p = np.asarray(lu[k]), np.asarray(lp[k])
            if u.shape != p.shape:        # attention KV: seq axis padded
                p = p[..., :u.shape[-2], :]
            np.testing.assert_allclose(u, p, atol=1e-4, rtol=1e-4,
                                       err_msg=f"{arch} state {k}")


def test_prefill_retrace_bounded_by_buckets():
    """Legacy two-executable mode: mixed prompt lengths compile at most
    len(buckets) prefill executables and exactly one decode executable
    (fused chunked prefill — the default — compiles zero prefill
    executables; tests/test_chunked_prefill.py covers that mode)."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=3, max_len=64, chunked_prefill=False)
    lengths = [1, 2, 3, 5, 7, 8, 9, 11, 13, 4, 6, 12]
    for i, plen in enumerate(lengths):
        eng.submit(Request(rid=i, prompt=[(i + j) % cfg.vocab_size
                                          for j in range(plen)],
                           max_new_tokens=4))
    done = eng.run()
    assert len(done) == len(lengths)
    assert eng.prefill_compiles <= len(eng.buckets), (
        eng.prefill_compiles, eng.buckets)
    assert eng.prefill_compiles == 2       # lengths 1..8 -> 8, 9..13 -> 16
    assert eng.decode_compiles == 1


def test_overlong_prompt_rejected_for_full_attention():
    """Full-attention caches cap at max_len; a longer prompt must fail
    loudly instead of silently mod-wrapping into the KV rows."""
    cfg, params = _model("internlm2-1.8b")   # non-windowed attention
    eng = Engine(cfg, params, slots=1, max_len=16)
    with pytest.raises(ValueError, match="max_len"):
        # raises at submit(), before anything is in flight
        eng.submit(Request(rid=0, prompt=list(range(1, 21)),
                           max_new_tokens=2))
    assert not eng.queue


def test_steady_state_decode_is_sync_free():
    """A fused decode chunk dispatch performs zero device->host transfers.
    The guard raises on any sync on accelerator backends (on CPU d2h is
    zero-copy so it cannot fire); the host_syncs accounting below is the
    backend-independent check."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, chunked_prefill=False)
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=32))
    eng.submit(Request(rid=1, prompt=[4, 5], max_new_tokens=32))
    eng._admit()
    with jax.transfer_guard_device_to_host("disallow"):
        toks = eng.step_chunk()
        toks2 = eng.step_chunk()
    eng._drain(jnp.concatenate([toks, toks2]))   # un-drained history so far
    reqs = [r for r in eng._slot_req if r is not None]
    assert len(reqs) == 2
    assert all(len(r.out_tokens) == 1 + 2 * eng.sync_interval for r in reqs)
    # 2 chunks of decode, exactly 1 batched host sync to read them back
    assert eng.host_syncs == 1 and eng.steps == 2 * eng.sync_interval


def test_warmup_precompiles_and_stays_inert():
    """warmup() compiles every bucket + the decode chunk without
    activating slots, and later serving adds no new compiles for bucketed
    lengths (legacy mode; the fused mode's 2-executable warmup is covered
    in tests/test_chunked_prefill.py)."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, chunked_prefill=False)
    eng.warmup()
    n_prefill, n_decode = eng.prefill_compiles, eng.decode_compiles
    assert n_prefill == len(eng.buckets) and n_decode == 1
    assert not bool(np.asarray(eng.state["active"]).any())
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[1 + i] * (2 + 7 * i),
                           max_new_tokens=4))
    done = eng.run()
    assert len(done) == 3
    assert eng.prefill_compiles == n_prefill
    assert eng.decode_compiles == n_decode


def test_warmup_preserves_sampling_reproducibility():
    """Seeded sampled runs are identical with and without warmup (the
    warmup chunk restores the threaded PRNG key)."""
    cfg, params = _model("internlm2-1.8b")
    outs = []
    for do_warmup in (False, True):
        eng = Engine(cfg, params, slots=2, max_len=64, temperature=1.0,
                     seed=3)
        if do_warmup:
            eng.warmup()
        eng.submit(Request(rid=0, prompt=[7, 8, 9], max_new_tokens=6))
        (r,) = eng.run()
        outs.append(r.out_tokens)
    assert outs[0] == outs[1]


def test_batched_admission_single_executable():
    """All admissions at a chunk boundary coalesce into ONE jitted splice
    dispatch whose executable compiles exactly once, whatever mix of
    buckets and batch sizes the workload produces."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=3, max_len=64)
    eng.warmup()
    assert eng.admit_compiles == 1
    for i in range(9):
        plen = 1 + (5 * i) % 11          # several buckets, ragged refills
        eng.submit(Request(rid=i, prompt=[(i + j) % cfg.vocab_size
                                          for j in range(plen)],
                           max_new_tokens=3 + i % 4))
    done = eng.run()
    assert len(done) == 9
    assert eng.admit_compiles == 1


def test_chunked_prefill_reuses_buckets():
    """A prompt longer than the largest bucket runs as several
    suffix-prefill segments (suffix-capable archs): no new bucket, no
    bucket-growth recompile, token output identical to teacher
    forcing."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64, buckets=[8],
                 chunked_prefill=False)
    prompt = [(7 * j) % 200 + 1 for j in range(30)]
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    (r,) = eng.run()
    assert eng.buckets == [8]                  # reuse, don't grow
    assert eng.prefill_compiles <= 1
    full = prompt + r.out_tokens
    dense = jax.jit(lambda p, b: forward_dense_logits(p, cfg, b))(
        params, {"tokens": jnp.asarray([full], jnp.int32)})
    for i, tok in enumerate(r.out_tokens):
        pos = len(prompt) - 1 + i
        assert int(jnp.argmax(dense[0, pos])) == tok, f"diverged at {i}"


def test_chunked_prefill_matches_single_shot():
    """Chunked prefill (buckets=[8]) and single-shot prefill (default
    buckets) produce identical outputs for the same requests — including
    a second request sharing the engine."""
    cfg, params = _model("internlm2-1.8b")
    prompts = [[(11 * j) % 250 + 1 for j in range(27)], [3, 1, 4]]
    outs = []
    for buckets in ([8], None):
        eng = Engine(cfg, params, slots=2, max_len=64, buckets=buckets,
                     chunked_prefill=False)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
        outs.append({r.rid: r.out_tokens for r in eng.run()})
    assert outs[0] == outs[1]


def test_chunked_prefill_non_capable_arch_grows_bucket():
    """Archs without the suffix machinery (windowed layers) keep the old
    fallback: the bucket list grows and output stays correct."""
    cfg, params = _model("gemma2-2b")
    eng = Engine(cfg, params, slots=1, max_len=96, buckets=[8],
                 chunked_prefill=False)
    prompt = [(5 * j) % 200 + 1 for j in range(22)]
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=5))
    (r,) = eng.run()
    assert eng.buckets != [8]                  # grew to cover the prompt
    full = prompt + r.out_tokens
    dense = jax.jit(lambda p, b: forward_dense_logits(p, cfg, b))(
        params, {"tokens": jnp.asarray([full], jnp.int32)})
    for i, tok in enumerate(r.out_tokens):
        pos = len(prompt) - 1 + i
        assert int(jnp.argmax(dense[0, pos])) == tok, f"diverged at {i}"


def test_per_request_temperature_mixed_batch():
    """Greedy and sampled requests share one compiled decode step."""
    cfg, params = _model("internlm2-1.8b")
    eng = Engine(cfg, params, slots=2, max_len=64)
    eng.submit(Request(rid=0, prompt=[2, 3], max_new_tokens=6))
    eng.submit(Request(rid=1, prompt=[2, 3], max_new_tokens=6,
                       temperature=2.0))
    done = {r.rid: r for r in eng.run()}
    # greedy slot must match a solo greedy run exactly
    cfg2, params2, solo = _engine("internlm2-1.8b", slots=2)
    solo.submit(Request(rid=0, prompt=[2, 3], max_new_tokens=6))
    (s,) = solo.run()
    assert done[0].out_tokens == s.out_tokens
    assert len(done[1].out_tokens) == 6
    assert eng.decode_compiles == 1
