"""AdamW + int8 second moment + schedules."""

import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, do not error

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.optim import adamw
from repro.optim.schedule import linear_warmup_cosine


def _tiny_params():
    k = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(k, (8, 256)),
            "b": jnp.zeros((256,)),
            "e": jax.random.normal(jax.random.fold_in(k, 1), (32, 128))}


def test_adamw_matches_manual_step():
    cfg = adamw.AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, weight_decay=0.0,
                            grad_clip=1e9)
    params = _tiny_params()
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.1, params)
    state = adamw.init(params, cfg)
    new_p, new_s, _ = adamw.update(grads, state, params, cfg)
    # manual first step: m=0.1g*... mhat = g, vhat = g^2 -> step = 1
    for k in params:
        step = np.asarray(params[k]) - 1e-2 * (0.1 / (0.1 + cfg.eps))
        np.testing.assert_allclose(np.asarray(new_p[k]), step, rtol=1e-4)
    assert int(new_s["count"]) == 1


def test_grad_clipping():
    cfg = adamw.AdamWConfig(grad_clip=1.0)
    params = {"w": jnp.zeros((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    state = adamw.init(params, cfg)
    _, _, metrics = adamw.update(grads, state, params, cfg)
    assert float(metrics["grad_norm"]) == jax.numpy.float32(400.0)


@given(st.integers(130, 4096), st.floats(0.01, 100.0))
@settings(max_examples=50, deadline=None)
def test_quantize_roundtrip_error_bound(n, scale):
    x = np.linspace(-scale, scale, n).astype(np.float32).reshape(1, n)
    qt = adamw.quantize(jnp.asarray(x))
    back = adamw.dequantize(qt, n)
    # block-wise int8: error <= blockmax/127
    err = np.abs(np.asarray(back) - x).max()
    assert err <= scale / 127 + 1e-6


def test_quantized_state_training_steps():
    cfg = adamw.AdamWConfig(lr=1e-2, quantize_v=True)
    params = _tiny_params()
    state = adamw.init(params, cfg)
    assert isinstance(state["v"]["w"], adamw.QTensor)
    p = params
    for i in range(3):
        grads = jax.tree.map(
            lambda x: 0.01 * jax.random.normal(jax.random.PRNGKey(i),
                                               x.shape), p)
        p, state, _ = adamw.update(grads, state, p, cfg)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(p))


def test_schedule_shape():
    lr0 = float(linear_warmup_cosine(0, peak_lr=1.0, warmup=10, total=100))
    lr10 = float(linear_warmup_cosine(10, peak_lr=1.0, warmup=10, total=100))
    lr100 = float(linear_warmup_cosine(100, peak_lr=1.0, warmup=10,
                                       total=100, floor=0.1))
    assert lr0 == 0.0 and abs(lr10 - 1.0) < 1e-6 and abs(lr100 - 0.1) < 1e-6
