"""Fused mixed prefill+decode chunks (Sarathi-style chunked prefill).

The fused mode deletes every prefill executable: each chunk micro-step
runs all decode rows plus up to ``prefill_budget`` prompt tokens per
admitting slot through ONE executable, with prompt context reads
streaming pool-direct through the paged attention path.  Token parity
against the legacy two-executable engine is the oracle throughout —
including un-aligned budgets, windowed ring wrap, speculation, and
preempt-then-resume mid-prefill."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model_defs
from repro.models import module as m
from repro.serve.engine import Engine, Request
from repro.serve.spec import SpecConfig


def _model(arch="internlm2-1.8b", **kw):
    cfg = reduced(get_config(arch), **kw)
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    return cfg, params


def _serve(cfg, params, prompts, max_new, slots=3, max_len=96, **kw):
    eng = Engine(cfg, params, slots=slots, max_len=max_len,
                 sync_interval=4, seed=0, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=max_new))
    done = eng.run(max_steps=50_000)
    assert len(done) == len(prompts), [r.status for r in done]
    return {r.rid: list(r.out_tokens) for r in done}, eng


PROMPTS = [[(7 * j + i) % 200 + 1 for j in range(3 + 9 * i)]
           for i in range(5)]            # lengths 3, 12, 21, 30, 39


# ---------------------------------------------------------------------------
# Mixed-chunk parity vs the two-executable engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [3, 8, 13])
def test_mixed_chunk_parity_unaligned_budgets(budget):
    """Token-identical to the legacy engine for budgets smaller than a
    page (3 < P=8), page-aligned (8), and straddling a page boundary
    (13): the per-slot right-aligned row layout and per-row position
    masks must hold at any prompt-slice/page phase."""
    cfg, params = _model()
    legacy, _ = _serve(cfg, params, PROMPTS, 10, chunked_prefill=False)
    fused, eng = _serve(cfg, params, PROMPTS, 10, chunked_prefill=True,
                        prefill_budget=budget)
    assert fused == legacy
    assert eng.prefill_compiles == 0
    assert eng.suffix_prefill_compiles == 0
    assert eng.decode_compiles == 1
    assert eng.admit_compiles == 1


def test_mixed_chunk_parity_gemma2_ring_wrap():
    """Windowed arch: generation runs ``window + 8`` tokens so the
    sliding-window ring wraps mid-serve; the fused chunk's per-slot
    ``cache_len`` keeps every ring-validity mask exact while neighbours
    sit mid-prefill."""
    cfg, params = _model("gemma2-2b")
    w = min(b.window for b in cfg.blocks if b.window is not None)
    prompts = [[3, 1, 4, 1, 5], [2, 7, 1, 8], [3, 1, 4, 1, 5, 9]]
    legacy, _ = _serve(cfg, params, prompts, w + 8, chunked_prefill=False)
    fused, eng = _serve(cfg, params, prompts, w + 8, chunked_prefill=True,
                        prefill_budget=4)
    assert fused == legacy
    assert eng.decode_compiles == 1 and eng.prefill_compiles == 0


def test_fused_pool_direct_prefill_attention_parity_and_hlo():
    """paged_kernel=True: the fused executable reads prompt context
    pool-direct.  Token parity with the gather build AND a textual HLO
    check that the gathered ring intermediates are absent from the one
    fused executable (prefill context reads included — there is no other
    executable they could hide in)."""
    cfg, params = _model()
    gather, _ = _serve(cfg, params, PROMPTS, 8, chunked_prefill=True,
                       prefill_budget=13, paged_kernel=False)
    pooled, eng = _serve(cfg, params, PROMPTS, 8, chunked_prefill=True,
                         prefill_budget=13, paged_kernel=True)
    assert pooled == gather
    ex = eng.executor
    with ex._ctx():
        hlo = ex._chunk_fn.lower(eng.params, eng.draft_params, eng.cache,
                                 eng.state).compile().as_text()
    spec = eng.spec
    kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    for g in spec.groups:
        ring = g.ring_blocks * spec.page_size
        assert f"[{spec.slots},{g.ring_blocks},{spec.page_size},{kv},{dh}]" \
            not in hlo
        assert f"[{spec.slots},{kv},{ring},{dh}]" not in hlo


# ---------------------------------------------------------------------------
# Compile-count telemetry: ONE fused executable (+ admission splice)
# ---------------------------------------------------------------------------

def test_fused_warmup_two_executables_and_inert():
    """Fused warmup compiles exactly the fused chunk + the admission
    bookkeeping splice — zero prefill executables exist — stays
    semantically inert, and steady-state serving adds no compiles for
    ANY prompt length (no buckets to miss)."""
    cfg, params = _model()
    eng = Engine(cfg, params, slots=2, max_len=96, sync_interval=4)
    assert eng.chunked_prefill     # "auto" resolves on for this arch
    eng.warmup()
    assert (eng.prefill_compiles, eng.suffix_prefill_compiles,
            eng.decode_compiles, eng.admit_compiles) == (0, 0, 1, 1)
    assert not bool(np.asarray(eng.state["active"]).any())
    for i, plen in enumerate([1, 5, 17, 40, 63]):   # no bucket ladder
        eng.submit(Request(rid=i, prompt=[(i + j) % 150 + 1
                                          for j in range(plen)],
                           max_new_tokens=4))
    done = eng.run(max_steps=50_000)
    assert len(done) == 5
    assert (eng.prefill_compiles, eng.suffix_prefill_compiles,
            eng.decode_compiles, eng.admit_compiles) == (0, 0, 1, 1)


def test_fused_steady_state_sync_free():
    """The fused chunk performs zero device->host transfers; the drain
    reads tokens AND the prefill cursor in ONE batched transfer."""
    cfg, params = _model()
    eng = Engine(cfg, params, slots=2, max_len=96, prefill_budget=8)
    assert eng.chunked_prefill
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=32))
    eng.submit(Request(rid=1, prompt=[(3 * j) % 99 + 1 for j in range(20)],
                       max_new_tokens=32))
    eng._admit()
    with jax.transfer_guard_device_to_host("disallow"):
        toks = eng.step_chunk()
        toks2 = eng.step_chunk()
    eng._drain(jnp.concatenate([toks, toks2]))
    assert eng.host_syncs == 1 and eng.steps == 2 * eng.sync_interval
    # slot 0 (3-token prompt) completed prefill on micro-step 1, then
    # decoded every remaining micro-step
    r0 = eng._slot_req[0]
    assert len(r0.out_tokens) == 2 * eng.sync_interval


# ---------------------------------------------------------------------------
# Speculation x chunked prefill (satellite: drafting gated on prefill end)
# ---------------------------------------------------------------------------

def test_spec_k4_drafting_disabled_until_prefill_complete():
    """K=4 regression: a slot mid-prefill must neither emit tokens nor
    advance the speculative counters — drafting starts only once its
    prefill cursor reaches the prompt end — and the final output is
    token-identical to the legacy speculative engine."""
    cfg, params = _model()
    prompt = [(5 * j) % 180 + 1 for j in range(20)]
    eng = Engine(cfg, params, slots=1, max_len=96, sync_interval=1,
                 seed=0, spec=SpecConfig(k=4), prefill_budget=4)
    assert eng.chunked_prefill
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    saw_mid_prefill = False
    # chunk_rows = max(budget=4, K+1=5) = 5 prompt tokens per micro-step
    for _ in range(3):                      # 3 chunks x 5 tokens < 20
        eng.step()
        req = eng._slot_req[0]
        assert req is not None and not req.out_tokens
        assert 0 < eng._slot_seen_len[0] < len(prompt)
        assert eng.spec_stats()["spec_steps"] == 0
        assert eng.spec_stats()["drafted_tokens"] == 0
        saw_mid_prefill = True
    (done,) = eng.run(max_steps=50_000)
    assert saw_mid_prefill and len(done.out_tokens) == 8
    assert eng.spec_stats()["spec_steps"] > 0    # drafting did engage
    cfg2, params2 = _model()
    legacy, _ = _serve(cfg2, params2, [prompt], 8, slots=1,
                       chunked_prefill=False, spec=SpecConfig(k=4))
    assert list(done.out_tokens) == legacy[0]


def test_spec_fused_statistics_match_legacy():
    """Beyond token parity: acceptance/emission counters of the fused
    engine are IDENTICAL to the legacy engine's (the fused step runs the
    same draft/verify/accept round for decoding slots)."""
    cfg, params = _model()
    prompts = [[1, 2, 3, 4, 5] * 3, [9, 8, 7, 6] * 4]
    legacy, el = _serve(cfg, params, prompts, 16, chunked_prefill=False,
                        spec="ngram")
    fused, ef = _serve(cfg, params, prompts, 16, chunked_prefill=True,
                       prefill_budget=8, spec="ngram")
    assert fused == legacy
    ls, fs = el.spec_stats(), ef.spec_stats()
    for key in ("spec_steps", "drafted_tokens", "accepted_tokens",
                "emitted_tokens"):
        assert ls[key] == fs[key], (key, ls[key], fs[key])


# ---------------------------------------------------------------------------
# Preemption / resume mid-prefill
# ---------------------------------------------------------------------------

def test_preempt_then_resume_mid_prefill_token_parity():
    """Preempting a slot whose prefill is underway preserves exactly the
    host-confirmed written prefix in the radix index; the resume recovers
    it as a prefix hit and the final output matches an undisturbed
    run."""
    cfg, params = _model()
    prompt = list(range(1, 41))
    eng = Engine(cfg, params, slots=1, max_len=96, sync_interval=1,
                 seed=0, prefill_budget=4)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    eng.step()
    eng.step()
    assert 0 < eng._slot_seen_len[0] < len(prompt)    # mid-prefill
    eng._preempt_slot(0, "pressure")
    (done,) = eng.run(max_steps=50_000)
    undisturbed, _ = _serve(cfg, params, [prompt], 8, slots=1,
                            chunked_prefill=False)
    assert list(done.out_tokens) == undisturbed[0]
    fs = eng.fault_stats()
    assert fs["resumes"] == 1
    assert fs["recovered_prefill_fraction"] > 0.0
    assert eng.leaked_pages() == 0


def test_fused_prefix_sharing_deferred_indexing():
    """A prompt enters the radix index only when its prefill COMPLETES:
    a same-boundary duplicate cannot share (its pages are not written
    yet), a later wave shares fully."""
    cfg, params = _model()
    head = [(3 * j) % 200 + 1 for j in range(24)]
    eng = Engine(cfg, params, slots=2, max_len=96, prefill_budget=8,
                 sync_interval=4, seed=0)
    for i in range(2):       # same boundary: no sharing possible
        eng.submit(Request(rid=i, prompt=head + [30 + i],
                           max_new_tokens=6))
    eng.run(max_steps=50_000)
    assert eng.prefix_stats()["prefix_hits"] == 0
    eng.submit(Request(rid=2, prompt=head + [77], max_new_tokens=6))
    (r2,) = [r for r in eng.run(max_steps=50_000) if r.rid == 2]
    ps = eng.prefix_stats()
    assert ps["prefix_hits"] == 1
    assert ps["prefill_tokens_skipped"] == 24
    legacy, _ = _serve(cfg, params, [head + [77]], 6, slots=2,
                       chunked_prefill=False)
    assert list(r2.out_tokens) == legacy[0]


# ---------------------------------------------------------------------------
# Contracts
# ---------------------------------------------------------------------------

def test_fused_submit_contracts():
    cfg, params = _model()
    eng = Engine(cfg, params, slots=1, max_len=32)
    assert eng.chunked_prefill
    with pytest.raises(ValueError, match="non-empty"):
        eng.submit(Request(rid=0, prompt=[], max_new_tokens=4))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(rid=1, prompt=list(range(1, 31)),
                           max_new_tokens=8))
    from dataclasses import replace
    cfgw = reduced(get_config("gemma2-2b"))
    cfgw = replace(cfgw, blocks=tuple(       # every layer windowed →
        replace(b, window=b.window or 8)     # legacy would allow long
        for b in cfgw.blocks))               # generations past max_len
    paramsw = m.init_params(model_defs(cfgw), jax.random.PRNGKey(0),
                            jnp.float32)
    engw = Engine(cfgw, paramsw, slots=1, max_len=32)
    assert engw.chunked_prefill and cfgw.supports_long_context
    with pytest.raises(ValueError, match="chunked_prefill"):
        # the fused prompt staging buffer caps the whole span
        engw.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=40))
    with pytest.raises(ValueError, match="prefill_budget"):
        Engine(cfg, params, slots=1, max_len=32, prefill_budget=0)
    # non-capable archs fall back to legacy under "auto" and refuse an
    # explicit opt-in
    cfgr, paramsr = _model("rwkv6-7b")
    engr = Engine(cfgr, paramsr, slots=1, max_len=32)
    assert not engr.chunked_prefill
    with pytest.raises(ValueError, match="chunked_prefill"):
        Engine(cfgr, paramsr, slots=1, max_len=32, chunked_prefill=True)
