"""On-device sampling: temperature/top-k semantics, PRNG threading, and
the device-side slot bookkeeping used by the fused decode step."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import sampling


def _logits(b=4, v=32, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, v)) * 3.0


def test_temperature_zero_is_argmax():
    lg = _logits()
    t0 = jnp.zeros((4,), jnp.float32)
    toks = sampling.sample(lg, jax.random.PRNGKey(1), temperature=t0)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.argmax(np.asarray(lg), axis=-1))


def test_top_k_restricts_support():
    lg = _logits(b=2, v=64)
    topk = 4
    allowed = np.argsort(np.asarray(lg), axis=-1)[:, -topk:]
    temp = jnp.full((2,), 5.0)   # hot: would leave top-4 without the filter
    for s in range(40):
        toks = np.asarray(sampling.sample(lg, jax.random.PRNGKey(s),
                                          temperature=temp, top_k=topk))
        for b in range(2):
            assert toks[b] in allowed[b], (b, toks[b])


def test_sampling_is_keyed_and_reproducible():
    lg = _logits(b=3, v=128)
    temp = jnp.ones((3,), jnp.float32)
    a = sampling.sample(lg, jax.random.PRNGKey(7), temperature=temp)
    b = sampling.sample(lg, jax.random.PRNGKey(7), temperature=temp)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    draws = {tuple(np.asarray(sampling.sample(
        lg, jax.random.PRNGKey(s), temperature=temp))) for s in range(20)}
    assert len(draws) > 1   # different keys actually vary


def test_mixed_greedy_and_sampled_rows():
    lg = _logits(b=4, v=32)
    temp = jnp.asarray([0.0, 1.0, 0.0, 2.0], jnp.float32)
    greedy = np.argmax(np.asarray(lg), axis=-1)
    for s in range(10):
        toks = np.asarray(sampling.sample(lg, jax.random.PRNGKey(s),
                                          temperature=temp))
        assert toks[0] == greedy[0] and toks[2] == greedy[2]


def test_decode_update_bookkeeping():
    state = sampling.make_slot_state(3)
    state["active"] = jnp.asarray([True, True, False])
    state["out_len"] = jnp.asarray([1, 1, 5], jnp.int32)
    state["max_new"] = jnp.asarray([2, 8, 5], jnp.int32)
    state["eos"] = jnp.asarray([-1, 42, -1], jnp.int32)
    state["tokens"] = jnp.asarray([10, 11, 12], jnp.int32)
    nxt = jnp.asarray([7, 42, 9], jnp.int32)
    new, emitted = sampling.decode_update(state, nxt,
                                          jax.random.PRNGKey(0))
    # slot 0 hits max_new, slot 1 hits EOS, slot 2 was idle
    np.testing.assert_array_equal(np.asarray(new["active"]),
                                  [False, False, False])
    np.testing.assert_array_equal(np.asarray(new["out_len"]), [2, 2, 5])
    np.testing.assert_array_equal(np.asarray(new["tokens"]), [7, 42, 12])
    np.testing.assert_array_equal(np.asarray(emitted), [7, 42, -1])


def test_decode_update_keeps_inactive_frozen():
    state = sampling.make_slot_state(2)
    state["active"] = jnp.asarray([False, True])
    state["out_len"] = jnp.asarray([3, 1], jnp.int32)
    state["max_new"] = jnp.asarray([3, 10], jnp.int32)
    state["tokens"] = jnp.asarray([5, 6], jnp.int32)
    nxt = jnp.asarray([99, 8], jnp.int32)
    new, emitted = sampling.decode_update(state, nxt,
                                          jax.random.PRNGKey(0))
    assert int(new["out_len"][0]) == 3 and int(new["tokens"][0]) == 5
    assert int(emitted[0]) == -1
    assert int(new["out_len"][1]) == 2 and int(new["tokens"][1]) == 8
