"""Paged decode-attention: kernel / XLA-lowering / oracle parity across
page sizes, ring widths, GQA ratios, un-aligned offsets, and trash-page
masking — plus engine-level token parity and the CacheSpec page-size
validation.

The Pallas-kernel tests self-gate on the runtime capability probe
(``kernels.paged_attention.supported``, interpret mode on CPU); the XLA
pool-wide lowering and engine tests need no Pallas toolchain and always
run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attention import (paged_attention_ref,
                                           paged_decode_attention,
                                           pool_attention_xla, supported)

KEY = jax.random.PRNGKey(11)

needs_pallas = pytest.mark.skipif(
    not supported(),
    reason="no Pallas-capable backend/toolchain (interpret-mode probe "
           "failed); kernel correctness is covered on TPU CI")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _case(b, h, hkv, dh, page_size, nb, num_pages, seed=0, trash_tail=0):
    """Random pool + *valid* tables: distinct non-trash pages per row
    (the scheduler invariant the pool-wide lowering relies on), with an
    optional all-trash tail on row 0."""
    k = jax.random.fold_in(KEY, seed)
    q = jax.random.normal(k, (b, h, dh)) * 0.5
    pool_k = jax.random.normal(jax.random.fold_in(k, 1),
                               (num_pages + 1, page_size, hkv, dh)) * 0.5
    pool_v = jax.random.normal(jax.random.fold_in(k, 2),
                               (num_pages + 1, page_size, hkv, dh))
    rs = np.random.RandomState(seed)
    pt = np.stack([rs.permutation(num_pages)[:nb] for _ in range(b)])
    if trash_tail:
        pt[0, -trash_tail:] = num_pages
    return q, pool_k, pool_v, jnp.asarray(pt, jnp.int32)


@needs_pallas
@pytest.mark.parametrize("page_size,nb", [(4, 4), (8, 8), (16, 2)])
@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("mode", ["full", "window", "softcap"])
def test_kernel_vs_ref(page_size, nb, h, hkv, mode):
    kw = {"full": {},
          "window": {"window": 3 * page_size},
          "softcap": {"softcap": 20.0}}[mode]
    ring = page_size * nb
    q, pk, pv, pt = _case(3, h, hkv, 16, page_size, nb, 4 * nb,
                          seed=nb + h)
    # un-aligned offsets on purpose: mid-page, page-boundary, wrapped
    cl = jnp.asarray([ring - 3, 1 + page_size, 2 * ring + 5], jnp.int32)
    got = paged_decode_attention(q, pk, pv, pt, cl,
                                 interpret=_interpret(), **kw)
    want = paged_attention_ref(q, pk, pv, pt, cl, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("page_size,nb", [(4, 4), (8, 8), (16, 2)])
@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("mode", ["full", "window", "softcap"])
def test_pool_lowering_vs_ref(page_size, nb, h, hkv, mode):
    """The gather-free XLA lowering must match the gather oracle on the
    same sweep the kernel runs (it is the non-TPU serving path)."""
    kw = {"full": {},
          "window": {"window": 3 * page_size},
          "softcap": {"softcap": 20.0}}[mode]
    ring = page_size * nb
    q, pk, pv, pt = _case(3, h, hkv, 16, page_size, nb, 4 * nb,
                          seed=50 + nb + h)
    cl = jnp.asarray([ring - 3, 1 + page_size, 2 * ring + 5], jnp.int32)
    got = pool_attention_xla(q, pk, pv, pt, cl, **kw)
    want = paged_attention_ref(q, pk, pv, pt, cl, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@needs_pallas
def test_unaligned_suffix_offsets():
    """Every cache_len in a full ring sweep, page-aligned or not."""
    page_size, nb = 4, 4
    q, pk, pv, pt = _case(1, 4, 2, 16, page_size, nb, 3 * nb, seed=7)
    for cl_val in range(1, 2 * page_size * nb + 1):
        cl = jnp.asarray([cl_val], jnp.int32)
        got = paged_decode_attention(q, pk, pv, pt, cl,
                                     interpret=_interpret())
        want = paged_attention_ref(q, pk, pv, pt, cl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4, err_msg=str(cl_val))


@needs_pallas
def test_trash_page_masked():
    """Table entries pointing at the trash page contribute -inf scores:
    corrupting the trash page must not change the output, and an
    all-trash row (unadmitted slot) returns exactly 0."""
    page_size, nb = 8, 4
    q, pk, pv, pt = _case(3, 4, 2, 16, page_size, nb, 3 * nb, seed=3,
                          trash_tail=2)
    trash = pk.shape[0] - 1
    pt = pt.at[1].set(trash)                      # slot 1: never admitted
    cl = jnp.asarray([2 * page_size + 1, 5, page_size * nb], jnp.int32)
    out1 = paged_decode_attention(q, pk, pv, pt, cl,
                                  interpret=_interpret())
    poisoned_k = pk.at[trash].set(1e4)
    poisoned_v = pv.at[trash].set(-1e4)
    out2 = paged_decode_attention(q, poisoned_k, poisoned_v, pt, cl,
                                  interpret=_interpret())
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(out1[1]), 0.0)
    out3 = pool_attention_xla(q, poisoned_k, poisoned_v, pt, cl)
    np.testing.assert_allclose(np.asarray(out3), np.asarray(out1),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("s", [2, 5])
@pytest.mark.parametrize("mode", ["full", "window", "softcap"])
def test_multiquery_pool_lowering_vs_ref(s, mode):
    """Speculative verify reads: S query rows per slot, per-row causal
    ring masks, against the multi-query gather oracle."""
    page_size, nb, h, hkv = 4, 4, 4, 2
    kw = {"full": {},
          "window": {"window": 3 * page_size},
          "softcap": {"softcap": 20.0}}[mode]
    ring = page_size * nb
    q, pk, pv, pt = _case(3, h, hkv, 16, page_size, nb, 4 * nb,
                          seed=90 + s)
    q = jnp.repeat(q[:, None], s, axis=1) * (1 + jnp.arange(s)[
        None, :, None, None] * 0.1)
    cl = jnp.asarray([ring - 3, s + 1, 2 * ring + 5], jnp.int32)
    got = pool_attention_xla(q, pk, pv, pt, cl, **kw)
    want = paged_attention_ref(q, pk, pv, pt, cl, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@needs_pallas
@pytest.mark.parametrize("s", [2, 5])
@pytest.mark.parametrize("mode", ["full", "window", "softcap"])
def test_multiquery_kernel_vs_ref(s, mode):
    kw = {"full": {},
          "window": {"window": 3 * 4},
          "softcap": {"softcap": 20.0}}[mode]
    page_size, nb, h, hkv = 4, 4, 4, 2
    ring = page_size * nb
    q, pk, pv, pt = _case(3, h, hkv, 16, page_size, nb, 4 * nb,
                          seed=70 + s)
    q = jnp.repeat(q[:, None], s, axis=1) * (1 + jnp.arange(s)[
        None, :, None, None] * 0.1)
    cl = jnp.asarray([ring - 3, s + 1, 2 * ring + 5], jnp.int32)
    got = paged_decode_attention(q, pk, pv, pt, cl,
                                 interpret=_interpret(), **kw)
    want = paged_attention_ref(q, pk, pv, pt, cl, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@needs_pallas
def test_multiquery_kernel_page_stale_for_newest_row_only():
    """Regression: a page whose tokens are all outside the NEWEST query
    row's window can still be in-window for earlier draft rows — the
    kernel's page-skip predicate must use the per-row mask, not the
    newest row's.  window=12, P=4, S=5, cache_len=40: the page holding
    absolute tokens 24..27 is stale for row 4 (position 39 needs u>27)
    but valid for rows at positions 35..38."""
    page_size, nb, h, hkv, s, window = 4, 4, 4, 2, 5, 12
    q, pk, pv, pt = _case(2, h, hkv, 16, page_size, nb, 4 * nb, seed=21)
    q = jnp.repeat(q[:, None], s, axis=1) * (1 + jnp.arange(s)[
        None, :, None, None] * 0.1)
    cl = jnp.asarray([40, 2 * 16 + 8], jnp.int32)   # page-aligned stale
    got = paged_decode_attention(q, pk, pv, pt, cl, window=window,
                                 interpret=_interpret())
    want = paged_attention_ref(q, pk, pv, pt, cl, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_multiquery_first_row_matches_single_query():
    """The newest row of an S-row verify equals the single-query result
    at the same cache_len, and a 3-D q keeps the legacy behavior."""
    page_size, nb, h, hkv = 4, 4, 4, 2
    q, pk, pv, pt = _case(2, h, hkv, 16, page_size, nb, 3 * nb, seed=31)
    cl = jnp.asarray([9, 14], jnp.int32)
    single = paged_attention_ref(q, pk, pv, pt, cl)
    multi = paged_attention_ref(
        jnp.stack([jax.random.normal(KEY, q.shape), q], axis=1),
        pk, pv, pt, cl)
    np.testing.assert_allclose(np.asarray(multi[:, -1]),
                               np.asarray(single), rtol=1e-5, atol=1e-5)


@needs_pallas
def test_model_paged_decode_step_kernel_vs_gather():
    """models/attention.paged_decode_step with paged_kernel on/off must
    produce the same attention output and pool writes."""
    from repro.models import attention

    b, h, hkv, dh, page_size, nb = 2, 4, 2, 16, 4, 4
    q = jax.random.normal(KEY, (b, 1, h, dh)) * 0.5
    kk = jax.random.normal(jax.random.fold_in(KEY, 1), (b, 1, hkv, dh)) * 0.5
    vv = jax.random.normal(jax.random.fold_in(KEY, 2), (b, 1, hkv, dh))
    _, pk, pv, pt = _case(b, h, hkv, dh, page_size, nb, 3 * nb, seed=9)
    cache = {"pk": pk, "pv": pv, "pt": pt}
    cl = jnp.asarray([6, 13], jnp.int32)
    outs = {}
    for paged_kernel in (False, True):
        out, new = attention.paged_decode_step(
            q, kk, vv, dict(cache), cl, window=None, softcap=None,
            paged_kernel=paged_kernel)
        outs[paged_kernel] = (out, new["pk"], new["pv"])
    for a, b_ in zip(outs[False], outs[True]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-4, atol=2e-4)


def _run_engine(eng, n_req=6, max_new=20):
    from repro.serve.engine import Request

    for i in range(n_req):
        eng.submit(Request(rid=i, prompt=[1 + i, 2, 3 + i % 3, 4],
                           max_new_tokens=max_new))
    done = eng.run(max_steps=100_000)
    assert len(done) == n_req
    return {r.rid: r.out_tokens for r in done}


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma2-2b"])
def test_engine_token_parity_paged_kernel(arch):
    """Pool-direct decode must be invisible in the tokens: paged-kernel
    engine == gather engine == dense ReferenceEngine, for a pure
    full-attention arch and a sliding-window arch whose 16-token ring
    wraps during the 20-token generation."""
    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.engine import Engine
    from repro.serve.reference import ReferenceEngine

    cfg = reduced(get_config(arch))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    out_paged = _run_engine(Engine(cfg, params, slots=3, max_len=64,
                                   sync_interval=8, prefix_sharing=False,
                                   paged_kernel=True))
    out_gather = _run_engine(Engine(cfg, params, slots=3, max_len=64,
                                    sync_interval=8, prefix_sharing=False,
                                    paged_kernel=False))
    out_ref = _run_engine(ReferenceEngine(cfg, params, slots=3, max_len=64))
    assert out_paged == out_gather == out_ref


def test_engine_paged_kernel_oversubscribed_pool():
    """The configuration the pool-direct path exists for: table width 32
    blocks (max_len=256) but only 24 physical pages — outputs must still
    match the gather path."""
    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.engine import Engine

    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    kw = dict(slots=3, max_len=256, page_size=8, num_pages=24,
              sync_interval=8, prefix_sharing=False)
    out_paged = _run_engine(Engine(cfg, params, paged_kernel=True, **kw))
    out_gather = _run_engine(Engine(cfg, params, paged_kernel=False, **kw))
    assert out_paged == out_gather


def test_page_size_rejected_at_spec_construction():
    """Bugfix: page sizes the kernel block spec can't tile fail at
    CacheSpec construction with an actionable error, not inside Pallas
    at trace time."""
    from repro.configs import get_config, reduced
    from repro.serve.cache import CacheSpec

    cfg = reduced(get_config("internlm2-1.8b"))
    with pytest.raises(ValueError, match="power of two"):
        CacheSpec.from_config(cfg, slots=2, max_len=64, page_size=6)
    with pytest.raises(ValueError, match="ring width"):
        CacheSpec.from_config(cfg, slots=2, max_len=64, page_size=128)
    wcfg = reduced(get_config("gemma2-2b"))     # window 16 < page 32
    with pytest.raises(ValueError, match="ring width"):
        CacheSpec.from_config(wcfg, slots=2, max_len=64, page_size=32)
    # the boundary cases stay constructible
    CacheSpec.from_config(cfg, slots=2, max_len=64, page_size=64)
    CacheSpec.from_config(wcfg, slots=2, max_len=64, page_size=16)


# ---------------------------------------------------------------- quantized
def _qdtypes():
    """Pool storage dtypes the toolchain can serve quantized."""
    from repro.serve.cache import KV_DTYPES, kv_dtype_supported

    return [d for d in KV_DTYPES if d != "fp32" and kv_dtype_supported(d)]


def _quantize_case(case, kv_dtype):
    """Quantize a ``_case`` pool pair into (q-pools, scale pools)."""
    from repro.models.attention import quantize_pages
    from repro.serve.cache import kv_pool_dtype

    q, pk, pv, pt = case
    dt = kv_pool_dtype(kv_dtype)
    qk, sk = quantize_pages(pk, dt)
    qv, sv = quantize_pages(pv, dt)
    return q, qk, qv, sk, sv, pt


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
@pytest.mark.parametrize("mode", ["full", "window", "softcap"])
def test_quantized_kernel_vs_ref(kv_dtype, mode):
    """In-kernel dequant (scales folded into scores / PV inside the
    Pallas kernel) must match the gather-then-dequant oracle bit-for-bit
    up to fp accumulation order — same quantized pools on both sides."""
    if kv_dtype not in _qdtypes():
        pytest.skip(f"{kv_dtype} pools unsupported on this toolchain")
    if not supported(kv_dtype):
        pytest.skip("no Pallas-capable backend/toolchain for "
                    f"{kv_dtype} pools")
    kw = {"full": {}, "window": {"window": 12},
          "softcap": {"softcap": 20.0}}[mode]
    page_size, nb = 4, 4
    ring = page_size * nb
    q, qk, qv, sk, sv, pt = _quantize_case(
        _case(3, 4, 2, 16, page_size, nb, 4 * nb, seed=5), kv_dtype)
    cl = jnp.asarray([ring - 3, 1 + page_size, 2 * ring + 5], jnp.int32)
    got = paged_decode_attention(q, qk, qv, pt, cl, k_scale=sk, v_scale=sv,
                                 interpret=_interpret(), **kw)
    want = paged_attention_ref(q, qk, qv, pt, cl, k_scale=sk, v_scale=sv,
                               **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8_e4m3"])
@pytest.mark.parametrize("s", [1, 3])
def test_quantized_pool_lowering_vs_ref(kv_dtype, s):
    """The XLA pool-wide lowering's folded dequant (scales into scores /
    softmax weights, no fp32 pool ever stored) vs the oracle — single
    and multi-query (speculative verify) row counts."""
    if kv_dtype not in _qdtypes():
        pytest.skip(f"{kv_dtype} pools unsupported on this toolchain")
    page_size, nb = 4, 4
    ring = page_size * nb
    q, qk, qv, sk, sv, pt = _quantize_case(
        _case(3, 4, 2, 16, page_size, nb, 4 * nb, seed=60 + s), kv_dtype)
    if s > 1:
        q = jnp.repeat(q[:, None], s, axis=1) * (1 + jnp.arange(s)[
            None, :, None, None] * 0.1)
    cl = jnp.asarray([ring - 3, s + 1, 2 * ring + 5], jnp.int32)
    got = pool_attention_xla(q, qk, qv, pt, cl, k_scale=sk, v_scale=sv)
    want = paged_attention_ref(q, qk, qv, pt, cl, k_scale=sk, v_scale=sv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_quantized_reconstruction_error_bounded_through_attention():
    """Quantized-pool attention vs the fp32 pool it was quantized from:
    the output error must stay within the per-page quantization step
    propagated through softmax (weights sum to 1, so the V error bound
    is max over pages of amax/qmax; scores perturb weights smoothly)."""
    page_size, nb = 4, 4
    case = _case(2, 4, 2, 16, page_size, nb, 3 * nb, seed=13)
    q, pk, pv, pt = case
    cl = jnp.asarray([11, 2 * page_size * nb + 3], jnp.int32)
    want = paged_attention_ref(q, pk, pv, pt, cl)
    for kv_dtype in _qdtypes():
        qq, qk, qv, sk, sv, _ = _quantize_case(case, kv_dtype)
        got = paged_attention_ref(qq, qk, qv, pt, cl,
                                  k_scale=sk, v_scale=sv)
        err = float(jnp.max(jnp.abs(got - want)))
        # amax/qmax per page; int8 grid is ~1/127 of amax, fp8 coarser
        step = {"int8": 1.0 / 127.0, "fp8_e4m3": 1.0 / 16.0}[kv_dtype]
        bound = float(jnp.max(jnp.abs(pv))) * step * 4 \
            + float(jnp.max(jnp.abs(pk))) * step * 4
        assert err < bound, (kv_dtype, err, bound)


def test_quantized_trash_page_invariance():
    """Corrupting the trash page AND its scale rows must not change
    quantized-pool attention output; an all-trash row returns exactly
    0 in every lowering."""
    if not _qdtypes():
        pytest.skip("no quantized pool dtypes on this toolchain")
    page_size, nb = 8, 4
    q, qk, qv, sk, sv, pt = _quantize_case(
        _case(3, 4, 2, 16, page_size, nb, 3 * nb, seed=3, trash_tail=2),
        "int8")
    trash = qk.shape[0] - 1
    pt = pt.at[1].set(trash)                      # slot 1: never admitted
    cl = jnp.asarray([2 * page_size + 1, 5, page_size * nb], jnp.int32)
    out1 = pool_attention_xla(q, qk, qv, pt, cl, k_scale=sk, v_scale=sv)
    bad_k = qk.at[trash].set(127)
    bad_v = qv.at[trash].set(-127)
    bad_sk = sk.at[trash].set(1e4)
    bad_sv = sv.at[trash].set(1e4)
    out2 = pool_attention_xla(q, bad_k, bad_v, pt, cl,
                              k_scale=bad_sk, v_scale=bad_sv)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(out2[1]), 0.0)
    out3 = paged_attention_ref(q, bad_k, bad_v, pt, cl,
                               k_scale=bad_sk, v_scale=bad_sv)
    np.testing.assert_allclose(np.asarray(out3), np.asarray(out1),
                               rtol=2e-4, atol=2e-4)
    if supported("int8"):
        out4 = paged_decode_attention(q, bad_k, bad_v, pt, cl,
                                      k_scale=bad_sk, v_scale=bad_sv,
                                      interpret=_interpret())
        np.testing.assert_allclose(np.asarray(out4), np.asarray(out1),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_array_equal(np.asarray(out4[1]), 0.0)


def test_quantized_model_paged_decode_step_kernel_vs_gather():
    """paged_decode_step on a quantized cache: kernel and gather paths
    must agree on the attention output AND the re-quantized pool + scale
    writes (the RMW write path is shared, so pools must be identical)."""
    from repro.models import attention

    if not _qdtypes():
        pytest.skip("no quantized pool dtypes on this toolchain")
    b, h, hkv, dh, page_size, nb = 2, 4, 2, 16, 4, 4
    q = jax.random.normal(KEY, (b, 1, h, dh)) * 0.5
    kk = jax.random.normal(jax.random.fold_in(KEY, 1), (b, 1, hkv, dh)) * 0.5
    vv = jax.random.normal(jax.random.fold_in(KEY, 2), (b, 1, hkv, dh))
    _, qk, qv, sk, sv, pt = _quantize_case(
        _case(b, h, hkv, dh, page_size, nb, 3 * nb, seed=9), "int8")
    cl = jnp.asarray([6, 13], jnp.int32)
    outs = {}
    for paged_kernel in (False, True):
        if paged_kernel and not supported("int8"):
            pytest.skip("no Pallas toolchain for int8 pools")
        cache = {"pk": qk, "pv": qv, "pt": pt, "ks": sk, "vs": sv}
        out, new = attention.paged_decode_step(
            q, kk, vv, cache, cl, window=None, softcap=None,
            paged_kernel=paged_kernel)
        outs[paged_kernel] = (out, new["pk"], new["pv"], new["ks"],
                              new["vs"])
    np.testing.assert_allclose(np.asarray(outs[False][0]),
                               np.asarray(outs[True][0]),
                               rtol=2e-4, atol=2e-4)
    for a, b_ in zip(outs[False][1:], outs[True][1:]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))


def test_quantized_engine_token_parity_kernel_vs_gather():
    """int8 pools end to end: pool-direct and gather engines run the
    same quantization (identical pool writes), so greedy tokens must
    match exactly — and no pages may leak."""
    from repro.configs import get_config, reduced
    from repro.models import model_defs
    from repro.models import module as m
    from repro.serve.engine import Engine

    if "int8" not in _qdtypes():
        pytest.skip("int8 pools unsupported on this toolchain")
    cfg = reduced(get_config("internlm2-1.8b"))
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    kw = dict(slots=3, max_len=64, sync_interval=8, prefix_sharing=False,
              kv_dtype="int8")
    gather = Engine(cfg, params, paged_kernel=False, **kw)
    out_gather = _run_engine(gather)
    assert gather.leaked_pages() == 0
    if supported("int8"):
        paged = Engine(cfg, params, paged_kernel=True, **kw)
        out_paged = _run_engine(paged)
        assert paged.leaked_pages() == 0
        assert out_paged == out_gather
