"""MoE dispatch invariants + sync/async schedule equivalence (single-device
numerics; the sharded version is exercised in test_distribution.py)."""

import dataclasses

import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, do not error

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.configs import get_config, reduced
from repro.models import moe
from repro.models import module as m

KEY = jax.random.PRNGKey(0)


def _cfg(experts=4, top_k=2, cap=8.0):
    cfg = reduced(get_config("dbrx-132b"), experts=experts)
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, top_k=top_k,
                                     capacity_factor=cap))


def test_dispatch_positions_unique_and_capped():
    e, cap = 4, 3
    top_e = jnp.asarray([[0, 1], [0, 2], [0, 3], [0, 1], [2, 3]])
    slot, keep = moe._dispatch_indices(top_e, e, cap)
    slots = np.asarray(slot)[np.asarray(keep)]
    assert len(set(slots.tolist())) == len(slots)  # no collisions among kept
    # expert 0 requested 4 times, cap 3 -> exactly one drop
    assert int(keep.sum()) == top_e.size - 1


@given(st.integers(2, 8), st.integers(1, 3), st.integers(24, 64))
@settings(max_examples=25, deadline=None)
def test_dispatch_property(experts, top_k, g):
    top_k = min(top_k, experts)
    key = jax.random.fold_in(KEY, experts * 100 + top_k * 10 + g)
    top_e = jax.random.randint(key, (g, top_k), 0, experts)
    cap = max(1, int(g * top_k / experts * 1.25))
    slot, keep = moe._dispatch_indices(top_e, experts, cap)
    slot_np, keep_np, e_np = (np.asarray(slot), np.asarray(keep),
                              np.asarray(top_e))
    # kept slots land in their expert's range and are unique
    kept = slot_np[keep_np]
    assert len(set(kept.tolist())) == len(kept)
    assert ((kept // cap) == e_np[keep_np]).all()
    # per-expert kept count never exceeds cap
    for ei in range(experts):
        assert (keep_np & (e_np == ei)).sum() <= cap


def test_moe_matches_per_token_oracle():
    """With generous capacity (no drops), scatter-dispatch MoE must equal a
    naive per-token loop over selected experts."""
    cfg = _cfg(experts=4, top_k=2, cap=8.0)
    defs = moe.moe_defs(cfg)
    params = m.init_params(defs, KEY, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 8, cfg.d_model))
    y, aux = moe.apply(params, x, cfg)
    assert float(aux["dropped_fraction"]) == 0.0

    x2d = x.reshape(-1, cfg.d_model)
    top_p, top_e, _ = moe.route(params, x2d, cfg.moe)
    want = np.zeros_like(np.asarray(x2d))
    for t in range(x2d.shape[0]):
        for j in range(cfg.moe.top_k):
            e = int(top_e[t, j])
            h = jax.nn.silu(x2d[t] @ params["w_gate"][e]) * \
                (x2d[t] @ params["w_up"][e])
            want[t] += float(top_p[t, j]) * np.asarray(h @ params["w_down"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)), want,
                               rtol=2e-4, atol=2e-4)


def test_sync_schedule_equals_async_dispatch():
    """Paper §4: synchronous and asynchronous schedules compute the same
    function — only the parallelism mapping differs."""
    cfg = _cfg(experts=4, top_k=2, cap=8.0)
    params = m.init_params(moe.moe_defs(cfg), KEY, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 16, cfg.d_model))
    y_async, _ = moe.apply(params, x, cfg)
    y_sync, _ = moe.apply_sync_schedule(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_async), np.asarray(y_sync),
                               rtol=2e-4, atol=2e-4)


def test_load_balance_aux_range():
    cfg = _cfg()
    params = m.init_params(moe.moe_defs(cfg), KEY, jnp.float32)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    _, aux = moe.apply(params, x, cfg)
    assert float(aux["load_balance_loss"]) >= 1.0 - 1e-3  # >=1 by Cauchy-Schwarz
    assert 0.0 <= float(aux["dropped_fraction"]) <= 1.0
