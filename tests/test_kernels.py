"""Per-kernel shape/dtype sweeps, assert_allclose against the pure-jnp
oracles (interpret mode executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.fused_matmul import fused_matmul, matmul1
from repro.kernels.mamba2_scan import mamba2_scan, mamba2_scan_ref
from repro.kernels.moe_gmm import moe_gmm, moe_gmm_ref
from repro.kernels.rwkv6_wkv import rwkv6_wkv, rwkv6_wkv_ref

KEY = jax.random.PRNGKey(7)


def _pallas_capable() -> bool:
    """Probe, don't version-sniff: run the smallest real kernel through the
    Pallas toolchain (interpret mode on CPU — the kernel bodies execute on
    the host; compiled mosaic elsewhere).  Any API drift or missing
    backend support surfaces here as a module-level skip instead of a
    wall of red."""
    try:
        x = jnp.zeros((128, 128), jnp.float32)
        out = fused_matmul(x, x, None, block_m=128, block_n=128,
                           block_k=128,
                           interpret=jax.default_backend() == "cpu")
        return out.shape == (128, 128)
    except Exception:
        return False


pytestmark = pytest.mark.skipif(
    not _pallas_capable(),
    reason="no Pallas-capable backend/toolchain (interpret-mode probe "
           "failed); kernel correctness is covered on TPU CI")


def _tol(dtype):
    # f32 tolerance allows k-block accumulation-order differences
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- fused mm
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 128),
                                   (512, 256, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("scaled", [False, True])
def test_fused_matmul(m, k, n, dtype, scaled):
    x = jax.random.normal(KEY, (m, k), dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), dtype)
    sc = (jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 2), (m, 1)))
          .astype(jnp.float32) if scaled else None)
    got = fused_matmul(x, w, sc, block_m=128, block_n=128, block_k=128,
                       interpret=True)
    want = matmul1(x, w, sc)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ------------------------------------------------------------------- flash
@pytest.mark.parametrize("sq,skv,h,hkv,dh", [(128, 128, 4, 4, 64),
                                             (256, 256, 4, 2, 64),
                                             (128, 128, 8, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["causal", "window", "full", "softcap"])
def test_flash_attention(sq, skv, h, hkv, dh, dtype, mode):
    kw = {"causal": {"causal": True},
          "window": {"causal": True, "window": 48},
          "full": {"causal": False},
          "softcap": {"causal": True, "softcap": 20.0}}[mode]
    q = jax.random.normal(KEY, (2, h, sq, dh), dtype) * 0.5
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (2, hkv, skv, dh),
                          dtype) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (2, hkv, skv, dh),
                          dtype)
    got = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True,
                          **kw)
    want = flash_attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ------------------------------------------------------------------ mamba2
@pytest.mark.parametrize("s,p,n,chunk", [(64, 32, 16, 16), (128, 64, 32, 32),
                                         (96, 64, 64, 32)])
def test_mamba2_scan(s, p, n, chunk):
    bh = 3
    x = jax.random.normal(KEY, (bh, s, p))
    dt = jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 5), (bh, s))) * 0.4 + 0.01
    b = jax.random.normal(jax.random.fold_in(KEY, 6), (bh, s, n)) * 0.5
    c = jax.random.normal(jax.random.fold_in(KEY, 7), (bh, s, n)) * 0.5
    a = -jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 8), (bh,))) - 0.05
    y1, h1 = mamba2_scan(x, dt, b, c, a, chunk=chunk, interpret=True)
    y2, h2 = mamba2_scan_ref(x, dt, b, c, a)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- rwkv6
@pytest.mark.parametrize("s,k,chunk", [(64, 32, 16), (128, 64, 16),
                                       (48, 64, 8)])
def test_rwkv6_wkv(s, k, chunk):
    bh = 3
    r = jax.random.normal(KEY, (bh, s, k)) * 0.5
    kk = jax.random.normal(jax.random.fold_in(KEY, 9), (bh, s, k)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 10), (bh, s, k))
    lw = jnp.clip(-jnp.abs(
        jax.random.normal(jax.random.fold_in(KEY, 11), (bh, s, k))) * 2,
        -5.0, 0.0)
    u = jax.random.normal(jax.random.fold_in(KEY, 12), (bh, k)) * 0.3
    y1, h1 = rwkv6_wkv(r, kk, v, lw, u, chunk=chunk, interpret=True)
    y2, h2 = rwkv6_wkv_ref(r, kk, v, lw, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------- moe gmm
@pytest.mark.parametrize("e,c,d,f", [(4, 64, 128, 128), (8, 32, 64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm(e, c, d, f, dtype):
    x = jax.random.normal(KEY, (e, c, d), dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 13), (e, d, f), dtype)
    counts = jnp.asarray([c, c // 2, 0, 1] * (e // 4), jnp.int32)
    got = moe_gmm(x, w, counts, block_m=32, block_n=64, block_k=64,
                  interpret=True)
    want = moe_gmm_ref(x, w, counts)
    valid = (np.arange(c)[None, :, None]
             < np.asarray(counts)[:, None, None])
    np.testing.assert_allclose(
        np.asarray(got, np.float32) * valid,
        np.asarray(want, np.float32) * valid, **_tol(dtype))


# --------------------------------------------------- model-vs-oracle (XLA)
def test_model_wkv_chunked_matches_naive():
    from repro.models.rwkv6 import wkv_chunked
    bh, s, h, k = 2, 64, 2, 32
    r = jax.random.normal(KEY, (bh, s, h, k)) * 0.5
    kk = jax.random.normal(jax.random.fold_in(KEY, 14), (bh, s, h, k)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 15), (bh, s, h, k))
    lw = jnp.clip(-jnp.abs(
        jax.random.normal(jax.random.fold_in(KEY, 16), (bh, s, h, k))),
        -5.0, 0.0)
    u = jax.random.normal(jax.random.fold_in(KEY, 17), (h, k)) * 0.3
    y1, h1 = wkv_chunked(r, kk, v, lw, u)
    # oracle via the kernel ref on flattened heads
    rf = jnp.swapaxes(r, 1, 2).reshape(bh * h, s, k)
    kf = jnp.swapaxes(kk, 1, 2).reshape(bh * h, s, k)
    vf = jnp.swapaxes(v, 1, 2).reshape(bh * h, s, k)
    lf = jnp.swapaxes(lw, 1, 2).reshape(bh * h, s, k)
    uf = jnp.broadcast_to(u[None], (bh, h, k)).reshape(bh * h, k)
    y2, h2 = rwkv6_wkv_ref(rf, kf, vf, lf, uf)
    y2 = jnp.swapaxes(y2.reshape(bh, h, s, k), 1, 2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1),
                               np.asarray(h2.reshape(bh, h, k, k)),
                               rtol=2e-4, atol=2e-4)


def test_model_ssd_chunked_matches_naive():
    from repro.models.mamba2 import ssd_chunked
    bsz, s, h, p, n = 2, 64, 3, 16, 8
    x = jax.random.normal(KEY, (bsz, s, h, p))
    dt = jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 18),
                                   (bsz, s, h))) * 0.4 + 0.01
    a_log = jnp.log(jnp.abs(
        jax.random.normal(jax.random.fold_in(KEY, 19), (h,))) + 0.5)
    b = jax.random.normal(jax.random.fold_in(KEY, 20), (bsz, s, n)) * 0.5
    c = jax.random.normal(jax.random.fold_in(KEY, 21), (bsz, s, n)) * 0.5
    y1, h1 = ssd_chunked(x, dt, a_log, b, c, chunk=16)
    # oracle: kernel ref per (b,h)
    a = -jnp.exp(a_log)
    xf = jnp.swapaxes(x, 1, 2).reshape(bsz * h, s, p)
    dtf = jnp.swapaxes(dt, 1, 2).reshape(bsz * h, s)
    bf = jnp.broadcast_to(b[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)
    cf = jnp.broadcast_to(c[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)
    af = jnp.broadcast_to(a[None], (bsz, h)).reshape(bsz * h)
    y2, h2 = mamba2_scan_ref(xf, dtf, bf, cf, af)
    y2 = jnp.swapaxes(y2.reshape(bsz, h, s, p), 1, 2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1),
                               np.asarray(h2.reshape(bsz, h, n, p)),
                               rtol=1e-4, atol=1e-4)
