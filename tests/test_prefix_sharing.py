"""Refcounted copy-on-write page pool + radix prefix sharing: match
granularities (full page / partial page / full prompt), CoW triggers,
refcount-guarded eviction, re-admission of evicted prefixes, and token
parity against the dense ReferenceEngine under aggressive sharing."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import model_defs
from repro.models import module as m
from repro.serve.cache import CacheSpec
from repro.serve.engine import Engine, Request
from repro.serve.reference import ReferenceEngine
from repro.serve.scheduler import PagePool, RadixIndex, Scheduler


def _model(arch="internlm2-1.8b", **kw):
    cfg = reduced(get_config(arch), **kw)
    params = m.init_params(model_defs(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    return cfg, params


PREFIX = [(3 * j) % 200 + 1 for j in range(16)]   # 2 full pages at P=8


# ---------------------------------------------------------------------------
# Capability gate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,capable", [
    ("internlm2-1.8b", True),    # pure full attention
    ("gemma2-2b", False),        # sliding windows ring-wrap into prefixes
    ("rwkv6-7b", False),         # recurrent state is not paged
    ("zamba2-7b", False),        # mamba2 backbone
])
def test_sharing_capability_gate(arch, capable):
    cfg, _ = _model(arch)
    spec = CacheSpec.from_config(cfg, slots=2, max_len=64, page_size=8)
    assert spec.prefix_sharing_capable == capable
    sched = Scheduler(spec)   # sharing on by default, self-gating
    assert (sched.radix is not None) == capable


# ---------------------------------------------------------------------------
# Radix index unit behaviour
# ---------------------------------------------------------------------------

def test_radix_match_insert_and_partial():
    pool = PagePool(8)
    idx = RadixIndex(page_size=4)
    prompt = [1, 2, 3, 4, 5, 6, 7, 8, 9]        # 2 full pages + 1 tail tok
    pages = pool.alloc(3)
    import numpy as np
    idx.insert(prompt, np.asarray(pages), pool)
    assert idx.node_count == 2                   # tail page never indexed
    assert pool.refcount(pages[0]) == 2          # slot + tree
    assert pool.refcount(pages[2]) == 1          # tail: slot only
    # exact full-page walk
    got = idx.match([1, 2, 3, 4, 5, 6, 7, 8, 11])
    assert got == [(0, pages[0], 4), (1, pages[1], 4)]
    # partial-page match: 2 of 4 tokens of page 1 agree
    got = idx.match([1, 2, 3, 4, 5, 6, 99, 99])
    assert got == [(0, pages[0], 4), (1, pages[1], 2)]
    # first-page divergence: no match at all
    assert idx.match([9, 9, 9, 9, 1]) == []


def test_radix_eviction_denied_until_refcount_drops():
    """A shared node (some slot still references its page) must survive
    eviction pressure; it becomes evictable only after every borrower
    releases."""
    import numpy as np
    pool = PagePool(4)
    idx = RadixIndex(page_size=4)
    pages = pool.alloc(2)
    idx.insert([1, 2, 3, 4, 5, 6, 7, 8], np.asarray(pages), pool)
    pool.free(pages)                       # originating slot released
    pool.retain(pages[1])                  # a borrower attaches the leaf
    assert idx.evict_one(pool) is None     # leaf rc=2: denied
    assert idx.node_count == 2
    pool.release(pages[1])                 # borrower finishes
    assert idx.evict_one(pool) == pages[1]      # LRU leaf goes first
    assert idx.evict_one(pool) == pages[0]      # parent became a leaf
    assert idx.evict_one(pool) is None
    assert pool.free_pages == 4 and idx.node_count == 0


# ---------------------------------------------------------------------------
# End-to-end CoW edge cases (token parity is the oracle throughout)
# ---------------------------------------------------------------------------

def _load(eng, reqs):
    for rid, prompt, mn in reqs:
        eng.submit(Request(rid=rid, prompt=list(prompt), max_new_tokens=mn))
    return {r.rid: r.out_tokens for r in eng.run()}


def _parity(waves, slots=2, **eng_kw):
    """Run request *waves* (each wave completes before the next submits)
    against the dense reference.  Waves matter under fused chunked
    prefill — the default — where a prompt's pages enter the radix index
    only at prefill COMPLETION (they are not written before that), so
    same-boundary admissions never share; a later wave hits the index
    the earlier wave seeded."""
    cfg, params = _model()
    eng = Engine(cfg, params, slots=slots, max_len=64, **eng_kw)
    ref = ReferenceEngine(cfg, params, slots=slots, max_len=64)
    got, want = {}, {}
    for wave in waves:
        got.update(_load(eng, wave))
        want.update(_load(ref, wave))
    assert got == want, (got, want)
    return eng


def test_full_page_prefix_match_skips_prefill():
    """Clean page-aligned prefix reuse: shared pages attach with a
    refcount bump, prefill runs only on the suffix, outputs identical."""
    eng = _parity([[(0, PREFIX + [7, 7], 6)], [(1, PREFIX + [9, 9, 9], 6)]])
    ps = eng.prefix_stats()
    assert ps["prefix_hits"] == 1
    assert ps["prefill_tokens_skipped"] == 16    # both full prefix pages
    assert ps["shared_page_attaches"] == 2
    assert ps["cow_copies"] == 0                 # first write block is fresh
    # fused chunked prefill: the hit still never compiles a prefill
    # executable — the suffix streams through the one fused chunk step
    assert eng.prefill_compiles == 0
    assert eng.suffix_prefill_compiles == 0


def test_partial_page_prefix_match_triggers_cow():
    """The second prompt diverges mid-page: the partially-matched page is
    attached via a private CoW copy; its valid prefix tokens are reused,
    the divergent tail is re-prefilled into the copy."""
    eng = _parity([[(0, PREFIX, 6)], [(1, PREFIX[:12] + [9, 9, 9], 6)]])
    ps = eng.prefix_stats()
    assert ps["prefix_hits"] == 1
    assert ps["cow_copies"] == 1
    assert ps["prefill_tokens_skipped"] == 12    # page0 + half of page1
    assert ps["shared_page_attaches"] == 1       # only page0 attaches shared


def test_write_into_shared_final_page_goes_cow():
    """An identical fully-matched prompt must still re-prefill its last
    token (first-token logits); that write lands in the final shared page,
    which therefore goes copy-on-write — and the original request's pages
    are untouched (its re-run produces the same tokens)."""
    # wave 2 admits both duplicates at ONE boundary: both hit the page
    # wave 1 indexed at completion
    eng = _parity([[(0, PREFIX, 8)], [(1, PREFIX, 8), (2, PREFIX, 8)]],
                  slots=3)
    ps = eng.prefix_stats()
    assert ps["prefix_hits"] == 2
    assert ps["cow_copies"] == 2                 # one per duplicate prompt
    assert ps["prefill_tokens_skipped"] == 2 * 15


def test_eviction_of_shared_prefix_denied_then_allowed_end_to_end():
    """While a slot still references the tree-held prefix pages, an
    unrelated request that needs the whole pool gets backpressure (shared
    nodes are not evictable); once the slot releases, LRU eviction frees
    the prefix and the big request admits.  Preemption is disabled to
    keep the waiting-for-release scenario — with it on, the engine would
    instead evict rid 0 under pool pressure and resume it later
    (tests/test_fault_tolerance.py covers that path)."""
    cfg, params = _model()
    eng = Engine(cfg, params, slots=2, max_len=64, num_pages=8,
                 preemption=False)
    # rid 0 runs long; its 2 prefix pages are tree-indexed AND slot-held
    eng.submit(Request(rid=0, prompt=list(PREFIX + [5]), max_new_tokens=24))
    # rid 1 needs all 8 pages -> must wait for rid 0 AND evict the tree
    eng.submit(Request(rid=1, prompt=[99] * 40, max_new_tokens=24))
    eng.step()
    assert [r.rid for r in eng.queue] == [1]     # denied while rc > 1
    assert eng.prefix_stats()["radix_evictions"] == 0
    done = {r.rid: r for r in eng.run(max_steps=10_000)}
    assert len(done[0].out_tokens) == 24 and len(done[1].out_tokens) == 24
    ps = eng.prefix_stats()
    assert ps["radix_evictions"] >= 2            # both prefix pages fell
    assert ps["radix_pages"] == 5                # rid 1's 5 prompt pages


def test_readmission_of_evicted_prefix_rebuilds_index():
    """After its pages are evicted, the same prompt admits as a miss,
    re-prefills fully, and re-seeds the radix index."""
    cfg, params = _model()
    eng = Engine(cfg, params, slots=2, max_len=64, num_pages=8)
    _load(eng, [(0, PREFIX + [5], 4)])
    _load(eng, [(1, [99] * 40, 24)])             # 8-page need: evicts all
    hits_before = eng.prefix_stats()["prefix_hits"]
    _load(eng, [(2, PREFIX + [5], 4)])           # miss: full prefill
    ps = eng.prefix_stats()
    assert ps["prefix_hits"] == hits_before
    _load(eng, [(3, PREFIX + [6], 4)])           # hit again: re-indexed
    assert eng.prefix_stats()["prefix_hits"] == hits_before + 1


def test_reference_parity_under_aggressive_sharing():
    """Nested / interleaved shared prefixes across more requests than
    slots: token-for-token parity with the dense reference engine, with a
    nonzero hit rate and pages measurably saved vs exclusive ownership."""
    reqs = []
    for i in range(9):
        cut = [16, 12, 8][i % 3]
        tail = [(11 * i + j) % 150 + 1 for j in range(1 + i % 3)]
        reqs.append((i, PREFIX[:cut] + tail, 4 + i % 3))
    # first request alone seeds the index; the crowd then shares it
    eng = _parity([reqs[:1], reqs[1:]], slots=3)
    ps = eng.prefix_stats()
    assert ps["prefix_hit_rate"] > 0.5
    assert ps["prefill_tokens_skipped"] > 40
    cfg, params = _model()
    excl = Engine(cfg, params, slots=3, max_len=64, prefix_sharing=False)
    _load(excl, reqs[:1])
    _load(excl, reqs[1:])
    assert (eng.scheduler.peak_pages_in_use
            < excl.scheduler.peak_pages_in_use)


def test_prefix_hit_falls_back_to_miss_when_match_pins_eviction():
    """Degenerate pool: the only evictable pages are the very prefix the
    match wants to attach.  Insisting on the match would livelock (its
    retains pin the refcount-1 radix leaves eviction needs); the planner
    must fall back to a plain miss, evict the prefix, and admit."""
    cfg, params = _model()
    eng = Engine(cfg, params, slots=1, max_len=32)    # 4-page pool
    prompt = [(3 * j) % 200 + 1 for j in range(24)]   # 3 full pages
    out0 = _load(eng, [(0, prompt, 4)])
    assert eng.prefix_stats()["radix_pages"] == 3     # 1 page free
    out1 = _load(eng, [(1, prompt, 4)])               # would pin 3 pages
    assert out1[1] == out0[0]                         # same greedy tokens
    ps = eng.prefix_stats()
    assert ps["radix_evictions"] >= 2                 # admitted as a miss
    assert ps["prefix_hits"] == 0


def test_generation_budget_cannot_wrap_shared_pages():
    """plen + max_new past the full-attention table would ring-wrap
    decode writes back into indexed/shared prefix pages (corrupting
    *other* requests); submit() must reject it up front."""
    cfg, params = _model()
    eng = Engine(cfg, params, slots=1, max_len=32)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(rid=0, prompt=[(3 * j) % 200 + 1
                                          for j in range(24)],
                           max_new_tokens=12))        # 36 > 32
    assert not eng.queue


def test_dead_tail_decode_cannot_wrap_into_shared_pages():
    """A slot that exhausts plen + max_new == max_len and finishes
    MID-CHUNK keeps stepping until the drain; its dead writes sit past
    the table and would ring-wrap into block 0 — a shared prefix page.
    The decode chunk's active-mask must route them to the trash page: the
    long-running neighbour sharing that prefix must match its solo run."""
    cfg, params = _model()
    eng = Engine(cfg, params, slots=2, max_len=64)
    long_req = (0, PREFIX + [3, 4], 30)          # shares blocks 0-1, lives
    full_req = (1, PREFIX + [(11 * j) % 150 + 1 for j in range(33)], 15)
    #           plen 49 + max_new 15 == max_len, done 2 steps before the
    #           chunk boundary: dead positions 63 then 64 -> 64 wraps to
    #           block 0 (a shared prefix page) without the write mask
    got = _load(eng, [long_req, full_req])
    assert len(got[1]) == 15
    cfg2, params2 = _model()
    solo = Engine(cfg2, params2, slots=2, max_len=64)
    want = _load(solo, [long_req])
    assert got[0] == want[0], "shared prefix corrupted by dead-tail write"


def test_disabled_sharing_is_fully_exclusive():
    cfg, params = _model()
    eng = Engine(cfg, params, slots=2, max_len=64, prefix_sharing=False)
    _load(eng, [(0, PREFIX, 4), (1, PREFIX, 4)])
    ps = eng.prefix_stats()
    assert not ps["prefix_sharing"] and ps["prefix_hits"] == 0
    assert eng.suffix_prefill_compiles == 0
